//! Quickstart: simulate the paper's outer-product method on a 2D9P box
//! stencil, verify against the scalar oracle, and compare against the
//! auto-vectorization baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{run_method, verify::speedup, Method, OuterParams};
use stencil_matrix::stencil::StencilSpec;
use stencil_matrix::sim::SimConfig;

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default(); // §5.1 machine: 512-bit vectors, 8×8 tiles
    let spec = StencilSpec::box2d(1); // the 2D9P stencil of Eq. (1)
    let n = 64; // the paper's in-cache problem size

    println!(
        "machine: {} f64 lanes, {} vector / {} matrix registers",
        cfg.vlen, cfg.n_vregs, cfg.n_mregs
    );
    println!("stencil: {spec}, domain {n}²\n");

    // Baseline: what a vectorizing compiler emits (gather mode).
    let base = run_method(&cfg, spec, n, Method::AutoVec, true)?;
    println!(
        "autovec : {:>8} cycles  {:.3} cyc/pt  verified={}",
        base.stats.cycles,
        base.cycles_per_point(),
        base.verified()
    );

    // The paper's method: scatter-mode outer products, parallel cover,
    // unroll uj=8, outer-product scheduling.
    let params = OuterParams::paper_best(spec);
    let ours = run_method(&cfg, spec, n, Method::Outer(params), true)?;
    println!(
        "ours    : {:>8} cycles  {:.3} cyc/pt  verified={}  ({} outer products)",
        ours.stats.cycles,
        ours.cycles_per_point(),
        ours.verified(),
        ours.stats.fmopa()
    );

    println!("\nspeedup over auto-vectorization: {:.2}x", speedup(&base, &ours));
    anyhow::ensure!(base.verified() && ours.verified());
    Ok(())
}
