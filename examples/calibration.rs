// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]
use stencil_matrix::codegen::*;
use stencil_matrix::codegen::common::OuterParams;
use stencil_matrix::stencil::*;
use stencil_matrix::sim::*;

fn main() {
    let cfg = SimConfig::default();
    let cases = [
        (StencilSpec::box2d(1), 64usize, 2.92),
        (StencilSpec::box2d(2), 64, 4.58),
        (StencilSpec::box2d(3), 64, 4.71),
        (StencilSpec::star2d(1), 64, 1.59),
        (StencilSpec::star2d(2), 64, 1.48),
        (StencilSpec::box2d(1), 512, 1.17),
        (StencilSpec::box2d(2), 512, 2.17),
        (StencilSpec::star2d(2), 512, 1.19),
        (StencilSpec::box3d(1), 16, 3.85),
        (StencilSpec::box3d(2), 16, 3.44),
        (StencilSpec::star3d(1), 16, 1.64),
        (StencilSpec::star3d(2), 16, 3.37),
    ];
    for (spec, n, paper) in cases {
        let base = run_method(&cfg, spec, n, Method::AutoVec, true).unwrap();
        let p = OuterParams::paper_best(spec);
        let ours = run_method(&cfg, spec, n, Method::Outer(p), true).unwrap();
        let d = run_method(&cfg, spec, n, Method::Dlt, true).unwrap();
        let t = run_method(&cfg, spec, n, Method::Tv, true).unwrap();
        assert!(base.verified() && ours.verified() && d.verified() && t.verified());
        println!("{:16} N={:4}  ours {:.2}x (paper {:.2})  dlt {:.2}x  tv {:.2}x  [cpp base {:.2} ours {:.2}]",
            spec.name(), n,
            verify::speedup(&base, &ours), paper,
            verify::speedup(&base, &d),
            verify::speedup(&base, &t),
            base.cycles_per_point(), ours.cycles_per_point());
    }
}
