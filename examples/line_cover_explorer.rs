//! Explore coefficient-line covers (§3.5): for each stencil shape, print
//! the applicable covers, their outer-product counts, and the minimal
//! axis-parallel cover found via Hopcroft–Karp + König — including the
//! bipartite-graph view of the coefficient matrix.
//!
//! ```sh
//! cargo run --release --example line_cover_explorer
//! ```

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::scatter::cover::Bipartite;
use stencil_matrix::scatter::{build_cover, CoverOption};
use stencil_matrix::stencil::{CoeffTensor, StencilSpec};

fn main() -> anyhow::Result<()> {
    let specs = [
        StencilSpec::box2d(1),
        StencilSpec::star2d(1),
        StencilSpec::star2d(2),
        StencilSpec::diag2d(1),
        StencilSpec::star3d(1),
    ];
    for spec in specs {
        let coeffs = CoeffTensor::paper_default(spec);
        println!("=== {spec} ({} non-zero weights) ===", spec.nonzero_points());
        if spec.dims == 2 {
            let g = Bipartite::from_coeffs(&coeffs);
            let (mu, _) = g.hopcroft_karp();
            let matching = mu.iter().filter(|&&v| v != usize::MAX).count();
            let (rows, cols) = g.min_vertex_cover();
            println!(
                "  bipartite view: max matching {matching} ⇒ min vertex cover {} \
                 (rows {rows:?}, cols {cols:?}) — König",
                rows.len() + cols.len()
            );
        }
        for option in CoverOption::applicable(spec) {
            let cover = build_cover(&coeffs, option)?;
            println!(
                "  {:12} {} line(s), {:3} outer products per n=8 block",
                format!("{option:?}"),
                cover.len(),
                cover.outer_products(8)
            );
            for line in &cover.lines {
                println!(
                    "      dir {:?} base {:?} ({} nz)",
                    line.dir,
                    line.base,
                    line.nonzeros()
                );
            }
        }
        println!();
    }
    Ok(())
}
