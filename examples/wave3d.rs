//! 3D pressure-pulse smoothing with the 3D7P star stencil: runs the three
//! coefficient-line cover options of Table 2 (parallel / orthogonal /
//! hybrid) on the simulator, verifies each against the oracle, and prints
//! the option trade-off the paper's §4.1 describes.
//!
//! ```sh
//! cargo run --release --example wave3d
//! ```

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{run_method, Method, OuterParams};
use stencil_matrix::scatter::{analysis, CoverOption};
use stencil_matrix::stencil::StencilSpec;
use stencil_matrix::sim::SimConfig;

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default();
    let n = 16usize;
    println!("3D star stencils on a {n}³ grid — cover options (Table 2):\n");
    for order in [1usize, 2, 3] {
        let spec = StencilSpec::star3d(order);
        println!("{spec}:");
        for (option, ui, uk) in [
            (CoverOption::Parallel, 4, 1),
            (CoverOption::Orthogonal, 4, 1),
            (CoverOption::Hybrid, 1, 4),
        ] {
            let a = analysis::analyze(spec, option, cfg.vlen)?;
            let params = OuterParams { option, ui, uk, scheduled: true };
            let res = run_method(&cfg, spec, n, Method::Outer(params), true)?;
            anyhow::ensure!(res.verified(), "{spec} {option:?} failed verification");
            println!(
                "  {:10}  theory {:5.2} outer/outvec | measured {:>7} fmopa, {:.3} cyc/pt",
                format!("{option:?}"),
                a.outer_per_outvec,
                res.stats.fmopa(),
                res.cycles_per_point()
            );
        }
        println!();
    }
    println!("(parallel wins at low order; orthogonal/hybrid flatten as order grows — Fig. 3c/3d)");
    Ok(())
}
