//! Heat diffusion (the canonical 2D5P star stencil) driven three ways:
//!
//! 1. scalar reference evolution (the oracle);
//! 2. the paper's outer-product method on the SME-like simulator;
//! 3. the AOT-compiled JAX/Pallas artifact executed over PJRT from Rust.
//!
//! All three must agree on the final temperature field.
//!
//! ```sh
//! make artifacts && cargo run --release --example heat_diffusion
//! ```

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::common::{CoeffTable, Layout};
use stencil_matrix::codegen::outer;
use stencil_matrix::codegen::OuterParams;
use stencil_matrix::coordinator::EvolutionService;
use stencil_matrix::scatter::build_cover;
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use stencil_matrix::sim::{Machine, SimConfig};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let spec = StencilSpec::star2d(1);
    let n = 64usize;
    let steps = 8usize;
    let coeffs = CoeffTensor::paper_default(spec);

    // A hot square in the middle of a cold plate.
    let ext = n + 2 * spec.order;
    let grid = DenseGrid::from_fn(&[ext, ext], |idx| {
        let hot = idx.iter().all(|&i| i > ext / 3 && i < 2 * ext / 3);
        if hot {
            100.0
        } else {
            0.0
        }
    });

    // 1. oracle
    let want = reference::evolve(&coeffs, &grid, steps);
    let centre = want.at(&[ext / 2, ext / 2]);
    println!("oracle      : centre temperature after {steps} steps = {centre:.4}");

    // 2. simulator (the paper's method, one generated program per step)
    let cfg = SimConfig::default();
    let mut machine = Machine::new(cfg.clone());
    let mut layout = Layout::alloc(&mut machine, spec, &grid);
    let params = OuterParams::paper_best(spec);
    let cover = build_cover(&coeffs, params.option)?;
    let table = CoeffTable::install_full(&mut machine, &coeffs, &cover);
    machine.finish();
    for _ in 0..steps {
        outer::generate(&cfg, &layout, &cover, &table, params, &mut machine)?;
        layout.swap(); // B becomes next step's A
    }
    let stats = machine.finish();
    layout.swap(); // point read_b back at the final array
    let sim_result = layout.read_b(&machine);
    let err_sim = sim_result.max_abs_diff_interior(&want, spec.order);
    println!(
        "simulator   : centre = {:.4}, max err {err_sim:.2e}, {} cycles ({:.3} cyc/pt/step)",
        sim_result.at(&[ext / 2, ext / 2]),
        stats.cycles,
        stats.cycles as f64 / (n * n * steps) as f64
    );

    // 3. PJRT artifact (8-step scan compiled from JAX/Pallas)
    let mut svc = EvolutionService::new(Path::new("artifacts"))?;
    let engine = svc.engine("evolve_2d5p_n64_t8")?;
    let (pjrt_result, report) = engine.evolve(&grid, 1, false)?;
    let err_pjrt = pjrt_result.max_abs_diff_interior(&want, spec.order);
    println!(
        "pjrt        : centre = {:.4}, max err {err_pjrt:.2e}, {:.2} Mpoints/s",
        pjrt_result.at(&[ext / 2, ext / 2]),
        report.points_per_sec / 1e6
    );

    anyhow::ensure!(err_sim < 1e-9, "simulator diverged from oracle");
    anyhow::ensure!(err_pjrt < 1e-9, "PJRT artifact diverged from oracle");
    println!("\nall three paths agree");
    Ok(())
}
