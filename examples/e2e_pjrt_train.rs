//! End-to-end driver (DESIGN.md §Per-experiment index, EXPERIMENTS.md
//! §E2E): proves all layers compose on a real workload.
//!
//! Pipeline: JAX/Pallas (L1 kernel) → lax.scan evolution (L2) → AOT HLO
//! text (`make artifacts`) → Rust PJRT runtime (L3) → batched evolution
//! service. Python is *not* running during this program.
//!
//! Workload: 256×256 heat diffusion (2D5P), 100 executions of the 4-step
//! scan artifact = 400 time steps (26 M point-updates). Reports
//! throughput, a convergence curve (the "loss curve" of a PDE solver:
//! interior energy settling toward the frozen-boundary equilibrium), and
//! verifies the final field against the scalar oracle.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt_train
//! ```

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::coordinator::EvolutionService;
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid};
use std::path::Path;
use std::time::Instant;

fn energy(g: &DenseGrid, halo: usize) -> f64 {
    // mean squared field over the interior
    let mut sum = 0.0;
    let mut count = 0usize;
    let mut idx = vec![0usize; g.shape.len()];
    for lin in 0..g.len() {
        g.unravel(lin, &mut idx);
        if idx.iter().zip(&g.shape).all(|(&i, &n)| i >= halo && i + halo < n) {
            sum += g.data[lin] * g.data[lin];
            count += 1;
        }
    }
    sum / count as f64
}

fn main() -> anyhow::Result<()> {
    let artifact = "evolve_2d5p_n256_t4";
    let executions = 100usize;

    let mut svc = EvolutionService::new(Path::new("artifacts"))?;
    println!("platform : {}", svc.platform());
    println!("artifacts: {:?}", svc.artifacts());
    let engine = svc.engine(artifact)?;
    let meta = engine.meta().clone();
    println!(
        "artifact : {} — {} N={} ({} steps per execution)\n",
        meta.name, meta.spec, meta.n, meta.steps
    );

    // initial condition: hot blob + noise
    let ext = meta.storage_extent;
    let mut grid = DenseGrid::verification_input(&[ext, ext], 2026);
    for i in ext / 3..2 * ext / 3 {
        for j in ext / 3..2 * ext / 3 {
            *grid.at_mut(&mut [i, j]) += 50.0;
        }
    }

    // evolution with a convergence curve every 10 executions
    let t0 = Instant::now();
    let mut cur = grid.clone();
    let mut curve = Vec::new();
    for chunk in 0..executions / 10 {
        let (next, _) = engine.evolve(&cur, 10, false)?;
        cur = next;
        let e = energy(&cur, meta.spec.order);
        curve.push(e);
        println!(
            "  after {:>4} steps: interior energy {:>12.4}",
            (chunk + 1) * 10 * meta.steps,
            e
        );
    }
    let secs = t0.elapsed().as_secs_f64();
    let steps = executions * meta.steps;
    let updates = (meta.n * meta.n) as f64 * steps as f64;
    println!(
        "\nthroughput: {steps} steps over {}² in {secs:.2}s = {:.2} Mpoint-updates/s",
        meta.n,
        updates / secs / 1e6
    );

    // energy must decay monotonically toward equilibrium (diffusion)
    for w in curve.windows(2) {
        anyhow::ensure!(w[1] <= w[0] * (1.0 + 1e-9), "energy increased: {w:?}");
    }

    // verify the full 400-step evolution against the scalar oracle
    print!("verifying against the scalar oracle ({steps} reference steps)... ");
    let coeffs = CoeffTensor::paper_default(meta.spec);
    let want = reference::evolve(&coeffs, &grid, steps);
    let err = cur.max_abs_diff_interior(&want, meta.spec.order);
    println!("max err {err:.2e}");
    anyhow::ensure!(err < 1e-8, "PJRT evolution diverged from the oracle");
    println!("e2e OK: JAX/Pallas → HLO text → Rust PJRT → verified.");
    Ok(())
}
