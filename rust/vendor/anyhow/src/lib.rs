//! Offline vendored shim of the `anyhow` error crate.
//!
//! The offline crate set this repo builds against has no crates.io access,
//! so this package provides the subset of `anyhow`'s API the repo actually
//! uses — [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!`
//! macros — with source-compatible semantics:
//!
//! - any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`;
//! - `{:#}` (alternate Display) prints the full cause chain, `{}` only the
//!   outermost message;
//! - `Debug` also prints the cause chain, so `unwrap()` failures in tests
//!   stay informative.
//!
//! Swapping this path dependency for the real `anyhow` requires no source
//! changes.

use std::fmt;

/// A type-erased error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with the same default as the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Create an error wrapping a concrete error value as its cause.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Iterate the cause chain (outermost message first is `self`; this
    /// yields the wrapped sources below it).
    fn sources(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next = self
            .source
            .as_ref()
            .map(|b| &**b as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.sources() {
                let s = cause.to_string();
                // the outermost message is the wrapped error's to_string();
                // avoid printing it twice
                if s != self.msg {
                    write!(f, ": {s}")?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> = self
            .sources()
            .map(|c| c.to_string())
            .filter(|s| *s != self.msg)
            .collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds. With no message
/// the error names the failed condition, like the real crate.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("Condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_it(s: &str) -> Result<usize> {
        let n: usize = s.parse()?; // std error converts via `?`
        ensure!(n > 10, "{n} is not > 10");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(parse_it("42").is_ok());
        let e = parse_it("nope").unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_format() {
        let e = parse_it("3").unwrap_err();
        assert_eq!(e.to_string(), "3 is not > 10");
        let f = || -> Result<()> { bail!("boom {}", 7) };
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        let f = |v: usize| -> Result<()> {
            ensure!(v > 2);
            Ok(())
        };
        assert!(f(3).is_ok());
        let e = f(1).unwrap_err().to_string();
        assert!(e.contains("v > 2"), "{e}");
    }

    #[test]
    fn alternate_display_prints_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner cause");
        let e = Error::from(io);
        // outer message equals the wrapped error's Display; no duplication
        assert_eq!(format!("{e:#}"), "inner cause");
        let m = anyhow!("just a message");
        assert_eq!(format!("{m:#}"), "just a message");
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("x = {}, y = {y}", 1, y = 2);
        assert_eq!(e.to_string(), "x = 1, y = 2");
    }
}
