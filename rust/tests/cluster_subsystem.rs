//! Integration tests for the distributed serving fleet: frame-codec
//! round-trips and fuzz over random payloads, decoder rejection of
//! truncated/stalled/wrong-version/oversized frames over real TCP
//! streams, fleet-vs-single-process bitwise equality on both exchange
//! paths (coordinator-mediated and peer-to-peer), the node-loss
//! property: kill a worker mid-evolution and the coordinator re-places
//! its slabs and still produces the oracle's bits, the peer-loss
//! property: kill a worker mid-*peer*-exchange and the coordinator
//! falls back to the mediated path and still produces the oracle's
//! bits, and the cross-version handshake error.
//!
//! Registry state is process-global and `cargo test` runs tests
//! concurrently in one process, so metric assertions here are about
//! deltas, never absolute totals.

use stencil_matrix::kir::Engine;
use stencil_matrix::serve::cluster::{frame, node, proto};
use stencil_matrix::serve::{
    Coordinator, ExchangeMode, KernelMethod, NodeConfig, PlanCache, ShardedEvolver, WorkerPool,
};
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use std::io::{Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic xorshift64* — the same generator the property tests
/// elsewhere in this repo use for reproducible fuzz.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
}

fn twin_evolver(engine: Engine) -> ShardedEvolver {
    let mut cache = PlanCache::new(32);
    cache.set_engine(engine);
    ShardedEvolver::with_parts(Arc::new(WorkerPool::new(2)), Arc::new(cache))
}

#[test]
fn frame_codec_fuzz_roundtrips_random_payloads() {
    let mut rng = Rng(0x5EED_CAFE);
    for _ in 0..200 {
        let kind = (rng.next() % 7 + 1) as u16;
        let len = (rng.next() % 4096) as usize;
        let payload: Vec<u8> = (0..len).map(|_| (rng.next() & 0xFF) as u8).collect();
        let mut buf = Vec::new();
        frame::send_frame(&mut buf, kind, &payload).unwrap();
        assert_eq!(buf.len(), frame::HEADER_LEN + len);
        let mut cur = Cursor::new(buf);
        match frame::recv_frame(&mut cur, Duration::from_secs(1)).unwrap() {
            frame::Recv::Frame(k, p) => {
                assert_eq!(k, kind);
                assert_eq!(p, payload);
            }
            other => panic!("expected a frame, got {other:?}"),
        }
        assert_eq!(
            frame::recv_frame(&mut cur, Duration::from_secs(1)).unwrap(),
            frame::Recv::Eof
        );
    }
}

#[test]
fn frame_codec_fuzz_rejects_random_truncations() {
    let mut rng = Rng(0xBAD_F00D);
    for _ in 0..200 {
        let len = (rng.next() % 512 + 1) as usize;
        let payload: Vec<u8> = (0..len).map(|_| (rng.next() & 0xFF) as u8).collect();
        let mut buf = Vec::new();
        frame::send_frame(&mut buf, 3, &payload).unwrap();
        // cut anywhere strictly inside the frame: always a clean error,
        // never a hang and never a bogus success
        let cut = (rng.next() as usize) % (buf.len() - 1) + 1;
        let mut cur = Cursor::new(buf[..cut].to_vec());
        let err = frame::recv_frame(&mut cur, Duration::from_secs(1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "cut at {cut}: {err}");
    }
}

/// A peer that stalls mid-frame must hit the read deadline, and a peer
/// that writes a partial frame and disconnects must produce a clean
/// truncation error — over a real TCP stream, not a cursor.
#[test]
fn decoder_deadline_and_truncation_over_tcp() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // stall: client sends 5 of 12 header bytes and keeps the socket open
    let client = TcpStream::connect(addr).unwrap();
    let (mut server, _) = listener.accept().unwrap();
    server.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
    let header = frame::encode_header(1, 0).unwrap();
    (&client).write_all(&header[..5]).unwrap();
    let err = frame::recv_frame(&mut server, Duration::from_millis(200))
        .unwrap_err()
        .to_string();
    assert!(err.contains("deadline"), "{err}");
    drop(client);

    // truncation: client sends a partial frame and disconnects
    let client = TcpStream::connect(addr).unwrap();
    let (mut server, _) = listener.accept().unwrap();
    server.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
    let mut buf = Vec::new();
    frame::send_frame(&mut buf, 2, b"payload-that-gets-cut").unwrap();
    (&client).write_all(&buf[..buf.len() - 7]).unwrap();
    drop(client);
    let err = loop {
        match frame::recv_frame(&mut server, Duration::from_secs(2)) {
            Ok(frame::Recv::Idle) => continue, // bytes may still be in flight
            Ok(other) => panic!("expected an error, got {other:?}"),
            Err(e) => break e.to_string(),
        }
    };
    assert!(err.contains("truncated"), "{err}");

    // idle: an open, silent connection is Idle, not an error
    let _client = TcpStream::connect(addr).unwrap();
    let (mut server, _) = listener.accept().unwrap();
    server.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
    assert_eq!(
        frame::recv_frame(&mut server, Duration::from_secs(1)).unwrap(),
        frame::Recv::Idle
    );
}

/// A node receiving a wrong-version or oversized frame drops the
/// connection cleanly instead of blocking or crashing, and keeps
/// serving fresh connections afterwards.
#[test]
fn node_rejects_bad_frames_and_survives() {
    let mut handle = node::spawn_local(NodeConfig::default()).unwrap();

    // wrong protocol version
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let mut h = frame::encode_header(1, 0).unwrap();
    h[4..6].copy_from_slice(&99u16.to_le_bytes());
    stream.write_all(&h).unwrap();
    assert_connection_closes(&mut stream);

    // oversized length field
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let mut h = frame::encode_header(1, 0).unwrap();
    h[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    stream.write_all(&h).unwrap();
    assert_connection_closes(&mut stream);

    // the node is still healthy for a well-formed peer
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    proto::send_msg(&mut stream, &proto::Msg::Ping).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        match proto::recv_msg(&mut stream, Duration::from_secs(10)).unwrap() {
            proto::MsgRecv::Msg(proto::Msg::Pong(_), _) => break,
            proto::MsgRecv::Idle => {
                assert!(std::time::Instant::now() < deadline, "ping timed out")
            }
            other => panic!("expected Pong, got {other:?}"),
        }
    }
    handle.shutdown();
}

fn assert_connection_closes(stream: &mut TcpStream) {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        match frame::recv_frame(stream, Duration::from_secs(10)) {
            Ok(frame::Recv::Eof) | Err(_) => return,
            Ok(frame::Recv::Idle) => {
                assert!(std::time::Instant::now() < deadline, "node never dropped the connection")
            }
            Ok(frame::Recv::Frame(k, _)) => panic!("unexpected frame kind {k}"),
        }
    }
}

/// The tentpole contract: a 2-node fleet evolution is bitwise identical
/// to the single-process sharded evolver and (taps) to the scalar
/// oracle, across fused and unfused chunking.
#[test]
fn two_node_fleet_is_bitwise_identical_to_single_process() {
    let engine = Engine::default();
    let spec = StencilSpec::box2d(1);
    let n = 32;
    let steps = 6;
    let grid = DenseGrid::verification_input(&[n + 2, n + 2], 0xFEED);
    let ev = twin_evolver(engine);

    let mut handles = vec![
        node::spawn_local(NodeConfig { workers: 2, engine, ..NodeConfig::default() }).unwrap(),
        node::spawn_local(NodeConfig { workers: 2, engine, ..NodeConfig::default() }).unwrap(),
    ];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();
    assert_eq!(cluster.nodes_alive(), 2);

    for (method, fuse, shards) in [
        (KernelMethod::Taps, 1, 4),
        (KernelMethod::Taps, 3, 4),
        (KernelMethod::Oracle, 2, 3),
        (KernelMethod::Outer, 3, 4),
    ] {
        let (fleet, report) =
            cluster.evolve_fused(spec, &grid, steps, shards, method, fuse).unwrap();
        let (twin, _, fr) = ev.evolve_fused(spec, &grid, steps, shards, method, fuse).unwrap();
        assert_eq!(
            fleet.data, twin.data,
            "{method} T={fuse}: fleet diverged bitwise from the single-process evolver"
        );
        assert_eq!(report.fuse, fr, "{method} T={fuse}: fusion accounting diverged");
        assert_eq!(report.replacements, 0);
        assert!(report.chunks >= report.shards);
        if matches!(method, KernelMethod::Taps | KernelMethod::Oracle) {
            let coeffs = CoeffTensor::paper_default(spec);
            let want = reference::evolve(&coeffs, &grid, steps);
            assert_eq!(
                fleet.data, want.data,
                "{method} T={fuse}: fleet diverged bitwise from the scalar oracle"
            );
        }
    }

    // steps = 0 is the identity, like the in-process evolver
    let (same, report) =
        cluster.evolve_fused(spec, &grid, 0, 4, KernelMethod::Taps, 2).unwrap();
    assert_eq!(same.data, grid.data);
    assert_eq!(report.chunks, 0);

    let health = cluster.health_json();
    assert_eq!(health.get("status").and_then(|j| j.as_str()), Some("ok"));
    assert_eq!(health.get("nodes_alive").and_then(|j| j.as_f64()), Some(2.0));

    cluster.shutdown_nodes();
    for h in &mut handles {
        h.shutdown();
    }
}

/// The node-loss property: a worker that dies mid-evolution (goes
/// silent after its first chunk) costs nothing but re-placement — the
/// coordinator detects the loss, re-places the orphaned slabs on the
/// survivors, and the final grid is still bitwise equal to the oracle.
#[test]
fn killing_a_node_mid_evolution_replaces_its_slabs_bitwise() {
    let engine = Engine::default();
    let spec = StencilSpec::star2d(1);
    let n = 36;
    let steps = 6;
    let shards = 6; // two slabs per node, so the dying node leaves an orphan
    let grid = DenseGrid::verification_input(&[n + 2, n + 2], 0xDEAD);

    let mut handles = vec![
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
        node::spawn_local(NodeConfig {
            workers: 1,
            engine,
            fail_after: Some(1),
            ..NodeConfig::default()
        })
        .unwrap(),
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
    ];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();
    cluster.set_rpc_timeout(Duration::from_secs(10));
    assert_eq!(cluster.nodes_alive(), 3);

    let (fleet, report) =
        cluster.evolve_fused(spec, &grid, steps, shards, KernelMethod::Taps, 2).unwrap();

    assert!(report.replacements >= 1, "the dying node never forced a re-placement: {report:?}");
    assert!(report.nodes_alive < 3, "the fault-injected node still counts as alive");
    assert_eq!(cluster.nodes_alive(), report.nodes_alive);

    let coeffs = CoeffTensor::paper_default(spec);
    let want = reference::evolve(&coeffs, &grid, steps);
    assert_eq!(
        fleet.data, want.data,
        "evolution with a node lost mid-run diverged bitwise from the oracle"
    );
    let ev = twin_evolver(engine);
    let (twin, _, _) = ev.evolve_fused(spec, &grid, steps, shards, KernelMethod::Taps, 2).unwrap();
    assert_eq!(fleet.data, twin.data);

    // degraded but answering: the health endpoint reflects the loss
    let health = cluster.health_json();
    assert_eq!(health.get("status").and_then(|j| j.as_str()), Some("degraded"));

    cluster.shutdown_nodes();
    for h in &mut handles {
        h.shutdown();
    }
}

/// Losing every node is a clean error, not a hang.
#[test]
fn losing_all_nodes_fails_cleanly() {
    let engine = Engine::default();
    let spec = StencilSpec::box2d(1);
    let grid = DenseGrid::verification_input(&[18, 18], 3);
    let mut handles = vec![node::spawn_local(NodeConfig {
        workers: 1,
        engine,
        fail_after: Some(0),
        ..NodeConfig::default()
    })
    .unwrap()];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();
    cluster.set_rpc_timeout(Duration::from_secs(5));
    let err = cluster
        .evolve_fused(spec, &grid, 2, 2, KernelMethod::Taps, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("all cluster nodes lost"), "{err}");
    for h in &mut handles {
        h.shutdown();
    }
}

/// The PR 10 tentpole contract, property-tested: peer-to-peer exchange
/// (nodes trading `order·T`-deep boundary bands directly, interior
/// computed while bands are in flight) is bitwise identical to the
/// single-process sharded evolver — across random specs, grid sizes,
/// step counts, fuse depths, node counts, and shard counts.
#[test]
fn peer_exchange_is_bitwise_identical_across_random_configs() {
    let engine = Engine::default();
    let ev = twin_evolver(engine);
    let mut rng = Rng(0x0DD5_EED5);
    for case in 0..6 {
        let spec = match rng.next() % 4 {
            0 => StencilSpec::box2d(1),
            1 => StencilSpec::star2d(1),
            2 => StencilSpec::box2d(2),
            _ => StencilSpec::star2d(2),
        };
        let n = 24 + (rng.next() % 16) as usize;
        let steps = 1 + (rng.next() % 7) as usize;
        let fuse = 1 + (rng.next() % 3) as usize;
        let nodes = 1 + (rng.next() % 3) as usize;
        let shards = nodes + (rng.next() as usize) % (nodes + 2);
        let grid = DenseGrid::verification_input(&[n + 2 * spec.order; 2], rng.next());

        let mut handles = Vec::new();
        for _ in 0..nodes {
            handles.push(
                node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() })
                    .unwrap(),
            );
        }
        let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();
        let (fleet, report) = cluster
            .evolve_exchange(
                ExchangeMode::Peer,
                spec,
                &grid,
                steps,
                shards,
                KernelMethod::Taps,
                fuse,
            )
            .unwrap();
        assert_eq!(report.path, ExchangeMode::Peer, "case {case}: wrong path taken");
        assert!(!report.fell_back, "case {case}: peer exchange fell back on a healthy fleet");

        let (twin, _, _) =
            ev.evolve_fused(spec, &grid, steps, shards, KernelMethod::Taps, fuse).unwrap();
        assert_eq!(
            fleet.data, twin.data,
            "case {case} ({spec} n={n} steps={steps} T={fuse} nodes={nodes} shards={shards}): \
             peer exchange diverged bitwise from the single-process evolver"
        );
        let coeffs = CoeffTensor::paper_default(spec);
        let want = reference::evolve(&coeffs, &grid, steps);
        assert_eq!(
            fleet.data, want.data,
            "case {case}: peer exchange diverged bitwise from the scalar oracle"
        );

        cluster.shutdown_nodes();
        for h in &mut handles {
            h.shutdown();
        }
    }
}

/// Peer exchange on a multi-node fleet actually moves bands node-to-node
/// (nonzero band bytes), performs the same number of logical halo
/// exchanges as the in-process fused path, and reports a sane overlap
/// accounting.
#[test]
fn peer_exchange_moves_bands_and_reports_overlap() {
    let engine = Engine::default();
    let spec = StencilSpec::box2d(1);
    let grid = DenseGrid::verification_input(&[34, 34], 0xBAD5);
    let mut handles = vec![
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
    ];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();

    // steps=8, T=2 → 4 rounds → 3 inter-round exchanges; alternating
    // placement puts neighbouring slabs on different nodes, so bands
    // must cross the wire
    let (fleet, report) = cluster
        .evolve_exchange(ExchangeMode::Peer, spec, &grid, 8, 4, KernelMethod::Taps, 2)
        .unwrap();
    assert_eq!(report.path, ExchangeMode::Peer);
    assert!(!report.fell_back);
    assert_eq!(report.fuse.halo_exchanges, 3, "{report:?}");
    assert!(report.band_bytes > 0, "no bands crossed the wire: {report:?}");
    let ratio = report.overlap_ratio();
    assert!((0.0..=1.0).contains(&ratio), "overlap ratio {ratio} out of range");

    let ev = twin_evolver(engine);
    let (twin, _, _) = ev.evolve_fused(spec, &grid, 8, 4, KernelMethod::Taps, 2).unwrap();
    assert_eq!(fleet.data, twin.data);

    cluster.shutdown_nodes();
    for h in &mut handles {
        h.shutdown();
    }
}

/// The peer-loss property: a node that dies mid-peer-exchange (goes
/// silent partway through the round loop) makes the coordinator fall
/// back to the coordinator-mediated path — and the final grid is still
/// bitwise equal to the oracle and the single-process evolver.
#[test]
fn killing_a_node_mid_peer_exchange_falls_back_to_mediated_bitwise() {
    let engine = Engine::default();
    let spec = StencilSpec::star2d(1);
    let n = 36;
    let steps = 6;
    let shards = 6;
    let grid = DenseGrid::verification_input(&[n + 2, n + 2], 0xD1ED);

    let mut handles = vec![
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
        // dies at peer round 1 of 3 (steps=6, T=2), after bands from
        // round 0 are already in flight
        node::spawn_local(NodeConfig {
            workers: 1,
            engine,
            fail_after: Some(1),
            ..NodeConfig::default()
        })
        .unwrap(),
        node::spawn_local(NodeConfig { workers: 1, engine, ..NodeConfig::default() }).unwrap(),
    ];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();
    // band timeout tracks this, so survivors report the lost peer fast
    cluster.set_rpc_timeout(Duration::from_secs(5));
    assert_eq!(cluster.nodes_alive(), 3);

    let (fleet, report) = cluster
        .evolve_exchange(ExchangeMode::Peer, spec, &grid, steps, shards, KernelMethod::Taps, 2)
        .unwrap();
    assert!(report.fell_back, "the dying node never forced a fallback: {report:?}");
    assert_eq!(report.path, ExchangeMode::Mediated, "fallback must land on the mediated path");
    assert!(report.nodes_alive < 3, "the fault-injected node still counts as alive");

    let coeffs = CoeffTensor::paper_default(spec);
    let want = reference::evolve(&coeffs, &grid, steps);
    assert_eq!(
        fleet.data, want.data,
        "peer exchange with a node lost mid-run diverged bitwise from the oracle"
    );
    let ev = twin_evolver(engine);
    let (twin, _, _) = ev.evolve_fused(spec, &grid, steps, shards, KernelMethod::Taps, 2).unwrap();
    assert_eq!(fleet.data, twin.data);

    cluster.shutdown_nodes();
    for h in &mut handles {
        h.shutdown();
    }
}

/// Version skew between coordinator and node is a clear, actionable
/// handshake error naming both versions — not a decode error, not a
/// silent dead node.
#[test]
fn version_skew_fails_the_handshake_with_a_clear_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake_old_node = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // absorb the coordinator's Ping, then answer with a version-1
        // frame header, as a stale PR 9 build would
        let mut buf = [0u8; 64];
        let _ = s.read(&mut buf);
        let mut h = frame::encode_header(2, 0).unwrap();
        h[4..6].copy_from_slice(&1u16.to_le_bytes());
        let _ = s.write_all(&h);
        let _ = s.flush();
        // keep the socket open long enough for the error to be about
        // the version, not a reset
        std::thread::sleep(Duration::from_millis(200));
    });

    let err =
        Coordinator::connect(&[addr.to_string()], Engine::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("failed the protocol handshake"), "{msg}");
    assert!(msg.contains("unsupported protocol version 1"), "{msg}");
    assert!(msg.contains("must run the same build"), "{msg}");
    fake_old_node.join().unwrap();
}

/// Pipelining across one connection: many chunks sent back-to-back on a
/// single node still come back correct and in request order.
#[test]
fn single_node_pipelined_chunks_stay_ordered_and_bitwise() {
    let engine = Engine::default();
    let spec = StencilSpec::box2d(2);
    let grid = DenseGrid::verification_input(&[44, 40], 11);
    let mut handles =
        vec![node::spawn_local(NodeConfig { workers: 2, engine, ..NodeConfig::default() })
            .unwrap()];
    let mut cluster = Coordinator::connect_local(&handles, engine).unwrap();

    // 8 shards on one node: the coordinator pipelines all 8 requests on
    // the single connection before draining replies
    let (fleet, report) =
        cluster.evolve_fused(spec, &grid, 4, 8, KernelMethod::Taps, 2).unwrap();
    assert_eq!(report.nodes, 1);
    assert!(report.shards > 1);
    let coeffs = CoeffTensor::paper_default(spec);
    let want = reference::evolve(&coeffs, &grid, 4);
    assert_eq!(fleet.data, want.data);

    cluster.shutdown_nodes();
    for h in &mut handles {
        h.shutdown();
    }
}
