//! Integration: AOT artifacts (JAX/Pallas → HLO text) executed over the
//! Rust PJRT runtime must reproduce the Rust scalar oracle bit-closely.
//!
//! Requires `make artifacts`. Tests are skipped (with a loud message) if
//! the artifact directory is missing, so `cargo test` stays usable before
//! the first build — but CI (`make test`) always builds artifacts first.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::coordinator::EvolutionService;
use stencil_matrix::runtime::Registry;
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_parses_and_names_resolve() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(&dir).unwrap();
    assert!(reg.artifacts.len() >= 4, "expected several artifacts");
    for a in &reg.artifacts {
        assert!(a.path.exists(), "{} missing", a.path.display());
        assert_eq!(a.storage_extent, a.n + 2 * a.spec.order);
    }
}

#[test]
fn single_step_artifacts_match_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut svc = EvolutionService::new(&dir).unwrap();
    for name in ["step_2d5p_n64", "step_2d9p_n64", "step_3d7p_n16"] {
        let engine = svc.engine(name).unwrap();
        let meta = engine.meta().clone();
        let grid = DenseGrid::verification_input(&meta.shape(), 7);
        let (out, report) = engine.evolve(&grid, 1, true).unwrap();
        let err = report.max_err.unwrap();
        assert!(err < 1e-12, "{name}: max err {err}");
        // halo must stay frozen
        let coeffs = CoeffTensor::paper_default(meta.spec);
        let want = reference::apply(&coeffs, &grid);
        assert!(out.max_abs_diff_interior(&want, 0) < 1e-12, "{name}: halo drifted");
    }
}

#[test]
fn multi_step_scan_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut svc = EvolutionService::new(&dir).unwrap();
    let engine = svc.engine("evolve_2d5p_n64_t8").unwrap();
    let grid = DenseGrid::verification_input(&engine.meta().shape(), 99);
    // 3 executions × 8 scanned steps = 24 steps
    let (_, report) = engine.evolve(&grid, 3, true).unwrap();
    assert_eq!(report.steps, 24);
    assert!(report.max_err.unwrap() < 1e-11, "err {:?}", report.max_err);
    assert!(report.points_per_sec > 0.0);
}

#[test]
fn pjrt_agrees_with_simulated_outer_method() {
    // The strongest cross-layer check: Pallas-kernel artifact over PJRT
    // vs the simulator running the generated outer-product program —
    // two completely independent implementations of Eq. (12).
    use stencil_matrix::codegen::{run_method, Method, OuterParams};
    use stencil_matrix::sim::SimConfig;
    use stencil_matrix::stencil::StencilSpec;

    let Some(dir) = artifacts_dir() else { return };
    let mut svc = EvolutionService::new(&dir).unwrap();
    let engine = svc.engine("step_2d9p_n64").unwrap();
    let spec = StencilSpec::box2d(1);
    let grid = DenseGrid::verification_input(&engine.meta().shape(), 0xC0FFEE);
    let (pjrt_out, _) = engine.evolve(&grid, 1, false).unwrap();

    let res = run_method(
        &SimConfig::default(),
        spec,
        64,
        Method::Outer(OuterParams::paper_best(spec)),
        false,
    )
    .unwrap();
    assert!(res.verified());
    // both were verified against the same oracle on the same input; tie
    // them together explicitly too:
    let coeffs = CoeffTensor::paper_default(spec);
    let want = reference::apply(&coeffs, &grid);
    assert!(pjrt_out.max_abs_diff_interior(&want, 1) < 1e-12);
}
