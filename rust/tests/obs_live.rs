//! Integration tests for the live observability service: the
//! `/metrics` / `/healthz` / `/profile` listener under concurrent
//! scrapes, Prometheus exposition invariants (parseable sample lines,
//! cumulative histogram buckets), the cost-model audit's JSON
//! round-trip, and the guarantee that running the listener plus tracing
//! never perturbs numerical results.
//!
//! Registry state is process-global and `cargo test` runs tests
//! concurrently in one process, so every assertion here is about
//! deltas, per-thread monotonicity, or structure — never exact global
//! totals.

use stencil_matrix::obs::audit::CostAudit;
use stencil_matrix::obs::live::{self, LiveSources};
use stencil_matrix::obs::registry::{self, SECONDS_BUCKETS};
use stencil_matrix::serve::scheduler::record_shard_times;
use stencil_matrix::serve::{KernelMethod, ShardedEvolver};
use stencil_matrix::stencil::{DenseGrid, StencilSpec};
use stencil_matrix::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Minimal HTTP GET: returns (status, body). Read timeout keeps a
/// wedged listener from hanging the whole test binary.
fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    raw(addr, &format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n"))
}

fn raw(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stream.write_all(request.as_bytes()).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 =
        response.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let body = response.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// Every non-comment line of a Prometheus exposition must be exactly
/// `NAME VALUE` with a f64-parseable value.
fn assert_prometheus_lines(body: &str) {
    for line in body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        assert_eq!(line.split(' ').count(), 2, "bad sample line: {line}");
        let val = line.split(' ').nth(1).unwrap();
        assert!(val.parse::<f64>().is_ok(), "unparseable value in: {line}");
    }
}

/// The value of the sample whose name+labels field equals `series`.
fn sample_value(body: &str, series: &str) -> Option<f64> {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| l.strip_prefix(series)?.strip_prefix(' ')?.parse().ok())
}

#[test]
fn concurrent_scrapes_parse_and_scrape_counter_is_monotonic() {
    registry::global().counter("test_obs_live_seed_total").inc();
    let srv = live::serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
    let addr = srv.addr();
    let threads = 4;
    let scrapes = 6;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || {
                let mut last = 0.0f64;
                for _ in 0..scrapes {
                    let (status, body) = get(addr, "/metrics");
                    assert_eq!(status, 200);
                    assert_prometheus_lines(&body);
                    assert!(body.contains("test_obs_live_seed_total"), "{body}");
                    // the scrape counter only ever moves up: each render
                    // happens after this thread's own increment, so
                    // successive scrapes within a thread are monotonic
                    let seen =
                        sample_value(&body, "stencil_live_scrapes_total{path=\"metrics\"}")
                            .expect("scrape counter present");
                    assert!(seen >= last, "counter went backwards: {seen} < {last}");
                    last = seen;
                }
                assert!(last >= scrapes as f64);
            });
        }
    });
}

#[test]
fn bad_requests_do_not_wedge_the_listener() {
    let srv = live::serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
    let addr = srv.addr();
    assert_eq!(get(addr, "/unknown").0, 404);
    assert_eq!(raw(addr, "NOT-HTTP\r\n\r\n").0, 400);
    assert_eq!(raw(addr, "POST /metrics HTTP/1.1\r\n\r\n").0, 400);
    assert_eq!(raw(addr, "GET\r\n\r\n").0, 400);
    // the listener still serves all three endpoints afterwards
    assert_eq!(get(addr, "/metrics").0, 200);
    let (status, health) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert!(Json::parse(&health).is_ok(), "{health}");
    let (status, profile) = get(addr, "/profile");
    assert_eq!(status, 200);
    assert!(Json::parse(&profile).is_ok(), "{profile}");
}

#[test]
fn histogram_buckets_are_cumulative_and_sum_to_count() {
    // a family only this test observes, so the quiesced totals are exact
    let h = registry::global().histogram("test_obs_live_latency_seconds", &SECONDS_BUCKETS);
    let values = [0.00005, 0.003, 0.02, 0.7, 9.0]; // last beyond every finite bucket
    for v in values {
        h.observe(v);
    }
    let srv = live::serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
    let (status, body) = get(srv.addr(), "/metrics");
    assert_eq!(status, 200);
    let buckets: Vec<f64> = body
        .lines()
        .filter(|l| l.starts_with("test_obs_live_latency_seconds_bucket{"))
        .map(|l| l.split(' ').nth(1).unwrap().parse().unwrap())
        .collect();
    assert_eq!(buckets.len(), SECONDS_BUCKETS.len() + 1, "{body}");
    assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "not cumulative: {buckets:?}");
    let count = sample_value(&body, "test_obs_live_latency_seconds_count").unwrap();
    assert_eq!(count, values.len() as f64);
    assert_eq!(*buckets.last().unwrap(), count, "+Inf bucket equals _count");
    let sum = sample_value(&body, "test_obs_live_latency_seconds_sum").unwrap();
    assert!((sum - values.iter().sum::<f64>()).abs() < 1e-9);
}

#[test]
fn cost_audit_round_trips_through_json() {
    let audit = CostAudit::new();
    for seed in 0..3u64 {
        audit.observe(
            "2d9p-box-r1",
            32,
            "u1x8-minimalaxis",
            "test-fingerprint",
            || Some((40.0, 16.0)),
            1.5e-3 + seed as f64 * 1e-4,
            1e6,
        );
    }
    let predict = || Some((90.0, 48.0));
    audit.observe("3d27p-box-r1", 16, "taps", "test-fingerprint", predict, 2e-3, 5e5);
    let json = audit.to_json();
    let restored = CostAudit::from_json(&json).unwrap();
    assert_eq!(restored.snapshot(), audit.snapshot());
    assert_eq!(restored.to_json(), json);
    // unknown versions are rejected, not misread
    let mut wrong = json.clone();
    if let Json::Obj(m) = &mut wrong {
        m.insert("version".into(), Json::Num(999.0));
    }
    assert!(CostAudit::from_json(&wrong).is_err());
}

#[test]
fn induced_shard_skew_moves_the_imbalance_gauge() {
    // one shard 3x slower than the rest: max/mean = 3 / ((3+1+1+1)/4)
    let ratio = record_shard_times(&[3_000_000, 1_000_000, 1_000_000, 1_000_000]);
    assert!((ratio - 2.0).abs() < 1e-12, "{ratio}");
    let srv = live::serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
    let (status, body) = get(srv.addr(), "/metrics");
    assert_eq!(status, 200);
    // other tests race the gauge's value; presence of both families is
    // the stable invariant here
    assert!(body.contains("stencil_shard_imbalance"), "{body}");
    assert!(body.contains("stencil_shard_kernel_seconds{shard=\"0\"}"), "{body}");
}

#[test]
fn traced_run_with_live_listener_is_bitwise_identical() {
    let spec = StencilSpec::box2d(1);
    let grid = DenseGrid::verification_input(&[18, 18], 0xBEEF);
    let ev = ShardedEvolver::new(2);
    let want = ev.evolve(spec, &grid, 4, 2, KernelMethod::Outer).unwrap();

    let srv = live::serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
    let addr = srv.addr();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_scraper = Arc::clone(&stop);
    let scraper = std::thread::spawn(move || {
        let mut scrapes = 0usize;
        while !stop_scraper.load(Ordering::SeqCst) {
            assert_eq!(get(addr, "/metrics").0, 200);
            scrapes += 1;
        }
        scrapes
    });
    let (result, spans) =
        stencil_matrix::obs::span::trace(|| ev.evolve(spec, &grid, 4, 2, KernelMethod::Outer));
    stop.store(true, Ordering::SeqCst);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes > 0, "scraper ran alongside the traced evolution");
    assert!(!spans.is_empty(), "traced run recorded spans");
    let got = result.unwrap();
    assert_eq!(got, want, "tracing + live scraping must not perturb results");
}
