//! Shard-correctness property tests: sharded **multi-threaded** evolution
//! must be *bitwise* equal to the single-shard scalar oracle
//! (`stencil::reference::evolve`) across random specs, orders, shard
//! counts, worker counts, step counts and kernels.
//!
//! Bitwise (not epsilon) equality is the point: the shard kernels
//! preserve the oracle's accumulation order, tiles see exactly the
//! neighbourhoods the global sweep sees, and halo exchange keeps ghost
//! rows current — any crack in partitioning, exchange scheduling, or the
//! frozen-boundary convention shows up as a single differing bit.
//!
//! The KIR host kernel (`--kernel outer`) runs the paper's outer-product
//! algorithm, whose accumulation order differs from the gather sweep's —
//! there the bitwise oracle is **single-shard execution of the same
//! kernel** (its per-output accumulation order is position-independent),
//! and the scalar oracle is matched within the usual 1e-9 bar.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::serve::{KernelMethod, Partition, ShardedEvolver};
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid, StencilKind, StencilSpec};
use stencil_matrix::util::prop::{cases, Rng};

fn random_spec(rng: &mut Rng, dims: usize) -> StencilSpec {
    let kinds: &[StencilKind] = if dims == 2 {
        &[StencilKind::Box, StencilKind::Star, StencilKind::Diagonal]
    } else {
        &[StencilKind::Box, StencilKind::Star]
    };
    StencilSpec::new(dims, rng.range(1, 3), *rng.choose(kinds)).unwrap()
}

fn check_case(
    spec: StencilSpec,
    shape: &[usize],
    steps: usize,
    shards: usize,
    workers: usize,
    method: KernelMethod,
    seed: u64,
) {
    let grid = DenseGrid::verification_input(shape, seed);
    let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, steps);
    let ev = ShardedEvolver::new(workers);
    let got = ev.evolve(spec, &grid, steps, shards, method).unwrap();
    assert_eq!(
        got, want,
        "{spec} shape={shape:?} steps={steps} shards={shards} workers={workers} {method}"
    );
}

#[test]
fn sharded_equals_oracle_bitwise_2d() {
    cases(16, 0x5A2D, |rng| {
        let spec = random_spec(rng, 2);
        // non-square shapes, including extents barely above 2r+1
        let lo = 2 * spec.order + 2;
        let shape = vec![rng.range(lo, lo + 24), rng.range(lo, lo + 24)];
        check_case(
            spec,
            &shape,
            rng.range(1, 4),
            rng.range(1, 8),
            rng.range(1, 4),
            *rng.choose(&[KernelMethod::Oracle, KernelMethod::Taps]),
            rng.next_u64(),
        );
    });
}

#[test]
fn sharded_equals_oracle_bitwise_3d() {
    cases(8, 0x5A3D, |rng| {
        let spec = random_spec(rng, 3);
        let lo = 2 * spec.order + 2;
        let shape = vec![
            rng.range(lo, lo + 8),
            rng.range(lo, lo + 8),
            rng.range(lo, lo + 8),
        ];
        check_case(
            spec,
            &shape,
            rng.range(1, 3),
            rng.range(1, 6),
            rng.range(1, 4),
            *rng.choose(&[KernelMethod::Oracle, KernelMethod::Taps]),
            rng.next_u64(),
        );
    });
}

#[test]
fn oversharding_clamps_and_stays_exact() {
    // More shards than rows-per-halo allows: the partition clamps, edge
    // shards may consist entirely of frozen-boundary rows, and the result
    // must still match bitwise.
    let spec = StencilSpec::box2d(2);
    let shape = vec![11usize, 9];
    let grid = DenseGrid::verification_input(&shape, 5);
    let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, 3);
    assert_eq!(Partition::max_shards(11, 2), 5);
    for shards in [5usize, 6, 64] {
        let ev = ShardedEvolver::new(3);
        let got = ev.evolve(spec, &grid, 3, shards, KernelMethod::Taps).unwrap();
        assert_eq!(got, want, "x{shards}");
    }
}

#[test]
fn minimal_grid_single_interior_point() {
    // The smallest legal grid (2r+2 per dim) has very few interior
    // points; every decomposition must agree with the oracle.
    for spec in [StencilSpec::box2d(1), StencilSpec::star2d(3), StencilSpec::box3d(1)] {
        let shape = vec![2 * spec.order + 2; spec.dims];
        let grid = DenseGrid::verification_input(&shape, 77);
        let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, 2);
        for shards in 1..=3usize {
            let ev = ShardedEvolver::new(2);
            let got = ev
                .evolve(spec, &grid, 2, shards, KernelMethod::Taps)
                .unwrap();
            assert_eq!(got, want, "{spec} x{shards}");
        }
    }
}

#[test]
fn outer_host_kernel_sharded_is_bitwise_unsharded_and_close_to_oracle() {
    // sharded multi-threaded `outer` == single-shard single-worker
    // `outer`, bit for bit — and both within 1e-9 of the scalar oracle
    let cases: &[(StencilSpec, &[usize], usize)] = &[
        (StencilSpec::box2d(1), &[26, 19], 3),
        (StencilSpec::star2d(2), &[21, 24], 2),
        (StencilSpec::diag2d(1), &[18, 18], 2),
        (StencilSpec::box3d(1), &[12, 10, 11], 2),
        (StencilSpec::star3d(2), &[11, 9, 10], 1),
    ];
    for &(spec, shape, steps) in cases {
        let grid = DenseGrid::verification_input(shape, 0xC0FFEE);
        let single = ShardedEvolver::new(1)
            .evolve(spec, &grid, steps, 1, KernelMethod::Outer)
            .unwrap();
        let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, steps);
        let err = single.max_abs_diff_interior(&want, 0);
        assert!(err < 1e-9, "{spec}: outer kernel vs oracle max err {err:e}");
        for (shards, workers) in [(2usize, 2usize), (3, 4), (5, 3)] {
            let multi = ShardedEvolver::new(workers)
                .evolve(spec, &grid, steps, shards, KernelMethod::Outer)
                .unwrap();
            assert_eq!(
                multi, single,
                "{spec} shards={shards} workers={workers}: sharded outer diverged bitwise"
            );
        }
    }
}

/// Temporal-blocking property: for random specs, shapes, step counts,
/// shard counts, worker counts and depths T, the fused evolution is
/// **bitwise** equal to the unfused evolution of the same kernel (and,
/// for the oracle-order kernels, to the scalar oracle), sharded or not.
fn check_fused_case(dims: usize, seed: u64, rounds: usize) {
    cases(rounds, seed, |rng| {
        let spec = random_spec(rng, dims);
        let lo = 2 * spec.order + 2;
        let extent = if dims == 2 { 24 } else { 8 };
        let shape: Vec<usize> = (0..dims).map(|_| rng.range(lo, lo + extent)).collect();
        let steps = rng.range(1, 8);
        let shards = rng.range(1, 6);
        let workers = rng.range(1, 4);
        let fuse = rng.range(2, 4);
        let method = *rng.choose(&[
            KernelMethod::Oracle,
            KernelMethod::Taps,
            KernelMethod::Outer,
        ]);
        let grid = DenseGrid::verification_input(&shape, rng.next_u64());
        let ev = ShardedEvolver::new(workers);
        let (unfused, _, fr1) = ev.evolve_fused(spec, &grid, steps, shards, method, 1).unwrap();
        let (fused, shards_used, fr) =
            ev.evolve_fused(spec, &grid, steps, shards, method, fuse).unwrap();
        let ctx = format!(
            "{spec} shape={shape:?} steps={steps} shards={shards} workers={workers} \
             fuse={fuse} {method}"
        );
        assert_eq!(fused, unfused, "{ctx}: fused diverged bitwise from unfused");
        assert_eq!(fr1.fuse_steps, 1);
        assert!(fr.fuse_steps >= 1 && fr.fuse_steps <= fuse, "{ctx}");
        if shards_used > 1 {
            assert_eq!(
                fr.halo_exchanges,
                steps.div_ceil(fr.fuse_steps) - 1,
                "{ctx}: exchanges must drop from steps-1 to ceil(steps/T)-1"
            );
        } else {
            assert_eq!(fr.halo_exchanges, 0, "{ctx}");
        }
        // fused sharded == fused unsharded, bit for bit
        let (single, _, _) = ShardedEvolver::new(1)
            .evolve_fused(spec, &grid, steps, 1, method, fuse)
            .unwrap();
        assert_eq!(fused, single, "{ctx}: sharded vs unsharded fused");
        // oracle-accumulation-order kernels stay bitwise vs the oracle
        if method != KernelMethod::Outer {
            let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, steps);
            assert_eq!(fused, want, "{ctx}: fused vs scalar oracle");
        }
    });
}

#[test]
fn fused_sharded_equals_unfused_bitwise_2d() {
    check_fused_case(2, 0xF05E, 10);
}

#[test]
fn fused_sharded_equals_unfused_bitwise_3d() {
    check_fused_case(3, 0xF03D, 5);
}

#[test]
fn many_steps_keep_halos_current() {
    // Longer evolutions amplify any stale-ghost bug: a single missed
    // exchange diverges more every step.
    let spec = StencilSpec::star2d(1);
    let grid = DenseGrid::verification_input(&[40, 24], 0xBEEF);
    let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, 12);
    let ev = ShardedEvolver::new(4);
    for shards in [2usize, 4, 8] {
        let got = ev
            .evolve(spec, &grid, 12, shards, KernelMethod::Taps)
            .unwrap();
        assert_eq!(got, want, "x{shards}");
    }
}
