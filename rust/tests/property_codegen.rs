//! Property tests (util::prop, seeded SplitMix64 cases) over the codegen
//! and scatter invariants:
//!
//! - every (method × spec × size × unroll × scheduling) cell produces
//!   oracle-exact output;
//! - König's minimal cover matches the brute-force oracle on random
//!   coefficient masks and always reconstructs the tensor;
//! - the Eq. (12) expansion conserves every weight's total contribution.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{run_method, Method, OuterParams};
use stencil_matrix::scatter::cover::{minimal_axis_cover_2d, Bipartite};
use stencil_matrix::scatter::line::LineCover;
use stencil_matrix::scatter::{build_cover, CoverOption};
use stencil_matrix::stencil::{CoeffTensor, StencilKind, StencilSpec};
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::prop::{cases, Rng};

fn random_spec(rng: &mut Rng, dims: usize) -> StencilSpec {
    let kinds: &[StencilKind] = if dims == 2 {
        &[StencilKind::Box, StencilKind::Star, StencilKind::Diagonal]
    } else {
        &[StencilKind::Box, StencilKind::Star]
    };
    StencilSpec::new(dims, rng.range(1, 3), *rng.choose(kinds)).unwrap()
}

#[test]
fn outer_method_is_oracle_exact_across_param_space_2d() {
    let cfg = SimConfig::default();
    cases(12, 0x2D, |rng| {
        let spec = random_spec(rng, 2);
        let n = *rng.choose(&[16usize, 24, 32]);
        let mut options = CoverOption::applicable(spec);
        options.retain(|o| *o != CoverOption::MinimalAxis || spec.kind != StencilKind::Diagonal);
        let option = *rng.choose(&options);
        let params = OuterParams {
            option,
            ui: 1,
            uk: rng.range(1, 8),
            scheduled: rng.bool(),
        };
        let res = run_method(&cfg, spec, n, Method::Outer(params), false).unwrap();
        assert!(
            res.verified(),
            "{spec} N={n} {params:?}: max_err {}",
            res.max_err
        );
    });
}

#[test]
fn outer_method_is_oracle_exact_across_param_space_3d() {
    let cfg = SimConfig::default();
    cases(8, 0x3D, |rng| {
        let spec = random_spec(rng, 3);
        let n = *rng.choose(&[8usize, 16]);
        let options = CoverOption::applicable(spec);
        let option = *rng.choose(&options);
        let (ui, uk) = *rng.choose(&[(1usize, 1usize), (2, 2), (4, 1), (1, 4)]);
        let params = OuterParams { option, ui, uk, scheduled: rng.bool() };
        let res = run_method(&cfg, spec, n, Method::Outer(params), false).unwrap();
        assert!(
            res.verified(),
            "{spec} N={n} {params:?}: max_err {}",
            res.max_err
        );
    });
}

#[test]
fn baselines_are_oracle_exact() {
    let cfg = SimConfig::default();
    cases(10, 0xBA5E, |rng| {
        let dims = rng.range(2, 3);
        let spec = random_spec(rng, dims);
        let n = if dims == 2 { *rng.choose(&[16usize, 32]) } else { 8 };
        let method = *rng.choose(&[Method::AutoVec, Method::Dlt, Method::Tv, Method::Scalar]);
        let res = run_method(&cfg, spec, n, method, false).unwrap();
        assert!(res.verified(), "{method} {spec} N={n}: {}", res.max_err);
    });
}

#[test]
fn koenig_cover_matches_bruteforce_on_random_masks() {
    cases(40, 0x4B0E, |rng| {
        let r = rng.range(1, 3);
        let spec = StencilSpec::box2d(r);
        let side = spec.side();
        // random mask with a guaranteed non-zero centre
        let mut c = CoeffTensor { spec, data: vec![0.0; side * side] };
        for v in c.data.iter_mut() {
            if rng.below(3) == 0 {
                *v = rng.f64() + 2.0; // strictly non-zero
            }
        }
        let centre = (side * side) / 2;
        c.data[centre] = 1.0;
        let g = Bipartite::from_coeffs(&c);
        let (rows, cols) = g.min_vertex_cover();
        assert_eq!(rows.len() + cols.len(), g.brute_force_cover_size());
        let cover = LineCover { spec, lines: minimal_axis_cover_2d(&c) };
        assert!(cover.reconstructs(&c), "minimal cover must reconstruct");
        assert_eq!(cover.len(), rows.len() + cols.len());
    });
}

#[test]
fn eq12_expansion_conserves_weights() {
    // Σ_p cv(p)[k] over all output rows k equals each weight's count of
    // uses: every weight w[d] appears exactly once per output row.
    cases(30, 0xE012, |rng| {
        let spec = random_spec(rng, 2);
        let coeffs = CoeffTensor::paper_default(spec);
        let options = CoverOption::applicable(spec);
        let option = *rng.choose(&options);
        let cover = build_cover(&coeffs, option).unwrap();
        let n = 8;
        let weight_sum: f64 = coeffs.data.iter().sum();
        let mut contrib = 0.0;
        for line in &cover.lines {
            for (_, cv) in line.coeff_vectors(n) {
                contrib += cv.iter().sum::<f64>();
            }
        }
        // every weight contributes to exactly n output rows
        assert!(
            (contrib - weight_sum * n as f64).abs() < 1e-9,
            "{spec} {option:?}: {contrib} vs {}",
            weight_sum * n as f64
        );
    });
}
