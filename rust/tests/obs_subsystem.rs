//! Integration tests for the observability subsystem: span-core
//! invariants across threads, Chrome-trace structural validity over
//! randomized workloads, the end-to-end serve request lifecycle, and
//! the guarantee that tracing never perturbs computed outputs.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::obs::{chrome, profile, prom, span};
use stencil_matrix::serve::{
    KernelMethod, ServeConfig, ShardRequest, ShardedEvolver, StencilServer,
};
use stencil_matrix::stencil::{DenseGrid, StencilSpec};

/// Deterministic 64-bit LCG (Knuth's MMIX constants) — the property
/// tests need repeatable "random" workloads without external crates.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

const NAMES: [&str; 5] = ["alpha", "beta", "gamma", "delta", "epsilon"];

/// Open a random tree of nested spans; count every span opened.
fn random_spans(rng: &mut Lcg, depth: usize, opened: &mut usize) {
    let children = (rng.next() % 4) as usize;
    for _ in 0..children {
        let name = NAMES[rng.next() as usize % NAMES.len()];
        *opened += 1;
        let _g = if rng.next() % 2 == 0 {
            span::span(name, "prop")
        } else {
            span::span_arg(name, "prop", ("k", (rng.next() % 100) as f64))
        };
        if depth < 4 {
            random_spans(rng, depth + 1, opened);
        }
    }
}

#[test]
fn disabled_spans_record_nothing_even_in_bulk() {
    // recording is off inside the session: a hot loop of span calls must
    // leave every thread-local buffer untouched
    let ((), threads) = span::trace(|| {
        span::disable();
        for i in 0..10_000 {
            let g = span::span_arg("hot", "test", ("i", i as f64));
            drop(g);
        }
    });
    assert!(threads.is_empty(), "disabled spans leaked events: {threads:?}");
}

#[test]
fn cross_thread_nesting_exports_one_valid_track_per_thread() {
    let ((), threads) = span::trace(|| {
        let _outer = span::span("request", "test");
        let workers: Vec<_> = (0..4)
            .map(|w| {
                std::thread::Builder::new()
                    .name(format!("obs-worker-{w}"))
                    .spawn(move || {
                        let _s = span::span_arg("shard", "test", ("shard", w as f64));
                        let _inner = span::span("inner", "test");
                    })
                    .unwrap()
            })
            .collect();
        for h in workers {
            h.join().unwrap();
        }
    });
    // one track for the main thread, one per worker
    assert_eq!(threads.len(), 5, "{threads:?}");
    let doc = chrome::to_chrome_json(&threads);
    let counts = chrome::validate(&doc).unwrap();
    assert_eq!(counts.get("request"), Some(&1));
    assert_eq!(counts.get("shard"), Some(&4));
    assert_eq!(counts.get("inner"), Some(&4));
}

#[test]
fn random_workloads_export_valid_chrome_traces() {
    // property test: any workload of nested spans across threads must
    // export a structurally valid trace whose completed-pair count
    // equals the number of spans opened
    for seed in 1..=5u64 {
        let (opened, threads) = span::trace(|| {
            let handles: Vec<_> = (0..3u64)
                .map(|t| {
                    std::thread::spawn(move || {
                        let mut rng = Lcg(seed * 1000 + t);
                        let mut opened = 0usize;
                        random_spans(&mut rng, 0, &mut opened);
                        opened
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        });
        let doc = chrome::to_chrome_json(&threads);
        let counts = chrome::validate(&doc).unwrap();
        assert_eq!(counts.values().sum::<usize>(), opened, "seed {seed}");
    }
}

#[test]
fn traced_evolution_is_bitwise_identical_to_untraced() {
    let spec = StencilSpec::box2d(1);
    let n = 16;
    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
    let ev = ShardedEvolver::new(2);
    let untraced = ev.evolve_fused(spec, &grid, 8, 2, KernelMethod::Outer, 4).unwrap();
    let (traced, spans) = span::trace(|| {
        ev.evolve_fused(spec, &grid, 8, 2, KernelMethod::Outer, 4).unwrap()
    });
    assert_eq!(traced.0, untraced.0, "tracing perturbed the evolved grid");
    let prof = profile::aggregate(&spans);
    assert!(prof.spans > 0, "traced run recorded no phase spans");
    assert!(prof.compute_s > 0.0, "{prof:?}");
    assert!(prof.total() > 0.0);
}

#[test]
fn server_trace_covers_the_request_lifecycle() {
    // one fused outer-kernel request through the full server: the
    // acceptance bar is >= 1 completed span for dispatch, halo
    // exchange, freeze phase, and row-group execution, with outputs
    // bitwise identical to an untraced run
    let serve_once = || {
        let server = StencilServer::new(ServeConfig {
            workers: 2,
            shards: 2,
            queue_depth: 8,
            plan_cache: 8,
            fuse_steps: 4,
            ..ServeConfig::default()
        });
        server.start();
        let req = ShardRequest {
            spec: StencilSpec::box2d(1),
            n: 24,
            steps: 8,
            seed: 7,
            method: KernelMethod::Outer,
            verify: true,
        };
        let resp = server.submit(req).unwrap().wait().unwrap();
        // shut down inside the (possibly traced) region: joining the
        // dispatcher guarantees its span guards dropped before a trace
        // session drains, keeping the exported document balanced
        server.shutdown();
        resp.grid
    };
    let untraced = serve_once();
    let (traced, spans) = span::trace(serve_once);
    assert_eq!(traced, untraced, "tracing perturbed the served output");

    let doc = chrome::to_chrome_json(&spans);
    let counts = chrome::validate(&doc).unwrap();
    for name in [
        "serve.enqueue",
        "serve.dispatch",
        "serve.kernel",
        "serve.halo_exchange",
        "pool.batch",
        "kernel.embed",
        "kernel.extract",
        "kir.compute",
        "kir.freeze",
        "kir.row_group",
    ] {
        assert!(
            counts.get(name).copied().unwrap_or(0) >= 1,
            "no completed '{name}' span in {counts:?}"
        );
    }
    let prof = profile::aggregate(&spans);
    assert!(prof.compute_s > 0.0 && prof.exchange_s > 0.0, "{prof:?}");
}

#[test]
fn prom_exposition_covers_the_metrics_snapshot() {
    let server = StencilServer::new(ServeConfig {
        workers: 2,
        shards: 2,
        queue_depth: 8,
        plan_cache: 8,
        ..ServeConfig::default()
    });
    server.start();
    for seed in 0..3 {
        let req = ShardRequest {
            spec: StencilSpec::box2d(1),
            n: 12,
            steps: 2,
            seed,
            method: KernelMethod::Taps,
            verify: true,
        };
        server.submit(req).unwrap().wait().unwrap();
    }
    server.shutdown();
    let text = prom::render(&server.metrics_json(), "stencil_serve");
    assert!(text.contains("_completed 3"), "{text}");
    assert!(text.contains("quantile=\"0.99\""), "{text}");
    assert!(text.contains("_window_len"), "{text}");
    // every sample line of the exposition is `NAME VALUE`
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        let mut parts = line.split(' ');
        let (name, val) = (parts.next().unwrap(), parts.next().unwrap());
        assert!(parts.next().is_none(), "bad sample line: {line}");
        assert!(!name.is_empty() && val.parse::<f64>().is_ok(), "bad sample line: {line}");
    }
}
