//! Cross-method and machine-model invariants, using shrunken machine
//! configs where that makes "out-of-cache" behaviour cheap to test.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{run_method, Method, OuterParams};
use stencil_matrix::scatter::{analysis, build_cover, CoverOption};
use stencil_matrix::stencil::{CoeffTensor, StencilSpec};
use stencil_matrix::sim::{trace, SimConfig};

fn tiny_cache(mut cfg: SimConfig) -> SimConfig {
    // shrink L1 hard but keep L2 big enough for TV's strip buffers
    cfg.cache.l1_bytes = 4 * 1024;
    cfg.cache.l2_bytes = 64 * 1024;
    cfg
}

#[test]
fn runs_are_deterministic() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::box2d(1);
    let p = Method::Outer(OuterParams::paper_best(spec));
    let a = run_method(&cfg, spec, 32, p, true).unwrap();
    let b = run_method(&cfg, spec, 32, p, true).unwrap();
    assert_eq!(a.stats.cycles, b.stats.cycles);
    assert_eq!(a.stats.instructions, b.stats.instructions);
    assert_eq!(a.stats.mix, b.stats.mix);
}

#[test]
fn fmopa_count_is_schedule_invariant_and_matches_theory() {
    // Scheduling changes loads/moves, never the outer-product count,
    // which must equal the Eq. (12) expansion exactly.
    let cfg = SimConfig::default();
    for spec in [StencilSpec::box2d(1), StencilSpec::box2d(2), StencilSpec::star2d(2)] {
        let coeffs = CoeffTensor::paper_default(spec);
        let cover = build_cover(&coeffs, CoverOption::Parallel).unwrap();
        let n = 32;
        let blocks = (n / cfg.vlen) * (n / cfg.vlen);
        let expect = (cover.outer_products(cfg.vlen) * blocks) as u64;
        for scheduled in [false, true] {
            let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 4, scheduled };
            let res = run_method(&cfg, spec, n, Method::Outer(p), false).unwrap();
            assert!(res.verified());
            assert_eq!(res.stats.fmopa(), expect, "{spec} scheduled={scheduled}");
        }
    }
}

#[test]
fn scheduling_reduces_loads_not_flops() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::box2d(1);
    let naive = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 8, scheduled: false };
    let sched = OuterParams { scheduled: true, ..naive };
    let a = run_method(&cfg, spec, 32, Method::Outer(naive), false).unwrap();
    let b = run_method(&cfg, spec, 32, Method::Outer(sched), false).unwrap();
    assert_eq!(a.stats.flops, b.stats.flops);
    assert!(
        b.stats.count("ld1d") < a.stats.count("ld1d"),
        "scheduled {} vs naive {} loads",
        b.stats.count("ld1d"),
        a.stats.count("ld1d")
    );
}

#[test]
fn smaller_cache_costs_cycles() {
    let spec = StencilSpec::box2d(1);
    let m = Method::Outer(OuterParams::paper_best(spec));
    let big = run_method(&SimConfig::default(), spec, 64, m, true).unwrap();
    let mut tiny = tiny_cache(SimConfig::default());
    tiny.cache.l2_bytes = 16 * 1024;
    let small = run_method(&tiny, spec, 64, m, true).unwrap();
    assert!(small.verified());
    assert!(
        small.stats.cycles > big.stats.cycles,
        "4KB L1 should hurt: {} vs {}",
        small.stats.cycles,
        big.stats.cycles
    );
    assert!(small.stats.cache.mem_accesses > big.stats.cache.mem_accesses);
}

#[test]
fn tv_reduces_memory_volume_out_of_cache() {
    // the defining TV property: 256² exceeds the default 512 KB L2
    // (2 × 550 KB arrays) while TV's strip buffers stay resident
    let cfg = SimConfig::default();
    let spec = StencilSpec::box2d(1);
    let auto = run_method(&cfg, spec, 256, Method::AutoVec, false).unwrap();
    let tv = run_method(&cfg, spec, 256, Method::Tv, false).unwrap();
    assert!(auto.verified() && tv.verified());
    let auto_bytes = auto.stats.mem_bytes() as f64 / auto.steps as f64;
    let tv_bytes = tv.stats.mem_bytes() as f64 / tv.steps as f64;
    assert!(
        tv_bytes < auto_bytes * 0.6,
        "TV per-step traffic {tv_bytes} should be well under autovec {auto_bytes}"
    );
}

#[test]
fn wider_issue_does_not_slow_down() {
    let spec = StencilSpec::star2d(1);
    let m = Method::Outer(OuterParams::paper_best(spec));
    let mut narrow = SimConfig::default();
    narrow.issue_width = 1;
    let a = run_method(&narrow, spec, 32, m, true).unwrap();
    let b = run_method(&SimConfig::default(), spec, 32, m, true).unwrap();
    assert!(b.stats.cycles <= a.stats.cycles);
}

#[test]
fn two_opu_units_help_opu_bound_kernels() {
    let spec = StencilSpec::box2d(3); // heavily outer-product bound
    let m = Method::Outer(OuterParams::paper_best(spec));
    // widen the front end + the other units so the OPU is the binding
    // resource (at issue_width=2 this kernel is front-end bound and the
    // OPU count is irrelevant — itself a finding worth pinning)
    let mut wide = SimConfig::default();
    wide.issue_width = 6;
    wide.valu_units = 4;
    wide.lsu_units = 4;
    let one = run_method(&wide, spec, 32, m, true).unwrap();
    let mut cfg2 = wide.clone();
    cfg2.opu_units = 2;
    let two = run_method(&cfg2, spec, 32, m, true).unwrap();
    assert!(
        (two.stats.cycles as f64) < one.stats.cycles as f64 * 0.85,
        "2 OPUs: {} vs {}",
        two.stats.cycles,
        one.stats.cycles
    );
}

#[test]
fn roofline_classifies_methods_sensibly() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::box2d(3);
    let ours = run_method(
        &cfg,
        spec,
        64,
        Method::Outer(OuterParams::paper_best(spec)),
        true,
    )
    .unwrap();
    let r = trace::roofline(&cfg, &ours.stats);
    assert_eq!(r.bound, "OPU", "high-order box outer method is OPU-bound: {r}");
    let auto = run_method(&cfg, spec, 64, Method::AutoVec, true).unwrap();
    let r = trace::roofline(&cfg, &auto.stats);
    assert!(r.bound == "VALU" || r.bound == "LSU", "autovec: {r}");
}

#[test]
fn instr_analysis_tracks_measured_fmopa() {
    // theory (outer products per output vector) × output vectors must
    // equal the measured fmopa count
    let cfg = SimConfig::default();
    for (spec, option) in [
        (StencilSpec::box2d(2), CoverOption::Parallel),
        (StencilSpec::star2d(2), CoverOption::Orthogonal),
    ] {
        let n = 32;
        let a = analysis::analyze(spec, option, cfg.vlen).unwrap();
        let p = OuterParams { option, ui: 1, uk: 4, scheduled: true };
        let res = run_method(&cfg, spec, n, Method::Outer(p), false).unwrap();
        let outvecs = (n * n / cfg.vlen) as f64;
        let predicted = a.outer_per_outvec * outvecs;
        assert!(
            (res.stats.fmopa() as f64 - predicted).abs() / predicted < 0.02,
            "{spec} {option:?}: measured {} vs predicted {predicted}",
            res.stats.fmopa()
        );
    }
}
