//! KIR backend-equivalence property tests (ISSUE 3 acceptance):
//!
//! - for random specs/sizes across all five methods, the KIR→sim lowering
//!   produces oracle-verified output (the same ≤ 1e-9 bar `run_method`
//!   has always enforced; the scalar method, whose accumulation order
//!   equals the oracle's, is bitwise);
//! - the KIR→host executor produces output **bitwise identical** to the
//!   simulated run of the same program (strictly stronger than the 1e-9
//!   requirement): both backends perform the same IEEE-754 operations in
//!   the same order;
//! - the **compiling host engine** (ISSUE 4: fused loop nests,
//!   precomputed gather tables, threaded row groups) is bitwise
//!   identical to the interpreting host backend — and hence to the
//!   simulator — across random specs/sizes × all five methods × 1–4
//!   worker threads;
//! - the **explicit-SIMD engine** (ISSUE 8: runtime-dispatched vector
//!   microkernels) is bitwise identical to the interpreter under the
//!   same sweep — every case, fused and unfused, at 1–4 threads — and
//!   stays so when dispatch is forced onto the scalar fallback path,
//!   proving the ISA choice never changes results.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{
    run_host, run_host_fused, run_host_fused_threads, run_host_threads, run_method,
    run_method_fused, supports_fusion, Method, OuterParams,
};
use stencil_matrix::kir::Engine;
use stencil_matrix::scatter::CoverOption;
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid, StencilKind, StencilSpec};
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::prop::{cases, Rng};

fn random_spec(rng: &mut Rng, dims: usize) -> StencilSpec {
    let kinds: &[StencilKind] = if dims == 2 {
        &[StencilKind::Box, StencilKind::Star, StencilKind::Diagonal]
    } else {
        &[StencilKind::Box, StencilKind::Star]
    };
    StencilSpec::new(dims, rng.range(1, 3), *rng.choose(kinds)).unwrap()
}

fn random_method(rng: &mut Rng, spec: StencilSpec) -> Method {
    match rng.below(5) {
        0 => Method::Scalar,
        1 => Method::AutoVec,
        2 => Method::Dlt,
        3 => Method::Tv,
        _ => {
            let mut options = CoverOption::applicable(spec);
            options.retain(|o| *o != CoverOption::MinimalAxis || spec.kind != StencilKind::Diagonal);
            let option = *rng.choose(&options);
            let (ui, uk) = if spec.dims == 2 {
                (1, rng.range(1, 8))
            } else {
                (rng.range(1, 4), rng.range(1, 2))
            };
            Method::Outer(OuterParams { option, ui, uk, scheduled: rng.bool() })
        }
    }
}

fn check_case(cfg: &SimConfig, spec: StencilSpec, n: usize, method: Method) {
    let sim = run_method(cfg, spec, n, method, false).unwrap();
    assert!(
        sim.verified(),
        "{spec} N={n} {method}: sim max_err {}",
        sim.max_err
    );
    let host = run_host(cfg, spec, n, method, Engine::Interpret).unwrap();
    // the issue's bar: host within 1e-9 of the oracle…
    assert!(
        host.verified(),
        "{spec} N={n} {method}: host max_err {}",
        host.max_err
    );
    // …and in fact bitwise identical to the simulated program's output
    assert_eq!(
        host.grid.data, sim.grid.data,
        "{spec} N={n} {method}: host/sim outputs differ bitwise"
    );
    assert_eq!(host.steps, sim.steps);
    assert!(host.ops > 0);
    // the compiling engine is bitwise identical to the interpreter (and
    // hence to the simulator) at every thread count
    for threads in 1..=4usize {
        let compiled =
            run_host_threads(cfg, spec, n, method, Engine::Compiled, threads).unwrap();
        assert_eq!(
            compiled.grid.data, host.grid.data,
            "{spec} N={n} {method}: compiled engine diverged at {threads} thread(s)"
        );
        assert_eq!(compiled.ops, host.ops, "{spec} N={n} {method}: op counts diverge");
        assert_eq!(compiled.steps, host.steps);
    }
    // so is the explicit-SIMD engine, whatever ISA dispatch selected
    for threads in 1..=4usize {
        let simd = run_host_threads(cfg, spec, n, method, Engine::Simd, threads).unwrap();
        assert_eq!(
            simd.grid.data, host.grid.data,
            "{spec} N={n} {method}: simd engine diverged at {threads} thread(s)"
        );
        assert_eq!(simd.ops, host.ops, "{spec} N={n} {method}: simd op count diverges");
        assert_eq!(simd.steps, host.steps);
    }
}

#[test]
fn host_executor_matches_sim_bitwise_2d() {
    let cfg = SimConfig::default();
    cases(12, 0x1C1B, |rng| {
        let spec = random_spec(rng, 2);
        let n = *rng.choose(&[16usize, 24, 32]);
        let method = random_method(rng, spec);
        check_case(&cfg, spec, n, method);
    });
}

#[test]
fn host_executor_matches_sim_bitwise_3d() {
    let cfg = SimConfig::default();
    cases(8, 0x1C3D, |rng| {
        let spec = random_spec(rng, 3);
        let method = random_method(rng, spec);
        check_case(&cfg, spec, 8, method);
    });
}

#[test]
fn every_method_is_covered_on_every_table3_style_spec() {
    // deterministic sweep: all five methods on a representative spec set
    let cfg = SimConfig::default();
    for spec in [
        StencilSpec::box2d(1),
        StencilSpec::star2d(2),
        StencilSpec::diag2d(1),
        StencilSpec::box3d(1),
        StencilSpec::star3d(2),
    ] {
        let n = if spec.dims == 2 { 16 } else { 8 };
        for method in [
            Method::Scalar,
            Method::AutoVec,
            Method::Dlt,
            Method::Tv,
            Method::Outer(OuterParams::paper_best(spec)),
        ] {
            check_case(&cfg, spec, n, method);
        }
    }
}

#[test]
fn compiled_engine_covers_multi_pass_covers() {
    // the 3D orthogonal cover generates a second i-line pass (a Phase
    // barrier plus read-modify-write row groups) — the hardest shape for
    // the fuser's independence proof — and the unscheduled variants
    // exercise the naive per-tile streams
    let cfg = SimConfig::default();
    let orth3d = OuterParams { option: CoverOption::Orthogonal, ui: 4, uk: 1, scheduled: true };
    check_case(&cfg, StencilSpec::star3d(2), 8, Method::Outer(orth3d));
    let orth2d = OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 4, scheduled: true };
    check_case(&cfg, StencilSpec::star2d(2), 32, Method::Outer(orth2d));
    let naive = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 1, scheduled: false };
    check_case(&cfg, StencilSpec::box2d(1), 24, Method::Outer(naive));
}

/// Fused-equivalence check for one case: the temporally blocked T-step
/// program verifies against T oracle steps on the simulator, the host
/// interpreter reproduces the simulated fused run bitwise, and the
/// compiling engine reproduces the interpreter bitwise at 1–4 threads.
fn check_fused_case(cfg: &SimConfig, spec: StencilSpec, n: usize, method: Method, t: usize) {
    let sim = run_method_fused(cfg, spec, n, method, false, t).unwrap();
    assert!(
        sim.verified(),
        "{spec} N={n} {method} T={t}: sim max_err {}",
        sim.max_err
    );
    assert_eq!(sim.steps, t);
    let host = run_host_fused(cfg, spec, n, method, Engine::Interpret, t).unwrap();
    assert!(
        host.verified(),
        "{spec} N={n} {method} T={t}: host max_err {}",
        host.max_err
    );
    assert_eq!(
        host.grid.data, sim.grid.data,
        "{spec} N={n} {method} T={t}: fused host/sim outputs differ bitwise"
    );
    for threads in 1..=4usize {
        let compiled =
            run_host_fused_threads(cfg, spec, n, method, Engine::Compiled, t, threads).unwrap();
        assert_eq!(
            compiled.grid.data, host.grid.data,
            "{spec} N={n} {method} T={t}: compiled engine diverged at {threads} thread(s)"
        );
        assert_eq!(compiled.steps, t);
    }
    for threads in 1..=4usize {
        let simd = run_host_fused_threads(cfg, spec, n, method, Engine::Simd, t, threads).unwrap();
        assert_eq!(
            simd.grid.data, host.grid.data,
            "{spec} N={n} {method} T={t}: simd engine diverged at {threads} thread(s)"
        );
        assert_eq!(simd.steps, t);
    }
}

#[test]
fn fused_programs_match_across_backends_2d() {
    let cfg = SimConfig::default();
    cases(8, 0x7E51, |rng| {
        let spec = random_spec(rng, 2);
        let n = *rng.choose(&[16usize, 24]);
        let mut method = random_method(rng, spec);
        if !supports_fusion(method) {
            method = Method::Scalar; // DLT/TV cannot be temporally blocked
        }
        let t = *rng.choose(&[2usize, 3, 4]);
        check_fused_case(&cfg, spec, n, method, t);
    });
}

#[test]
fn fused_programs_match_across_backends_3d() {
    let cfg = SimConfig::default();
    cases(4, 0x7E3D, |rng| {
        let spec = random_spec(rng, 3);
        let mut method = random_method(rng, spec);
        if !supports_fusion(method) {
            method = Method::Outer(OuterParams::paper_best(spec));
        }
        let t = *rng.choose(&[2usize, 4]);
        check_fused_case(&cfg, spec, 8, method, t);
    });
}

#[test]
fn fused_multi_pass_covers_keep_step_barriers() {
    // the 3D orthogonal cover's second i-line pass (Phase barrier +
    // read-modify-write row groups) inside every fused step is the
    // hardest shape for the fuser: step barriers and phase barriers
    // interleave
    let cfg = SimConfig::default();
    let orth3d = OuterParams { option: CoverOption::Orthogonal, ui: 4, uk: 1, scheduled: true };
    check_fused_case(&cfg, StencilSpec::star3d(2), 8, Method::Outer(orth3d), 3);
    let orth2d = OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 4, scheduled: true };
    check_fused_case(&cfg, StencilSpec::star2d(2), 16, Method::Outer(orth2d), 4);
}

#[test]
fn forced_scalar_fallback_never_changes_results() {
    // force dispatch onto the portable scalar path and prove the engine
    // still reproduces the interpreter bitwise — the dispatch choice is
    // a pure performance decision, never a semantic one. (While the
    // override is set, concurrently running simd cases also take the
    // scalar path; they assert the same bitwise contract, so the sweep
    // stays sound either way.)
    let cfg = SimConfig::default();
    stencil_matrix::kir::simd::force_scalar(true);
    assert_eq!(stencil_matrix::kir::simd::active_isa(), stencil_matrix::kir::SimdIsa::Scalar);
    let star2 = StencilSpec::star2d(2);
    let box3 = StencilSpec::box3d(1);
    for (spec, method, t) in [
        (star2, Method::Outer(OuterParams::paper_best(star2)), 1),
        (StencilSpec::box2d(1), Method::AutoVec, 2),
        (box3, Method::Outer(OuterParams::paper_best(box3)), 4),
    ] {
        let n = if spec.dims == 2 { 16 } else { 8 };
        let host = run_host_fused(&cfg, spec, n, method, Engine::Interpret, t).unwrap();
        for threads in [1usize, 4] {
            let simd =
                run_host_fused_threads(&cfg, spec, n, method, Engine::Simd, t, threads).unwrap();
            assert_eq!(
                simd.grid.data, host.grid.data,
                "{spec} {method} T={t}: forced-scalar simd diverged at {threads} thread(s)"
            );
        }
    }
    stencil_matrix::kir::simd::force_scalar(false);
}

#[test]
fn scalar_sim_lowering_is_bitwise_oracle() {
    // the scalar generator preserves the oracle's accumulation order
    // (dense-offset taps, in order), so its KIR→sim output is not just
    // within 1e-9 — it is the oracle, bit for bit
    let cfg = SimConfig::default();
    for spec in [StencilSpec::box2d(1), StencilSpec::star2d(2), StencilSpec::box3d(1)] {
        let n = if spec.dims == 2 { 16 } else { 8 };
        let sim = run_method(&cfg, spec, n, Method::Scalar, false).unwrap();
        let shape = vec![n + 2 * spec.order; spec.dims];
        let input = DenseGrid::verification_input(&shape, 0xC0FFEE);
        let want = reference::evolve(&CoeffTensor::paper_default(spec), &input, 1);
        assert_eq!(sim.grid.data, want.data, "{spec}");
    }
}
