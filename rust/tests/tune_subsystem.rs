//! Integration tests for the autotuning subsystem (ISSUE 2 acceptance):
//!
//! - for a 2D star and a 3D box stencil, `tune` finds a plan whose
//!   simulated cycle count is ≤ the paper-default outer-product plan
//!   (never worse — the default is always in the measured set);
//! - every searched candidate is verified against the scalar oracle
//!   (an unverifiable candidate aborts the search, so measurements exist
//!   only for verified plans);
//! - the tuning database round-trips through disk with its version
//!   enforced;
//! - `serve` demonstrably loads the tuned plan from the DB: a server
//!   built over the database answers `tuned`-kernel requests with the
//!   DB plan's label in the report and counts the match in its plan-cache
//!   metrics. Tuned plans now compile to real KIR host kernels, so
//!   results match the scalar oracle within the 1e-9 bar (bitwise when
//!   the plan falls back to the taps kernel).

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::Method;
use stencil_matrix::serve::{KernelMethod, ServeConfig, ShardRequest, StencilServer};
use stencil_matrix::stencil::StencilSpec;
use stencil_matrix::sim::SimConfig;
use stencil_matrix::tune::{tune, Strategy, TuneDb};
use stencil_matrix::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("stencil_tune_{}_{name}.json", std::process::id()))
}

#[test]
fn time_tile_axis_is_searched_and_db_compatible() {
    let cfg = SimConfig::default();
    // the exhaustive space includes temporally blocked candidates, all
    // sim-measured and oracle-verified like every other plan
    let out = tune(&cfg, StencilSpec::star2d(1), 16, 1, Strategy::Exhaustive).unwrap();
    let fused: Vec<_> = out.measurements.iter().filter(|m| m.plan.steps > 1).collect();
    assert!(!fused.is_empty(), "time-tile axis missing from the space");
    for m in &fused {
        assert!(m.max_err < 1e-9, "{}: unverified", m.plan.label(2));
    }
    // whatever wins, its depth survives the database round-trip
    let path = temp_path("fused");
    let mut db = TuneDb::new();
    db.record(&out);
    db.save(&path).unwrap();
    let back = TuneDb::load(&path).unwrap();
    let e = back.best_for(out.spec, &out.fingerprint).unwrap();
    assert_eq!(e.plan, out.best().plan);
    assert_eq!(e.plan.steps, out.best().plan.steps);
    std::fs::remove_file(&path).ok();
}

#[test]
fn tuned_plan_never_loses_to_paper_default_2d_star() {
    let cfg = SimConfig::default();
    let out = tune(&cfg, StencilSpec::star2d(2), 16, 8, Strategy::CostGuided).unwrap();
    assert!(out.best().cycles <= out.paper_default().cycles);
    assert!(out.best().cycles_per_point <= out.paper_default().cycles_per_point);
    assert!(out.speedup_vs_default() >= 1.0);
    // every measured candidate was verified bitwise-close to the oracle
    assert!(!out.measurements.is_empty());
    for m in &out.measurements {
        assert!(m.max_err < 1e-9, "{:?} not verified: {}", m.plan, m.max_err);
    }
    // the winner is a real outer-product plan description
    match out.best().plan.to_method() {
        Method::Outer(_) | Method::AutoVec | Method::Dlt | Method::Tv | Method::Scalar => {}
    }
}

#[test]
fn tuned_plan_never_loses_to_paper_default_3d_box() {
    let cfg = SimConfig::default();
    let out = tune(&cfg, StencilSpec::box3d(1), 8, 8, Strategy::CostGuided).unwrap();
    assert!(out.best().cycles <= out.paper_default().cycles);
    assert!(out.speedup_vs_default() >= 1.0);
    assert!(out.measurements.iter().all(|m| m.max_err < 1e-9));
    assert_eq!(out.fingerprint, cfg.fingerprint());
}

#[test]
fn tuning_db_roundtrips_through_disk_with_version_enforcement() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::star2d(1);
    let out = tune(&cfg, spec, 16, 4, Strategy::CostGuided).unwrap();
    let mut db = TuneDb::new();
    db.record(&out);

    let path = temp_path("roundtrip");
    db.save(&path).unwrap();
    let loaded = TuneDb::load(&path).unwrap();
    assert_eq!(loaded.len(), 1);
    let e = loaded.lookup(spec, 16, &cfg.fingerprint()).unwrap();
    assert_eq!(e.plan, out.best().plan);
    assert_eq!(e.cycles, out.best().cycles);
    assert!(e.speedup_vs_default >= 1.0);

    // load_or_new: missing file is an empty DB, corrupt version is an error
    let missing = temp_path("missing");
    let _ = std::fs::remove_file(&missing);
    assert_eq!(TuneDb::load_or_new(&missing).unwrap().len(), 0);
    let bad = temp_path("badversion");
    std::fs::write(&bad, r#"{"version":99,"entries":[]}"#).unwrap();
    assert!(TuneDb::load_or_new(&bad).is_err());

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&bad);
}

#[test]
fn serve_loads_the_tuned_plan_from_the_db() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::star2d(2);
    let out = tune(&cfg, spec, 16, 6, Strategy::CostGuided).unwrap();
    let mut db = TuneDb::new();
    db.record(&out);
    let expected_label = out.best().plan.label(spec.dims);

    let server = StencilServer::with_tune_db(
        ServeConfig { workers: 2, shards: 2, queue_depth: 8, plan_cache: 8, ..ServeConfig::default() },
        Arc::new(db),
        cfg.fingerprint(),
    );
    let ticket = server
        .submit(ShardRequest {
            spec,
            n: 12,
            steps: 2,
            seed: 7,
            method: KernelMethod::Tuned,
            verify: true,
        })
        .unwrap();
    server.drain();
    let resp = ticket.wait().unwrap();
    // the tuned plan runs as a real host kernel (1e-9 bar; 0.0 when the
    // plan fell back to the bitwise taps kernel)
    let err = resp.report.max_err.expect("verification ran");
    assert!(err < 1e-9, "max_err {err:e}");
    // the response names the DB plan the kernel LRU matched
    assert_eq!(resp.report.tuned_plan.as_deref(), Some(expected_label.as_str()));
    // and the plan-cache metrics count the tuning-DB match
    let metrics = server.metrics_json();
    let tuned_hits = metrics
        .get("plan_cache")
        .and_then(|c| c.get("tuned_hits"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(tuned_hits >= 1.0, "expected tuned_hits >= 1, got {tuned_hits}");
    server.shutdown();
}

#[test]
fn tuned_kernel_without_db_serves_and_reports_no_plan() {
    let server = StencilServer::new(ServeConfig {
        workers: 1,
        shards: 2,
        queue_depth: 4,
        plan_cache: 4, ..ServeConfig::default() });
    let ticket = server
        .submit(ShardRequest {
            spec: StencilSpec::box2d(1),
            n: 10,
            steps: 1,
            seed: 1,
            method: KernelMethod::Tuned,
            verify: true,
        })
        .unwrap();
    server.drain();
    let resp = ticket.wait().unwrap();
    assert_eq!(resp.report.max_err, Some(0.0));
    assert_eq!(resp.report.tuned_plan, None);
    server.shutdown();
}

#[test]
fn db_entries_are_machine_specific() {
    let cfg = SimConfig::default();
    let spec = StencilSpec::star2d(2);
    let out = tune(&cfg, spec, 16, 4, Strategy::CostGuided).unwrap();
    let mut db = TuneDb::new();
    db.record(&out);

    // a server identifying as a *different* machine must not match
    let server = StencilServer::with_tune_db(
        ServeConfig { workers: 1, shards: 1, queue_depth: 4, plan_cache: 4, ..ServeConfig::default() },
        Arc::new(db),
        SimConfig::default().with_mregs(16).fingerprint(),
    );
    let ticket = server
        .submit(ShardRequest {
            spec,
            n: 12,
            steps: 1,
            seed: 3,
            method: KernelMethod::Tuned,
            verify: true,
        })
        .unwrap();
    server.drain();
    let resp = ticket.wait().unwrap();
    assert_eq!(resp.report.max_err, Some(0.0));
    assert_eq!(resp.report.tuned_plan, None);
    server.shutdown();
}
