//! Integration tests for the batched serving front-end: queue semantics
//! (backpressure, coalescing), the background dispatcher, and the metrics
//! snapshot consumed as JSON.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use std::sync::Arc;
use stencil_matrix::serve::{KernelMethod, ServeConfig, ShardRequest, StencilServer};
use stencil_matrix::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use stencil_matrix::util::json::Json;

fn outer_req(spec: StencilSpec, n: usize, steps: usize, seed: u64) -> ShardRequest {
    ShardRequest { spec, n, steps, seed, method: KernelMethod::Outer, verify: true }
}

fn req(spec: StencilSpec, n: usize, steps: usize, seed: u64) -> ShardRequest {
    ShardRequest { spec, n, steps, seed, method: KernelMethod::Taps, verify: true }
}

#[test]
fn served_grid_matches_oracle() {
    let server = StencilServer::new(ServeConfig {
        workers: 3,
        shards: 4,
        queue_depth: 8,
        plan_cache: 8, ..ServeConfig::default() });
    let spec = StencilSpec::star2d(2);
    let ticket = server.submit(req(spec, 20, 3, 9)).unwrap();
    server.drain();
    let resp = ticket.wait().unwrap();
    // the server's report already claims bitwise verification…
    assert_eq!(resp.report.max_err, Some(0.0));
    // …and we re-derive the oracle result independently out here
    let input = DenseGrid::verification_input(&[24, 24], 9);
    let want = reference::evolve(&CoeffTensor::paper_default(spec), &input, 3);
    assert_eq!(resp.grid, want);
}

#[test]
fn backpressure_rejects_when_full_and_recovers() {
    let server = StencilServer::new(ServeConfig {
        workers: 1,
        shards: 1,
        queue_depth: 2,
        plan_cache: 4, ..ServeConfig::default() });
    let spec = StencilSpec::box2d(1);
    let t1 = server.try_submit(req(spec, 10, 1, 1)).unwrap();
    let t2 = server.try_submit(req(spec, 10, 1, 2)).unwrap();
    // queue full → distinct request rejected…
    let err = server.try_submit(req(spec, 10, 1, 3)).unwrap_err().to_string();
    assert!(err.contains("queue full"), "{err}");
    // …but an identical one still coalesces (consumes no capacity)
    let t2b = server.try_submit(req(spec, 10, 1, 2)).unwrap();
    assert_eq!(server.queue_len(), 2);
    server.drain();
    // capacity is back
    let t3 = server.try_submit(req(spec, 10, 1, 3)).unwrap();
    server.drain();
    for t in [t1, t2, t2b, t3] {
        assert_eq!(t.wait().unwrap().report.max_err, Some(0.0));
    }
    let m = server.metrics_json();
    let svc = m.get("service").unwrap();
    assert_eq!(svc.get("rejected").unwrap().as_usize(), Some(1));
    assert_eq!(svc.get("coalesced").unwrap().as_usize(), Some(1));
    // 3 distinct computations served 4 submissions
    assert_eq!(svc.get("completed").unwrap().as_usize(), Some(4));
}

#[test]
fn dispatcher_serves_concurrent_clients() {
    let server = Arc::new(StencilServer::new(ServeConfig {
        workers: 2,
        shards: 2,
        queue_depth: 16,
        plan_cache: 8, ..ServeConfig::default() }));
    server.start();
    let spec = StencilSpec::box2d(1);
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let server = Arc::clone(&server);
        clients.push(std::thread::spawn(move || {
            for i in 0..4u64 {
                // seeds overlap across clients → some submissions coalesce
                let t = server.submit(req(spec, 12, 2, (c + i) % 5)).unwrap();
                let resp = t.wait().unwrap();
                assert_eq!(resp.report.max_err, Some(0.0));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    server.shutdown();
    let m = server.metrics_json();
    let svc = m.get("service").unwrap();
    assert_eq!(svc.get("completed").unwrap().as_usize(), Some(12));
    assert_eq!(svc.get("failed").unwrap().as_usize(), Some(0));
}

#[test]
fn metrics_snapshot_is_valid_json_with_cache_stats() {
    let server = StencilServer::new(ServeConfig {
        workers: 2,
        shards: 3,
        queue_depth: 8,
        plan_cache: 8, ..ServeConfig::default() });
    let spec = StencilSpec::box2d(1);
    // same (spec, size): plans compile once, then hit
    for seed in 0..3u64 {
        let t = server.submit(req(spec, 16, 2, seed)).unwrap();
        server.drain();
        t.wait().unwrap();
    }
    let text = server.metrics_json().to_string_compact();
    let m = Json::parse(&text).unwrap();
    let cache = m.get("plan_cache").unwrap();
    let misses = cache.get("misses").unwrap().as_usize().unwrap();
    let hits = cache.get("hits").unwrap().as_usize().unwrap();
    assert!(misses >= 1);
    assert!(hits > 0, "repeat requests should hit the plan cache");
    let svc = m.get("service").unwrap();
    assert_eq!(svc.get("completed").unwrap().as_usize(), Some(3));
    assert!(
        svc.get("service_time").unwrap().get("p95_s").unwrap().as_f64().is_some()
    );
    let cfgj = m.get("config").unwrap();
    assert_eq!(cfgj.get("workers").unwrap().as_usize(), Some(2));
    assert_eq!(cfgj.get("shards").unwrap().as_usize(), Some(3));
}

#[test]
fn distinct_methods_are_distinct_cache_plans() {
    let server = StencilServer::new(ServeConfig {
        workers: 2,
        shards: 2,
        queue_depth: 8,
        plan_cache: 8, ..ServeConfig::default() });
    let spec = StencilSpec::box2d(1);
    let mut a = req(spec, 14, 1, 3);
    let mut b = req(spec, 14, 1, 3);
    a.method = KernelMethod::Taps;
    b.method = KernelMethod::Oracle;
    // different method → NOT coalesced
    let ta = server.submit(a).unwrap();
    let tb = server.submit(b).unwrap();
    assert_eq!(server.queue_len(), 2);
    server.drain();
    let ra = ta.wait().unwrap();
    let rb = tb.wait().unwrap();
    // …but bitwise-identical results
    assert_eq!(ra.grid, rb.grid);
    assert_eq!(ra.report.waiters, 1);
    assert_eq!(rb.report.waiters, 1);
}

#[test]
fn outer_kernel_request_serves_the_kir_host_program() {
    let server = StencilServer::new(ServeConfig {
        workers: 2,
        shards: 3,
        queue_depth: 8,
        plan_cache: 8, ..ServeConfig::default() });
    let spec = StencilSpec::star2d(2);
    let ticket = server.submit(outer_req(spec, 20, 2, 9)).unwrap();
    server.drain();
    let resp = ticket.wait().unwrap();
    // the server verified within the host-kernel bar (1e-9, not bitwise)
    let err = resp.report.max_err.expect("verification ran");
    assert!(err < 1e-9, "max_err {err:e}");
    // independent re-derivation out here
    let input = DenseGrid::verification_input(&[24, 24], 9);
    let want = reference::evolve(&CoeffTensor::paper_default(spec), &input, 2);
    assert!(resp.grid.max_abs_diff_interior(&want, 0) < 1e-9);
    assert_eq!(resp.grid.shape, want.shape);
}

#[test]
fn fused_requests_serve_bitwise_results_with_fewer_exchanges() {
    // two identically configured servers, one temporally blocked at T=4:
    // same grids bit for bit, but the fused one exchanges halos only
    // every T steps — observable per request and in the metrics JSON
    let spec = StencilSpec::star2d(2);
    let base = ServeConfig {
        workers: 2,
        shards: 2,
        queue_depth: 8,
        plan_cache: 16,
        ..ServeConfig::default()
    };
    let plain = StencilServer::new(base.clone());
    let fused = StencilServer::new(ServeConfig { fuse_steps: 4, ..base });
    for (method, bitwise) in [(KernelMethod::Taps, true), (KernelMethod::Outer, false)] {
        let mut r = req(spec, 24, 8, 11);
        r.method = method;
        let tp = plain.submit(r.clone()).unwrap();
        plain.drain();
        let tf = fused.submit(r).unwrap();
        fused.drain();
        let rp = tp.wait().unwrap();
        let rf = tf.wait().unwrap();
        assert_eq!(rp.grid, rf.grid, "{method}: fused serving diverged bitwise");
        if bitwise {
            assert_eq!(rf.report.max_err, Some(0.0));
        } else {
            assert!(rf.report.max_err.unwrap() < 1e-9);
        }
        assert_eq!(rp.report.fused_steps, 1);
        assert_eq!(rp.report.halo_exchanges, 7);
        assert!(rf.report.fused_steps > 1);
        assert_eq!(
            rf.report.halo_exchanges,
            8usize.div_ceil(rf.report.fused_steps) - 1,
            "{method}"
        );
    }
    let m = Json::parse(&fused.metrics_json().to_string_compact()).unwrap();
    let svc = m.get("service").unwrap();
    let he = svc.get("halo_exchanges").unwrap();
    assert_eq!(he.get("count").unwrap().as_usize(), Some(2));
    assert!(he.get("p99").unwrap().as_f64().unwrap() <= 3.0);
    let fs = svc.get("fused_steps").unwrap();
    assert!(fs.get("p50").unwrap().as_f64().unwrap() > 1.0);
}

#[test]
fn kernel_wall_clock_is_recorded_with_percentiles() {
    let server = StencilServer::new(ServeConfig {
        workers: 2,
        shards: 2,
        queue_depth: 8,
        plan_cache: 8, ..ServeConfig::default() });
    let spec = StencilSpec::box2d(1);
    for seed in 0..3u64 {
        let t = server.submit(outer_req(spec, 16, 2, seed)).unwrap();
        server.drain();
        let resp = t.wait().unwrap();
        // kernel time is a sub-interval of service time
        assert!(resp.report.kernel_seconds >= 0.0);
        assert!(resp.report.kernel_seconds <= resp.report.service_seconds + 1e-6);
    }
    let m = Json::parse(&server.metrics_json().to_string_compact()).unwrap();
    let kt = m.get("service").unwrap().get("kernel_time").unwrap();
    assert_eq!(kt.get("count").unwrap().as_usize(), Some(3));
    let p50 = kt.get("p50_s").unwrap().as_f64().unwrap();
    let p99 = kt.get("p99_s").unwrap().as_f64().unwrap();
    assert!(p50 >= 0.0 && p99 >= p50, "p50={p50} p99={p99}");
}
