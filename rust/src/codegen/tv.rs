//! Temporal-vectorization baseline (Yuan et al., SC'21; the paper's
//! comparison method [57]), modeled as overlapped temporal blocking.
//!
//! TV's defining property — the one the paper's §5.2 discussion leans on —
//! is a large reduction in memory volume (up to ¼) by advancing several
//! time steps over a cache-resident strip before moving on, at the price
//! of redundant halo computation and a larger in-flight working set that
//! hurts 3D. We reproduce exactly that profile:
//!
//! - `TB = 4` time steps are advanced per spatial strip (hence the ¼
//!   memory volume on streaming sizes);
//! - strips are tiled along the unit-stride dimension; two scratch grids
//!   ping-pong the intermediate steps, staying cache-resident;
//! - each strip's compute region shrinks by `r` per remaining step
//!   (overlapped / ghost-zone tiling), so adjacent strips recompute the
//!   overlap — the redundant work visible at small strip widths;
//! - per-step compute uses the same gather-mode vector kernel as the
//!   auto-vectorization baseline.
//!
//! For 3D grids the strip working set is `N² × width` and no longer fits
//! L1/L2, which is why TV shows limited or negative speedups on 3D
//! stencils — in the paper (§5.2, Table 3) and in this model.
//!
//! The harness must compare the result against `TB` reference steps and
//! normalize cycles by `TB`.

use super::common::{CoeffTable, Layout};
use crate::stencil::CoeffTensor;
use crate::kir::{Arena, KirSink, Op, VReg};
use crate::sim::SimConfig;

/// Time steps advanced per strip.
pub const TIME_BLOCK: usize = 4;
/// Strip width in vector blocks (3D).
const STRIP_VECS_3D: usize = 2;

const V_ACC0: u8 = 0;
const V_LOAD: u8 = 4;
const V_CSPILL: u8 = 5;
const V_COEFF0: u8 = 6;
const JAM: usize = 4;

/// Rows per 2D strip (tiled along `i`, full row width).
const STRIP_ROWS_2D: usize = 32;

/// A cache-resident strip buffer: `rows` domain rows × the full domain
/// width, with an `r` halo on all sides. Reused by every strip, so after
/// the first strip it lives permanently in L2 — the residency that gives
/// TV its memory-volume reduction.
pub struct StripBuf {
    base: usize,
    stride: usize,
    /// Domain rows the buffer can hold.
    pub rows: usize,
    r: usize,
    n: usize,
}

impl StripBuf {
    fn alloc(machine: &mut impl Arena, rows: usize, n: usize, r: usize, vlen: usize) -> StripBuf {
        let stride = (n + 2 * r).div_ceil(vlen) * vlen + vlen;
        let raw = machine.alloc((rows + 2 * r) * stride + vlen);
        let base = raw + (vlen - (raw + r) % vlen) % vlen;
        StripBuf { base, stride, rows, r, n }
    }

    /// Address of buffer-domain row `x` (may be in the ±r halo), column
    /// `j` (domain, may be in the ±r halo).
    fn addr(&self, x: isize, j: isize) -> usize {
        let r = self.r as isize;
        debug_assert!(x >= -r && x < (self.rows + self.r) as isize);
        debug_assert!(j >= -r - 8 && j < (self.n + self.r) as isize + 8);
        (self.base as isize + (x + r) * self.stride as isize + j) as usize
    }
}

/// TV's scratch state (built once; reused across measured runs).
pub struct Scratch {
    /// 2D: two strip buffers (ping-pong).
    bufs: Option<[StripBuf; 2]>,
    /// 3D fallback: two full scratch grids.
    grids: Option<[Layout; 2]>,
    /// Max halo growth across the time block: `(TB-1) * r`.
    margin: usize,
}

/// Allocate the scratch state. 2D uses two reusable strip buffers (the
/// real TV structure); 3D keeps full scratch grids — the working set that
/// is exactly why TV does not pay off for 3D stencils (§5.2).
pub fn setup(machine: &mut impl Arena, layout: &Layout) -> Scratch {
    let r = layout.spec.order;
    let margin = (TIME_BLOCK - 1) * r;
    if layout.spec.dims == 2 {
        let rows = STRIP_ROWS_2D + 2 * margin;
        let vlen = machine.vlen();
        let b0 = StripBuf::alloc(machine, rows, layout.n, r, vlen);
        let b1 = StripBuf::alloc(machine, rows, layout.n, r, vlen);
        Scratch { bufs: Some([b0, b1]), grids: None, margin }
    } else {
        let a_grid = layout.read_a(machine);
        let s0 = Layout::alloc(machine, layout.spec, &a_grid);
        let s1 = Layout::alloc(machine, layout.spec, &a_grid);
        Scratch { bufs: None, grids: Some([s0, s1]), margin }
    }
}

/// Generate the TV program into `sink`. The program must be *executed*
/// in emission order (intermediate values flow through the scratch
/// grids), which every backend does — the simulator and the host
/// machine both execute on emit or replay the captured stream in order.
///
/// After execution, `B` holds the grid after [`TIME_BLOCK`] steps.
pub fn generate(
    cfg: &SimConfig,
    layout: &Layout,
    scratch: &Scratch,
    coeffs: &CoeffTensor,
    table: &CoeffTable,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let vlen = cfg.vlen;
    anyhow::ensure!(layout.n % vlen == 0, "domain must be a multiple of the vector length");
    let taps: Vec<(Vec<isize>, usize)> = layout
        .spec
        .dense_offsets()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| coeffs.data[*i] != 0.0)
        .map(|(i, off)| (off, i))
        .collect();
    let resident = taps.len() <= (cfg.n_vregs - V_COEFF0 as usize);
    if resident {
        for (slot, (_, di)) in taps.iter().enumerate() {
            sink.emit(Op::Splat {
                dst: VReg(V_COEFF0 + slot as u8),
                addr: table.splat_addr(*di),
            });
        }
    }
    if layout.spec.dims == 2 {
        gen2d_strips(cfg, sink, layout, scratch, &taps, table, resident)
    } else {
        gen3d_grids(cfg, sink, layout, scratch, &taps, table, resident)
    }
}

/// 2D: strips along `i`, full row width, ping-ponging through the two
/// cache-resident strip buffers. A is read once and B written once per
/// TIME_BLOCK steps — the ÷4 memory volume.
#[allow(clippy::too_many_arguments)]
fn gen2d_strips<S: KirSink>(
    cfg: &SimConfig,
    sink: &mut S,
    layout: &Layout,
    scratch: &Scratch,
    taps: &[(Vec<isize>, usize)],
    table: &CoeffTable,
    resident: bool,
) -> anyhow::Result<()> {
    let bufs = scratch.bufs.as_ref().expect("2D scratch");
    let n = layout.n as isize;
    let r = layout.spec.order as isize;
    let m = scratch.margin as isize;
    let vlen = cfg.vlen as isize;
    let mut i0 = 0isize;
    while i0 < n {
        let ih = (STRIP_ROWS_2D as isize).min(n - i0);
        // prefill frozen values in both buffers (instructions, charged):
        // rows mapping outside the domain get the full frozen row; domain
        // rows get the 2r frozen halo columns.
        for buf in bufs.iter() {
            let rows = buf.rows as isize;
            for x in -r..rows + r {
                let g = i0 - m + x;
                if !(-r..n + r).contains(&g) {
                    continue; // never read
                }
                if g < 0 || g >= n {
                    // frozen full row, vector copies
                    let mut c = -vlen; // cover the left halo block too
                    while c < n + r {
                        sink.emit(Op::Load {
                            dst: VReg(V_LOAD),
                            addr: layout.a_addr(&[g, c]),
                        });
                        sink.emit(Op::Store { src: VReg(V_LOAD), addr: buf.addr(x, c) });
                        c += vlen;
                    }
                } else {
                    for c in 1..=r {
                        sink.emit(Op::Splat {
                            dst: VReg(V_LOAD),
                            addr: layout.a_addr(&[g, -c]),
                        });
                        sink.emit(Op::StoreLane { src: VReg(V_LOAD), lane: 0, addr: buf.addr(x, -c) });
                        sink.emit(Op::Splat {
                            dst: VReg(V_LOAD),
                            addr: layout.a_addr(&[g, n - 1 + c]),
                        });
                        sink.emit(Op::StoreLane {
                            src: VReg(V_LOAD),
                            lane: 0,
                            addr: buf.addr(x, n - 1 + c),
                        });
                    }
                }
            }
        }
        // backward-derived row regions (no vector rounding needed in i)
        let mut regions = [(0isize, 0isize); TIME_BLOCK];
        regions[TIME_BLOCK - 1] = (i0, i0 + ih);
        for s in (0..TIME_BLOCK - 1).rev() {
            let (nlo, nhi) = regions[s + 1];
            regions[s] = ((nlo - r).max(0), (nhi + r).min(n));
        }
        for (s, &(lo, hi)) in regions.iter().enumerate() {
            let src_buf = if s == 0 { None } else { Some(&bufs[(s - 1) % 2]) };
            let dst_buf = if s == TIME_BLOCK - 1 { None } else { Some(&bufs[s % 2]) };
            for g in lo..hi {
                let mut c0 = 0isize;
                while c0 < n {
                    let jam = JAM.min(((n - c0) / vlen) as usize).max(1);
                    for u in 0..jam {
                        sink.emit(Op::Zero { dst: VReg(V_ACC0 + u as u8) });
                    }
                    for (slot, (off, di)) in taps.iter().enumerate() {
                        let coeff = if resident {
                            VReg(V_COEFF0 + slot as u8)
                        } else {
                            sink.emit(Op::Splat {
                                dst: VReg(V_CSPILL),
                                addr: table.splat_addr(*di),
                            });
                            VReg(V_CSPILL)
                        };
                        for u in 0..jam {
                            let gi = g + off[0];
                            let gc = c0 + (u as isize) * vlen + off[1];
                            let addr = match src_buf {
                                None => layout.a_addr(&[gi, gc]),
                                Some(b) => b.addr(gi - (i0 - m), gc),
                            };
                            sink.emit(Op::Load { dst: VReg(V_LOAD), addr });
                            sink.emit(Op::Fma {
                                acc: VReg(V_ACC0 + u as u8),
                                a: VReg(V_LOAD),
                                b: coeff,
                            });
                        }
                    }
                    for u in 0..jam {
                        let gc = c0 + (u as isize) * vlen;
                        let addr = match dst_buf {
                            None => layout.b_addr(&[g, gc]),
                            Some(b) => b.addr(g - (i0 - m), gc),
                        };
                        sink.emit(Op::Store { src: VReg(V_ACC0 + u as u8), addr });
                    }
                    c0 += (jam as isize) * vlen;
                }
            }
        }
        i0 += ih;
    }
    Ok(())
}

/// 3D: overlapped temporal blocking over unit-stride slabs with full
/// scratch grids — the oversized working set that makes TV unprofitable
/// in 3D (§5.2).
#[allow(clippy::too_many_arguments)]
fn gen3d_grids<S: KirSink>(
    cfg: &SimConfig,
    sink: &mut S,
    layout: &Layout,
    scratch: &Scratch,
    taps: &[(Vec<isize>, usize)],
    table: &CoeffTable,
    resident: bool,
) -> anyhow::Result<()> {
    let grids = scratch.grids.as_ref().expect("3D scratch");
    let (s0, s1) = (&grids[0], &grids[1]);
    let vlen = cfg.vlen;
    let n = layout.n as isize;
    let r = layout.spec.order as isize;
    let strip = (STRIP_VECS_3D * vlen) as isize;
    let vl = vlen as isize;
    let mut c0 = 0isize;
    while c0 < n {
        let cw = strip.min(n - c0);
        // derive each step's compute region backward from the strip so
        // every read of step s+1 lands inside step s's region (or the
        // frozen halo): reg[s] = round_out(reg[s+1] grown by r), clamped.
        let mut regions = [(0isize, 0isize); TIME_BLOCK];
        regions[TIME_BLOCK - 1] = (c0, c0 + cw);
        for s in (0..TIME_BLOCK - 1).rev() {
            let (nlo, nhi) = regions[s + 1];
            let lo = ((nlo - r).div_euclid(vl) * vl).max(0);
            let hi = (nhi + r + vl - 1).div_euclid(vl) * vl;
            regions[s] = (lo, hi.min(n));
        }
        for (s, &(lo, hi)) in regions.iter().enumerate() {
            let src: &Layout = match s {
                0 => layout,
                _ if s % 2 == 1 => s0,
                _ => s1,
            };
            let dst: &Layout = if s == TIME_BLOCK - 1 {
                layout
            } else if s % 2 == 0 {
                s0
            } else {
                s1
            };
            // dst for the final step is B of `layout`; intermediate steps
            // use the A side of the scratch layouts.
            step(
                cfg,
                sink,
                layout,
                src,
                dst,
                s == TIME_BLOCK - 1,
                taps,
                table,
                resident,
                lo,
                hi,
            );
        }
        c0 += cw;
    }
    Ok(())
}

/// One gather-mode vector time-step over unit-stride range `[lo, hi)`.
#[allow(clippy::too_many_arguments)]
fn step<S: KirSink>(
    cfg: &SimConfig,
    sink: &mut S,
    layout: &Layout,
    src: &Layout,
    dst: &Layout,
    dst_is_b: bool,
    taps: &[(Vec<isize>, usize)],
    table: &CoeffTable,
    resident: bool,
    lo: isize,
    hi: isize,
) {
    let vlen = cfg.vlen as isize;
    let n = layout.n as isize;
    let dims = layout.spec.dims;
    // sources always read the A side (scratch grids live in their layout's
    // A array); only the final step writes the real B.
    let src_addr = |idx: &[isize]| src.a_addr(idx);
    let dst_addr = |idx: &[isize]| if dst_is_b { dst.b_addr(idx) } else { dst.a_addr(idx) };
    let outer_loop = |sink: &mut S, outer: &[isize]| {
        let mut c = lo;
        while c < hi {
            let jam = JAM.min(((hi - c) / vlen) as usize).max(1);
            for u in 0..jam {
                sink.emit(Op::Zero { dst: VReg(V_ACC0 + u as u8) });
            }
            for (slot, (off, di)) in taps.iter().enumerate() {
                let coeff = if resident {
                    VReg(V_COEFF0 + slot as u8)
                } else {
                    sink.emit(Op::Splat { dst: VReg(V_CSPILL), addr: table.splat_addr(*di) });
                    VReg(V_CSPILL)
                };
                for u in 0..jam {
                    let mut idx: Vec<isize> =
                        outer.iter().enumerate().map(|(d, &o)| o + off[d]).collect();
                    idx.push(c + (u as isize) * vlen + off[dims - 1]);
                    sink.emit(Op::Load { dst: VReg(V_LOAD), addr: src_addr(&idx) });
                    sink.emit(Op::Fma {
                        acc: VReg(V_ACC0 + u as u8),
                        a: VReg(V_LOAD),
                        b: coeff,
                    });
                }
            }
            for u in 0..jam {
                let mut idx: Vec<isize> = outer.to_vec();
                idx.push(c + (u as isize) * vlen);
                sink.emit(Op::Store { src: VReg(V_ACC0 + u as u8), addr: dst_addr(&idx) });
            }
            c += (jam as isize) * vlen;
        }
    };
    if dims == 2 {
        for i in 0..n {
            outer_loop(sink, &[i]);
        }
    } else {
        for i in 0..n {
            for j in 0..n {
                outer_loop(sink, &[i, j]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, DenseGrid, StencilSpec};
    use crate::sim::Machine;

    #[test]
    fn tv_computes_four_steps() {
        let cfg = SimConfig::default();
        let mut m = Machine::new(cfg.clone());
        let spec = StencilSpec::star2d(1);
        let coeffs = CoeffTensor::paper_default(spec);
        let g = DenseGrid::verification_input(&[34, 34], 3); // N = 32
        let layout = Layout::alloc(&mut m, spec, &g);
        let table = CoeffTable::install_splats(&mut m, &coeffs);
        let scratch = setup(&mut m, &layout);
        generate(&cfg, &layout, &scratch, &coeffs, &table, &mut m).unwrap();
        let got = layout.read_b(&m);
        let want = reference::evolve(&coeffs, &g, TIME_BLOCK);
        let err = got.max_abs_diff_interior(&want, 1);
        assert!(err < 1e-12, "err={err}");
    }
}
