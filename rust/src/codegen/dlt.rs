//! DLT baseline — Data Layout Transformation for short-vector SIMD
//! (Henretty et al., CC'11; the paper's comparison method [20]).
//!
//! DLT dimension-lifts the unit-stride dimension: a row of `N` elements is
//! viewed as `vlen` strips of length `W = N / vlen` and transposed so lane
//! `l` of vector block `m` holds element `l*W + m`. A tap shifted by `dj`
//! along the unit-stride dimension then needs vector block `m + dj` —
//! an **aligned** load — eliminating the data-alignment conflict entirely
//! (no unaligned loads, no inter-register shuffles in steady state).
//!
//! We give DLT its best case: strip-private halos (each strip padded by
//! `r` blocks on both sides, the standard implementation trick), so even
//! strip-boundary blocks are plain aligned loads. The costs that remain —
//! and that the paper's method beats — are the unreduced FLOP count (one
//! FMA per tap per output vector) and the layout's larger footprint.
//! The one-time transform cost is not charged (steady-state comparison,
//! as in [20]); the harness performs the transforms host-side.

use super::common::{CoeffTable, Layout};
use crate::stencil::{CoeffTensor, DenseGrid};
use crate::kir::{Arena, KirSink, Op, VReg};
use crate::sim::SimConfig;

const JAM: usize = 4;
const V_ACC0: u8 = 0;
const V_LOAD: u8 = 4;
const V_CSPILL: u8 = 5;
const V_COEFF0: u8 = 6;

/// The DLT-transformed pair of arrays in simulator memory.
#[derive(Debug, Clone)]
pub struct DltLayout {
    /// Strips per row: `W = N / vlen`.
    pub w: usize,
    /// Blocks per transformed row including strip halos: `W + 2r`.
    pub blocks: usize,
    /// Base of transformed `A`.
    pub a_base: usize,
    /// Base of transformed `B`.
    pub b_base: usize,
    vlen: usize,
    n: usize,
    r: usize,
    dims: usize,
}

impl DltLayout {
    /// Build the transformed arrays from the (already allocated) standard
    /// layout's input grid. Host-side transform — not simulated.
    pub fn build(machine: &mut impl Arena, layout: &Layout, grid: &DenseGrid) -> DltLayout {
        let vlen = machine.vlen();
        let n = layout.n;
        let r = layout.spec.order;
        let dims = layout.spec.dims;
        assert_eq!(n % vlen, 0, "DLT needs vlen | N");
        let w = n / vlen;
        let blocks = w + 2 * r;
        // transformed rows: one per (i) in 2D (incl. halo rows), per (i,j)
        // in 3D
        let rows_i = n + 2 * r;
        let rows = if dims == 2 { rows_i } else { rows_i * rows_i };
        let row_elems = blocks * vlen;
        let a_base = machine.alloc(rows * row_elems);
        let b_base = machine.alloc(rows * row_elems);
        let mut dlt = DltLayout { w, blocks, a_base, b_base, vlen, n, r, dims };
        // fill transformed A from the storage-shape grid
        let ext = n + 2 * r;
        let g = |idx: &[usize]| grid.data[idx.iter().fold(0, |acc, &x| acc * ext + x)];
        let mut buf = vec![0.0; row_elems];
        for row in 0..rows {
            for m in 0..blocks {
                for l in 0..vlen {
                    // unit-stride coordinate of this slot (storage coords)
                    let jc = l * w + m; // m already includes the +r halo shift
                    // jc in 0..(w*vlen + 2r) = storage col directly when we
                    // treat block index m as storage-halo-based:
                    let val = if dims == 2 {
                        g(&[row, jc])
                    } else {
                        g(&[row / ext_row(ext), row % ext_row(ext), jc])
                    };
                    buf[m * vlen + l] = val;
                }
            }
            machine.write_mem(a_base + row * row_elems, &buf);
            machine.write_mem(b_base + row * row_elems, &buf);
        }
        dlt.n = n;
        dlt
    }

    /// Address of transformed-A block `m` (domain block coords,
    /// `-r <= m < w + r`) at outer coordinates `outer` (domain, may be in
    /// halo).
    pub fn a_block(&self, outer: &[isize], m: isize) -> usize {
        self.block_addr(self.a_base, outer, m)
    }

    /// Address of transformed-B block `m` (`0 <= m < w`).
    pub fn b_block(&self, outer: &[isize], m: isize) -> usize {
        self.block_addr(self.b_base, outer, m)
    }

    fn block_addr(&self, base: usize, outer: &[isize], m: isize) -> usize {
        let r = self.r as isize;
        debug_assert!(m >= -r && m < (self.w + self.r) as isize);
        let ext = self.n + 2 * self.r;
        let mut row = (outer[0] + r) as usize;
        if self.dims == 3 {
            row = row * ext + (outer[1] + r) as usize;
        }
        base + (row * self.blocks + (m + r) as usize) * self.vlen
    }

    /// Inverse transform: read transformed `B` back into a storage-shape
    /// grid (boundary slots taken from `boundary`).
    pub fn read_b(&self, machine: &impl Arena, boundary: &DenseGrid) -> DenseGrid {
        let ext = self.n + 2 * self.r;
        let mut out = boundary.clone();
        let rows_i = self.n + 2 * self.r;
        let rows = if self.dims == 2 { rows_i } else { rows_i * rows_i };
        let row_elems = self.blocks * self.vlen;
        for row in 0..rows {
            let data = machine.read_mem(self.b_base + row * row_elems, row_elems);
            // only interior rows and interior strips are outputs
            for m in self.r..self.w + self.r {
                for l in 0..self.vlen {
                    let jc = l * self.w + m; // storage col
                    let interior_j = jc >= self.r && jc < self.r + self.n;
                    if !interior_j {
                        continue;
                    }
                    let (i, j3): (usize, Option<usize>) = if self.dims == 2 {
                        (row, None)
                    } else {
                        (row / ext, Some(row % ext))
                    };
                    let interior_outer = if self.dims == 2 {
                        i >= self.r && i < self.r + self.n
                    } else {
                        let j = j3.unwrap();
                        i >= self.r && i < self.r + self.n && j >= self.r && j < self.r + self.n
                    };
                    if !interior_outer {
                        continue;
                    }
                    let lin = if self.dims == 2 {
                        i * ext + jc
                    } else {
                        (i * ext + j3.unwrap()) * ext + jc
                    };
                    out.data[lin] = data[m * self.vlen + l];
                }
            }
        }
        out
    }
}

fn ext_row(ext: usize) -> usize {
    ext
}

/// Generate the DLT stencil program (operates on the transformed arrays).
pub fn generate(
    cfg: &SimConfig,
    layout: &Layout,
    dlt: &DltLayout,
    coeffs: &CoeffTensor,
    table: &CoeffTable,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let taps: Vec<(Vec<isize>, usize)> = layout
        .spec
        .dense_offsets()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| coeffs.data[*i] != 0.0)
        .map(|(i, off)| (off, i))
        .collect();
    let resident = taps.len() <= (cfg.n_vregs - V_COEFF0 as usize);
    if resident {
        for (slot, (_, di)) in taps.iter().enumerate() {
            sink.emit(Op::Splat {
                dst: VReg(V_COEFF0 + slot as u8),
                addr: table.splat_addr(*di),
            });
        }
    }
    let big_n = layout.n as isize;
    // iterate interior output rows; block index m runs over the strips.
    // Note: inside a strip, a tap's dj becomes a block shift of dj (since
    // strips are contiguous runs of the original row, neighbouring
    // elements are in the same lane of the neighbouring block).
    let w = dlt.w as isize;
    match layout.spec.dims {
        2 => {
            for i in 0..big_n {
                emit_row(&taps, table, resident, dlt, &[i], w, sink);
            }
        }
        3 => {
            for i in 0..big_n {
                for j in 0..big_n {
                    emit_row(&taps, table, resident, dlt, &[i, j], w, sink);
                }
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

fn emit_row(
    taps: &[(Vec<isize>, usize)],
    table: &CoeffTable,
    resident: bool,
    dlt: &DltLayout,
    outer: &[isize],
    w: isize,
    sink: &mut impl KirSink,
) {
    let dims = outer.len() + 1;
    let mut m0 = 0isize;
    while m0 < w {
        let jam = JAM.min((w - m0) as usize);
        for u in 0..jam {
            sink.emit(Op::Zero { dst: VReg(V_ACC0 + u as u8) });
        }
        for (slot, (off, di)) in taps.iter().enumerate() {
            let coeff = if resident {
                VReg(V_COEFF0 + slot as u8)
            } else {
                sink.emit(Op::Splat { dst: VReg(V_CSPILL), addr: table.splat_addr(*di) });
                VReg(V_CSPILL)
            };
            for u in 0..jam {
                let souter: Vec<isize> =
                    outer.iter().enumerate().map(|(d, &o)| o + off[d]).collect();
                let m = m0 + u as isize + off[dims - 1];
                sink.emit(Op::Load { dst: VReg(V_LOAD), addr: dlt.a_block(&souter, m) });
                sink.emit(Op::Fma { acc: VReg(V_ACC0 + u as u8), a: VReg(V_LOAD), b: coeff });
            }
        }
        for u in 0..jam {
            sink.emit(Op::Store {
                src: VReg(V_ACC0 + u as u8),
                addr: dlt.b_block(outer, m0 + u as isize),
            });
        }
        m0 += jam as isize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Machine;
    use crate::stencil::StencilSpec;

    #[test]
    fn transform_roundtrip() {
        // A DLT build followed by read_b (B was initialized = A) must
        // reproduce the interior of the original grid.
        let cfg = SimConfig::default();
        let mut m = Machine::new(cfg);
        let spec = StencilSpec::box2d(1);
        let g = DenseGrid::verification_input(&[18, 18], 5); // N = 16
        let layout = Layout::alloc(&mut m, spec, &g);
        let dlt = DltLayout::build(&mut m, &layout, &g);
        assert_eq!(dlt.w, 2);
        assert_eq!(dlt.blocks, 4);
        let back = dlt.read_b(&m, &g);
        assert_eq!(back.data, g.data);
    }

    #[test]
    fn block_addresses_are_aligned() {
        let cfg = SimConfig::default();
        let mut m = Machine::new(cfg);
        let spec = StencilSpec::star2d(2);
        let g = DenseGrid::verification_input(&[20, 20], 2); // N = 16
        let layout = Layout::alloc(&mut m, spec, &g);
        let dlt = DltLayout::build(&mut m, &layout, &g);
        for i in -2..18isize {
            for blk in -2..dlt.w as isize + 2 {
                assert_eq!(dlt.a_block(&[i], blk) % 8, 0);
            }
        }
    }
}
