//! Shared code-generation infrastructure: grid layouts in simulator
//! memory, coefficient tables, and generator parameters.

use crate::kir::Arena;
use crate::scatter::CoverOption;
use crate::stencil::{CoeffTensor, DenseGrid, StencilSpec};

/// Placement of the `A` and `B` grids in simulator memory.
///
/// Grids are stored with an `r`-deep halo on every side (storage extent
/// `N + 2r` per dimension); *domain* coordinates run `0..N` and map to
/// storage coordinates `+r`. All paper problem sizes are multiples of the
/// vector length, so the domain tiles exactly — no ragged edges.
///
/// Rows are padded to a multiple of the vector length and the base is
/// shifted so that **domain column 0 of every row is 64-byte aligned** —
/// the standard leading-dimension padding of real stencil codes, and what
/// lets the generators' block loads be genuinely aligned.
#[derive(Debug, Clone)]
pub struct Layout {
    /// The stencil (fixes the halo depth `r`).
    pub spec: StencilSpec,
    /// Domain extent `N` per dimension.
    pub n: usize,
    /// Logical storage extent `N + 2r` (without padding).
    pub ext: usize,
    /// Padded row stride in elements (multiple of the vector length).
    pub stride_row: usize,
    /// Base element address of `A` (shifted for alignment).
    pub a_base: usize,
    /// Base element address of `B`.
    pub b_base: usize,
    vlen: usize,
}

impl Layout {
    /// Allocate `A` and `B` (with halos) in machine memory and fill them:
    /// `A` from `grid` (storage shape `(N+2r)^d`), `B` as a copy of `A`
    /// (frozen boundary convention).
    pub fn alloc(machine: &mut impl Arena, spec: StencilSpec, grid: &DenseGrid) -> Layout {
        let vlen = machine.vlen();
        let r = spec.order;
        let n = grid.shape[0] - 2 * r;
        assert!(grid.shape.iter().all(|&s| s == n + 2 * r), "cubic grids only");
        let ext = n + 2 * r;
        let stride_row = ext.div_ceil(vlen) * vlen + vlen; // pad + slack for shift
        let rows: usize = if spec.dims == 2 { ext } else { ext * ext };
        let total = rows * stride_row + vlen;
        let raw_a = machine.alloc(total);
        let raw_b = machine.alloc(total);
        // shift so (base + r) % vlen == 0: domain col 0 lands 64B-aligned
        let shift = |raw: usize| raw + (vlen - (raw + r) % vlen) % vlen;
        let layout = Layout {
            spec,
            n,
            ext,
            stride_row,
            a_base: shift(raw_a),
            b_base: shift(raw_b),
            vlen,
        };
        layout.write_grid(machine, layout.a_base, grid);
        layout.write_grid(machine, layout.b_base, grid);
        layout
    }

    fn write_grid(&self, machine: &mut impl Arena, base: usize, grid: &DenseGrid) {
        let rows = if self.spec.dims == 2 { self.ext } else { self.ext * self.ext };
        for row in 0..rows {
            let src = &grid.data[row * self.ext..(row + 1) * self.ext];
            machine.write_mem(base + row * self.stride_row, src);
        }
    }

    fn read_grid(&self, machine: &impl Arena, base: usize) -> DenseGrid {
        let shape = vec![self.ext; self.spec.dims];
        let rows = if self.spec.dims == 2 { self.ext } else { self.ext * self.ext };
        let mut data = Vec::with_capacity(rows * self.ext);
        for row in 0..rows {
            data.extend_from_slice(machine.read_mem(base + row * self.stride_row, self.ext));
        }
        DenseGrid { shape, data }
    }

    /// Storage row stride in elements (distance between consecutive rows
    /// along the second-to-last dimension).
    pub fn row_stride(&self) -> usize {
        self.stride_row
    }

    /// Storage plane stride (3D).
    pub fn plane_stride(&self) -> usize {
        self.ext * self.stride_row
    }

    /// Element address of `A` at *domain* coordinates (components may lie
    /// in the halo, `-r .. n+r`).
    pub fn a_addr(&self, idx: &[isize]) -> usize {
        self.addr(self.a_base, idx)
    }

    /// Element address of `B` at domain coordinates.
    pub fn b_addr(&self, idx: &[isize]) -> usize {
        self.addr(self.b_base, idx)
    }

    fn addr(&self, base: usize, idx: &[isize]) -> usize {
        debug_assert_eq!(idx.len(), self.spec.dims);
        let r = self.spec.order as isize;
        let d = self.spec.dims;
        for &i in &idx[..d - 1] {
            debug_assert!(
                i >= -r && i < (self.n + self.spec.order) as isize,
                "domain index {i} out of halo range"
            );
        }
        // the unit-stride dimension may reach one vector beyond the halo:
        // EXT-based assembly loads a whole aligned block of which only the
        // in-halo lanes are consumed (the guard bands keep this mapped).
        let v = self.vlen as isize;
        debug_assert!(
            idx[d - 1] >= -r - v && idx[d - 1] < (self.n + self.spec.order) as isize + v,
            "unit-stride index {} out of guard range",
            idx[d - 1]
        );
        let mut lin = idx[d - 1] + r;
        lin += (idx[d - 2] + r) * self.stride_row as isize;
        if d == 3 {
            lin += (idx[0] + r) * self.plane_stride() as isize;
        }
        let a = base as isize + lin;
        debug_assert!(a >= 0, "address underflow");
        a as usize
    }

    /// Read `B` back from machine memory as a grid in storage shape
    /// (padding stripped).
    pub fn read_b(&self, machine: &impl Arena) -> DenseGrid {
        self.read_grid(machine, self.b_base)
    }

    /// Read `A` back from machine memory (TV ping-pongs A/B).
    pub fn read_a(&self, machine: &impl Arena) -> DenseGrid {
        self.read_grid(machine, self.a_base)
    }

    /// Swap the roles of A and B (time-step ping-pong).
    pub fn swap(&mut self) {
        std::mem::swap(&mut self.a_base, &mut self.b_base);
    }

    /// Rewrite both grids from a fresh input image (e.g. between
    /// measurement passes of a temporally blocked run, whose ping-pong
    /// steps overwrite the original `A` contents). Host-side work — on
    /// the simulator it is never charged to the measured run.
    pub fn reinit(&self, machine: &mut impl Arena, grid: &DenseGrid) {
        self.write_grid(machine, self.a_base, grid);
        self.write_grid(machine, self.b_base, grid);
    }
}

/// A coefficient table resident in simulator memory.
///
/// Two sections:
/// - `splat`: the raw non-zero weights packed densely (for `VFmaLane`
///   coefficient broadcasting in the vector baselines);
/// - `cv`: for the outer method, every shifted coefficient vector
///   `cv(line, p)` of Eq. (12), `n` elements each, so CV assembly is a
///   single (L1-resident) vector load.
#[derive(Debug, Clone)]
pub struct CoeffTable {
    /// Base address of the packed weights section.
    pub splat_base: usize,
    /// Base address of the coefficient-vector section.
    pub cv_base: usize,
    /// Vector length used for cv layout.
    pub vlen: usize,
    /// Number of `p` slots per line (`n + 2r`).
    pub p_slots: usize,
}

impl CoeffTable {
    /// Write the packed weights of `coeffs` (dense footprint order,
    /// including zeros so lane indices are predictable).
    pub fn install_splats(machine: &mut impl Arena, coeffs: &CoeffTensor) -> CoeffTable {
        let splat_base = machine.alloc(coeffs.data.len().max(1));
        machine.write_mem(splat_base, &coeffs.data);
        CoeffTable { splat_base, cv_base: 0, vlen: machine.vlen(), p_slots: 0 }
    }

    /// Write both sections, including cv vectors for every line of
    /// `cover`.
    pub fn install_full(
        machine: &mut impl Arena,
        coeffs: &CoeffTensor,
        cover: &crate::scatter::LineCover,
    ) -> CoeffTable {
        let vlen = machine.vlen();
        let r = coeffs.spec.order;
        let p_slots = vlen + 2 * r;
        let splat_base = machine.alloc(coeffs.data.len());
        machine.write_mem(splat_base, &coeffs.data);
        let cv_base = machine.alloc(cover.lines.len() * p_slots * vlen);
        for (li, line) in cover.lines.iter().enumerate() {
            for ps in 0..p_slots {
                let p = ps as isize - r as isize;
                let cv = line.coeff_vector(p, vlen);
                machine.write_mem(cv_base + (li * p_slots + ps) * vlen, &cv);
            }
        }
        CoeffTable { splat_base, cv_base, vlen, p_slots }
    }

    /// Address of the cv vector for line `li`, input position `p`
    /// (relative, `-r ..= vlen-1+r`).
    pub fn cv_addr(&self, li: usize, p: isize, r: usize) -> usize {
        let ps = (p + r as isize) as usize;
        debug_assert!(ps < self.p_slots);
        self.cv_base + (li * self.p_slots + ps) * self.vlen
    }

    /// Address of the packed weight with dense footprint index `di`.
    pub fn splat_addr(&self, di: usize) -> usize {
        self.splat_base + di
    }
}

/// Parameters of the paper's outer-product generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OuterParams {
    /// Which coefficient-line cover to use (§4.1).
    pub option: CoverOption,
    /// Unroll factor along the leading non-contiguous dimension
    /// (2D: unused; 3D: `ui` of §4.2).
    pub ui: usize,
    /// Unroll factor along the unit-stride dimension (2D: `uj`; 3D: `uk`).
    pub uk: usize,
    /// Outer-product scheduling (§4.3): share input vectors and
    /// coefficient vectors across the unrolled tiles. When off, each tile
    /// is generated independently (the naive scheme of §4.3).
    pub scheduled: bool,
}

impl OuterParams {
    /// The paper's default for a spec: parallel cover, `uj = 8` (2D box /
    /// star r=1) or orthogonal `uj = 4` (2D star r>=2); 3D: `i4k2`-style.
    pub fn paper_best(spec: StencilSpec) -> OuterParams {
        use crate::stencil::StencilKind;
        match (spec.dims, spec.kind, spec.order) {
            (2, StencilKind::Star, r) if r >= 2 => {
                OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 4, scheduled: true }
            }
            (2, _, _) => OuterParams { option: CoverOption::Parallel, ui: 1, uk: 8, scheduled: true },
            (3, StencilKind::Star, 1) => {
                OuterParams { option: CoverOption::Parallel, ui: 4, uk: 1, scheduled: true }
            }
            (3, StencilKind::Star, _) => {
                OuterParams { option: CoverOption::Orthogonal, ui: 4, uk: 1, scheduled: true }
            }
            _ => OuterParams { option: CoverOption::Parallel, ui: 4, uk: 2, scheduled: true },
        }
    }

    /// Table 3-style label, e.g. `p-j8`, `o-i4`, `h-k4`.
    pub fn label(&self, dims: usize) -> String {
        let opt = self.option.label();
        if dims == 2 {
            format!("{opt}-j{}", self.uk)
        } else if self.uk > 1 && self.ui > 1 {
            format!("{opt}-i{}k{}", self.ui, self.uk)
        } else if self.ui > 1 {
            format!("{opt}-i{}", self.ui)
        } else {
            format!("{opt}-k{}", self.uk)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, SimConfig};

    #[test]
    fn layout_addressing_2d() {
        let mut m = Machine::new(SimConfig::default());
        let spec = StencilSpec::box2d(1);
        let g = DenseGrid::verification_input(&[10, 10], 1); // N = 8
        let l = Layout::alloc(&mut m, spec, &g);
        assert_eq!(l.n, 8);
        assert_eq!(l.ext, 10);
        // domain (0,0) is storage (1,1)
        assert_eq!(l.a_addr(&[0, 0]), l.a_base + l.stride_row + 1);
        // halo corner (-1,-1) is storage (0,0)
        assert_eq!(l.a_addr(&[-1, -1]), l.a_base);
        // unit stride on the last dim
        assert_eq!(l.a_addr(&[3, 4]) + 1, l.a_addr(&[3, 5]));
        // domain column 0 is 64B-aligned on every row
        assert_eq!(l.a_addr(&[0, 0]) % 8, 0);
        assert_eq!(l.a_addr(&[5, 0]) % 8, 0);
        assert_eq!(l.b_addr(&[2, 0]) % 8, 0);
        // B initialized as a copy of A (padding stripped on read)
        assert_eq!(l.read_b(&m).data, g.data);
        assert_eq!(l.read_a(&m).data, g.data);
    }

    #[test]
    fn layout_addressing_3d() {
        let mut m = Machine::new(SimConfig::default());
        let spec = StencilSpec::star3d(2);
        let g = DenseGrid::verification_input(&[12, 12, 12], 2); // N = 8
        let l = Layout::alloc(&mut m, spec, &g);
        assert_eq!(l.n, 8);
        assert_eq!(l.plane_stride(), 12 * l.stride_row);
        assert_eq!(
            l.a_addr(&[0, 0, 0]),
            l.a_base + 2 * l.plane_stride() + 2 * l.stride_row + 2
        );
        assert_eq!(l.a_addr(&[1, 0, 0]) - l.a_addr(&[0, 0, 0]), l.plane_stride());
        assert_eq!(l.a_addr(&[0, 0, 0]) % 8, 0);
        assert_eq!(l.read_a(&m).data, g.data);
    }

    #[test]
    fn coeff_table_cv_roundtrip() {
        let mut m = Machine::new(SimConfig::default());
        let spec = StencilSpec::box2d(1);
        let coeffs = CoeffTensor::paper_default(spec);
        let cover = crate::scatter::build_cover(&coeffs, CoverOption::Parallel).unwrap();
        let t = CoeffTable::install_full(&mut m, &coeffs, &cover);
        for (li, line) in cover.lines.iter().enumerate() {
            for p in -1..=8isize {
                let addr = t.cv_addr(li, p, 1);
                let got = m.read_mem(addr, 8);
                assert_eq!(got, &line.coeff_vector(p, 8)[..], "line {li} p {p}");
            }
        }
    }

    #[test]
    fn paper_best_labels() {
        assert_eq!(OuterParams::paper_best(StencilSpec::box2d(1)).label(2), "p-j8");
        assert_eq!(OuterParams::paper_best(StencilSpec::star2d(2)).label(2), "o-j4");
        assert_eq!(OuterParams::paper_best(StencilSpec::box3d(1)).label(3), "p-i4k2");
        assert_eq!(OuterParams::paper_best(StencilSpec::star3d(2)).label(3), "o-i4");
    }

    #[test]
    fn swap_ping_pongs() {
        let mut m = Machine::new(SimConfig::default());
        let spec = StencilSpec::box2d(1);
        let g = DenseGrid::verification_input(&[10, 10], 3);
        let mut l = Layout::alloc(&mut m, spec, &g);
        let (a0, b0) = (l.a_base, l.b_base);
        l.swap();
        assert_eq!((l.a_base, l.b_base), (b0, a0));
    }
}
