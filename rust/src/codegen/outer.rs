//! The paper's stencil algorithm: scatter-mode vector outer products
//! (Eq. (12)) over a coefficient-line cover, with the §4 optimizations.
//!
//! Structure of the generated code (mirrors Algorithm 1):
//!
//! - The output is processed in `n×n` tiles held in matrix registers
//!   (`n` = vector length), grouped `ui × uk` by **multi-dimensional
//!   unrolling** (§4.2).
//! - For every input row position `p`, the needed aligned `A` vectors are
//!   loaded once and the shifted input vectors of each tile/line are
//!   assembled by inter-register `EXT` — the **data reorganization**
//!   solution to the alignment conflict (§4.3).
//! - With **outer-product scheduling** (§4.3) on, coefficient vectors are
//!   loaded once per `(line, p)` and reused across all unrolled tiles, and
//!   input vectors are scattered to every tile that needs them right after
//!   assembly. With it off, every tile is generated independently (the
//!   naive scheme), reloading coefficient and input vectors per tile.
//! - Lines running along a non-unit-stride dimension consume contiguous
//!   `A` row vectors; lines along the unit-stride dimension need
//!   strided column vectors, produced by the matrix-register transpose
//!   trick (§4.1) for in-tile columns and gather loads for halo columns.
//! - 3D covers whose lines run along `i` (the orthogonal option's
//!   `CLS(*,r,r)`) need a second pass with the other tile orientation
//!   (`B_{n×1×n}`), accumulating into `B` in memory — the extra output
//!   references Table 2 charges that option with.

use super::common::{CoeffTable, Layout, OuterParams};
use crate::scatter::line::{CoeffLine, LineCover};
use crate::kir::{KirSink, Marker, MReg, Op, VReg};
use crate::sim::SimConfig;

// ---- vector register plan (see module doc in codegen/mod.rs) ----
/// Aligned A blocks: v0..=v9 (block index t maps to v(t+1), t in -1..=8).
const V_BLOCK0: u8 = 0;
/// Assembled input vector.
const V_AV: u8 = 10;
/// Coefficient vector (reload slot).
const V_CV: u8 = 11;
/// Gather / transpose scratch.
const V_SCRATCH: u8 = 12;
/// Second scratch (diagonal path B row).
const V_SCRATCH2: u8 = 13;
/// First register of the resident CV bank (3D scheduled).
const V_CV_BANK: u8 = 14;
/// Size of the resident CV bank.
const CV_BANK: usize = 18;

/// Generate the outer-product stencil program into `sink`.
///
/// `B` must be pre-initialized with the boundary values (the harness
/// copies `A`); the generated code computes all `N^d` interior points.
pub fn generate(
    cfg: &SimConfig,
    layout: &Layout,
    cover: &LineCover,
    table: &CoeffTable,
    params: OuterParams,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let n = cfg.vlen;
    anyhow::ensure!(layout.n % n == 0, "domain must be a multiple of the vector length");
    anyhow::ensure!(layout.spec.order <= n, "order larger than vector length unsupported");
    match layout.spec.dims {
        2 => gen2d(cfg, layout, cover, table, params, sink),
        3 => gen3d(cfg, layout, cover, table, params, sink),
        _ => unreachable!(),
    }
}

/// Line classification by direction.
struct Classified<'a> {
    /// `(cover_index, line)` for lines along dimension 0 (2D `i`).
    dim0: Vec<(usize, &'a CoeffLine)>,
    /// Lines along dimension 1 (2D `j`, 3D `j`).
    dim1: Vec<(usize, &'a CoeffLine)>,
    /// Lines along dimension 2 (3D `k`).
    dim2: Vec<(usize, &'a CoeffLine)>,
    /// 2D diagonal lines `(idx, line, slope)`.
    diag: Vec<(usize, &'a CoeffLine, isize)>,
}

fn classify(cover: &LineCover) -> Classified<'_> {
    let mut c = Classified { dim0: vec![], dim1: vec![], dim2: vec![], diag: vec![] };
    for (i, l) in cover.lines.iter().enumerate() {
        let nz: Vec<usize> = (0..l.dir.len()).filter(|&d| l.dir[d] != 0).collect();
        if nz.len() == 2 {
            c.diag.push((i, l, l.dir[1]));
        } else {
            match nz[0] {
                0 => c.dim0.push((i, l)),
                1 => c.dim1.push((i, l)),
                _ => c.dim2.push((i, l)),
            }
        }
    }
    c
}

/// Emit the aligned-block load for block `t` (origin `col0 + t*n`).
fn block_reg(t: isize) -> VReg {
    VReg(V_BLOCK0 + (t + 1) as u8)
}

/// Assemble `A[row, col0 + t*n + off .. +n]` into a register, given that
/// aligned blocks `t-1 ..= t+1` are resident (per `block_reg`). Returns
/// the register holding the vector (a block register when `off == 0`).
fn assemble(n: usize, t: isize, off: isize, sink: &mut impl KirSink) -> VReg {
    if off == 0 {
        return block_reg(t);
    }
    let dst = VReg(V_AV);
    if off > 0 {
        sink.emit(Op::Ext { dst, lo: block_reg(t), hi: block_reg(t + 1), shift: off as usize });
    } else {
        sink.emit(Op::Ext {
            dst,
            lo: block_reg(t - 1),
            hi: block_reg(t),
            shift: (n as isize + off) as usize,
        });
    }
    dst
}

// ===================================================================
// 2D
// ===================================================================

fn gen2d(
    cfg: &SimConfig,
    layout: &Layout,
    cover: &LineCover,
    table: &CoeffTable,
    params: OuterParams,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let n = cfg.vlen;
    let big_n = layout.n;
    let _r = layout.spec.order as isize;
    let cls = classify(cover);
    // tiles per group along j; the transpose trick needs one spare tile
    let max_tiles = if cls.dim1.is_empty() { cfg.n_mregs } else { cfg.n_mregs - 1 };
    let uj = params.uk.clamp(1, max_tiles);
    let tiles_j = big_n / n;

    for i0 in (0..big_n as isize).step_by(n) {
        let mut tj = 0usize;
        while tj < tiles_j {
            let group = uj.min(tiles_j - tj);
            let j0 = (tj * n) as isize;
            let marker = Marker::TileGroup { i0, j0, k0: 0, ui: 1, uk: group };
            sink.emit(Op::Begin(marker));
            for t in 0..group {
                sink.emit(Op::TileZero { m: MReg(t as u8) });
            }
            if params.scheduled {
                gen2d_group_scheduled(cfg, layout, &cls, table, i0, j0, group, sink);
            } else {
                for t in 0..group {
                    gen2d_tile_naive(cfg, layout, &cls, table, i0, j0 + (t * n) as isize, t, sink);
                }
            }
            // diagonal lines (vector path, accumulates into the tiles)
            if !cls.diag.is_empty() {
                gen2d_diag(cfg, layout, &cls, table, i0, j0, group, sink);
            }
            // store the group
            for t in 0..group {
                for x in 0..n {
                    let addr = layout.b_addr(&[i0 + x as isize, j0 + (t * n) as isize]);
                    sink.emit(Op::RowStore { m: MReg(t as u8), row: x, addr });
                }
            }
            sink.emit(Op::End(marker));
            tj += group;
        }
    }
    Ok(())
}

/// Scheduled 2D group: input vectors and coefficient vectors shared
/// across the `group` tiles (§4.3).
fn gen2d_group_scheduled(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    i0: isize,
    j0: isize,
    group: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    if !cls.dim0.is_empty() {
        let need_left = cls.dim0.iter().any(|(_, l)| l.base[1] < 0);
        let need_right = cls.dim0.iter().any(|(_, l)| l.base[1] > 0);
        for p in -r..(n as isize + r) {
            let row = i0 + p;
            // load the aligned blocks this input row contributes through
            let t_lo = if need_left { -1 } else { 0 };
            let t_hi = group as isize - 1 + if need_right { 1 } else { 0 };
            for t in t_lo..=t_hi {
                sink.emit(Op::Load {
                    dst: block_reg(t),
                    addr: layout.a_addr(&[row, j0 + t * n as isize]),
                });
            }
            for &(li, line) in &cls.dim0 {
                if !line.cv_nonzero(p, n) {
                    continue;
                }
                sink.emit(Op::Load { dst: VReg(V_CV), addr: table.cv_addr(li, p, r as usize) });
                let oj = line.base[1];
                for t in 0..group as isize {
                    let av = assemble(n, t, oj, sink);
                    sink.emit(Op::Outer { m: MReg(t as u8), a: VReg(V_CV), b: av });
                }
            }
        }
    }
    // lines along j: strided input columns via the transpose trick
    for t in 0..group {
        gen2d_jlines_tile(cfg, layout, cls, table, i0, j0 + (t * n) as isize, t, sink);
    }
}

/// Naive 2D tile: everything reloaded per tile (§4.3's strawman).
fn gen2d_tile_naive(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    i0: isize,
    jt: isize,
    tile: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    for &(li, line) in &cls.dim0 {
        let oj = line.base[1];
        for p in -r..(n as isize + r) {
            if !line.cv_nonzero(p, n) {
                continue;
            }
            let row = i0 + p;
            sink.emit(Op::Load { dst: VReg(V_CV), addr: table.cv_addr(li, p, r as usize) });
            // load only the blocks this tile needs (t = 0 locally)
            sink.emit(Op::Load { dst: block_reg(0), addr: layout.a_addr(&[row, jt]) });
            if oj < 0 {
                sink.emit(Op::Load {
                    dst: block_reg(-1),
                    addr: layout.a_addr(&[row, jt - n as isize]),
                });
            } else if oj > 0 {
                sink.emit(Op::Load {
                    dst: block_reg(1),
                    addr: layout.a_addr(&[row, jt + n as isize]),
                });
            }
            let av = assemble(n, 0, oj, sink);
            sink.emit(Op::Outer { m: MReg(tile as u8), a: VReg(V_CV), b: av });
        }
    }
    gen2d_jlines_tile(cfg, layout, cls, table, i0, jt, tile, sink);
}

/// Lines along `j` for one 2D tile (Eq. (14)): input columns
/// `A[i0..i0+n, jt+p]`. In-tile columns (`0 <= p < n`) come from the
/// matrix-register transpose; halo columns use gather loads.
fn gen2d_jlines_tile(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    i0: isize,
    jt: isize,
    tile: usize,
    sink: &mut impl KirSink,
) {
    if cls.dim1.is_empty() {
        return;
    }
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    let scratch_m = MReg((cfg.n_mregs - 1) as u8);
    // group j-lines by their row offset oi: each group shares one
    // transpose scratch holding rows i0+oi .. i0+oi+n of block jt.
    let mut ois: Vec<isize> = cls.dim1.iter().map(|(_, l)| l.base[0]).collect();
    ois.sort_unstable();
    ois.dedup();
    for oi in ois {
        // fill the scratch tile with A rows (vector-to-matrix moves); the
        // in-tile columns are then matrix-to-vector column moves (§4.1).
        for x in 0..n {
            sink.emit(Op::Load {
                dst: VReg(V_SCRATCH),
                addr: layout.a_addr(&[i0 + oi + x as isize, jt]),
            });
            sink.emit(Op::RowIn { m: scratch_m, row: x, src: VReg(V_SCRATCH) });
        }
        for &(li, line) in &cls.dim1 {
            if line.base[0] != oi {
                continue;
            }
            for p in -r..(n as isize + r) {
                if !line.cv_nonzero(p, n) {
                    continue;
                }
                sink.emit(Op::Load {
                    dst: VReg(V_CV),
                    addr: table.cv_addr(li, p, r as usize),
                });
                let col = if (0..n as isize).contains(&p) {
                    sink.emit(Op::ColOut {
                        dst: VReg(V_SCRATCH),
                        m: scratch_m,
                        col: p as usize,
                    });
                    VReg(V_SCRATCH)
                } else {
                    sink.emit(Op::Gather {
                        dst: VReg(V_SCRATCH),
                        base: layout.a_addr(&[i0 + oi, jt + p]),
                        stride: layout.row_stride(),
                    });
                    VReg(V_SCRATCH)
                };
                sink.emit(Op::Outer { m: MReg(tile as u8), a: col, b: VReg(V_CV) });
            }
        }
    }
}

/// Diagonal lines (Eq. (15)/(16)) — vector path: the sheared output tiles
/// a diagonal outer product would need do not tile `B` cleanly, so each
/// diagonal line is applied as vector FMAs accumulated straight into the
/// matrix-register tiles row by row.
fn gen2d_diag(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    i0: isize,
    j0: isize,
    group: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    for t in 0..group {
        let jt = j0 + (t * n) as isize;
        for x in 0..n {
            // current tile row
            sink.emit(Op::RowOut { dst: VReg(V_SCRATCH2), m: MReg(t as u8), row: x });
            for &(li, line, slope) in &cls.diag {
                // coefficient lanes: the 2r+1 weights live in the splat
                // table at the line's footprint offsets
                for d in -r..=r {
                    let w = line.weights[(d + r) as usize];
                    if w == 0.0 {
                        continue;
                    }
                    // load the weight as a broadcast (splat table is in
                    // dense footprint order)
                    let off = line.point(d);
                    let side = layout.spec.side() as isize;
                    let idx = ((off[0] + r) * side + (off[1] + r)) as usize;
                    sink.emit(Op::Splat { dst: VReg(V_CV), addr: table.splat_addr(idx) });
                    // input row: A[i0+x+d, jt + slope*d .. +n] (sheared)
                    let row = i0 + x as isize + d;
                    let cs = jt + slope * d;
                    let base = cs.div_euclid(n as isize) * n as isize;
                    let off_in = cs - base;
                    sink.emit(Op::Load {
                        dst: block_reg(0),
                        addr: layout.a_addr(&[row, base]),
                    });
                    if off_in > 0 {
                        sink.emit(Op::Load {
                            dst: block_reg(1),
                            addr: layout.a_addr(&[row, base + n as isize]),
                        });
                    }
                    let av = assemble(n, 0, off_in, sink);
                    sink.emit(Op::Fma { acc: VReg(V_SCRATCH2), a: av, b: VReg(V_CV) });
                    let _ = li;
                }
            }
            sink.emit(Op::RowIn { m: MReg(t as u8), row: x, src: VReg(V_SCRATCH2) });
        }
    }
}

// ===================================================================
// 3D
// ===================================================================

fn gen3d(
    cfg: &SimConfig,
    layout: &Layout,
    cover: &LineCover,
    table: &CoeffTable,
    params: OuterParams,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let n = cfg.vlen;
    let big_n = layout.n;
    let cls = classify(cover);
    anyhow::ensure!(cls.diag.is_empty(), "diagonal lines are 2D-only");
    let needs_scratch = !cls.dim2.is_empty();
    let max_tiles = if needs_scratch { cfg.n_mregs - 1 } else { cfg.n_mregs };
    let ui = params.ui.clamp(1, max_tiles);
    let uk = params.uk.clamp(1, max_tiles / ui);
    let tiles_k = big_n / n;

    // ---- pass 1: tiles B[i ; j0..j0+n ; k0..k0+n], lines along j and k
    for i0 in (0..big_n as isize).step_by(ui) {
        let gi = (ui as isize).min(big_n as isize - i0) as usize;
        for j0 in (0..big_n as isize).step_by(n) {
            let mut tk = 0usize;
            while tk < tiles_k {
                let gk = uk.min(tiles_k - tk);
                let k0 = (tk * n) as isize;
                let marker = Marker::TileGroup { i0, j0, k0, ui: gi, uk: gk };
                sink.emit(Op::Begin(marker));
                for m in 0..gi * gk {
                    sink.emit(Op::TileZero { m: MReg(m as u8) });
                }
                if params.scheduled {
                    gen3d_group_scheduled(cfg, layout, &cls, table, i0, j0, k0, gi, gk, sink);
                } else {
                    for u in 0..gi {
                        for t in 0..gk {
                            gen3d_tile_naive(
                                cfg,
                                layout,
                                &cls,
                                table,
                                i0 + u as isize,
                                j0,
                                k0 + (t * n) as isize,
                                u * gk + t,
                                sink,
                            );
                        }
                    }
                }
                for u in 0..gi {
                    for t in 0..gk {
                        let m = MReg((u * gk + t) as u8);
                        for y in 0..n {
                            let addr = layout.b_addr(&[
                                i0 + u as isize,
                                j0 + y as isize,
                                k0 + (t * n) as isize,
                            ]);
                            sink.emit(Op::RowStore { m, row: y, addr });
                        }
                    }
                }
                sink.emit(Op::End(marker));
                tk += gk;
            }
        }
    }

    // ---- pass 2: lines along i (orthogonal option's CLS(*,r,r)) with the
    // other tile orientation B[i0..i0+n ; j ; k0..k0+n], accumulating into
    // the B written by pass 1 (the extra output references of Table 2).
    if !cls.dim0.is_empty() {
        gen3d_ipass(cfg, layout, &cls, table, params, sink)?;
    }
    Ok(())
}

/// Scheduled 3D group (Algorithm 1): iterate input `j` positions; per
/// input plane row, load the A vectors once and scatter to every tile.
#[allow(clippy::too_many_arguments)]
fn gen3d_group_scheduled(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    i0: isize,
    j0: isize,
    k0: isize,
    gi: usize,
    gk: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    if !cls.dim1.is_empty() {
        // 3D j-lines have base = [oi, 0, ok]
        let need_left = cls.dim1.iter().any(|(_, l)| l.base[2] < 0);
        let need_right = cls.dim1.iter().any(|(_, l)| l.base[2] > 0);
        // distinct ko offsets present in the cover
        let mut kos: Vec<isize> = cls.dim1.iter().map(|(_, l)| l.base[2]).collect();
        kos.sort_unstable();
        kos.dedup();
        for p in -r..(n as isize + r) {
            let jrow = j0 + p;
            // resident CV bank for this p: one register per line
            for (slot, &(li, line)) in cls.dim1.iter().enumerate() {
                if slot >= CV_BANK {
                    break;
                }
                if line.cv_nonzero(p, n) {
                    sink.emit(Op::Load {
                        dst: VReg(V_CV_BANK + slot as u8),
                        addr: table.cv_addr(li, p, r as usize),
                    });
                }
            }
            for ii in (i0 - r)..(i0 + gi as isize + r) {
                // does any line scatter this input plane into a tile?
                let used = cls.dim1.iter().any(|(_, l)| {
                    let u = ii - i0 - l.base[0];
                    (0..gi as isize).contains(&u)
                });
                if !used {
                    continue;
                }
                let t_lo = if need_left { -1 } else { 0 };
                let t_hi = gk as isize - 1 + if need_right { 1 } else { 0 };
                for t in t_lo..=t_hi {
                    sink.emit(Op::Load {
                        dst: block_reg(t),
                        addr: layout.a_addr(&[ii, jrow, k0 + t * n as isize]),
                    });
                }
                for &ko in &kos {
                    for t in 0..gk as isize {
                        let mut av = VReg(0); // assembled lazily
                        let mut assembled = false;
                        for (slot, &(li, line)) in cls.dim1.iter().enumerate() {
                            if line.base[2] != ko {
                                continue;
                            }
                            let u = ii - i0 - line.base[0];
                            if !(0..gi as isize).contains(&u) {
                                continue;
                            }
                            if !line.cv_nonzero(p, n) {
                                continue;
                            }
                            if !assembled {
                                av = assemble(n, t, ko, sink);
                                assembled = true;
                            }
                            let cv_reg = if slot < CV_BANK {
                                VReg(V_CV_BANK + slot as u8)
                            } else {
                                // overflow: reload (register spill behaviour)
                                sink.emit(Op::Load {
                                    dst: VReg(V_CV),
                                    addr: table.cv_addr(li, p, r as usize),
                                });
                                VReg(V_CV)
                            };
                            let m = MReg((u as usize * gk + t as usize) as u8);
                            sink.emit(Op::Outer { m, a: cv_reg, b: av });
                        }
                    }
                }
            }
        }
    }
    // k-lines (strided j-columns) per tile
    for u in 0..gi {
        for t in 0..gk {
            gen3d_klines_tile(
                cfg,
                layout,
                cls,
                table,
                i0 + u as isize,
                j0,
                k0 + (t * n) as isize,
                u * gk + t,
                sink,
            );
        }
    }
}

/// Naive 3D tile: per-tile reloads (no sharing).
#[allow(clippy::too_many_arguments)]
fn gen3d_tile_naive(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    it: isize,
    j0: isize,
    kt: isize,
    tile: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    for &(li, line) in &cls.dim1 {
        let (oi, ok) = (line.base[0], line.base[2]);
        for p in -r..(n as isize + r) {
            if !line.cv_nonzero(p, n) {
                continue;
            }
            sink.emit(Op::Load { dst: VReg(V_CV), addr: table.cv_addr(li, p, r as usize) });
            let plane = it + oi;
            let jrow = j0 + p;
            sink.emit(Op::Load { dst: block_reg(0), addr: layout.a_addr(&[plane, jrow, kt]) });
            if ok < 0 {
                sink.emit(Op::Load {
                    dst: block_reg(-1),
                    addr: layout.a_addr(&[plane, jrow, kt - n as isize]),
                });
            } else if ok > 0 {
                sink.emit(Op::Load {
                    dst: block_reg(1),
                    addr: layout.a_addr(&[plane, jrow, kt + n as isize]),
                });
            }
            let av = assemble(n, 0, ok, sink);
            sink.emit(Op::Outer { m: MReg(tile as u8), a: VReg(V_CV), b: av });
        }
    }
    gen3d_klines_tile(cfg, layout, cls, table, it, j0, kt, tile, sink);
}

/// Lines along `k` for one 3D tile: input columns `A[it+oi, j0+oj+y, kcol]`
/// along `j` — transpose trick for in-tile columns, gathers for halo.
#[allow(clippy::too_many_arguments)]
fn gen3d_klines_tile(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    it: isize,
    j0: isize,
    kt: isize,
    tile: usize,
    sink: &mut impl KirSink,
) {
    if cls.dim2.is_empty() {
        return;
    }
    let n = cfg.vlen;
    let r = layout.spec.order as isize;
    let scratch_m = MReg((cfg.n_mregs - 1) as u8);
    for &(li, line) in &cls.dim2 {
        let (oi, oj) = (line.base[0], line.base[1]);
        debug_assert_eq!(oi, 0, "3D k-lines with i offsets unsupported");
        debug_assert_eq!(oj, 0, "3D k-lines with j offsets unsupported");
        // transpose scratch: rows y hold A[it, j0+y, kt..kt+n]
        for y in 0..n {
            sink.emit(Op::Load {
                dst: VReg(V_SCRATCH),
                addr: layout.a_addr(&[it, j0 + y as isize, kt]),
            });
            sink.emit(Op::RowIn { m: scratch_m, row: y, src: VReg(V_SCRATCH) });
        }
        for p in -r..(n as isize + r) {
            if !line.cv_nonzero(p, n) {
                continue;
            }
            sink.emit(Op::Load { dst: VReg(V_CV), addr: table.cv_addr(li, p, r as usize) });
            let col = if (0..n as isize).contains(&p) {
                sink.emit(Op::ColOut {
                    dst: VReg(V_SCRATCH),
                    m: scratch_m,
                    col: p as usize,
                });
                VReg(V_SCRATCH)
            } else {
                sink.emit(Op::Gather {
                    dst: VReg(V_SCRATCH),
                    base: layout.a_addr(&[it, j0, kt + p]),
                    stride: layout.row_stride(),
                });
                VReg(V_SCRATCH)
            };
            sink.emit(Op::Outer { m: MReg(tile as u8), a: col, b: VReg(V_CV) });
        }
    }
}

/// Pass 2: lines along `i`, tile orientation `B[i0..i0+n ; j ; k0..k0+n]`,
/// read-modify-write on `B`.
fn gen3d_ipass(
    cfg: &SimConfig,
    layout: &Layout,
    cls: &Classified<'_>,
    table: &CoeffTable,
    params: OuterParams,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let n = cfg.vlen;
    let big_n = layout.n;
    let r = layout.spec.order as isize;
    let uk = params.uk.clamp(1, cfg.n_mregs);
    let tiles_k = big_n / n;
    sink.emit(Op::Begin(Marker::Phase("i-line pass")));
    for i0 in (0..big_n as isize).step_by(n) {
        // one self-contained group per i0 block (tiles B[i0..i0+n; *; *]),
        // so backends can reason about row ranges (host tile kernels trim
        // blocks whose rows a tile does not need)
        let marker = Marker::TileGroup { i0, j0: 0, k0: 0, ui: n, uk };
        sink.emit(Op::Begin(marker));
        for j in 0..big_n as isize {
            let mut tk = 0usize;
            while tk < tiles_k {
                let gk = uk.min(tiles_k - tk);
                let k0 = (tk * n) as isize;
                // load current B tiles (RMW)
                for t in 0..gk {
                    for x in 0..n {
                        sink.emit(Op::RowLoad {
                            m: MReg(t as u8),
                            row: x,
                            addr: layout.b_addr(&[i0 + x as isize, j, k0 + (t * n) as isize]),
                        });
                    }
                }
                for p in -r..(n as isize + r) {
                    let plane = i0 + p;
                    // shared aligned loads for this input row
                    for t in 0..gk as isize {
                        sink.emit(Op::Load {
                            dst: block_reg(t),
                            addr: layout.a_addr(&[plane, j, k0 + t * n as isize]),
                        });
                    }
                    for &(li, line) in &cls.dim0 {
                        debug_assert_eq!(line.base, vec![0, 0, 0], "i-lines off centre unsupported");
                        if !line.cv_nonzero(p, n) {
                            continue;
                        }
                        sink.emit(Op::Load {
                            dst: VReg(V_CV),
                            addr: table.cv_addr(li, p, r as usize),
                        });
                        for t in 0..gk {
                            sink.emit(Op::Outer {
                                m: MReg(t as u8),
                                a: VReg(V_CV),
                                b: block_reg(t as isize),
                            });
                        }
                    }
                }
                for t in 0..gk {
                    for x in 0..n {
                        sink.emit(Op::RowStore {
                            m: MReg(t as u8),
                            row: x,
                            addr: layout.b_addr(&[i0 + x as isize, j, k0 + (t * n) as isize]),
                        });
                    }
                }
                tk += gk;
            }
        }
        sink.emit(Op::End(marker));
    }
    sink.emit(Op::End(Marker::Phase("i-line pass")));
    Ok(())
}

#[cfg(test)]
mod tests {
    // Correctness of this generator is exercised end-to-end in
    // codegen::verify (every spec × option × unroll × scheduling), and in
    // the integration tests under rust/tests/. Unit tests here cover the
    // pure helpers.
    use super::*;
    use crate::kir::Kernel;

    #[test]
    fn assemble_zero_offset_uses_block_directly() {
        let mut p = Kernel::default();
        let reg = assemble(8, 2, 0, &mut p);
        assert_eq!(reg, block_reg(2));
        assert!(p.is_empty());
    }

    #[test]
    fn assemble_positive_offset_exts_right() {
        let mut p = Kernel::default();
        let reg = assemble(8, 0, 2, &mut p);
        assert_eq!(reg, VReg(V_AV));
        assert_eq!(
            p.ops,
            vec![Op::Ext { dst: VReg(V_AV), lo: block_reg(0), hi: block_reg(1), shift: 2 }]
        );
    }

    #[test]
    fn assemble_negative_offset_exts_left() {
        let mut p = Kernel::default();
        assemble(8, 1, -3, &mut p);
        assert_eq!(
            p.ops,
            vec![Op::Ext { dst: VReg(V_AV), lo: block_reg(0), hi: block_reg(1), shift: 5 }]
        );
    }
}
