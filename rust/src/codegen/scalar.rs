//! Plain scalar baseline: one output point at a time, no SIMD.
//!
//! Not reported in the paper's tables (its baseline is the compiler's
//! auto-vectorization), but useful as a sanity floor and for the
//! quickstart example. Uses lane-0 of the vector registers: broadcast
//! loads for inputs, indexed FMA against packed coefficient vectors, and
//! single-lane stores.

use super::common::{CoeffTable, Layout};
use crate::stencil::CoeffTensor;
use crate::kir::{KirSink, Op, VReg};
use crate::sim::SimConfig;

const V_ACC: u8 = 0;
const V_IN: u8 = 1;
/// First packed-coefficient register (`vlen` weights per register).
const V_COEFF0: u8 = 2;

/// Generate the scalar stencil program.
pub fn generate(
    cfg: &SimConfig,
    layout: &Layout,
    coeffs: &CoeffTensor,
    table: &CoeffTable,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let taps: Vec<(Vec<isize>, usize)> = layout
        .spec
        .dense_offsets()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| coeffs.data[*i] != 0.0)
        .map(|(i, off)| (off, i))
        .collect();
    anyhow::ensure!(cfg.n_vregs >= 3, "scalar baseline needs 3 registers");
    let big_n = layout.n as isize;
    let dims = layout.spec.dims;
    let walk = |sink: &mut dyn FnMut(&[isize])| {
        if dims == 2 {
            for i in 0..big_n {
                for j in 0..big_n {
                    sink(&[i, j]);
                }
            }
        } else {
            for i in 0..big_n {
                for j in 0..big_n {
                    for k in 0..big_n {
                        sink(&[i, j, k]);
                    }
                }
            }
        }
    };
    let mut body = |pt: &[isize]| {
        sink.emit(Op::Zero { dst: VReg(V_ACC) });
        for (off, di) in &taps {
            let mut q: Vec<isize> = pt.iter().zip(off.iter()).map(|(a, b)| a + b).collect();
            sink.emit(Op::Splat { dst: VReg(V_IN), addr: layout.a_addr(&q) });
            sink.emit(Op::Splat { dst: VReg(V_COEFF0), addr: table.splat_addr(*di) });
            sink.emit(Op::Fma { acc: VReg(V_ACC), a: VReg(V_IN), b: VReg(V_COEFF0) });
            q.clear();
        }
        sink.emit(Op::StoreLane { src: VReg(V_ACC), lane: 0, addr: layout.b_addr(pt) });
    };
    walk(&mut body);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::Kernel;
    use crate::stencil::{DenseGrid, StencilSpec};

    #[test]
    fn per_point_instruction_count() {
        let cfg = SimConfig::default();
        let mut m = crate::sim::Machine::new(cfg.clone());
        let spec = StencilSpec::star2d(1);
        let coeffs = CoeffTensor::paper_default(spec);
        let g = DenseGrid::verification_input(&[10, 10], 1);
        let layout = Layout::alloc(&mut m, spec, &g);
        let table = CoeffTable::install_splats(&mut m, &coeffs);
        let mut p = Kernel::default();
        generate(&cfg, &layout, &coeffs, &table, &mut p).unwrap();
        // per point: zero + 5 × (2 loads + fma) + store = 17
        assert_eq!(p.len(), 64 * 17);
    }
}
