//! Code generators targeting the simulator ISA.
//!
//! Five methods, all producing *functionally correct* instruction streams
//! that are verified element-wise against [`crate::stencil::reference`]:
//!
//! - [`outer`] — **the paper's method**: scatter-mode outer products over
//!   coefficient-line covers, with multi-dimensional unrolling (§4.2),
//!   outer-product scheduling (§4.3) and inter-register data
//!   reorganization for the alignment conflict.
//! - [`vectorize`] — the compiler-auto-vectorization baseline (gather
//!   mode, one unaligned load + FMA per tap; Table 3's "1.0×").
//! - [`dlt`] — the DLT baseline [Henretty et al. 2011]: dimension-lifted
//!   transposed layout, all loads aligned, strip-private halos.
//! - [`tv`] — the temporal-vectorization baseline [Yuan et al. 2021],
//!   modeled as overlapped temporal blocking over 4 time steps (the
//!   memory-volume ÷4 behaviour the paper cites).
//! - [`scalar`] — plain scalar code, for completeness and sanity.
//!
//! [`verify`] hosts the end-to-end runner: allocate grids in simulator
//! memory, generate + execute, check against the oracle, return stats.

pub mod common;
pub mod dlt;
pub mod outer;
pub mod scalar;
pub mod tv;
pub mod vectorize;
pub mod verify;

pub use common::{Layout, OuterParams};
pub use verify::{run_method, Method, MethodResult};
