//! Code generators emitting the backend-agnostic kernel IR
//! ([`crate::kir`]).
//!
//! Five methods, all producing *functionally correct* operation streams
//! that are verified element-wise against [`crate::stencil::reference`].
//! Generators emit [`crate::kir::Op`]s into any [`crate::kir::KirSink`];
//! the sim backend lowers them 1:1 to the simulator ISA on emit
//! (timing), and the host backend interprets them natively (wall-clock):
//!
//! - [`outer`] — **the paper's method**: scatter-mode outer products over
//!   coefficient-line covers, with multi-dimensional unrolling (§4.2),
//!   outer-product scheduling (§4.3) and inter-register data
//!   reorganization for the alignment conflict.
//! - [`vectorize`] — the compiler-auto-vectorization baseline (gather
//!   mode, one unaligned load + FMA per tap; Table 3's "1.0×").
//! - [`dlt`] — the DLT baseline [Henretty et al. 2011]: dimension-lifted
//!   transposed layout, all loads aligned, strip-private halos.
//! - [`tv`] — the temporal-vectorization baseline [Yuan et al. 2021],
//!   modeled as overlapped temporal blocking over 4 time steps (the
//!   memory-volume ÷4 behaviour the paper cites).
//! - [`scalar`] — plain scalar code, for completeness and sanity.
//!
//! [`verify`] hosts the end-to-end runners: allocate grids in backend
//! memory, generate + execute, check against the oracle, return stats
//! ([`run_method`] on the simulator, [`run_host`] on the host).

pub mod common;
pub mod dlt;
pub mod outer;
pub mod scalar;
pub mod tv;
pub mod vectorize;
pub mod verify;

pub use common::{Layout, OuterParams};
pub use verify::{
    kernel_for, kernel_for_fused, run_host, run_host_fused, run_host_fused_threads,
    run_host_threads, run_method, run_method_fused, supports_fusion, HostRun, Method,
    MethodResult,
};
