//! Compiler-style auto-vectorization baseline (gather mode) — Table 3's
//! normalization denominator ("speedup over auto-vectorization").
//!
//! Shape of the generated code, matching what vectorizing compilers emit
//! for a stencil loop nest (§2.2 "one can rely on compilers"):
//!
//! - outputs are produced one vector at a time along the unit-stride
//!   dimension;
//! - each non-zero tap contributes one (generally unaligned) vector load
//!   plus one FMA with the broadcast coefficient — the classic *data
//!   alignment conflict*: the same input value is reloaded at a different
//!   lane position for every tap along the unit-stride dimension;
//! - 4 output vectors are processed per iteration with independent
//!   accumulators (compiler unroll-and-jam, hides FMA latency);
//! - coefficients are kept broadcast in registers when they fit
//!   (`nonzeros + working set <= 32`), else reloaded per row-strip
//!   (register spilling, visible for high-order box stencils).

use super::common::{CoeffTable, Layout};
use crate::stencil::CoeffTensor;
use crate::kir::{KirSink, Op, VReg};
use crate::sim::SimConfig;

/// Unroll-and-jam factor (independent accumulators).
const JAM: usize = 4;
/// First accumulator register.
const V_ACC0: u8 = 0;
/// Load scratch.
const V_LOAD: u8 = 4;
/// Coefficient splat slot when spilling.
const V_CSPILL: u8 = 5;
/// First resident coefficient register.
const V_COEFF0: u8 = 6;

/// Generate the auto-vectorized gather-mode stencil.
pub fn generate(
    cfg: &SimConfig,
    layout: &Layout,
    coeffs: &CoeffTensor,
    table: &CoeffTable,
    sink: &mut impl KirSink,
) -> anyhow::Result<()> {
    let n = cfg.vlen;
    anyhow::ensure!(layout.n % n == 0, "domain must be a multiple of the vector length");
    let taps: Vec<(Vec<isize>, usize)> = layout
        .spec
        .dense_offsets()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| coeffs.data[*i] != 0.0)
        .map(|(i, off)| (off, i))
        .collect();
    let resident = taps.len() <= (cfg.n_vregs - V_COEFF0 as usize);
    if resident {
        for (slot, (_, di)) in taps.iter().enumerate() {
            sink.emit(Op::Splat {
                dst: VReg(V_COEFF0 + slot as u8),
                addr: table.splat_addr(*di),
            });
        }
    }
    let big_n = layout.n as isize;
    let nv = n as isize;
    match layout.spec.dims {
        2 => {
            for i in 0..big_n {
                let mut j0 = 0isize;
                while j0 < big_n {
                    let jam = JAM.min(((big_n - j0) / nv) as usize);
                    emit_strip(cfg, layout, &taps, table, resident, &[i], j0, jam, sink);
                    j0 += (jam as isize) * nv;
                }
            }
        }
        3 => {
            for i in 0..big_n {
                for j in 0..big_n {
                    let mut k0 = 0isize;
                    while k0 < big_n {
                        let jam = JAM.min(((big_n - k0) / nv) as usize);
                        emit_strip(cfg, layout, &taps, table, resident, &[i, j], k0, jam, sink);
                        k0 += (jam as isize) * nv;
                    }
                }
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

/// One unroll-and-jam strip: `jam` output vectors starting at unit-stride
/// coordinate `c0`, outer coordinates `outer`.
#[allow(clippy::too_many_arguments)]
fn emit_strip(
    cfg: &SimConfig,
    layout: &Layout,
    taps: &[(Vec<isize>, usize)],
    table: &CoeffTable,
    resident: bool,
    outer: &[isize],
    c0: isize,
    jam: usize,
    sink: &mut impl KirSink,
) {
    let n = cfg.vlen as isize;
    for u in 0..jam {
        sink.emit(Op::Zero { dst: VReg(V_ACC0 + u as u8) });
    }
    for (slot, (off, di)) in taps.iter().enumerate() {
        let coeff = if resident {
            VReg(V_COEFF0 + slot as u8)
        } else {
            sink.emit(Op::Splat { dst: VReg(V_CSPILL), addr: table.splat_addr(*di) });
            VReg(V_CSPILL)
        };
        for u in 0..jam {
            // unaligned load of the tap's shifted input vector
            let mut idx: Vec<isize> = Vec::with_capacity(layout.spec.dims);
            for (d, &o) in outer.iter().enumerate() {
                idx.push(o + off[d]);
            }
            idx.push(c0 + (u as isize) * n + off[layout.spec.dims - 1]);
            sink.emit(Op::Load { dst: VReg(V_LOAD), addr: layout.a_addr(&idx) });
            sink.emit(Op::Fma { acc: VReg(V_ACC0 + u as u8), a: VReg(V_LOAD), b: coeff });
        }
    }
    for u in 0..jam {
        let mut idx: Vec<isize> = outer.to_vec();
        idx.push(c0 + (u as isize) * n);
        sink.emit(Op::Store { src: VReg(V_ACC0 + u as u8), addr: layout.b_addr(&idx) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::Kernel;
    use crate::stencil::{DenseGrid, StencilSpec};

    #[test]
    fn instruction_mix_matches_tap_count() {
        // 2D9P over N=16: per output vector, 9 loads + 9 FMA; 2 strips per
        // row × 16 rows; coefficients resident (9 <= 26).
        let cfg = SimConfig::default();
        let mut m = crate::sim::Machine::new(cfg.clone());
        let spec = StencilSpec::box2d(1);
        let coeffs = CoeffTensor::paper_default(spec);
        let g = DenseGrid::verification_input(&[18, 18], 1);
        let layout = Layout::alloc(&mut m, spec, &g);
        let table = CoeffTable::install_splats(&mut m, &coeffs);
        let mut p = Kernel::default();
        generate(&cfg, &layout, &coeffs, &table, &mut p).unwrap();
        let outvecs = 16 * 2;
        assert_eq!(p.count(|i| matches!(i, Op::Fma { .. })), 9 * outvecs);
        assert_eq!(p.count(|i| matches!(i, Op::Load { .. })), 9 * outvecs);
        assert_eq!(p.count(|i| matches!(i, Op::Store { .. })), outvecs);
        // 9 resident coefficient splats
        assert_eq!(p.count(|i| matches!(i, Op::Splat { .. })), 9);
    }

    #[test]
    fn high_order_box_spills_coefficients() {
        // 2D box r=3: 49 taps > 26 resident slots → splat reloads inside
        // the loop.
        let cfg = SimConfig::default();
        let mut m = crate::sim::Machine::new(cfg.clone());
        let spec = StencilSpec::box2d(3);
        let coeffs = CoeffTensor::paper_default(spec);
        let g = DenseGrid::verification_input(&[22, 22], 1);
        let layout = Layout::alloc(&mut m, spec, &g);
        let table = CoeffTable::install_splats(&mut m, &coeffs);
        let mut p = Kernel::default();
        generate(&cfg, &layout, &coeffs, &table, &mut p).unwrap();
        let strips = 16 / 8 / 4; // ceil over jam... one 2-vector strip per row
        let _ = strips;
        assert!(p.count(|i| matches!(i, Op::Splat { .. })) > 49);
    }
}
