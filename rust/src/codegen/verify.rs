//! End-to-end method runners: allocate grids in backend memory, generate
//! a method's KIR program, execute it, verify against the scalar oracle,
//! and report.
//!
//! Two backends, one generation path:
//!
//! - [`run_method`] — the simulator: generators stream KIR into the
//!   [`Machine`] (which lowers each op to the sim ISA on emit), returning
//!   cycle-approximate timing. Every benchmark number in this repo flows
//!   through it, so a result is only ever reported for a program that
//!   produced bit-accurate (within 1e-9) stencil output.
//! - [`run_host`] — the host: the same generators emit the same program,
//!   captured once and executed natively over flat f64 buffers by the
//!   selected [`Engine`] — the op-by-op interpreter
//!   ([`crate::kir::HostMachine`]), the compiling engine
//!   ([`crate::kir::ExecPlan`]: fused loop nests, gather index tables,
//!   threaded row groups) or the explicit-SIMD engine
//!   ([`crate::kir::SimdPlan`]: runtime-dispatched vector microkernels)
//!   — returning wall-clock seconds. Host output is bitwise identical
//!   to the simulated output on every engine at any thread count
//!   (`rust/tests/kir_equivalence.rs`).

use super::common::{CoeffTable, Layout, OuterParams};
use super::{dlt, outer, scalar, tv, vectorize};
use crate::kir::{Engine, ExecPlan, HostMachine, Kernel, KirSink, Marker, Op, PingPong, SimdPlan};
use crate::scatter::build_cover;
use crate::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use crate::sim::{Machine, RunStats, SimConfig};
use std::fmt;

/// True when a method can be temporally blocked (T fused ping-pong steps
/// per application): it must evolve grids in place with one sweep per
/// step. DLT restructures the storage layout around every sweep and TV
/// blocks time internally already, so both are rejected.
pub fn supports_fusion(method: Method) -> bool {
    matches!(method, Method::Outer(_) | Method::AutoVec | Method::Scalar)
}

fn ensure_fusable(cfg: &SimConfig, n: usize, method: Method, fuse_steps: usize) -> anyhow::Result<()> {
    anyhow::ensure!(fuse_steps >= 1, "an application must advance at least one step");
    if fuse_steps > 1 {
        anyhow::ensure!(
            supports_fusion(method),
            "{method} cannot be temporally blocked (it restructures grids or blocks time itself)"
        );
        anyhow::ensure!(
            n % cfg.vlen == 0,
            "temporal blocking needs an exactly tiled domain (N={n} is not a multiple of the \
             vector length {})",
            cfg.vlen
        );
    }
    Ok(())
}

/// A stencil execution method.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// The paper's outer-product algorithm.
    Outer(OuterParams),
    /// Compiler-style auto-vectorization (the speedup baseline).
    AutoVec,
    /// Data Layout Transformation [20].
    Dlt,
    /// Temporal vectorization [57] (modeled as 4-step temporal blocking).
    Tv,
    /// Plain scalar code.
    Scalar,
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Method::Outer(p) => write!(f, "outer({:?},ui={},uk={},sched={})",
                p.option, p.ui, p.uk, p.scheduled),
            Method::AutoVec => write!(f, "autovec"),
            Method::Dlt => write!(f, "dlt"),
            Method::Tv => write!(f, "tv"),
            Method::Scalar => write!(f, "scalar"),
        }
    }
}

/// Outcome of one verified simulation run.
#[derive(Debug, Clone)]
pub struct MethodResult {
    /// The method that ran.
    pub method: Method,
    /// The stencil.
    pub spec: StencilSpec,
    /// Domain extent per dimension.
    pub n: usize,
    /// Time steps the program advanced (1, or 4 for TV).
    pub steps: usize,
    /// Timing/instruction counters of the measured run.
    pub stats: RunStats,
    /// Max |error| vs. the scalar reference over the interior.
    pub max_err: f64,
    /// The produced output grid (storage shape) — what `max_err` was
    /// computed from, kept so callers can compare backends bitwise.
    pub grid: DenseGrid,
}

impl MethodResult {
    /// Domain points.
    pub fn points(&self) -> usize {
        self.n.pow(self.spec.dims as u32)
    }

    /// Cycles per output point per time step — the normalized cost all
    /// figures/tables are computed from.
    pub fn cycles_per_point(&self) -> f64 {
        self.stats.cycles as f64 / (self.points() * self.steps) as f64
    }

    /// True when the run reproduced the oracle.
    pub fn verified(&self) -> bool {
        self.max_err < 1e-9
    }
}

/// Run `method` on a fresh machine and verify the result.
///
/// `warm` runs the program once before measuring (steady-state caches, the
/// paper's in-cache methodology); pass `false` for cold-cache runs.
pub fn run_method(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    warm: bool,
) -> anyhow::Result<MethodResult> {
    run_method_fused(cfg, spec, n, method, warm, 1)
}

/// [`run_method`] with a time-tile depth: each application generates
/// `fuse_steps` ping-pong fused steps (step `s` reads what step `s - 1`
/// wrote, buffers alternating per [`PingPong`]) and the result is
/// verified against `fuse_steps` oracle steps. On the full grid the
/// generated programs write exactly the domain interior, so the frozen
/// global boundary stays frozen across every fused step with no extra
/// ops. `fuse_steps = 1` is byte-identical to the classic [`run_method`]
/// path. Methods that cannot be fused ([`supports_fusion`]) are
/// rejected for `fuse_steps > 1`.
pub fn run_method_fused(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    warm: bool,
    fuse_steps: usize,
) -> anyhow::Result<MethodResult> {
    ensure_fusable(cfg, n, method, fuse_steps)?;
    let coeffs = CoeffTensor::paper_default(spec);
    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
    let mut machine = Machine::new(cfg.clone());
    let mut layout = Layout::alloc(&mut machine, spec, &grid);

    // ---- one-time setup (never charged to the measured run) ----
    let cfg2 = machine.cfg.clone();
    let outer_setup = if let Method::Outer(params) = method {
        let cover = build_cover(&coeffs, params.option)?;
        let table = CoeffTable::install_full(&mut machine, &coeffs, &cover);
        Some((cover, table, params))
    } else {
        None
    };
    let splat_table = match method {
        Method::Outer(_) => None,
        _ => Some(CoeffTable::install_splats(&mut machine, &coeffs)),
    };
    let dlt_layout = if method == Method::Dlt {
        Some(dlt::DltLayout::build(&mut machine, &layout, &grid))
    } else {
        None
    };
    let tv_scratch = if method == Method::Tv {
        Some(tv::setup(&mut machine, &layout))
    } else {
        None
    };
    machine.finish(); // reset timing; setup is host work

    let passes = if warm { 2 } else { 1 };
    let mut stats = RunStats::default();
    let mut steps = 1usize;
    let mut swapped = false;
    for pass in 0..passes {
        if fuse_steps > 1 && pass > 0 {
            // the previous pass's ping-pong overwrote the original A
            // contents: restore the untouched input image (host work,
            // never charged to the measured run) before re-measuring
            if swapped {
                layout.swap();
                swapped = false;
            }
            layout.reinit(&mut machine, &grid);
            machine.finish();
        }
        for step in 0..fuse_steps {
            if step > 0 {
                layout.swap();
                swapped = !swapped;
            }
            match method {
                Method::Outer(_) => {
                    let (cover, table, params) = outer_setup.as_ref().unwrap();
                    outer::generate(&cfg2, &layout, cover, table, *params, &mut machine)?;
                }
                Method::AutoVec => {
                    vectorize::generate(
                        &cfg2,
                        &layout,
                        &coeffs,
                        splat_table.as_ref().unwrap(),
                        &mut machine,
                    )?;
                }
                Method::Scalar => {
                    scalar::generate(
                        &cfg2,
                        &layout,
                        &coeffs,
                        splat_table.as_ref().unwrap(),
                        &mut machine,
                    )?;
                }
                Method::Dlt => {
                    dlt::generate(
                        &cfg2,
                        &layout,
                        dlt_layout.as_ref().unwrap(),
                        &coeffs,
                        splat_table.as_ref().unwrap(),
                        &mut machine,
                    )?;
                }
                Method::Tv => {
                    tv::generate(
                        &cfg2,
                        &layout,
                        tv_scratch.as_ref().unwrap(),
                        &coeffs,
                        splat_table.as_ref().unwrap(),
                        &mut machine,
                    )?;
                    steps = tv::TIME_BLOCK;
                }
            }
        }
        stats = machine.finish();
    }
    if fuse_steps > 1 {
        steps = fuse_steps;
        // after T - 1 swaps the layout's B side is the ping-pong result
        debug_assert_eq!(PingPong::result_in_back(fuse_steps), !swapped);
    }
    let got = match &dlt_layout {
        Some(d) => d.read_b(&machine, &grid),
        None => layout.read_b(&machine),
    };
    let want = reference::evolve(&coeffs, &grid, steps);
    let max_err = got.max_abs_diff_interior(&want, spec.order);
    Ok(MethodResult { method, spec, n, steps, stats, max_err, grid: got })
}

/// Outcome of one verified host-backend run.
#[derive(Debug, Clone)]
pub struct HostRun {
    /// The produced output grid (storage shape).
    pub grid: DenseGrid,
    /// Time steps the program advanced (1, or 4 for TV).
    pub steps: usize,
    /// Pure-execution wall-clock seconds (program generated — and, for
    /// the compiled engine, planned — before the clock starts).
    pub seconds: f64,
    /// Non-marker operations executed.
    pub ops: u64,
    /// Max |error| vs. the scalar reference over the interior.
    pub max_err: f64,
    /// Engine that executed the program.
    pub engine: Engine,
    /// Worker threads the compiled engine used (1 for the interpreter).
    pub threads: usize,
}

impl HostRun {
    /// True when the run reproduced the oracle (same bar as
    /// [`MethodResult::verified`]).
    pub fn verified(&self) -> bool {
        self.max_err < 1e-9
    }

    /// Host throughput in Mpoints/s for a run over `points` domain
    /// points (time steps included) — the one formula every report
    /// shares.
    pub fn mpts_per_s(&self, points: usize) -> f64 {
        (points * self.steps) as f64 / self.seconds.max(1e-12) / 1e6
    }
}

/// Everything the host backend needs to run one method: the prepared
/// machine (grids + tables resident), the layouts, and the captured
/// program.
struct HostPrep {
    machine: HostMachine,
    layout: Layout,
    dlt: Option<dlt::DltLayout>,
    steps: usize,
    kernel: Kernel,
    coeffs: CoeffTensor,
    grid: DenseGrid,
}

fn prepare_host(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    fuse_steps: usize,
) -> anyhow::Result<HostPrep> {
    ensure_fusable(cfg, n, method, fuse_steps)?;
    let coeffs = CoeffTensor::paper_default(spec);
    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
    let mut machine = HostMachine::from_config(cfg);
    let mut layout = Layout::alloc(&mut machine, spec, &grid);
    let mut kernel = Kernel::default();
    let mut dlt_layout = None;
    let mut steps = 1usize;
    // one-time setup: tables (and DLT/TV scratch) are step-invariant
    let outer_setup = if let Method::Outer(params) = method {
        let cover = build_cover(&coeffs, params.option)?;
        let table = CoeffTable::install_full(&mut machine, &coeffs, &cover);
        Some((cover, table, params))
    } else {
        None
    };
    let splat_table = match method {
        Method::Outer(_) => None,
        _ => Some(CoeffTable::install_splats(&mut machine, &coeffs)),
    };
    let tv_scratch = if method == Method::Tv {
        Some(tv::setup(&mut machine, &layout))
    } else {
        None
    };
    for step in 0..fuse_steps {
        if step > 0 {
            layout.swap();
        }
        if fuse_steps > 1 {
            kernel.emit(Op::Begin(Marker::Step { t: step, of: fuse_steps }));
        }
        match method {
            Method::Outer(_) => {
                let (cover, table, params) = outer_setup.as_ref().unwrap();
                outer::generate(cfg, &layout, cover, table, *params, &mut kernel)?;
            }
            Method::AutoVec => {
                vectorize::generate(cfg, &layout, &coeffs, splat_table.as_ref().unwrap(), &mut kernel)?;
            }
            Method::Scalar => {
                scalar::generate(cfg, &layout, &coeffs, splat_table.as_ref().unwrap(), &mut kernel)?;
            }
            Method::Dlt => {
                let d = dlt::DltLayout::build(&mut machine, &layout, &grid);
                dlt::generate(cfg, &layout, &d, &coeffs, splat_table.as_ref().unwrap(), &mut kernel)?;
                dlt_layout = Some(d);
            }
            Method::Tv => {
                tv::generate(
                    cfg,
                    &layout,
                    tv_scratch.as_ref().unwrap(),
                    &coeffs,
                    splat_table.as_ref().unwrap(),
                    &mut kernel,
                )?;
                steps = tv::TIME_BLOCK;
            }
        }
        if fuse_steps > 1 {
            kernel.emit(Op::End(Marker::Step { t: step, of: fuse_steps }));
        }
    }
    if fuse_steps > 1 {
        steps = fuse_steps;
    }
    kernel.steps = steps;
    Ok(HostPrep { machine, layout, dlt: dlt_layout, steps, kernel, coeffs, grid })
}

/// Capture the KIR program a method generates for `spec` at extent `n`
/// (what `dump-ir` prints and the cost model counts).
pub fn kernel_for(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
) -> anyhow::Result<Kernel> {
    kernel_for_fused(cfg, spec, n, method, 1)
}

/// [`kernel_for`] with a time-tile depth: the captured program holds
/// `fuse_steps` [`Marker::Step`]-delimited fused steps against the
/// ping-pong buffers (what `dump-ir --fuse-steps` prints).
pub fn kernel_for_fused(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    fuse_steps: usize,
) -> anyhow::Result<Kernel> {
    prepare_host(cfg, spec, n, method, fuse_steps).map(|p| p.kernel)
}

/// Run `method` on the host backend with `engine` and verify the result
/// (compiled engine: one thread per available core).
///
/// The program is generated (and all tables installed, and the compiled
/// engine's plan built) before the clock starts, so `seconds` measures
/// pure native execution — the wall-clock column next to the simulator's
/// cycle counts.
pub fn run_host(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    engine: Engine,
) -> anyhow::Result<HostRun> {
    run_host_fused_threads(cfg, spec, n, method, engine, 1, 0)
}

/// [`run_host`] with an explicit thread budget for the compiled engine
/// (0 = one per available core; ignored by the interpreter).
pub fn run_host_threads(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    engine: Engine,
    threads: usize,
) -> anyhow::Result<HostRun> {
    run_host_fused_threads(cfg, spec, n, method, engine, 1, threads)
}

/// [`run_host`] with a time-tile depth: one execution advances
/// `fuse_steps` fused ping-pong steps (`HostRun::steps` reports it, so
/// `mpts_per_s` counts the amortized throughput), verified against
/// `fuse_steps` oracle steps.
pub fn run_host_fused(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    engine: Engine,
    fuse_steps: usize,
) -> anyhow::Result<HostRun> {
    run_host_fused_threads(cfg, spec, n, method, engine, fuse_steps, 0)
}

/// [`run_host_fused`] with an explicit thread budget for the compiled
/// engine (0 = one per available core; ignored by the interpreter).
pub fn run_host_fused_threads(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    method: Method,
    engine: Engine,
    fuse_steps: usize,
    threads: usize,
) -> anyhow::Result<HostRun> {
    let mut p = prepare_host(cfg, spec, n, method, fuse_steps)?;
    let (seconds, ops, threads_used) = match engine {
        Engine::Interpret => {
            let t0 = std::time::Instant::now();
            p.machine.run(&p.kernel.ops);
            (t0.elapsed().as_secs_f64(), p.machine.executed, 1)
        }
        Engine::Compiled => {
            let plan = ExecPlan::from_config(cfg, &p.kernel.ops);
            let threads_used = plan.effective_threads(threads);
            let t0 = std::time::Instant::now();
            plan.run(&mut p.machine.mem, threads);
            (t0.elapsed().as_secs_f64(), plan.op_count(), threads_used)
        }
        Engine::Simd => {
            let plan = ExecPlan::from_config(cfg, &p.kernel.ops);
            let splan = SimdPlan::new(&plan);
            let threads_used = splan.effective_threads(threads);
            let t0 = std::time::Instant::now();
            splan.run(&mut p.machine.mem, threads);
            (t0.elapsed().as_secs_f64(), splan.op_count(), threads_used)
        }
    };
    let got = match &p.dlt {
        Some(d) => d.read_b(&p.machine, &p.grid),
        None => p.layout.read_b(&p.machine),
    };
    let want = reference::evolve(&p.coeffs, &p.grid, p.steps);
    let max_err = got.max_abs_diff_interior(&want, spec.order);
    Ok(HostRun {
        grid: got,
        steps: p.steps,
        seconds,
        ops,
        max_err,
        engine,
        threads: threads_used,
    })
}

/// Speedup of `m` over `base`, normalized per point per step.
pub fn speedup(base: &MethodResult, m: &MethodResult) -> f64 {
    base.cycles_per_point() / m.cycles_per_point()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scatter::CoverOption;

    fn check(spec: StencilSpec, n: usize, method: Method) -> MethodResult {
        let cfg = SimConfig::default();
        let r = run_method(&cfg, spec, n, method, true).unwrap();
        assert!(
            r.verified(),
            "{method} on {spec} N={n}: max_err={}",
            r.max_err
        );
        r
    }

    #[test]
    fn scalar_verifies_2d() {
        check(StencilSpec::box2d(1), 16, Method::Scalar);
        check(StencilSpec::star2d(2), 16, Method::Scalar);
        check(StencilSpec::diag2d(1), 16, Method::Scalar);
    }

    #[test]
    fn scalar_verifies_3d() {
        check(StencilSpec::box3d(1), 8, Method::Scalar);
        check(StencilSpec::star3d(2), 8, Method::Scalar);
    }

    #[test]
    fn autovec_verifies() {
        check(StencilSpec::box2d(1), 16, Method::AutoVec);
        check(StencilSpec::box2d(3), 16, Method::AutoVec);
        check(StencilSpec::star2d(1), 24, Method::AutoVec);
        check(StencilSpec::box3d(1), 8, Method::AutoVec);
        check(StencilSpec::star3d(3), 16, Method::AutoVec);
    }

    #[test]
    fn dlt_verifies() {
        check(StencilSpec::box2d(1), 16, Method::Dlt);
        check(StencilSpec::star2d(2), 32, Method::Dlt);
        check(StencilSpec::box3d(1), 8, Method::Dlt);
        check(StencilSpec::star3d(1), 16, Method::Dlt);
    }

    #[test]
    fn tv_verifies() {
        let r = check(StencilSpec::star2d(1), 32, Method::Tv);
        assert_eq!(r.steps, 4);
        check(StencilSpec::box3d(1), 8, Method::Tv);
    }

    #[test]
    fn outer_parallel_verifies_2d() {
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 8, scheduled: true };
        check(StencilSpec::box2d(1), 16, Method::Outer(p));
        check(StencilSpec::box2d(2), 16, Method::Outer(p));
        check(StencilSpec::star2d(1), 16, Method::Outer(p));
    }

    #[test]
    fn outer_parallel_verifies_2d_unscheduled() {
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 1, scheduled: false };
        check(StencilSpec::box2d(1), 16, Method::Outer(p));
        check(StencilSpec::star2d(3), 16, Method::Outer(p));
    }

    #[test]
    fn outer_orthogonal_verifies_2d() {
        let p = OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 4, scheduled: true };
        check(StencilSpec::star2d(1), 16, Method::Outer(p));
        check(StencilSpec::star2d(2), 16, Method::Outer(p));
    }

    #[test]
    fn outer_minimal_verifies_2d() {
        let p = OuterParams { option: CoverOption::MinimalAxis, ui: 1, uk: 4, scheduled: true };
        check(StencilSpec::box2d(1), 16, Method::Outer(p));
        check(StencilSpec::star2d(2), 16, Method::Outer(p));
    }

    #[test]
    fn outer_diagonals_verify() {
        let p = OuterParams { option: CoverOption::Diagonals, ui: 1, uk: 2, scheduled: true };
        check(StencilSpec::diag2d(1), 16, Method::Outer(p));
        check(StencilSpec::diag2d(2), 16, Method::Outer(p));
    }

    #[test]
    fn outer_parallel_verifies_3d() {
        let p = OuterParams { option: CoverOption::Parallel, ui: 4, uk: 2, scheduled: true };
        check(StencilSpec::box3d(1), 8, Method::Outer(p));
        check(StencilSpec::star3d(1), 8, Method::Outer(p));
    }

    #[test]
    fn outer_orthogonal_verifies_3d() {
        let p = OuterParams { option: CoverOption::Orthogonal, ui: 4, uk: 1, scheduled: true };
        check(StencilSpec::star3d(1), 8, Method::Outer(p));
        check(StencilSpec::star3d(2), 8, Method::Outer(p));
    }

    #[test]
    fn outer_hybrid_verifies_3d() {
        let p = OuterParams { option: CoverOption::Hybrid, ui: 1, uk: 4, scheduled: true };
        check(StencilSpec::star3d(1), 8, Method::Outer(p));
        check(StencilSpec::star3d(3), 8, Method::Outer(p));
    }

    #[test]
    fn outer_3d_unscheduled() {
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 1, scheduled: false };
        check(StencilSpec::box3d(1), 8, Method::Outer(p));
        check(StencilSpec::star3d(2), 8, Method::Outer(p));
    }

    #[test]
    fn host_backend_matches_sim_backend_bitwise() {
        let cfg = SimConfig::default();
        for (spec, n, method) in [
            (StencilSpec::box2d(1), 16, Method::Scalar),
            (StencilSpec::star2d(2), 16, Method::AutoVec),
            (
                StencilSpec::box2d(1),
                16,
                Method::Outer(OuterParams::paper_best(StencilSpec::box2d(1))),
            ),
        ] {
            let sim = run_method(&cfg, spec, n, method, false).unwrap();
            let host = run_host(&cfg, spec, n, method, Engine::Interpret).unwrap();
            assert!(host.verified(), "{spec} {method}: {}", host.max_err);
            assert_eq!(host.steps, sim.steps);
            assert_eq!(host.grid.data, sim.grid.data, "{spec} {method}");
            assert!(host.ops > 0 && host.seconds >= 0.0);
            assert_eq!((host.engine, host.threads), (Engine::Interpret, 1));
            // the compiling engine is bitwise identical to the
            // interpreter — and hence to the simulator — per thread count
            for threads in [1usize, 3] {
                let comp =
                    run_host_threads(&cfg, spec, n, method, Engine::Compiled, threads).unwrap();
                assert_eq!(comp.grid.data, sim.grid.data, "{spec} {method} t={threads}");
                assert_eq!(comp.ops, host.ops, "both engines execute the same op count");
                assert_eq!(comp.engine, Engine::Compiled);
            }
        }
    }

    #[test]
    fn kernel_capture_matches_streamed_program_size() {
        let cfg = SimConfig::default();
        // scalar star2d(1) emits no markers: 16² points × (zero + 5 taps
        // × (2 loads + fma) + store) = 17 ops per point
        let k = kernel_for(&cfg, StencilSpec::star2d(1), 16, Method::Scalar).unwrap();
        assert_eq!(k.len(), 256 * 17);
        assert_eq!(k.stats().markers, 0);
        let spec = StencilSpec::box2d(1);
        let ko = kernel_for(
            &cfg,
            spec,
            16,
            Method::Outer(OuterParams::paper_best(spec)),
        )
        .unwrap();
        assert!(ko.outer_count() > 0);
        assert!(ko.stats().markers > 0, "outer programs carry structure markers");
    }

    #[test]
    fn fused_runs_verify_and_backends_agree_bitwise() {
        let cfg = SimConfig::default();
        for (spec, n, method) in [
            (
                StencilSpec::box2d(1),
                16,
                Method::Outer(OuterParams::paper_best(StencilSpec::box2d(1))),
            ),
            (StencilSpec::star2d(2), 16, Method::AutoVec),
            (
                StencilSpec::box3d(1),
                8,
                Method::Outer(OuterParams::paper_best(StencilSpec::box3d(1))),
            ),
        ] {
            for t in [2usize, 4] {
                let sim = run_method_fused(&cfg, spec, n, method, true, t).unwrap();
                assert!(sim.verified(), "{spec} {method} T={t}: sim max_err {}", sim.max_err);
                assert_eq!(sim.steps, t);
                let host = run_host_fused(&cfg, spec, n, method, Engine::Interpret, t).unwrap();
                assert!(host.verified(), "{spec} {method} T={t}: host max_err {}", host.max_err);
                assert_eq!(host.steps, t);
                assert_eq!(host.grid.data, sim.grid.data, "{spec} {method} T={t}: host vs sim");
                for threads in [1usize, 3] {
                    let comp = run_host_fused_threads(
                        &cfg,
                        spec,
                        n,
                        method,
                        Engine::Compiled,
                        t,
                        threads,
                    )
                    .unwrap();
                    assert_eq!(
                        comp.grid.data, host.grid.data,
                        "{spec} {method} T={t} threads={threads}"
                    );
                }
            }
        }
        // grid-restructuring / time-blocking methods reject fusion
        assert!(!supports_fusion(Method::Dlt) && !supports_fusion(Method::Tv));
        assert!(run_method_fused(&cfg, StencilSpec::box2d(1), 16, Method::Dlt, false, 2).is_err());
        assert!(run_method_fused(&cfg, StencilSpec::box2d(1), 16, Method::Tv, false, 2).is_err());
        // fused domains must tile exactly
        assert!(run_method_fused(&cfg, StencilSpec::box2d(1), 12, Method::Scalar, false, 2).is_err());
        // the captured fused kernel carries its step structure
        let k = kernel_for_fused(
            &cfg,
            StencilSpec::box2d(1),
            16,
            Method::Outer(OuterParams::paper_best(StencilSpec::box2d(1))),
            3,
        )
        .unwrap();
        assert_eq!(k.steps, 3);
        assert_eq!(crate::kir::step_stats(&k).len(), 3);
    }

    #[test]
    fn outer_beats_autovec_on_box2d() {
        let cfg = SimConfig::default();
        let base = run_method(&cfg, StencilSpec::box2d(1), 64, Method::AutoVec, true).unwrap();
        let p = OuterParams::paper_best(StencilSpec::box2d(1));
        let ours = run_method(&cfg, StencilSpec::box2d(1), 64, Method::Outer(p), true).unwrap();
        assert!(base.verified() && ours.verified());
        let s = speedup(&base, &ours);
        assert!(s > 1.5, "expected clear speedup, got {s:.2}×");
    }
}
