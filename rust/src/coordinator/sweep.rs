//! Generic parameter sweeps over verified simulation runs.

use crate::codegen::{run_method, Method, MethodResult, OuterParams};
use crate::stencil::StencilSpec;
use crate::sim::SimConfig;
use crate::tune::TuneDb;
use std::sync::Arc;

/// Source of tuned plans for [`Sweep`]'s `tuned` method variant.
#[derive(Debug, Clone)]
pub struct TunedSweep {
    /// The tuning database to resolve plans from.
    pub db: Arc<TuneDb>,
    /// Machine fingerprint the sweep's `cfg` corresponds to (see
    /// [`crate::sim::SimConfig::fingerprint`]).
    pub fingerprint: String,
}

impl TunedSweep {
    /// Tuned-plan source for a machine config.
    pub fn new(db: Arc<TuneDb>, cfg: &SimConfig) -> TunedSweep {
        TunedSweep { db, fingerprint: cfg.fingerprint() }
    }

    /// Resolve the method to run for a sweep cell: the database entry for
    /// the exact `(spec, n)` key, else the entry tuned at the largest
    /// size for `spec`, else the paper-default outer plan.
    pub fn resolve(&self, spec: StencilSpec, n: usize) -> Method {
        self.db
            .lookup(spec, n, &self.fingerprint)
            .or_else(|| self.db.best_for(spec, &self.fingerprint))
            .map(|e| e.plan.to_method())
            .unwrap_or(Method::Outer(OuterParams::paper_best(spec)))
    }
}

/// A cartesian sweep of (spec, size, method) cells.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    /// Stencils to sweep.
    pub specs: Vec<StencilSpec>,
    /// Domain sizes to sweep.
    pub sizes: Vec<usize>,
    /// Methods to sweep.
    pub methods: Vec<Method>,
    /// When set, each (spec, size) cell additionally runs the `tuned`
    /// method variant: the plan the tuning database holds for that cell
    /// (falling back to the paper default when the database has none).
    pub tuned: Option<TunedSweep>,
    /// Warm (steady-state) or cold caches.
    pub warm: bool,
}

impl Sweep {
    /// New warm sweep.
    pub fn new() -> Sweep {
        Sweep { warm: true, ..Default::default() }
    }

    /// Number of cells (the `tuned` variant counts as one method).
    pub fn len(&self) -> usize {
        self.specs.len() * self.sizes.len() * (self.methods.len() + self.tuned.is_some() as usize)
    }

    /// True when the sweep is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Run every cell, calling `progress` after each; all results are
    /// oracle-verified (an unverified run is an error).
    pub fn run(
        &self,
        cfg: &SimConfig,
        mut progress: impl FnMut(usize, usize, &MethodResult),
    ) -> anyhow::Result<Vec<MethodResult>> {
        let total = self.len();
        let mut out = Vec::with_capacity(total);
        for &spec in &self.specs {
            for &n in &self.sizes {
                let tuned_method = self.tuned.as_ref().map(|t| t.resolve(spec, n));
                for &method in self.methods.iter().chain(tuned_method.iter()) {
                    let res = run_method(cfg, spec, n, method, self.warm)?;
                    anyhow::ensure!(
                        res.verified(),
                        "sweep cell {spec} N={n} {method}: max_err {}",
                        res.max_err
                    );
                    progress(out.len() + 1, total, &res);
                    out.push(res);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::OuterParams;
    use crate::tune::{tune, Strategy};

    #[test]
    fn sweep_runs_all_cells() {
        let mut sweep = Sweep::new();
        sweep.specs = vec![StencilSpec::star2d(1)];
        sweep.sizes = vec![16, 32];
        sweep.methods = vec![
            Method::AutoVec,
            Method::Outer(OuterParams::paper_best(StencilSpec::star2d(1))),
        ];
        let mut seen = 0;
        let res = sweep.run(&SimConfig::default(), |_, _, _| seen += 1).unwrap();
        assert_eq!(res.len(), 4);
        assert_eq!(seen, 4);
    }

    #[test]
    fn tuned_variant_resolves_from_the_db_and_falls_back() {
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let mut db = TuneDb::new();
        let outcome = tune(&cfg, spec, 16, 3, Strategy::CostGuided).unwrap();
        db.record(&outcome);
        let tuned = TunedSweep::new(Arc::new(db), &cfg);

        // exact key hit
        assert_eq!(tuned.resolve(spec, 16), outcome.best().plan.to_method());
        // size miss → the entry tuned at the largest size for the spec
        assert_eq!(tuned.resolve(spec, 32), outcome.best().plan.to_method());
        // spec miss → paper default
        let other = StencilSpec::star3d(1);
        assert_eq!(tuned.resolve(other, 16), Method::Outer(OuterParams::paper_best(other)));

        let mut sweep = Sweep::new();
        sweep.specs = vec![spec];
        sweep.sizes = vec![16];
        sweep.methods = vec![Method::AutoVec];
        sweep.tuned = Some(tuned);
        assert_eq!(sweep.len(), 2);
        let res = sweep.run(&cfg, |_, _, _| {}).unwrap();
        assert_eq!(res.len(), 2);
        assert_eq!(res[1].method, outcome.best().plan.to_method());
    }
}
