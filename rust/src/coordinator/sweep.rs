//! Generic parameter sweeps over verified simulation runs.

use crate::codegen::{run_method, Method, MethodResult};
use crate::stencil::StencilSpec;
use crate::sim::SimConfig;

/// A cartesian sweep of (spec, size, method) cells.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    /// Stencils to sweep.
    pub specs: Vec<StencilSpec>,
    /// Domain sizes to sweep.
    pub sizes: Vec<usize>,
    /// Methods to sweep.
    pub methods: Vec<Method>,
    /// Warm (steady-state) or cold caches.
    pub warm: bool,
}

impl Sweep {
    /// New warm sweep.
    pub fn new() -> Sweep {
        Sweep { warm: true, ..Default::default() }
    }

    /// Number of cells.
    pub fn len(&self) -> usize {
        self.specs.len() * self.sizes.len() * self.methods.len()
    }

    /// True when the sweep is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Run every cell, calling `progress` after each; all results are
    /// oracle-verified (an unverified run is an error).
    pub fn run(
        &self,
        cfg: &SimConfig,
        mut progress: impl FnMut(usize, usize, &MethodResult),
    ) -> anyhow::Result<Vec<MethodResult>> {
        let total = self.len();
        let mut out = Vec::with_capacity(total);
        for &spec in &self.specs {
            for &n in &self.sizes {
                for &method in &self.methods {
                    let res = run_method(cfg, spec, n, method, self.warm)?;
                    anyhow::ensure!(
                        res.verified(),
                        "sweep cell {spec} N={n} {method}: max_err {}",
                        res.max_err
                    );
                    progress(out.len() + 1, total, &res);
                    out.push(res);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::OuterParams;

    #[test]
    fn sweep_runs_all_cells() {
        let mut sweep = Sweep::new();
        sweep.specs = vec![StencilSpec::star2d(1)];
        sweep.sizes = vec![16, 32];
        sweep.methods = vec![
            Method::AutoVec,
            Method::Outer(OuterParams::paper_best(StencilSpec::star2d(1))),
        ];
        let mut seen = 0;
        let res = sweep.run(&SimConfig::default(), |_, _, _| seen += 1).unwrap();
        assert_eq!(res.len(), 4);
        assert_eq!(seen, 4);
    }
}
