//! Named experiments: each regenerates one paper artifact (or all).

use crate::bench_harness::{ablation, fig3, fig4, fig5, table3, Report};
use crate::sim::SimConfig;
use std::str::FromStr;
use std::time::Instant;

/// The experiment catalogue (`stencil-matrix bench <name>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Experiment {
    /// Fig. 3 — CLS options for star stencils.
    Fig3,
    /// Fig. 4 — unrolling + scheduling ablation.
    Fig4,
    /// Fig. 5 — method comparison at r = 1.
    Fig5,
    /// Table 3 — full speedup matrix.
    Table3,
    /// Extra ablations (unroll sweep, register-count sensitivity).
    Ablations,
    /// Everything above.
    All,
}

impl FromStr for Experiment {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Experiment> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fig3" => Experiment::Fig3,
            "fig4" => Experiment::Fig4,
            "fig5" => Experiment::Fig5,
            "table3" => Experiment::Table3,
            "ablations" | "ablation" => Experiment::Ablations,
            "all" => Experiment::All,
            other => anyhow::bail!(
                "unknown experiment '{other}' (fig3|fig4|fig5|table3|ablations|all)"
            ),
        })
    }
}

/// Run an experiment; reports are printed and written to
/// `target/bench-reports/`.
pub fn run_experiment(cfg: &SimConfig, exp: Experiment) -> anyhow::Result<Vec<Report>> {
    let t0 = Instant::now();
    let reports = match exp {
        Experiment::Fig3 => fig3::run_all(cfg)?,
        Experiment::Fig4 => fig4::run_all(cfg)?,
        Experiment::Fig5 => fig5::run_all(cfg)?,
        Experiment::Table3 => table3::run_all(cfg)?,
        Experiment::Ablations => ablation::run_all(cfg)?,
        Experiment::All => {
            let mut all = fig3::run_all(cfg)?;
            all.extend(fig4::run_all(cfg)?);
            all.extend(fig5::run_all(cfg)?);
            all.extend(table3::run_all(cfg)?);
            all.extend(ablation::run_all(cfg)?);
            all
        }
    };
    for r in &reports {
        r.emit()?;
    }
    eprintln!(
        "[{exp:?}] {} report(s) in {:.1}s → {}",
        reports.len(),
        t0.elapsed().as_secs_f64(),
        Report::dir().display()
    );
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_parsing() {
        assert_eq!("fig3".parse::<Experiment>().unwrap(), Experiment::Fig3);
        assert_eq!("TABLE3".parse::<Experiment>().unwrap(), Experiment::Table3);
        assert!("fig9".parse::<Experiment>().is_err());
    }
}
