//! The PJRT evolution service: a small request loop over compiled
//! artifacts — the "request path" of the three-layer architecture
//! (Rust + compiled XLA only; Python never runs here).

use crate::runtime::{PjrtRuntime, Registry, StencilEngine};
use crate::stencil::DenseGrid;
use std::collections::HashMap;
use std::path::Path;

/// A request to advance a grid.
#[derive(Debug, Clone)]
pub struct EvolveRequest {
    /// Artifact name (see `artifacts/manifest.json`).
    pub artifact: String,
    /// Number of executions (each advances `artifact.steps` steps).
    pub executions: usize,
    /// Verify the result against the scalar oracle.
    pub verify: bool,
}

/// Serves evolve requests, caching compiled executables per artifact.
pub struct EvolutionService {
    runtime: PjrtRuntime,
    registry: Registry,
    engines: HashMap<String, StencilEngine>,
}

impl EvolutionService {
    /// Start the service over an artifact directory.
    pub fn new(artifact_dir: &Path) -> anyhow::Result<EvolutionService> {
        let runtime = PjrtRuntime::cpu()?;
        let registry = Registry::load(artifact_dir)?;
        Ok(EvolutionService { runtime, registry, engines: HashMap::new() })
    }

    /// Platform the service runs on.
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Artifact names available.
    pub fn artifacts(&self) -> Vec<String> {
        self.registry.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Compile (or fetch the cached) engine for an artifact.
    pub fn engine(&mut self, name: &str) -> anyhow::Result<&StencilEngine> {
        if !self.engines.contains_key(name) {
            let meta = self.registry.find(name)?.clone();
            let exe = self.runtime.compile(&meta)?;
            self.engines.insert(name.to_string(), StencilEngine::new(exe));
        }
        Ok(&self.engines[name])
    }

    /// Serve one request: build the deterministic verification input for
    /// the artifact's shape, evolve, and report.
    pub fn serve(
        &mut self,
        req: &EvolveRequest,
    ) -> anyhow::Result<(DenseGrid, crate::runtime::EvolutionReport)> {
        let engine = self.engine(&req.artifact)?;
        let shape = engine.meta().shape();
        let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
        engine.evolve(&grid, req.executions, req.verify)
    }
}
