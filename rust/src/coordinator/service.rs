//! Back-compat shim: the evolution service moved to
//! [`crate::serve::service`] when the serving subsystem grew its own
//! layer (domain decomposition, worker pool, batched front-end). The
//! coordinator remains a *driver* and delegates all serving to `serve`.

pub use crate::serve::service::{EvolutionService, EvolveRequest};
