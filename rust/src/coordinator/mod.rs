//! The L3 coordinator: experiment orchestration around the simulator and
//! the PJRT runtime.
//!
//! For this paper the system contribution lives in-core (L1/L2-of-the-
//! stack: the outer-product algorithm and its code generator), so L3 is a
//! *driver* per the architecture contract: CLI, experiment running,
//! sweeps, report collection, and the PJRT evolution service.

pub mod experiment;
pub mod service;
pub mod sweep;

pub use experiment::{run_experiment, Experiment};
pub use service::EvolutionService;
pub use sweep::{Sweep, TunedSweep};
