//! Prometheus-style text exposition of the serve metrics snapshot.
//!
//! [`render`] walks the JSON tree `StencilServer::metrics_json` already
//! produces and emits the [text exposition format]: nested object keys
//! are flattened with `_` (`service.kernel_time` →
//! `<prefix>_service_kernel_time`), plain numbers become gauges, and
//! latency-recorder snapshots (recognized by their `count` + `p50`/
//! `p50_s` keys) become `summary` families with `quantile` labels plus
//! `_sum`/`_count` and a `_max` gauge — so the existing counters
//! (`completed`, `coalesced`, `tuned_hits`, …) and histograms
//! (`kernel_time`, `halo_exchanges`, `fused_steps`) are scrapeable
//! without a second bookkeeping path that could drift from the JSON.
//!
//! [text exposition format]: https://prometheus.io/docs/instrumenting/exposition_formats/

use crate::util::json::Json;
use std::fmt::Write as _;

/// Render a metrics JSON tree as Prometheus text. `prefix` namespaces
/// every family (e.g. `stencil_serve`).
pub fn render(metrics: &Json, prefix: &str) -> String {
    let mut out = String::new();
    walk(metrics, &sanitize(prefix), &mut out);
    out
}

fn walk(v: &Json, path: &str, out: &mut String) {
    match v {
        Json::Obj(m) => {
            if let Some(rec) = recorder_fields(v) {
                emit_summary(path, &rec, out);
                return;
            }
            for (k, child) in m {
                walk(child, &format!("{path}_{}", sanitize(k)), out);
            }
        }
        Json::Num(n) => {
            let _ = writeln!(out, "# TYPE {path} gauge\n{path} {}", fmt(*n));
        }
        Json::Bool(b) => {
            let _ = writeln!(out, "# TYPE {path} gauge\n{path} {}", u8::from(*b));
        }
        Json::Str(s) => {
            // strings (engine name, …) carry no numeric value; surface
            // them as a comment so the exposition stays self-describing
            let _ = writeln!(out, "# {path} = {s:?}");
        }
        Json::Null | Json::Arr(_) => {}
    }
}

/// A latency-recorder snapshot's fields, normalized across the
/// seconds-suffixed (`p50_s`) and unit-less (`p50`) JSON variants.
struct Recorder {
    count: f64,
    mean: f64,
    max: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    window_len: Option<f64>,
}

fn recorder_fields(v: &Json) -> Option<Recorder> {
    let count = v.get("count")?.as_f64()?;
    let suffix = if v.get("p50_s").is_some() { "_s" } else { "" };
    let f = |k: &str| v.get(&format!("{k}{suffix}")).and_then(Json::as_f64);
    Some(Recorder {
        count,
        mean: f("mean")?,
        max: f("max")?,
        p50: f("p50")?,
        p95: f("p95")?,
        p99: f("p99")?,
        window_len: v.get("window_len").and_then(Json::as_f64),
    })
}

fn emit_summary(path: &str, r: &Recorder, out: &mut String) {
    let _ = writeln!(out, "# TYPE {path} summary");
    for (q, v) in [("0.5", r.p50), ("0.95", r.p95), ("0.99", r.p99)] {
        let _ = writeln!(out, "{path}{{quantile=\"{q}\"}} {}", fmt(v));
    }
    let _ = writeln!(out, "{path}_sum {}", fmt(r.mean * r.count));
    let _ = writeln!(out, "{path}_count {}", fmt(r.count));
    let _ = writeln!(out, "# TYPE {path}_max gauge\n{path}_max {}", fmt(r.max));
    if let Some(w) = r.window_len {
        let _ = writeln!(out, "# TYPE {path}_window_len gauge\n{path}_window_len {}", fmt(w));
    }
}

/// Metric-name characters are `[a-zA-Z0-9_:]`; anything else becomes `_`.
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' })
        .collect()
}

fn fmt(v: f64) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::LatencyRecorder;
    use crate::util::json::obj;

    #[test]
    fn counters_and_recorders_expose() {
        let mut rec = LatencyRecorder::default();
        for v in [0.5, 1.5, 2.5] {
            rec.record(v);
        }
        let metrics = obj(vec![
            (
                "service",
                obj(vec![
                    ("completed", Json::Num(64.0)),
                    ("kernel_time", rec.to_json()),
                    ("halo_exchanges", rec.to_json_counts()),
                ]),
            ),
            ("config", obj(vec![("engine", Json::Str("compiled".into()))])),
        ]);
        let text = render(&metrics, "stencil_serve");
        assert!(text.contains("# TYPE stencil_serve_service_completed gauge"), "{text}");
        assert!(text.contains("stencil_serve_service_completed 64"), "{text}");
        assert!(text.contains("# TYPE stencil_serve_service_kernel_time summary"), "{text}");
        assert!(
            text.contains("stencil_serve_service_kernel_time{quantile=\"0.5\"} 1.5"),
            "{text}"
        );
        assert!(text.contains("stencil_serve_service_kernel_time_count 3"), "{text}");
        assert!(text.contains("stencil_serve_service_kernel_time_sum 4.5"), "{text}");
        // the unit-less recorder variant is recognized too
        assert!(
            text.contains("stencil_serve_service_halo_exchanges{quantile=\"0.99\"} 2.5"),
            "{text}"
        );
        // strings surface as comments, not bogus samples
        assert!(text.contains("# stencil_serve_config_engine = \"compiled\""), "{text}");
        // every sample line is NAME VALUE (2 space-separated fields)
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad sample line: {line}");
            let val = line.split(' ').nth(1).unwrap();
            assert!(val.parse::<f64>().is_ok(), "unparseable value in: {line}");
        }
    }

    #[test]
    fn names_are_sanitized() {
        let metrics = obj(vec![("queue-depth", Json::Num(32.0))]);
        let text = render(&metrics, "x");
        assert!(text.contains("x_queue_depth 32"), "{text}");
    }
}
