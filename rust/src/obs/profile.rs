//! The profile model: aggregate drained spans into a per-phase
//! wall-clock breakdown — embed vs compute vs freeze vs halo-exchange
//! vs extract seconds.
//!
//! A span stream answers "what happened when"; benchmarks need "where
//! did the time go". [`aggregate`] folds the five attributable phase
//! spans into a [`PhaseProfile`]:
//!
//! | phase      | span                  | recorded in                 |
//! |------------|-----------------------|-----------------------------|
//! | `embed`    | `kernel.embed`        | `kir::kernel::apply_with`   |
//! | `compute`  | `kir.compute`         | `kir::exec` / interpreter   |
//! | `freeze`   | `kir.freeze`          | `kir::exec` freeze sections |
//! | `exchange` | `serve.halo_exchange` | `serve::halo`               |
//! | `extract`  | `kernel.extract`      | `kir::kernel::apply_with`   |
//!
//! Only these leaf-phase spans are summed — enclosing spans
//! (`serve.kernel`, `serve.dispatch`) and finer-grained children
//! (`kir.row_group`, which nests *inside* `kir.compute`) are excluded
//! so no nanosecond is counted twice. Durations are summed across all
//! threads, so on a parallel section the profile reports aggregate CPU
//! seconds, not wall-clock.
//!
//! `shard-bench` and `engine-bench` render profiles as markdown job
//! tables, and the bench snapshot (`BENCH_8.json`, v6) embeds them
//! machine-readably so `bench-compare` can say *which phase* moved.

use super::span::ThreadEvents;
use crate::util::bench::{fmt_secs, Table};
use crate::util::json::{obj, Json};

/// Per-phase aggregate seconds over one traced region.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseProfile {
    /// Tile → padded-domain embedding (`kernel.embed`).
    pub embed_s: f64,
    /// Kernel compute sections (`kir.compute`), both engines.
    pub compute_s: f64,
    /// Inter-step freeze phases of fused programs (`kir.freeze`).
    pub freeze_s: f64,
    /// Halo-exchange rounds (`serve.halo_exchange`).
    pub exchange_s: f64,
    /// Padded domain → tile extraction (`kernel.extract`).
    pub extract_s: f64,
    /// Completed spans that contributed to any phase.
    pub spans: usize,
}

impl PhaseProfile {
    /// Sum over the five phases.
    pub fn total(&self) -> f64 {
        self.embed_s + self.compute_s + self.freeze_s + self.exchange_s + self.extract_s
    }

    /// `(label, seconds)` per phase, in pipeline order.
    pub fn phases(&self) -> [(&'static str, f64); 5] {
        [
            ("embed", self.embed_s),
            ("compute", self.compute_s),
            ("freeze", self.freeze_s),
            ("exchange", self.exchange_s),
            ("extract", self.extract_s),
        ]
    }

    /// Machine-readable form for the bench snapshot.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = self
            .phases()
            .iter()
            .map(|&(name, s)| (phase_key(name), Json::Num(s)))
            .collect();
        pairs.push(("spans", Json::Num(self.spans as f64)));
        obj(pairs)
    }

    /// Parse the [`Self::to_json`] form (absent/malformed fields read
    /// as zero so older snapshots degrade instead of erroring).
    pub fn from_json(j: &Json) -> PhaseProfile {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        PhaseProfile {
            embed_s: f("embed_s"),
            compute_s: f("compute_s"),
            freeze_s: f("freeze_s"),
            exchange_s: f("exchange_s"),
            extract_s: f("extract_s"),
            spans: f("spans") as usize,
        }
    }
}

fn phase_key(name: &'static str) -> &'static str {
    match name {
        "embed" => "embed_s",
        "compute" => "compute_s",
        "freeze" => "freeze_s",
        "exchange" => "exchange_s",
        "extract" => "extract_s",
        _ => unreachable!("unknown phase"),
    }
}

/// Fold a drained span stream into per-phase seconds. Unmatched or
/// foreign spans are ignored; per-thread streams are matched with a
/// stack, so nested same-name spans pair correctly.
pub fn aggregate(threads: &[ThreadEvents]) -> PhaseProfile {
    let mut p = PhaseProfile::default();
    for t in threads {
        let mut stack: Vec<(&'static str, u64)> = Vec::new();
        for e in &t.events {
            if e.begin {
                stack.push((e.name, e.ts_ns));
            } else if let Some((name, t0)) = stack.pop() {
                let secs = e.ts_ns.saturating_sub(t0) as f64 / 1e9;
                let slot = match name {
                    "kernel.embed" => Some(&mut p.embed_s),
                    "kir.compute" => Some(&mut p.compute_s),
                    "kir.freeze" => Some(&mut p.freeze_s),
                    "serve.halo_exchange" => Some(&mut p.exchange_s),
                    "kernel.extract" => Some(&mut p.extract_s),
                    _ => None,
                };
                if let Some(slot) = slot {
                    *slot += secs;
                    p.spans += 1;
                }
            }
        }
    }
    p
}

/// Publish `p` as the process's most recent traced window, served by
/// the live `/profile` endpoint ([`crate::obs::live`]).
pub fn publish(p: &PhaseProfile) {
    *latest_slot().lock().unwrap() = Some(*p);
}

/// The most recently published profile, if any traced window ran.
pub fn latest() -> Option<PhaseProfile> {
    *latest_slot().lock().unwrap()
}

/// The `/profile` endpoint body: the latest profile's JSON, or a
/// `status` stub when no traced window has run yet.
pub fn latest_json() -> Json {
    match latest() {
        Some(p) => p.to_json(),
        None => obj(vec![("status", Json::Str("no traced window yet".into()))]),
    }
}

fn latest_slot() -> &'static std::sync::Mutex<Option<PhaseProfile>> {
    static LATEST: std::sync::OnceLock<std::sync::Mutex<Option<PhaseProfile>>> =
        std::sync::OnceLock::new();
    LATEST.get_or_init(|| std::sync::Mutex::new(None))
}

/// Render labeled profiles as a markdown breakdown table (the
/// `engine-bench`/`shard-bench` job-summary form).
pub fn to_markdown(rows: &[(String, PhaseProfile)]) -> String {
    let mut table =
        Table::new(&["config", "embed", "compute", "freeze", "exchange", "extract", "total"]);
    for (label, p) in rows {
        let mut cells = vec![label.clone()];
        cells.extend(p.phases().iter().map(|&(_, s)| fmt_secs(s)));
        cells.push(fmt_secs(p.total()));
        table.row(cells);
    }
    table.to_markdown()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{Event, ThreadEvents};

    fn ev(name: &'static str, begin: bool, ts_ns: u64) -> Event {
        Event { name, cat: "test", begin, ts_ns, arg: None }
    }

    #[test]
    fn aggregates_phase_spans_and_ignores_the_rest() {
        let threads = vec![
            ThreadEvents {
                tid: 1,
                name: "a".into(),
                events: vec![
                    ev("serve.kernel", true, 0),
                    ev("kernel.embed", true, 100),
                    ev("kernel.embed", false, 1_100),
                    ev("kir.compute", true, 2_000),
                    ev("kir.row_group", true, 2_100), // nested child: excluded
                    ev("kir.row_group", false, 2_600),
                    ev("kir.compute", false, 5_000),
                    ev("kernel.extract", true, 5_000),
                    ev("kernel.extract", false, 5_500),
                    ev("serve.kernel", false, 6_000), // enclosing: excluded
                ],
            },
            ThreadEvents {
                tid: 2,
                name: "b".into(),
                events: vec![
                    ev("serve.halo_exchange", true, 0),
                    ev("serve.halo_exchange", false, 4_000),
                ],
            },
        ];
        let p = aggregate(&threads);
        assert_eq!(p.spans, 4);
        assert!((p.embed_s - 1e-6).abs() < 1e-12);
        assert!((p.compute_s - 3e-6).abs() < 1e-12);
        assert!((p.exchange_s - 4e-6).abs() < 1e-12);
        assert!((p.extract_s - 0.5e-6).abs() < 1e-12);
        assert_eq!(p.freeze_s, 0.0);
        assert!((p.total() - 8.5e-6).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_and_markdown() {
        let p = PhaseProfile {
            embed_s: 0.25,
            compute_s: 1.5,
            freeze_s: 0.125,
            exchange_s: 0.5,
            extract_s: 0.0625,
            spans: 9,
        };
        let back = PhaseProfile::from_json(&p.to_json());
        assert_eq!(back, p);
        // degraded parse of a foreign object reads as zeros
        assert_eq!(PhaseProfile::from_json(&Json::Null), PhaseProfile::default());
        let md = to_markdown(&[("compiled T=4".into(), p)]);
        assert!(md.contains("| config | embed | compute | freeze | exchange | extract | total |"));
        assert!(md.contains("compiled T=4"), "{md}");
        assert!(md.contains("1.50 s"), "{md}");
    }

    #[test]
    fn published_profile_is_served_as_latest() {
        let p = PhaseProfile { compute_s: 2.0, spans: 3, ..PhaseProfile::default() };
        publish(&p);
        assert_eq!(latest(), Some(p));
        let j = latest_json();
        assert_eq!(j.get("compute_s").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("spans").and_then(Json::as_usize), Some(3));
    }
}
