//! End-to-end observability: structured spans, Chrome-trace export,
//! Prometheus-style metrics exposition, and per-phase profiles.
//!
//! The simulator side of this repo is perfectly observable — cycles are
//! deterministic and attributable by construction. The host side (the
//! compiled engine, sharded serving, fused time tiles) is real threads
//! on real hardware, where until now only coarse JSON aggregates
//! existed. This subsystem makes host time attributable:
//!
//! - [`span`] — the low-overhead span core: thread-local event buffers,
//!   one monotonic process epoch, RAII guards, and a global switch.
//!   Disabled (the default), an instrumented call site costs one relaxed
//!   atomic load; enabled, recording a span is two `Instant` reads and
//!   two buffer pushes behind an uncontended thread-local mutex.
//!   [`span::trace`] wraps a closure in an enable→run→drain session,
//!   serialized globally so concurrent sessions can't interleave;
//! - [`chrome`] — exports drained spans as Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto), one named track per thread, with
//!   a structural [`chrome::validate`] pass (balanced + nested B/E,
//!   monotonic timestamps) run on every CLI trace write;
//! - [`prom`] — Prometheus text exposition rendered generically from
//!   the serve metrics JSON tree (counters → gauges, latency recorders
//!   → `summary` families with quantile labels), so the exposition can
//!   never drift from the JSON snapshot;
//! - [`profile`] — aggregates spans into the per-phase breakdown
//!   (embed / compute / freeze / exchange / extract seconds) rendered
//!   in `engine-bench`/`shard-bench` summaries and embedded in the
//!   `BENCH_8.json` snapshot so `bench-compare` can attribute host
//!   regressions to a phase; also holds the most recent traced window
//!   for the live `/profile` endpoint;
//! - [`registry`] — the global live-metrics registry: cumulative atomic
//!   counters, gauges and fixed-bucket streaming histograms, fed
//!   continuously by the serving hot paths (service counters, per-shard
//!   kernel time, pool steal counts, halo-exchange waits, row-group
//!   throughput) and rendered as scrape-aggregatable Prometheus text
//!   (`_total` counters, `_bucket{le=...}` histograms);
//! - [`live`] — a std-only blocking HTTP/1.1 listener
//!   (`serve --listen-metrics <addr>`) serving `GET /metrics`
//!   (registry + snapshot exposition), `GET /healthz` (queue depth,
//!   worker liveness, last-request age, shard-imbalance verdict) and
//!   `GET /profile` (the latest traced per-phase window);
//! - [`audit`] — the cost-model accuracy auditor: for every compiled
//!   plan the server runs, records measured kernel seconds per
//!   point-step next to `tune/cost.rs`'s predicted cycles/traffic,
//!   maintains per-(spec, shape, fingerprint) model-error statistics
//!   under `stencil_cost_model_*`, and dumps the `cost-audit.json`
//!   artifact.
//!
//! # Span taxonomy
//!
//! | span                  | cat      | where                                   | arg        |
//! |-----------------------|----------|-----------------------------------------|------------|
//! | `serve.enqueue`       | `serve`  | request admission (`service::admit`)    | —          |
//! | `serve.coalesce`      | `serve`  | merge into an identical queued request  | —          |
//! | `serve.dispatch`      | `serve`  | dispatcher handling one request         | —          |
//! | `serve.kernel`        | `serve`  | one shard's kernel application          | `shard`    |
//! | `serve.halo_exchange` | `serve`  | one shard's ghost refresh               | `shard`    |
//! | `pool.batch`          | `serve`  | one worker-pool batch barrier           | `jobs`     |
//! | `kernel.embed`        | `kernel` | tile → padded-domain embedding          | —          |
//! | `kernel.extract`      | `kernel` | padded domain → tile extraction         | —          |
//! | `kir.compute`         | `kir`    | one compute section (either engine)     | `step`     |
//! | `kir.freeze`          | `kir`    | one inter-step freeze section           | `step`     |
//! | `kir.row_group`       | `kir`    | one independent block of a Par section  | `block`    |
//! | `tune.measure`        | `tune`   | one candidate's simulator measurement   | `candidate`|
//! | `cluster.round`       | `cluster`| one fleet chunk round (T fused steps)   | `steps`    |
//! | `cluster.rpc`         | `cluster`| draining one node's pipelined replies   | `chunks`   |
//! | `cluster.exchange`    | `cluster`| coordinator-mediated deep-halo exchange | —          |
//! | `cluster.peer_exchange` | `cluster`| node-side band waits + ghost refresh + boundary finish | — |
//!
//! Consumers: `serve --trace-out`/`--metrics-out`/`--listen-metrics`,
//! `engine-bench --trace-out`, the `shard-bench`/`engine-bench`
//! per-phase tables, the bench snapshot, and CI (which captures,
//! validates, and uploads a serve trace on every build, and live-scrapes
//! `/metrics` + `/healthz` on every build). The overhead budget, the
//! checklist for adding a span, and the metric naming/typing conventions
//! for the registry live in CONTRIBUTING.md.

pub mod audit;
pub mod chrome;
pub mod live;
pub mod profile;
pub mod prom;
pub mod registry;
pub mod span;

pub use profile::PhaseProfile;
pub use span::{SpanGuard, ThreadEvents};
