//! Live observability endpoint: a minimal blocking HTTP/1.1 listener
//! (std-only — `TcpListener` + one short-lived thread per connection)
//! serving the running process's telemetry:
//!
//! - `GET /metrics` — Prometheus text exposition: the global
//!   [`crate::obs::registry`] (cumulative counters, gauges, streaming
//!   `_bucket` histograms) plus whatever snapshot text the caller's
//!   source closure appends (the server wires in
//!   [`crate::obs::prom::render`] over its JSON metrics).
//! - `GET /healthz` — JSON liveness verdict (queue depth, worker
//!   liveness, last-request age, shard-imbalance verdict).
//! - `GET /profile` — JSON per-phase breakdown of the most recent
//!   traced window ([`crate::obs::profile::latest`]).
//!
//! Malformed requests get `400`, unknown paths `404`; each connection
//! is handled on its own thread with read/write timeouts, so a slow or
//! broken client can never wedge the accept loop. Bind to port `0` for
//! an ephemeral port and read it back from [`LiveServer::addr`].

use super::registry;
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Producer of the `/metrics` text body.
pub type TextSource = Arc<dyn Fn() -> String + Send + Sync>;
/// Producer of a JSON endpoint body (`/healthz`, `/profile`).
pub type JsonSource = Arc<dyn Fn() -> Json + Send + Sync>;

/// The three endpoint bodies, produced fresh per request.
#[derive(Clone)]
pub struct LiveSources {
    /// `/metrics` body (Prometheus text). The global registry is
    /// rendered *in addition* to this text.
    pub metrics_text: TextSource,
    /// `/healthz` body.
    pub health_json: JsonSource,
    /// `/profile` body.
    pub profile_json: JsonSource,
}

impl LiveSources {
    /// Sources exposing only the global registry, an `ok` health verdict
    /// and the latest traced profile — enough for tools and tests that
    /// have no serving state to wire in.
    pub fn registry_only() -> LiveSources {
        LiveSources {
            metrics_text: Arc::new(String::new),
            health_json: Arc::new(|| {
                Json::Obj([("status".to_string(), Json::Str("ok".into()))].into_iter().collect())
            }),
            profile_json: Arc::new(super::profile::latest_json),
        }
    }
}

/// Handle to a running listener; shuts down on [`LiveServer::shutdown`]
/// or drop.
pub struct LiveServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl LiveServer {
    /// The address actually bound (resolves an ephemeral `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Idempotent; also runs
    /// on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for LiveServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind `addr` (e.g. `127.0.0.1:9184`, or port `0` for ephemeral) and
/// serve `sources` until shutdown.
pub fn serve(addr: &str, sources: LiveSources) -> anyhow::Result<LiveServer> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("cannot bind metrics listener on {addr}: {e}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_accept = Arc::clone(&stop);
    let accept = std::thread::Builder::new()
        .name("stencil-live-accept".to_string())
        .spawn(move || {
            while !stop_accept.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let sources = sources.clone();
                        let _ = std::thread::Builder::new()
                            .name("stencil-live-conn".to_string())
                            .spawn(move || handle_conn(stream, &sources));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        })
        .expect("failed to spawn live-metrics accept thread");
    Ok(LiveServer { addr: local, stop, accept: Some(accept) })
}

fn handle_conn(mut stream: TcpStream, sources: &LiveSources) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() >= 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let (status, content_type, body) = respond(&buf, sources);
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: \
         {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// Route one raw request to (status, content type, body).
fn respond(raw: &[u8], sources: &LiveSources) -> (u16, &'static str, String) {
    let text = String::from_utf8_lossy(raw);
    let line = text.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return (400, "text/plain", "malformed request\n".to_string());
    };
    if method != "GET" || !version.starts_with("HTTP/") {
        return (400, "text/plain", "only GET is supported\n".to_string());
    }
    let path = path.split('?').next().unwrap_or(path);
    let scrape = |endpoint: &str| {
        registry::global()
            .counter_with("stencil_live_scrapes_total", &format!("path=\"{endpoint}\""))
            .inc();
    };
    match path {
        "/metrics" => {
            scrape("metrics");
            let mut body = registry::global().render();
            body.push_str(&(sources.metrics_text)());
            (200, "text/plain; version=0.0.4", body)
        }
        "/healthz" => {
            scrape("healthz");
            let mut body = (sources.health_json)().to_string_compact();
            body.push('\n');
            (200, "application/json", body)
        }
        "/profile" => {
            scrape("profile");
            let mut body = (sources.profile_json)().to_string_compact();
            body.push('\n');
            (200, "application/json", body)
        }
        _ => (404, "text/plain", "not found\n".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal HTTP client: send `request` verbatim, return (status,
    /// body).
    fn raw_request(addr: SocketAddr, request: &str) -> (u16, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(request.as_bytes()).unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        let status: u16 =
            response.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        let body = response.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        raw_request(addr, &format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n"))
    }

    #[test]
    fn endpoints_respond_and_errors_do_not_wedge() {
        registry::global().counter("test_live_total").inc();
        let mut srv = serve("127.0.0.1:0", LiveSources::registry_only()).unwrap();
        let addr = srv.addr();
        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("test_live_total"), "{body}");
        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).is_ok(), "{body}");
        let (status, body) = get(addr, "/profile");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).is_ok(), "{body}");
        // unknown path and malformed request line
        assert_eq!(get(addr, "/nope").0, 404);
        assert_eq!(raw_request(addr, "BLARG\r\n\r\n").0, 400);
        assert_eq!(raw_request(addr, "PUT /metrics HTTP/1.1\r\n\r\n").0, 400);
        // the listener survives the abuse
        assert_eq!(get(addr, "/metrics").0, 200);
        srv.shutdown();
        assert!(TcpStream::connect(addr).is_err() || get_after_shutdown(addr));
    }

    /// After shutdown the accept thread is gone; a connection may still
    /// be accepted by the OS backlog but never answered. Treat "no
    /// response" as success.
    fn get_after_shutdown(addr: SocketAddr) -> bool {
        let Ok(mut stream) = TcpStream::connect(addr) else { return true };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
        let _ = stream.write_all(b"GET /metrics HTTP/1.1\r\n\r\n");
        let mut out = String::new();
        stream.read_to_string(&mut out).is_err() || out.is_empty()
    }
}
