//! Low-overhead structured spans: thread-local event buffers, monotonic
//! timestamps, and a global on/off switch.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled means free.** A `span()` call with recording off is one
//!    relaxed atomic load returning an inert guard — no thread-local
//!    access, no allocation, no timestamp read. Instrumented hot paths
//!    (the compiled engine's row groups, the scheduler's per-shard jobs)
//!    must stay within noise of their uninstrumented selves.
//! 2. **Per-thread streams are well-formed by construction.** Every
//!    thread buffers its own events behind a rarely-contended mutex
//!    (only `drain` ever takes it from another thread), the begin event
//!    is recorded at guard creation and the end event at guard drop, and
//!    timestamps come from one process-wide monotonic epoch — so each
//!    thread's stream is balanced, properly nested, and non-decreasing
//!    in time without any exporter-side sorting or repair.
//! 3. **No spooky cross-talk.** A guard created while recording was off
//!    stays inert for its whole life (it does not record a dangling end
//!    event after `enable`), and [`trace`] serializes whole sessions
//!    behind a global mutex so concurrent callers (tests) never observe
//!    each other's spans.
//!
//! Buffers belong to a process-wide registry and survive thread exit
//! (the registry holds the owning `Arc`), so events recorded by a
//! short-lived worker are still visible to a later [`drain`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Global recording switch. Off by default.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Dense trace-local thread ids, assigned on a thread's first recorded
/// event and stable for the life of the process.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// One begin or end event. `ts_ns` is nanoseconds since the process
/// trace epoch (a monotonic [`Instant`], so a thread's event stream is
/// non-decreasing by construction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Span name (e.g. `serve.halo_exchange`); `&'static` so recording
    /// never allocates.
    pub name: &'static str,
    /// Category (the subsystem: `serve`, `kir`, `kernel`, `tune`, …).
    pub cat: &'static str,
    /// `true` for the begin event, `false` for the matching end.
    pub begin: bool,
    /// Nanoseconds since the trace epoch.
    pub ts_ns: u64,
    /// Optional numeric argument attached to the begin event (shard or
    /// block index, fused-step number, …).
    pub arg: Option<(&'static str, f64)>,
}

/// One thread's drained event stream.
#[derive(Debug, Clone)]
pub struct ThreadEvents {
    /// Trace-local thread id (dense, assignment order).
    pub tid: u64,
    /// OS thread name at first event (workers are named; unnamed threads
    /// get `thread-<tid>`).
    pub name: String,
    /// The events, in recording order (chronological per thread).
    pub events: Vec<Event>,
}

struct ThreadBuf {
    tid: u64,
    name: String,
    events: Mutex<Vec<Event>>,
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static BUF: Arc<ThreadBuf> = {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(|n| n.to_string())
            .unwrap_or_else(|| format!("thread-{tid}"));
        let buf = Arc::new(ThreadBuf { tid, name, events: Mutex::new(Vec::new()) });
        registry().lock().unwrap().push(Arc::clone(&buf));
        buf
    };
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn push(ev: Event) {
    BUF.with(|b| b.events.lock().unwrap().push(ev));
}

/// Turn recording on (idempotent; pins the trace epoch on first use).
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn recording off. Guards opened while recording was on still
/// record their end events, keeping every stream balanced.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Whether spans are currently being recorded.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span guard: begin event at creation, matching end event at
/// drop. Created inert when recording is off (records nothing, ever).
#[must_use = "a span measures the scope it is alive for"]
pub struct SpanGuard {
    live: bool,
    name: &'static str,
    cat: &'static str,
}

/// Open a span. One relaxed atomic load when recording is disabled.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    open(name, cat, None)
}

/// Open a span with one numeric argument attached to its begin event.
#[inline]
pub fn span_arg(name: &'static str, cat: &'static str, arg: (&'static str, f64)) -> SpanGuard {
    open(name, cat, Some(arg))
}

#[inline]
fn open(
    name: &'static str,
    cat: &'static str,
    arg: Option<(&'static str, f64)>,
) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: false, name, cat };
    }
    push(Event { name, cat, begin: true, ts_ns: now_ns(), arg });
    SpanGuard { live: true, name, cat }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            push(Event { name: self.name, cat: self.cat, begin: false, ts_ns: now_ns(), arg: None });
        }
    }
}

/// Drain every thread's buffered events (clearing the buffers), ordered
/// by thread id. Threads that recorded nothing are omitted.
pub fn drain() -> Vec<ThreadEvents> {
    let bufs = registry().lock().unwrap();
    let mut out: Vec<ThreadEvents> = bufs
        .iter()
        .filter_map(|b| {
            let events = std::mem::take(&mut *b.events.lock().unwrap());
            if events.is_empty() {
                None
            } else {
                Some(ThreadEvents { tid: b.tid, name: b.name.clone(), events })
            }
        })
        .collect();
    out.sort_by_key(|t| t.tid);
    out
}

/// Run `f` with recording enabled and return its result together with
/// the spans it recorded. Sessions are serialized behind a global
/// mutex, so concurrent callers (e.g. parallel tests) never observe
/// each other's spans; any stray events left over from an unserialized
/// `enable`/`disable` pair are discarded at session start.
pub fn trace<R>(f: impl FnOnce() -> R) -> (R, Vec<ThreadEvents>) {
    static SESSION: OnceLock<Mutex<()>> = OnceLock::new();
    let session = SESSION.get_or_init(|| Mutex::new(()));
    let _guard = session.lock().unwrap_or_else(|p| p.into_inner());
    let _ = drain();
    enable();
    let out = f();
    disable();
    (out, drain())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let ((), threads) = trace(|| {
            disable(); // recording off inside the session
            let g = span("quiet", "test");
            drop(g);
        });
        assert!(threads.is_empty(), "disabled span left events: {threads:?}");
    }

    #[test]
    fn spans_nest_and_balance_on_one_thread() {
        let ((), threads) = trace(|| {
            let outer = span("outer", "test");
            {
                let _inner = span_arg("inner", "test", ("k", 3.0));
            }
            drop(outer);
        });
        assert_eq!(threads.len(), 1);
        let ev = &threads[0].events;
        assert_eq!(ev.len(), 4);
        let names: Vec<(&str, bool)> = ev.iter().map(|e| (e.name, e.begin)).collect();
        assert_eq!(
            names,
            vec![("outer", true), ("inner", true), ("inner", false), ("outer", false)]
        );
        assert_eq!(ev[1].arg, Some(("k", 3.0)));
        assert!(ev.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns), "timestamps decrease");
    }

    #[test]
    fn threads_get_their_own_tracks() {
        let ((), threads) = trace(|| {
            let _a = span("main-side", "test");
            std::thread::Builder::new()
                .name("obs-test-worker".into())
                .spawn(|| {
                    let _b = span("worker-side", "test");
                })
                .unwrap()
                .join()
                .unwrap();
        });
        assert_eq!(threads.len(), 2);
        let worker = threads
            .iter()
            .find(|t| t.events.iter().any(|e| e.name == "worker-side"))
            .expect("worker track present");
        assert_eq!(worker.name, "obs-test-worker");
        assert_eq!(worker.events.len(), 2);
    }

    #[test]
    fn guard_opened_while_disabled_stays_inert_across_enable() {
        let ((), threads) = trace(|| {
            disable();
            let g = span("ghost", "test");
            enable();
            drop(g); // must not record a dangling end event
            let _live = span("real", "test");
        });
        let all: Vec<&str> =
            threads.iter().flat_map(|t| t.events.iter().map(|e| e.name)).collect();
        assert_eq!(all, vec!["real", "real"]);
    }
}
