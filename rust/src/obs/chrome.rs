//! Chrome trace-event JSON export: render drained spans as a document
//! `chrome://tracing` and Perfetto load directly.
//!
//! The format is the ["Trace Event Format"] JSON object flavour:
//! `{"traceEvents": [...]}` where each span contributes a `"B"` (begin)
//! and `"E"` (end) event with microsecond `ts` timestamps, and every
//! thread gets an `"M"` (metadata) `thread_name` event so worker tracks
//! are labeled (`stencil-worker-0`, `kir-worker-1`, …) instead of
//! numbered. One process (`pid` 1), one track per recorded thread.
//!
//! ["Trace Event Format"]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! [`validate`] re-parses a document and checks the structural
//! invariants exporters must uphold (balanced and properly nested B/E
//! pairs per thread, non-decreasing timestamps) — the serve CLI runs it
//! on every `--trace-out` write, so a malformed trace fails the smoke
//! run instead of failing later in a viewer.

use super::span::{Event, ThreadEvents};
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;

/// Process id stamped on every event (single-process traces).
const PID: f64 = 1.0;

/// Render drained spans as a Chrome trace-event document.
pub fn to_chrome_json(threads: &[ThreadEvents]) -> Json {
    let mut events = Vec::new();
    for t in threads {
        events.push(obj(vec![
            ("name", Json::Str("thread_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(PID)),
            ("tid", Json::Num(t.tid as f64)),
            ("args", obj(vec![("name", Json::Str(t.name.clone()))])),
        ]));
        for e in &t.events {
            events.push(event_json(t.tid, e));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

fn event_json(tid: u64, e: &Event) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(e.name.to_string())),
        ("cat", Json::Str(e.cat.to_string())),
        ("ph", Json::Str(if e.begin { "B" } else { "E" }.to_string())),
        ("pid", Json::Num(PID)),
        ("tid", Json::Num(tid as f64)),
        // trace-event timestamps are microseconds; fractional µs keep
        // the full nanosecond resolution
        ("ts", Json::Num(e.ts_ns as f64 / 1000.0)),
    ];
    if let Some((k, v)) = e.arg {
        pairs.push(("args", obj(vec![(k, Json::Num(v))])));
    }
    obj(pairs)
}

/// Validate a Chrome trace-event document structurally and return the
/// span-name counts (completed B/E pairs per name).
///
/// Checks, per `tid`: every `"E"` closes the most recent open `"B"` of
/// the same name (proper nesting), no unclosed spans remain, and
/// timestamps never decrease. `"M"` metadata events are skipped.
pub fn validate(doc: &Json) -> anyhow::Result<BTreeMap<String, usize>> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no traceEvents array"))?;
    let mut open: BTreeMap<i64, Vec<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<i64, f64> = BTreeMap::new();
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no ph"))?;
        if ph == "M" {
            continue;
        }
        anyhow::ensure!(ph == "B" || ph == "E", "event {i} has unknown ph '{ph}'");
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no name"))?;
        let tid = e
            .get("tid")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no tid"))? as i64;
        let ts = e
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no ts"))?;
        let prev = last_ts.entry(tid).or_insert(ts);
        anyhow::ensure!(
            ts >= *prev,
            "event {i} ({name}): ts went backwards on tid {tid} ({ts} < {prev})"
        );
        *prev = ts;
        let stack = open.entry(tid).or_default();
        if ph == "B" {
            stack.push(name.to_string());
        } else {
            let top = stack
                .pop()
                .ok_or_else(|| anyhow::anyhow!("event {i}: E '{name}' with no open B on tid {tid}"))?;
            anyhow::ensure!(
                top == name,
                "event {i}: E '{name}' closes open span '{top}' on tid {tid} (bad nesting)"
            );
            *counts.entry(top).or_insert(0) += 1;
        }
    }
    for (tid, stack) in &open {
        anyhow::ensure!(
            stack.is_empty(),
            "tid {tid} has {} unclosed span(s): {stack:?}",
            stack.len()
        );
    }
    Ok(counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span;

    #[test]
    fn export_roundtrips_and_validates() {
        let ((), threads) = span::trace(|| {
            let _a = span::span("alpha", "test");
            let _b = span::span_arg("beta", "test", ("shard", 2.0));
        });
        let doc = to_chrome_json(&threads);
        // survives a serialize → parse round trip
        let back = Json::parse(&doc.to_string_compact()).unwrap();
        let counts = validate(&back).unwrap();
        assert_eq!(counts.get("alpha"), Some(&1));
        assert_eq!(counts.get("beta"), Some(&1));
        // thread metadata present
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| {
            e.get("ph").and_then(Json::as_str) == Some("M")
                && e.get("name").and_then(Json::as_str) == Some("thread_name")
        }));
        // the argument rides on the begin event
        assert!(events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("beta")
                && e.get("args").and_then(|a| a.get("shard")).and_then(Json::as_f64)
                    == Some(2.0)
        }));
    }

    #[test]
    fn validate_rejects_malformed_traces() {
        let ev = |name: &str, ph: &str, ts: f64| {
            obj(vec![
                ("name", Json::Str(name.into())),
                ("ph", Json::Str(ph.into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(1.0)),
                ("ts", Json::Num(ts)),
            ])
        };
        // unbalanced: B with no E
        let doc = obj(vec![("traceEvents", Json::Arr(vec![ev("a", "B", 0.0)]))]);
        assert!(validate(&doc).unwrap_err().to_string().contains("unclosed"));
        // bad nesting: E closes the wrong span
        let doc = obj(vec![(
            "traceEvents",
            Json::Arr(vec![ev("a", "B", 0.0), ev("b", "B", 1.0), ev("a", "E", 2.0)]),
        )]);
        assert!(validate(&doc).unwrap_err().to_string().contains("nesting"));
        // time going backwards on one tid
        let doc = obj(vec![(
            "traceEvents",
            Json::Arr(vec![ev("a", "B", 5.0), ev("a", "E", 1.0)]),
        )]);
        assert!(validate(&doc).unwrap_err().to_string().contains("backwards"));
        // not a trace document at all
        assert!(validate(&Json::Num(3.0)).is_err());
    }
}
