//! Global metrics registry: cumulative counters, gauges, and fixed-bucket
//! streaming histograms behind atomic handles, rendered in Prometheus
//! text exposition format for the live `/metrics` endpoint
//! ([`crate::obs::live`]).
//!
//! This complements the end-of-run JSON snapshot ([`crate::obs::prom`]
//! over `serve/metrics.rs`): the snapshot summarizes one run after the
//! fact, while the registry is fed *continuously* by the serving hot
//! paths and is **aggregatable across scrapes** — counters are monotone
//! totals and histograms expose cumulative `_bucket{le="..."}` counts
//! plus `_sum`/`_count`, so `rate()` and `histogram_quantile()` work at
//! any scrape interval. Everything is std-only: the record path is a
//! handful of relaxed atomic operations on a pre-fetched handle; the
//! only mutex guards registration and rendering.
//!
//! Conventions (see CONTRIBUTING.md): families are `stencil_*`,
//! counters end in `_total`, second-valued histograms end in
//! `_seconds`, and label strings are pre-rendered `key="value"` pairs
//! with no spaces (the exposition's sample lines must stay
//! `NAME VALUE`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default histogram bucket upper bounds for second-valued series
/// (100 µs … 2.5 s; the `+Inf` bucket is implicit).
pub const SECONDS_BUCKETS: [f64; 12] =
    [1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.5, 2.5];

/// A monotone cumulative counter.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge holding one `f64` (stored as bits in an atomic).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistogramCore {
    /// Finite upper bounds, ascending; the `+Inf` bucket is derived from
    /// `count` at render time.
    bounds: Vec<f64>,
    /// Per-bound (non-cumulative) observation counts.
    buckets: Vec<AtomicU64>,
    /// Sum of observed values, stored as `f64` bits (CAS-accumulated).
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// A fixed-bucket streaming histogram.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let c = &self.0;
        if let Some(i) = c.bounds.iter().position(|&b| v <= b) {
            c.buckets[i].fetch_add(1, Ordering::Relaxed);
        }
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match c.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        c.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of observed values so far.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }
}

#[derive(Clone)]
enum Series {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Series {
    fn kind(&self) -> &'static str {
        match self {
            Series::Counter(_) => "counter",
            Series::Gauge(_) => "gauge",
            Series::Histogram(_) => "histogram",
        }
    }
}

/// A named collection of metric series, keyed by (family, labels).
///
/// Handles returned by the getters are cheap to clone and record through
/// relaxed atomics; fetching a handle takes the registry mutex once, so
/// hot paths should fetch once and hold the handle.
pub struct Registry {
    inner: Mutex<BTreeMap<(String, String), Series>>,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

/// Keep metric names to the exposition alphabet (`[A-Za-z0-9_:]`), like
/// [`crate::obs::prom`] does for snapshot keys.
fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' }).collect()
}

/// `{labels}` or `{labels,extra}` or `{extra}` — never with spaces, so
/// every rendered sample line stays `NAME VALUE`.
fn braced(labels: &str, extra: &str) -> String {
    match (labels.is_empty(), extra.is_empty()) {
        (true, true) => String::new(),
        (true, false) => format!("{{{extra}}}"),
        (false, true) => format!("{{{labels}}}"),
        (false, false) => format!("{{{labels},{extra}}}"),
    }
}

fn sample(out: &mut String, name: &str, labels: &str, extra: &str, value: f64) {
    out.push_str(name);
    out.push_str(&braced(labels, extra));
    out.push(' ');
    out.push_str(&format!("{value}"));
    out.push('\n');
}

impl Registry {
    /// An empty registry (the process-wide one is [`global`]).
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn series(&self, family: &str, labels: &str, make: impl FnOnce() -> Series) -> Series {
        let key = (sanitize(family), labels.replace(' ', ""));
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.entry(key).or_insert_with(make);
        entry.clone()
    }

    /// Counter handle for `family` (no labels), registering on first use.
    pub fn counter(&self, family: &str) -> Counter {
        self.counter_with(family, "")
    }

    /// Counter handle for `family{labels}`, registering on first use.
    pub fn counter_with(&self, family: &str, labels: &str) -> Counter {
        match self.series(family, labels, || Series::Counter(Counter(Arc::new(AtomicU64::new(0)))))
        {
            Series::Counter(c) => c,
            other => panic!("metric {family} already registered as a {}", other.kind()),
        }
    }

    /// Gauge handle for `family` (no labels), registering on first use.
    pub fn gauge(&self, family: &str) -> Gauge {
        self.gauge_with(family, "")
    }

    /// Gauge handle for `family{labels}`, registering on first use.
    pub fn gauge_with(&self, family: &str, labels: &str) -> Gauge {
        match self.series(family, labels, || {
            Series::Gauge(Gauge(Arc::new(AtomicU64::new(0f64.to_bits()))))
        }) {
            Series::Gauge(g) => g,
            other => panic!("metric {family} already registered as a {}", other.kind()),
        }
    }

    /// Histogram handle for `family` (no labels), registering with
    /// `bounds` on first use (later calls reuse the first bounds).
    pub fn histogram(&self, family: &str, bounds: &[f64]) -> Histogram {
        self.histogram_with(family, "", bounds)
    }

    /// Histogram handle for `family{labels}`, registering with `bounds`
    /// on first use (later calls reuse the first bounds).
    pub fn histogram_with(&self, family: &str, labels: &str, bounds: &[f64]) -> Histogram {
        match self.series(family, labels, || {
            Series::Histogram(Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets: bounds.iter().map(|_| AtomicU64::new(0)).collect(),
                sum_bits: AtomicU64::new(0f64.to_bits()),
                count: AtomicU64::new(0),
            })))
        }) {
            Series::Histogram(h) => h,
            other => panic!("metric {family} already registered as a {}", other.kind()),
        }
    }

    /// Render every series in Prometheus text exposition format: one
    /// `# TYPE` comment per family, then `NAME VALUE` sample lines
    /// (histograms as cumulative `_bucket{le="..."}` + `_sum` +
    /// `_count`).
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        let mut last_family = "";
        for ((family, labels), series) in inner.iter() {
            if family != last_family {
                out.push_str(&format!("# TYPE {family} {}\n", series.kind()));
                last_family = family;
            }
            match series {
                Series::Counter(c) => sample(&mut out, family, labels, "", c.get() as f64),
                Series::Gauge(g) => sample(&mut out, family, labels, "", g.get()),
                Series::Histogram(h) => {
                    // reading buckets before `count` (and clamping) keeps
                    // the invariant cumulative ≤ count = `+Inf` even when
                    // an observation lands mid-render
                    let core = &h.0;
                    let bucket = format!("{family}_bucket");
                    let mut cum = 0u64;
                    let counts: Vec<u64> =
                        core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                    let count = core.count.load(Ordering::Relaxed);
                    for (b, n) in core.bounds.iter().zip(counts) {
                        cum = (cum + n).min(count);
                        sample(&mut out, &bucket, labels, &format!("le=\"{b}\""), cum as f64);
                    }
                    sample(&mut out, &bucket, labels, "le=\"+Inf\"", count as f64);
                    sample(&mut out, &format!("{family}_sum"), labels, "", h.sum());
                    sample(&mut out, &format!("{family}_count"), labels, "", count as f64);
                }
            }
        }
        out
    }
}

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let r = Registry::new();
        let c = r.counter("test_requests_total");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // a second fetch of the same key shares the series
        assert_eq!(r.counter("test_requests_total").get(), 5);
        let g = r.gauge("test_depth");
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        let text = r.render();
        assert!(text.contains("# TYPE test_requests_total counter"), "{text}");
        assert!(text.contains("test_requests_total 5"), "{text}");
        assert!(text.contains("test_depth 2.5"), "{text}");
    }

    #[test]
    fn labeled_series_stay_space_free() {
        let r = Registry::new();
        r.counter_with("test_jobs_total", "kind=\"own\"").add(3);
        r.counter_with("test_jobs_total", "kind=\"stolen\"").inc();
        let text = r.render();
        assert!(text.contains("test_jobs_total{kind=\"own\"} 3"), "{text}");
        assert!(text.contains("test_jobs_total{kind=\"stolen\"} 1"), "{text}");
        // one TYPE line for the family, not one per series
        assert_eq!(text.matches("# TYPE test_jobs_total").count(), 1, "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.split(' ');
            let (name, val) = (parts.next().unwrap(), parts.next().unwrap());
            assert!(parts.next().is_none(), "bad sample line: {line}");
            assert!(!name.is_empty() && val.parse::<f64>().is_ok(), "bad sample line: {line}");
        }
    }

    #[test]
    fn histogram_buckets_cumulate_to_count() {
        let r = Registry::new();
        let h = r.histogram("test_latency_seconds", &[0.001, 0.01, 0.1]);
        for v in [0.0005, 0.002, 0.05, 0.05, 7.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 7.1025).abs() < 1e-12);
        let text = r.render();
        assert!(text.contains("# TYPE test_latency_seconds histogram"), "{text}");
        assert!(text.contains("test_latency_seconds_bucket{le=\"0.001\"} 1"), "{text}");
        assert!(text.contains("test_latency_seconds_bucket{le=\"0.01\"} 2"), "{text}");
        assert!(text.contains("test_latency_seconds_bucket{le=\"0.1\"} 4"), "{text}");
        assert!(text.contains("test_latency_seconds_bucket{le=\"+Inf\"} 5"), "{text}");
        assert!(text.contains("test_latency_seconds_count 5"), "{text}");
    }

    #[test]
    fn concurrent_observations_are_all_counted() {
        let r = std::sync::Arc::new(Registry::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let r = std::sync::Arc::clone(&r);
                std::thread::spawn(move || {
                    let c = r.counter("test_concurrent_total");
                    let h = r.histogram("test_concurrent_seconds", &SECONDS_BUCKETS);
                    for i in 0..1000 {
                        c.inc();
                        h.observe((t * 1000 + i) as f64 * 1e-6);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("test_concurrent_total").get(), 4000);
        assert_eq!(r.histogram("test_concurrent_seconds", &SECONDS_BUCKETS).count(), 4000);
    }

    #[test]
    fn names_are_sanitized() {
        let r = Registry::new();
        r.counter("bad name-here_total").inc();
        assert!(r.render().contains("bad_name_here_total 1"));
    }
}
