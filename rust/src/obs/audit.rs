//! Cost-model accuracy auditor: predicted vs measured per-plan
//! performance, closing the loop between `tune/cost.rs`'s analytic
//! model and what the serving hot path actually measures.
//!
//! Every time the sharded scheduler runs a compiled plan it records one
//! observation: the plan's predicted cycles/point and memory
//! slots/point (computed once per key and memoized) next to the
//! measured kernel CPU-seconds per point-step. The analytic model is
//! *relative* — it ranks plans, it does not know the host's clock — so
//! accuracy is judged after a single global calibration: the mean
//! implied rate `predicted_cycles_per_point / measured_s_per_pt` over
//! all keys scales predictions to seconds, and each key's relative
//! error is how far its measurement sits from its calibrated
//! prediction. A model that ranks plans consistently has near-zero
//! errors after calibration; drift between the model and reality (the
//! ROADMAP's online-autotuning prerequisite) shows up directly in
//! `stencil_cost_model_mean_rel_error` / `_max_rel_error`.
//!
//! Keys are `(spec, n, plan, machine fingerprint)`; the whole audit
//! dumps to / reloads from a `cost-audit.json` artifact.

use super::registry;
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Audit artifact schema version.
pub const AUDIT_VERSION: u64 = 1;

/// Accumulated statistics for one (spec, n, plan, fingerprint) key.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyStats {
    /// Stencil name (e.g. `2d9p-box-r1`).
    pub spec: String,
    /// Interior domain extent per dimension.
    pub n: usize,
    /// Plan label (tune-plan label, or the paper default for `outer`).
    pub plan: String,
    /// Machine fingerprint the prediction was made for.
    pub fingerprint: String,
    /// Model-predicted cycles per output point per step.
    pub predicted_cycles_per_point: f64,
    /// Model-predicted memory-pipe slots per output point per step.
    pub predicted_mem_per_point: f64,
    /// Observations recorded.
    pub count: u64,
    /// Mean measured kernel CPU-seconds per point-step.
    pub mean_s_per_pt: f64,
    /// Fastest observation.
    pub min_s_per_pt: f64,
    /// Slowest observation.
    pub max_s_per_pt: f64,
}

impl KeyStats {
    fn key(&self) -> String {
        format!("{}|n{}|{}|{}", self.spec, self.n, self.plan, self.fingerprint)
    }
}

/// Model-error summary over every audited key.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AuditSummary {
    /// Distinct (spec, n, plan, fingerprint) keys audited.
    pub keys: usize,
    /// Observations across all keys.
    pub observations: u64,
    /// Calibrated prediction rate: mean implied
    /// `predicted_cycles_per_point / measured_s_per_pt` (≈ effective Hz).
    pub calibrated_hz: f64,
    /// Mean per-key relative error of the calibrated prediction.
    pub mean_rel_error: f64,
    /// Worst per-key relative error.
    pub max_rel_error: f64,
}

/// Thread-safe predicted-vs-measured store (see module docs).
pub struct CostAudit {
    inner: Mutex<BTreeMap<String, KeyStats>>,
}

impl Default for CostAudit {
    fn default() -> CostAudit {
        CostAudit::new()
    }
}

fn rel_error(stats: &KeyStats, calibrated_hz: f64) -> f64 {
    if stats.mean_s_per_pt <= 0.0 || calibrated_hz <= 0.0 {
        return 0.0;
    }
    let predicted_s = stats.predicted_cycles_per_point / calibrated_hz;
    (predicted_s / stats.mean_s_per_pt - 1.0).abs()
}

fn summarize(map: &BTreeMap<String, KeyStats>) -> AuditSummary {
    let rated: Vec<&KeyStats> = map.values().filter(|k| k.mean_s_per_pt > 0.0).collect();
    let observations = map.values().map(|k| k.count).sum();
    if rated.is_empty() {
        return AuditSummary { keys: map.len(), observations, ..AuditSummary::default() };
    }
    let calibrated_hz = rated
        .iter()
        .map(|k| k.predicted_cycles_per_point / k.mean_s_per_pt)
        .sum::<f64>()
        / rated.len() as f64;
    let errors: Vec<f64> = rated.iter().map(|k| rel_error(k, calibrated_hz)).collect();
    AuditSummary {
        keys: map.len(),
        observations,
        calibrated_hz,
        mean_rel_error: errors.iter().sum::<f64>() / errors.len() as f64,
        max_rel_error: errors.iter().cloned().fold(0.0, f64::max),
    }
}

impl CostAudit {
    /// An empty audit (the process-wide one is [`global`]).
    pub fn new() -> CostAudit {
        CostAudit { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Record one measured plan execution. `predict` supplies
    /// `(cycles_per_point, mem_per_point)` and runs only the first time
    /// a key is seen (predictions are memoized — it may be expensive);
    /// returning `None` skips the observation (no model for this plan).
    /// `measured_seconds` is the kernel CPU-time of the run,
    /// `point_steps` the output points × time steps it covered.
    pub fn observe(
        &self,
        spec: &str,
        n: usize,
        plan: &str,
        fingerprint: &str,
        predict: impl FnOnce() -> Option<(f64, f64)>,
        measured_seconds: f64,
        point_steps: f64,
    ) {
        if !(measured_seconds > 0.0) || !(point_steps > 0.0) {
            return;
        }
        let s_per_pt = measured_seconds / point_steps;
        let summary = {
            let mut map = self.inner.lock().unwrap();
            let key = format!("{spec}|n{n}|{plan}|{fingerprint}");
            match map.get_mut(&key) {
                Some(stats) => {
                    stats.count += 1;
                    stats.mean_s_per_pt +=
                        (s_per_pt - stats.mean_s_per_pt) / stats.count as f64;
                    stats.min_s_per_pt = stats.min_s_per_pt.min(s_per_pt);
                    stats.max_s_per_pt = stats.max_s_per_pt.max(s_per_pt);
                }
                None => {
                    let Some((cycles, mem)) = predict() else { return };
                    map.insert(
                        key,
                        KeyStats {
                            spec: spec.to_string(),
                            n,
                            plan: plan.to_string(),
                            fingerprint: fingerprint.to_string(),
                            predicted_cycles_per_point: cycles,
                            predicted_mem_per_point: mem,
                            count: 1,
                            mean_s_per_pt: s_per_pt,
                            min_s_per_pt: s_per_pt,
                            max_s_per_pt: s_per_pt,
                        },
                    );
                }
            }
            summarize(&map)
        };
        let reg = registry::global();
        reg.counter("stencil_cost_model_observations_total").inc();
        reg.gauge("stencil_cost_model_keys").set(summary.keys as f64);
        reg.gauge("stencil_cost_model_calibrated_hz").set(summary.calibrated_hz);
        reg.gauge("stencil_cost_model_mean_rel_error").set(summary.mean_rel_error);
        reg.gauge("stencil_cost_model_max_rel_error").set(summary.max_rel_error);
    }

    /// Keys audited so far.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every key's statistics, sorted by key.
    pub fn snapshot(&self) -> Vec<KeyStats> {
        self.inner.lock().unwrap().values().cloned().collect()
    }

    /// The model-error summary over the current contents.
    pub fn summary(&self) -> AuditSummary {
        summarize(&self.inner.lock().unwrap())
    }

    /// Serialize the audit (the `cost-audit.json` artifact).
    pub fn to_json(&self) -> Json {
        let map = self.inner.lock().unwrap();
        let summary = summarize(&map);
        let entries: Vec<Json> = map
            .values()
            .map(|k| {
                obj(vec![
                    ("spec", Json::Str(k.spec.clone())),
                    ("n", Json::Num(k.n as f64)),
                    ("plan", Json::Str(k.plan.clone())),
                    ("fingerprint", Json::Str(k.fingerprint.clone())),
                    ("predicted_cycles_per_point", Json::Num(k.predicted_cycles_per_point)),
                    ("predicted_mem_per_point", Json::Num(k.predicted_mem_per_point)),
                    ("count", Json::Num(k.count as f64)),
                    ("measured_s_per_pt_mean", Json::Num(k.mean_s_per_pt)),
                    ("measured_s_per_pt_min", Json::Num(k.min_s_per_pt)),
                    ("measured_s_per_pt_max", Json::Num(k.max_s_per_pt)),
                    ("rel_error", Json::Num(rel_error(k, summary.calibrated_hz))),
                ])
            })
            .collect();
        obj(vec![
            ("version", Json::Num(AUDIT_VERSION as f64)),
            ("kind", Json::Str("cost-audit".into())),
            ("keys", Json::Num(summary.keys as f64)),
            ("observations", Json::Num(summary.observations as f64)),
            ("calibrated_hz", Json::Num(summary.calibrated_hz)),
            ("mean_rel_error", Json::Num(summary.mean_rel_error)),
            ("max_rel_error", Json::Num(summary.max_rel_error)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Rebuild an audit from a dumped artifact ([`CostAudit::to_json`]).
    pub fn from_json(json: &Json) -> anyhow::Result<CostAudit> {
        anyhow::ensure!(
            json.get("version").and_then(Json::as_usize) == Some(AUDIT_VERSION as usize),
            "unsupported cost-audit version (want {AUDIT_VERSION})"
        );
        let entries = json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("cost-audit has no entries array"))?;
        let mut map = BTreeMap::new();
        for e in entries {
            let str_field = |f: &str| -> anyhow::Result<String> {
                e.get(f)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("cost-audit entry missing '{f}'"))
            };
            let num_field = |f: &str| -> anyhow::Result<f64> {
                e.get(f)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("cost-audit entry missing '{f}'"))
            };
            let stats = KeyStats {
                spec: str_field("spec")?,
                n: num_field("n")? as usize,
                plan: str_field("plan")?,
                fingerprint: str_field("fingerprint")?,
                predicted_cycles_per_point: num_field("predicted_cycles_per_point")?,
                predicted_mem_per_point: num_field("predicted_mem_per_point")?,
                count: num_field("count")? as u64,
                mean_s_per_pt: num_field("measured_s_per_pt_mean")?,
                min_s_per_pt: num_field("measured_s_per_pt_min")?,
                max_s_per_pt: num_field("measured_s_per_pt_max")?,
            };
            map.insert(stats.key(), stats);
        }
        Ok(CostAudit { inner: Mutex::new(map) })
    }
}

/// The process-wide audit the serving scheduler records into.
pub fn global() -> &'static CostAudit {
    static GLOBAL: OnceLock<CostAudit> = OnceLock::new();
    GLOBAL.get_or_init(CostAudit::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seed(audit: &CostAudit) {
        // two keys whose measurements agree with the model's ratio (2:1)
        // and one observation each of noise-free data
        audit.observe("2d9p-box-r1", 64, "planA", "fp", || Some((2.0, 1.0)), 2e-3, 1e6);
        audit.observe("2d25p-box-r2", 64, "planB", "fp", || Some((4.0, 2.0)), 4e-3, 1e6);
    }

    #[test]
    fn consistent_model_has_zero_error_after_calibration() {
        let audit = CostAudit::new();
        seed(&audit);
        let s = audit.summary();
        assert_eq!(s.keys, 2);
        assert_eq!(s.observations, 2);
        // both keys imply the same rate: 2.0 cycles/pt over 2e-9 s/pt
        assert!((s.calibrated_hz / 1e9 - 1.0).abs() < 1e-9, "{s:?}");
        assert!(s.mean_rel_error < 1e-12, "{s:?}");
        assert!(s.max_rel_error < 1e-12, "{s:?}");
    }

    #[test]
    fn inconsistent_measurement_shows_up_as_error() {
        let audit = CostAudit::new();
        seed(&audit);
        // a third key measured 4x slower than the model's ranking implies
        audit.observe("3d27p-box-r1", 16, "planC", "fp", || Some((2.0, 1.0)), 8e-3, 1e6);
        let s = audit.summary();
        assert_eq!(s.keys, 3);
        assert!(s.max_rel_error > 0.3, "{s:?}");
        assert!(s.mean_rel_error > 0.05, "{s:?}");
    }

    #[test]
    fn predictions_are_memoized_and_running_stats_update() {
        let audit = CostAudit::new();
        let mut calls = 0usize;
        for ms in [2e-3, 4e-3, 6e-3] {
            audit.observe(
                "2d9p-box-r1",
                64,
                "planA",
                "fp",
                || {
                    calls += 1;
                    Some((2.0, 1.0))
                },
                ms,
                1e6,
            );
        }
        assert_eq!(calls, 1, "prediction must be computed once per key");
        let snap = audit.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].count, 3);
        assert!((snap[0].mean_s_per_pt / 4e-9 - 1.0).abs() < 1e-12);
        assert!((snap[0].min_s_per_pt / 2e-9 - 1.0).abs() < 1e-12);
        assert!((snap[0].max_s_per_pt / 6e-9 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unpredictable_plans_are_skipped() {
        let audit = CostAudit::new();
        audit.observe("2d9p-box-r1", 64, "oracle", "fp", || None, 1e-3, 1e6);
        assert!(audit.is_empty());
    }

    #[test]
    fn json_roundtrip_preserves_entries() {
        let audit = CostAudit::new();
        seed(&audit);
        audit.observe("2d9p-box-r1", 64, "planA", "fp", || Some((2.0, 1.0)), 3e-3, 1e6);
        let dumped = audit.to_json();
        let text = dumped.to_string_compact();
        let reloaded = CostAudit::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reloaded.snapshot(), audit.snapshot());
        assert_eq!(reloaded.to_json().to_string_compact(), text);
        // version gate
        let mut bad = dumped.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("version".into(), Json::Num(99.0));
        }
        assert!(CostAudit::from_json(&bad).is_err());
    }
}
