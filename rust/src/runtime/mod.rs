//! The PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO text)
//! and executes them from Rust. Python never runs on this path — after
//! `make artifacts`, the binary is self-contained.
//!
//! - [`registry`] — parses `artifacts/manifest.json` into typed
//!   [`ArtifactMeta`] records.
//! - [`client`] — thin wrapper over the `xla` crate: PJRT CPU client,
//!   HLO-text loading, compilation, execution.
//! - [`engine`] — the stencil engine: typed grid in/out, multi-step
//!   evolution, throughput accounting and oracle verification.

pub mod client;
pub mod engine;
pub mod registry;

pub use client::{PjrtRuntime, StencilExecutable};
pub use engine::{EvolutionReport, StencilEngine};
pub use registry::{ArtifactMeta, Registry};
