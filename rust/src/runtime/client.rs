//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Pattern (see /opt/xla-example/src/bin/load_hlo.rs): `PjRtClient::cpu()`
//! → `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos).

use super::registry::ArtifactMeta;
use crate::stencil::DenseGrid;

/// A live PJRT client plus the executables compiled on it.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled stencil executable.
pub struct StencilExecutable {
    /// The artifact this executable was compiled from.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client })
    }

    /// Platform name of the underlying client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn compile(&self, meta: &ArtifactMeta) -> anyhow::Result<StencilExecutable> {
        let path = meta
            .path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(StencilExecutable { meta: meta.clone(), exe })
    }
}

impl StencilExecutable {
    /// Run one execution: grid in (storage shape), grid out. Advances
    /// `meta.steps` time steps.
    pub fn run(&self, grid: &DenseGrid) -> anyhow::Result<DenseGrid> {
        anyhow::ensure!(
            grid.shape == self.meta.shape(),
            "grid shape {:?} does not match artifact {:?}",
            grid.shape,
            self.meta.shape()
        );
        let dims: Vec<i64> = grid.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&grid.data).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f64>()?;
        anyhow::ensure!(data.len() == grid.data.len(), "output size mismatch");
        Ok(DenseGrid { shape: grid.shape.clone(), data })
    }
}
