//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Pattern (see /opt/xla-example/src/bin/load_hlo.rs): `PjRtClient::cpu()`
//! → `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos).
//!
//! The `xla` crate is not part of the offline vendor set, so the real
//! implementation is gated behind the `pjrt` cargo feature. Without it
//! this module compiles a stub whose [`PjrtRuntime::cpu`] returns an
//! error, keeping every non-PJRT layer (simulator, codegen, scatter, the
//! sharded serving subsystem) fully usable.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::runtime::registry::ArtifactMeta;
    use crate::stencil::DenseGrid;

    /// A live PJRT client plus the executables compiled on it.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    /// One compiled stencil executable.
    pub struct StencilExecutable {
        /// The artifact this executable was compiled from.
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    impl PjrtRuntime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> anyhow::Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu()?;
            Ok(PjrtRuntime { client })
        }

        /// Platform name of the underlying client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one artifact.
        pub fn compile(&self, meta: &ArtifactMeta) -> anyhow::Result<StencilExecutable> {
            let path = meta
                .path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(StencilExecutable { meta: meta.clone(), exe })
        }
    }

    impl StencilExecutable {
        /// Run one execution: grid in (storage shape), grid out. Advances
        /// `meta.steps` time steps.
        pub fn run(&self, grid: &DenseGrid) -> anyhow::Result<DenseGrid> {
            anyhow::ensure!(
                grid.shape == self.meta.shape(),
                "grid shape {:?} does not match artifact {:?}",
                grid.shape,
                self.meta.shape()
            );
            let dims: Vec<i64> = grid.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&grid.data).reshape(&dims)?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple
            let out = result.to_tuple1()?;
            let data = out.to_vec::<f64>()?;
            anyhow::ensure!(data.len() == grid.data.len(), "output size mismatch");
            Ok(DenseGrid { shape: grid.shape.clone(), data })
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::runtime::registry::ArtifactMeta;
    use crate::stencil::DenseGrid;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this binary was built without the \
                               `pjrt` cargo feature (which requires the `xla` crate)";

    /// Stub standing in for the PJRT client when `pjrt` is disabled.
    pub struct PjrtRuntime {
        _private: (),
    }

    /// Stub compiled executable; only its metadata is real.
    pub struct StencilExecutable {
        /// The artifact this executable was compiled from.
        pub meta: ArtifactMeta,
    }

    impl PjrtRuntime {
        /// Always fails: the feature is off.
        pub fn cpu() -> anyhow::Result<PjrtRuntime> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        /// Platform name (unreachable in practice: `cpu()` cannot succeed).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always fails: the feature is off.
        pub fn compile(&self, _meta: &ArtifactMeta) -> anyhow::Result<StencilExecutable> {
            anyhow::bail!("{UNAVAILABLE}")
        }
    }

    impl StencilExecutable {
        /// Always fails: the feature is off.
        pub fn run(&self, _grid: &DenseGrid) -> anyhow::Result<DenseGrid> {
            anyhow::bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::{PjrtRuntime, StencilExecutable};
