//! Artifact registry: `artifacts/manifest.json` → typed metadata.

use crate::stencil::{StencilKind, StencilSpec};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Metadata of one AOT artifact (written by `python/compile/aot.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Variant name, e.g. `step_2d5p_n64`.
    pub name: String,
    /// The stencil the artifact computes.
    pub spec: StencilSpec,
    /// Domain extent `N`.
    pub n: usize,
    /// Storage extent `N + 2r` (the executable's array shape per dim).
    pub storage_extent: usize,
    /// Time steps one execution advances.
    pub steps: usize,
    /// Path to the HLO text.
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Total elements of the input/output array.
    pub fn elements(&self) -> usize {
        self.storage_extent.pow(self.spec.dims as u32)
    }

    /// Array shape per dimension.
    pub fn shape(&self) -> Vec<usize> {
        vec![self.storage_extent; self.spec.dims]
    }
}

/// The set of available artifacts.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    /// All artifacts, manifest order.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Registry> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| anyhow::anyhow!("{}: {e} (run `make artifacts` first)", manifest.display()))?;
        let v = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for item in v.as_arr().ok_or_else(|| anyhow::anyhow!("manifest must be an array"))? {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("artifact missing name"))?
                .to_string();
            let spec_v = item.get("spec").ok_or_else(|| anyhow::anyhow!("{name}: missing spec"))?;
            let dims = spec_v.get("dims").and_then(Json::as_usize).unwrap_or(0);
            let order = spec_v.get("order").and_then(Json::as_usize).unwrap_or(0);
            let kind = match spec_v.get("kind").and_then(Json::as_str) {
                Some("box") => StencilKind::Box,
                Some("star") => StencilKind::Star,
                Some("diag") => StencilKind::Diagonal,
                k => anyhow::bail!("{name}: bad kind {k:?}"),
            };
            let spec = StencilSpec::new(dims, order, kind)?;
            let file = item
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("{name}: missing file"))?;
            artifacts.push(ArtifactMeta {
                spec,
                n: item.get("n").and_then(Json::as_usize).unwrap_or(0),
                storage_extent: item
                    .get("storage_extent")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                steps: item.get("steps").and_then(Json::as_usize).unwrap_or(1),
                path: dir.join(file),
                name,
            });
        }
        Ok(Registry { artifacts })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                anyhow::anyhow!("no artifact '{name}' (have: {names:?})")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let dir = std::env::temp_dir().join("sm-registry-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"[{"name":"step_2d5p_n64","spec":{"dims":2,"order":1,"kind":"star"},
                 "n":64,"storage_extent":66,"steps":1,"dtype":"f64",
                 "file":"step_2d5p_n64.hlo.txt"}]"#,
        )
        .unwrap();
        let reg = Registry::load(&dir).unwrap();
        let a = reg.find("step_2d5p_n64").unwrap();
        assert_eq!(a.n, 64);
        assert_eq!(a.storage_extent, 66);
        assert_eq!(a.elements(), 66 * 66);
        assert_eq!(a.spec, StencilSpec::star2d(1));
        assert!(reg.find("nope").is_err());
    }
}
