//! The stencil engine: multi-execution evolution over a compiled
//! artifact, with throughput accounting and oracle verification.

use super::client::StencilExecutable;
use crate::stencil::{reference, CoeffTensor, DenseGrid};
use std::time::Instant;

/// Outcome of an engine evolution run.
#[derive(Debug, Clone)]
pub struct EvolutionReport {
    /// Executions performed.
    pub executions: usize,
    /// Total time steps advanced (`executions × artifact.steps`).
    pub steps: usize,
    /// Wall-clock seconds of the execute loop (PJRT only, no verify).
    pub seconds: f64,
    /// Grid points updated per second (points × steps / seconds).
    pub points_per_sec: f64,
    /// Max |error| vs the scalar reference (interior), if verified.
    pub max_err: Option<f64>,
}

/// Drives a [`StencilExecutable`] over many executions.
pub struct StencilEngine {
    exe: StencilExecutable,
}

impl StencilEngine {
    /// Wrap a compiled executable.
    pub fn new(exe: StencilExecutable) -> StencilEngine {
        StencilEngine { exe }
    }

    /// The artifact metadata.
    pub fn meta(&self) -> &super::registry::ArtifactMeta {
        &self.exe.meta
    }

    /// Run `executions` back-to-back executions starting from `grid`,
    /// optionally verifying the final state against the scalar oracle.
    pub fn evolve(
        &self,
        grid: &DenseGrid,
        executions: usize,
        verify: bool,
    ) -> anyhow::Result<(DenseGrid, EvolutionReport)> {
        let meta = &self.exe.meta;
        let t0 = Instant::now();
        let mut cur = grid.clone();
        for _ in 0..executions {
            cur = self.exe.run(&cur)?;
        }
        let seconds = t0.elapsed().as_secs_f64();
        let steps = executions * meta.steps;
        let interior_points = meta.n.pow(meta.spec.dims as u32);
        let max_err = if verify {
            let coeffs = CoeffTensor::paper_default(meta.spec);
            let want = reference::evolve(&coeffs, grid, steps);
            Some(cur.max_abs_diff_interior(&want, meta.spec.order))
        } else {
            None
        };
        let report = EvolutionReport {
            executions,
            steps,
            seconds,
            points_per_sec: interior_points as f64 * steps as f64 / seconds.max(1e-12),
            max_err,
        };
        Ok((cur, report))
    }
}
