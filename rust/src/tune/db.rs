//! The versioned JSON tuning database.
//!
//! Entries are keyed by `(StencilSpec, domain extent n, SimConfig
//! fingerprint)`; recording a new outcome for an existing key replaces
//! the old entry. See [`crate::tune`] module docs for the on-disk schema.

use super::search::TuneOutcome;
use super::space::TunePlan;
use crate::stencil::{StencilKind, StencilSpec};
use crate::util::json::{obj, Json};
use std::path::Path;

/// Schema version written to (and required from) every database file.
pub const TUNE_DB_VERSION: u64 = 1;

/// One tuned result.
#[derive(Debug, Clone)]
pub struct TuneEntry {
    /// Stencil the plan was tuned for.
    pub spec: StencilSpec,
    /// Domain extent the plan was tuned at.
    pub n: usize,
    /// [`crate::sim::SimConfig::fingerprint`] of the machine measured on.
    pub fingerprint: String,
    /// The winning plan.
    pub plan: TunePlan,
    /// Measured simulated cycles of the winning plan.
    pub cycles: u64,
    /// Measured cycles per point per step of the winning plan.
    pub cycles_per_point: f64,
    /// Measured cycles per point per step of the paper-default plan.
    pub default_cycles_per_point: f64,
    /// `default_cycles_per_point / cycles_per_point` (≥ 1).
    pub speedup_vs_default: f64,
    /// Candidates in the full search space.
    pub searched: usize,
    /// Candidates measured (all oracle-verified).
    pub measured: usize,
}

impl TuneEntry {
    fn to_json(&self) -> Json {
        obj(vec![
            (
                "spec",
                obj(vec![
                    ("dims", Json::Num(self.spec.dims as f64)),
                    ("order", Json::Num(self.spec.order as f64)),
                    ("kind", Json::Str(self.spec.kind.to_string())),
                ]),
            ),
            ("n", Json::Num(self.n as f64)),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("plan", self.plan.to_json()),
            ("cycles", Json::Num(self.cycles as f64)),
            ("cycles_per_point", Json::Num(self.cycles_per_point)),
            ("default_cycles_per_point", Json::Num(self.default_cycles_per_point)),
            ("speedup_vs_default", Json::Num(self.speedup_vs_default)),
            ("searched", Json::Num(self.searched as f64)),
            ("measured", Json::Num(self.measured as f64)),
        ])
    }

    fn from_json(v: &Json) -> anyhow::Result<TuneEntry> {
        let spec_v = v.get("spec").ok_or_else(|| anyhow::anyhow!("entry missing 'spec'"))?;
        let dims = spec_v
            .get("dims")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("spec missing 'dims'"))?;
        let order = spec_v
            .get("order")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("spec missing 'order'"))?;
        let kind = match spec_v.get("kind").and_then(Json::as_str) {
            Some("box") => StencilKind::Box,
            Some("star") => StencilKind::Star,
            Some("diag") => StencilKind::Diagonal,
            other => anyhow::bail!("unknown stencil kind {other:?}"),
        };
        let spec = StencilSpec::new(dims, order, kind)?;
        let field = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("entry missing numeric '{k}'"))
        };
        Ok(TuneEntry {
            spec,
            n: field("n")? as usize,
            fingerprint: v
                .get("fingerprint")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("entry missing 'fingerprint'"))?
                .to_string(),
            plan: TunePlan::from_json(
                v.get("plan").ok_or_else(|| anyhow::anyhow!("entry missing 'plan'"))?,
            )?,
            cycles: field("cycles")? as u64,
            cycles_per_point: field("cycles_per_point")?,
            default_cycles_per_point: field("default_cycles_per_point")?,
            speedup_vs_default: field("speedup_vs_default")?,
            searched: field("searched")? as usize,
            measured: field("measured")? as usize,
        })
    }
}

/// The database: a flat, versioned set of [`TuneEntry`]s.
#[derive(Debug, Clone, Default)]
pub struct TuneDb {
    entries: Vec<TuneEntry>,
}

impl TuneDb {
    /// An empty database.
    pub fn new() -> TuneDb {
        TuneDb::default()
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the database has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries.
    pub fn entries(&self) -> &[TuneEntry] {
        &self.entries
    }

    /// Record a tuning outcome (insert or replace by key).
    pub fn record(&mut self, outcome: &TuneOutcome) -> &TuneEntry {
        let best = outcome.best();
        let entry = TuneEntry {
            spec: outcome.spec,
            n: outcome.n,
            fingerprint: outcome.fingerprint.clone(),
            plan: best.plan,
            cycles: best.cycles,
            cycles_per_point: best.cycles_per_point,
            default_cycles_per_point: outcome.paper_default().cycles_per_point,
            speedup_vs_default: outcome.speedup_vs_default(),
            searched: outcome.space_size,
            measured: outcome.measurements.len(),
        };
        let pos = self.entries.iter().position(|e| {
            e.spec == entry.spec && e.n == entry.n && e.fingerprint == entry.fingerprint
        });
        match pos {
            Some(i) => {
                self.entries[i] = entry;
                &self.entries[i]
            }
            None => {
                self.entries.push(entry);
                self.entries.last().unwrap()
            }
        }
    }

    /// Exact-key lookup.
    pub fn lookup(&self, spec: StencilSpec, n: usize, fingerprint: &str) -> Option<&TuneEntry> {
        self.entries
            .iter()
            .find(|e| e.spec == spec && e.n == n && e.fingerprint == fingerprint)
    }

    /// Best entry for a (spec, machine) pair regardless of tuned size:
    /// the entry tuned at the **largest** `n` (the most representative
    /// working set). This is what the serving layer consults, since shard
    /// tile shapes rarely match a tuned grid size exactly.
    pub fn best_for(&self, spec: StencilSpec, fingerprint: &str) -> Option<&TuneEntry> {
        self.entries
            .iter()
            .filter(|e| e.spec == spec && e.fingerprint == fingerprint)
            .max_by_key(|e| e.n)
    }

    /// Serialize the whole database.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::Num(TUNE_DB_VERSION as f64)),
            ("entries", Json::Arr(self.entries.iter().map(TuneEntry::to_json).collect())),
        ])
    }

    /// Deserialize, enforcing the schema version.
    pub fn from_json(v: &Json) -> anyhow::Result<TuneDb> {
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("tuning DB missing 'version'"))?;
        anyhow::ensure!(
            version as u64 == TUNE_DB_VERSION,
            "tuning DB version {version} unsupported (expected {TUNE_DB_VERSION}); \
             re-run `stencil-matrix tune` to regenerate it"
        );
        let entries = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("tuning DB missing 'entries'"))?
            .iter()
            .map(TuneEntry::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TuneDb { entries })
    }

    /// Load a database from disk.
    pub fn load(path: &Path) -> anyhow::Result<TuneDb> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading tuning DB {}: {e}", path.display()))?;
        TuneDb::from_json(&Json::parse(&text)?)
    }

    /// Load a database, or start an empty one when the file does not
    /// exist yet (a corrupt or version-mismatched file is still an error).
    pub fn load_or_new(path: &Path) -> anyhow::Result<TuneDb> {
        if path.exists() {
            TuneDb::load(path)
        } else {
            Ok(TuneDb::new())
        }
    }

    /// Write the database to disk (creating parent directories).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())
            .map_err(|e| anyhow::anyhow!("writing tuning DB {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::search::{tune, Strategy};
    use crate::sim::SimConfig;

    fn outcome() -> TuneOutcome {
        tune(&SimConfig::default(), StencilSpec::box2d(1), 16, 3, Strategy::CostGuided).unwrap()
    }

    #[test]
    fn record_lookup_and_replace() {
        let mut db = TuneDb::new();
        let out = outcome();
        db.record(&out);
        assert_eq!(db.len(), 1);
        let e = db.lookup(out.spec, out.n, &out.fingerprint).unwrap();
        assert_eq!(e.plan, out.best().plan);
        assert!(e.speedup_vs_default >= 1.0);
        // same key replaces rather than duplicates
        db.record(&out);
        assert_eq!(db.len(), 1);
        assert!(db.lookup(out.spec, out.n, "other-machine").is_none());
        assert!(db.lookup(StencilSpec::star3d(1), out.n, &out.fingerprint).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let mut db = TuneDb::new();
        db.record(&outcome());
        let text = db.to_json().to_string_compact();
        let back = TuneDb::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        let (a, b) = (&db.entries()[0], &back.entries()[0]);
        assert_eq!(a.spec, b.spec);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let bad = r#"{"version":99,"entries":[]}"#;
        let err = TuneDb::from_json(&Json::parse(bad).unwrap()).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        assert!(TuneDb::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn best_for_prefers_the_largest_tuned_size() {
        let cfg = SimConfig::default();
        let mut db = TuneDb::new();
        let spec = StencilSpec::box2d(1);
        let small = tune(&cfg, spec, 16, 2, Strategy::CostGuided).unwrap();
        let large = tune(&cfg, spec, 32, 2, Strategy::CostGuided).unwrap();
        db.record(&small);
        db.record(&large);
        assert_eq!(db.len(), 2);
        let fp = cfg.fingerprint();
        assert_eq!(db.best_for(spec, &fp).unwrap().n, 32);
        assert!(db.best_for(StencilSpec::star3d(1), &fp).is_none());
        assert!(db.best_for(spec, "elsewhere").is_none());
    }
}
