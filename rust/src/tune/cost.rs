//! Analytic cost model: ranks candidate plans *before* any simulation.
//!
//! The model is derived from [`SimConfig`] and the cover algebra of §3–§4:
//! for every plan it counts, per output point, the work each execution
//! unit has to do — outer products (exact, from
//! [`LineCover::outer_products`]), vector loads/stores including the
//! gather expansion of strided column accesses and the per-(line, p)
//! reload behaviour of unscheduled code, and vector-ALU operations (EXT
//! assembly, tile↔vector moves, FMAs) — and takes the binding-unit
//! bottleneck under the machine's issue width:
//!
//! ```text
//! cyc/pt ≈ max(opu/OPU, mem/LSU, valu/VALU, total/issue_width)
//! ```
//!
//! with a DRAM-bandwidth floor (`mem_line_interval`) once the working set
//! spills L2. Register pressure enters through the effective-unroll
//! normalization of [`super::space::effective_outer`]: a plan that asks
//! for more tiles than the machine has matrix registers is costed (and
//! later run) at its clamped shape.
//!
//! This is a *pruning heuristic*, not a cycle predictor: the search
//! (`super::search`) re-ranks every surviving candidate on the functional
//! + timing simulator, so model error can waste budget but never corrupt
//! results.

use super::space::{effective_outer, TunePlan};
use crate::codegen::Method;
use crate::scatter::line::{CoeffLine, LineCover};
use crate::scatter::build_cover;
use crate::stencil::{CoeffTensor, StencilSpec};
use crate::sim::SimConfig;

/// Modelled per-point cost of one candidate plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    /// Estimated cycles per output point per time step.
    pub cycles_per_point: f64,
    /// Outer products per output point (exact for outer plans, 0 for the
    /// vector baselines).
    pub fmopa_per_point: f64,
    /// Load/store-unit operations per output point (gathers expanded).
    pub mem_per_point: f64,
    /// True when the DRAM-bandwidth floor is the binding constraint.
    pub mem_bound: bool,
}

/// Per-unit work accumulated per output point.
#[derive(Debug, Default, Clone, Copy)]
struct UnitWork {
    opu: f64,
    lsu: f64,
    valu: f64,
}

impl UnitWork {
    fn add(&mut self, other: UnitWork, scale: f64) {
        self.opu += other.opu * scale;
        self.lsu += other.lsu * scale;
        self.valu += other.valu * scale;
    }
}

/// Estimate the cost of `plan` for `spec` at domain extent `n` on `cfg`.
pub fn estimate(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    plan: &TunePlan,
) -> anyhow::Result<CostEstimate> {
    let nz = spec.nonzero_points() as f64;
    let v = cfg.vlen as f64;
    let (work, fmopa_pt, mem_scale) = match plan.method {
        Method::Outer(p) => {
            let w = outer_work(cfg, spec, n, p)?;
            (w, w.opu, 1.0)
        }
        Method::AutoVec => {
            // one mostly-unaligned load + one indexed FMA per tap per
            // output vector, plus the store
            let unaligned = 1.0 + cfg.split_line_penalty as f64 * 0.5;
            let w = UnitWork { opu: 0.0, lsu: (nz * unaligned + 1.0) / v, valu: nz / v };
            (w, 0.0, 1.0)
        }
        Method::Dlt => {
            // all loads aligned after the dimension-lifted transpose, at
            // the price of the in/out layout transformation each step
            let w = UnitWork { opu: 0.0, lsu: (nz + 5.0) / v, valu: (nz + 2.0) / v };
            (w, 0.0, 1.0)
        }
        Method::Tv => {
            // temporal blocking over 4 steps: slightly more register
            // shuffling per step, a quarter of the memory traffic
            let w = UnitWork { opu: 0.0, lsu: (nz * 1.1 + 1.0) / v, valu: nz * 1.3 / v };
            (w, 0.0, 0.25)
        }
        Method::Scalar => {
            let w = UnitWork { opu: 0.0, lsu: nz + 1.0, valu: nz };
            (w, 0.0, 1.0)
        }
    };
    let total = work.opu + work.lsu + work.valu;
    let mut cpp = (work.opu / cfg.opu_units as f64)
        .max(work.lsu / cfg.lsu_units as f64)
        .max(work.valu / cfg.valu_units as f64)
        .max(total / cfg.issue_width as f64);
    // DRAM-bandwidth floor once A and B no longer fit in L2: ~3 streams
    // of 8 B/pt (read A, write-allocate + write back B)
    let ext = n + 2 * spec.order;
    let grid_bytes = 2 * ext.pow(spec.dims as u32) * 8;
    let floor = 24.0 / cfg.cache.line_bytes as f64 * cfg.cache.mem_line_interval as f64;
    let mut mem_bound = false;
    if grid_bytes > cfg.cache.l2_bytes {
        let floor = floor * mem_scale;
        if floor > cpp {
            cpp = floor;
            mem_bound = true;
        }
    }
    Ok(CostEstimate {
        cycles_per_point: cpp,
        fmopa_per_point: fmopa_pt,
        mem_per_point: work.lsu,
        mem_bound,
    })
}

/// Cover lines classified by direction (mirrors `codegen::outer`).
struct Lines<'a> {
    /// Axis lines along the leading non-unit-stride dimension (2D `i`,
    /// 3D `i` — the pass-2 lines).
    d_lead: Vec<&'a CoeffLine>,
    /// Axis lines feeding the main outer-product pass (2D `i`-lines live
    /// here too; 3D `j`-lines).
    d_main: Vec<&'a CoeffLine>,
    /// Axis lines along the unit-stride dimension (transpose trick).
    d_unit: Vec<&'a CoeffLine>,
    /// 2D diagonal lines.
    diag: Vec<&'a CoeffLine>,
}

fn classify(spec: StencilSpec, cover: &LineCover) -> Lines<'_> {
    let mut l = Lines { d_lead: vec![], d_main: vec![], d_unit: vec![], diag: vec![] };
    for line in &cover.lines {
        let nzd: Vec<usize> = (0..line.dir.len()).filter(|&d| line.dir[d] != 0).collect();
        if nzd.len() == 2 {
            l.diag.push(line);
        } else if nzd[0] == spec.dims - 1 {
            l.d_unit.push(line);
        } else if spec.dims == 3 && nzd[0] == 0 {
            l.d_lead.push(line);
        } else {
            l.d_main.push(line);
        }
    }
    l
}

/// Expanded coefficient-vector count of a line at block extent `vlen`.
fn cvs(line: &CoeffLine, vlen: usize) -> f64 {
    line.coeff_vectors(vlen).len() as f64
}

/// How many of a line's coefficient vectors have an in-tile `p`
/// (`0 <= p < vlen`): these resolve via the matrix-register transpose;
/// the remainder are halo positions served by gather loads.
fn in_tile(line: &CoeffLine, vlen: usize) -> f64 {
    (0..vlen as isize).filter(|&p| line.cv_nonzero(p, vlen)).count() as f64
}

/// Per-point unit work of the outer-product generator.
fn outer_work(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    params: crate::codegen::OuterParams,
) -> anyhow::Result<UnitWork> {
    let p = effective_outer(cfg, spec, n, params)?;
    let coeffs = CoeffTensor::paper_default(spec);
    let cover = build_cover(&coeffs, p.option)?;
    let lines = classify(spec, &cover);
    let v = cfg.vlen as f64;
    let vlen = cfg.vlen;
    let r = spec.order as f64;
    let sched = p.scheduled;
    let mut per_point = UnitWork::default();

    if spec.dims == 2 {
        let g = p.uk as f64;
        let points = g * v * v; // one unrolled group of g tiles
        let mut w = UnitWork::default();
        // ---- i-lines (contiguous A rows → the main fmopa stream) ----
        let cv_main: f64 = lines.d_main.iter().map(|l| cvs(l, vlen)).sum();
        let ext_main: f64 =
            lines.d_main.iter().filter(|l| l.base[1] != 0).map(|l| cvs(l, vlen)).sum();
        w.opu += cv_main * g;
        w.valu += ext_main * g;
        if sched {
            let lr = lines.d_main.iter().any(|l| l.base[1] < 0) as usize as f64
                + lines.d_main.iter().any(|l| l.base[1] > 0) as usize as f64;
            w.lsu += cv_main; // one CV load per (line, p), shared
            if !lines.d_main.is_empty() {
                w.lsu += (v + 2.0 * r) * (g + lr); // shared aligned A blocks
            }
        } else {
            // naive: CV + A blocks reloaded per tile
            let reload: f64 = lines
                .d_main
                .iter()
                .map(|l| cvs(l, vlen) * (2.0 + (l.base[1] != 0) as usize as f64))
                .sum();
            w.lsu += reload * g;
        }
        // ---- j-lines (strided columns via the transpose trick) ----
        if !lines.d_unit.is_empty() {
            let mut ois: Vec<isize> = lines.d_unit.iter().map(|l| l.base[0]).collect();
            ois.sort_unstable();
            ois.dedup();
            // per tile: transpose fill per oi group + per-(line, p) work
            w.lsu += g * ois.len() as f64 * v;
            w.valu += g * ois.len() as f64 * v;
            for l in &lines.d_unit {
                let c = cvs(l, vlen);
                let it = in_tile(l, vlen);
                w.opu += g * c;
                w.lsu += g * (c + (c - it) * v); // CV loads + halo gathers
                w.valu += g * it; // column moves
            }
        }
        // ---- diagonal lines (vector-FMA path, per tile row) ----
        for l in &lines.diag {
            let taps = l.nonzeros() as f64;
            w.valu += g * v * (2.0 + taps * 1.9); // row moves + ext + fma
            w.lsu += g * v * taps * 2.5; // splat + sheared block loads
        }
        // ---- stores + tile zeroing ----
        w.lsu += g * v;
        w.valu += g;
        per_point.add(w, 1.0 / points);
    } else {
        let (gi, gk) = (p.ui as f64, p.uk as f64);
        let points = gi * gk * v * v;
        let mut w = UnitWork::default();
        // ---- pass 1: j-lines into gi×gk tiles ----
        let cv_main: f64 = lines.d_main.iter().map(|l| cvs(l, vlen)).sum();
        w.opu += cv_main * gi * gk;
        if sched {
            let lr = lines.d_main.iter().any(|l| l.base[2] < 0) as usize as f64
                + lines.d_main.iter().any(|l| l.base[2] > 0) as usize as f64;
            let (lo, hi) = lines
                .d_main
                .iter()
                .fold((0isize, 0isize), |(lo, hi), l| (lo.min(l.base[0]), hi.max(l.base[0])));
            let planes = gi + (hi - lo) as f64;
            let mut kos: Vec<isize> = lines.d_main.iter().map(|l| l.base[2]).collect();
            kos.sort_unstable();
            kos.dedup();
            let kos_nz = kos.iter().filter(|&&k| k != 0).count() as f64;
            w.lsu += cv_main; // CV bank fills
            if !lines.d_main.is_empty() {
                w.lsu += (v + 2.0 * r) * planes * (gk + lr); // A blocks
                w.valu += kos_nz * (v + 2.0 * r) * planes * gk; // EXT assembly
            }
        } else {
            let reload: f64 = lines
                .d_main
                .iter()
                .map(|l| cvs(l, vlen) * (2.0 + (l.base[2] != 0) as usize as f64))
                .sum();
            w.lsu += reload * gi * gk;
            let ext: f64 =
                lines.d_main.iter().filter(|l| l.base[2] != 0).map(|l| cvs(l, vlen)).sum();
            w.valu += ext * gi * gk;
        }
        // ---- k-lines: per-tile transpose trick ----
        for l in &lines.d_unit {
            let c = cvs(l, vlen);
            let it = in_tile(l, vlen);
            w.lsu += gi * gk * (v + c + (c - it) * v);
            w.valu += gi * gk * (v + it);
            w.opu += gi * gk * c;
        }
        // ---- stores + tile zeroing ----
        w.lsu += gi * gk * v;
        w.valu += gi * gk;
        per_point.add(w, 1.0 / points);
        // ---- pass 2: i-lines, other tile orientation, RMW on B ----
        if !lines.d_lead.is_empty() {
            let cv_lead: f64 = lines.d_lead.iter().map(|l| cvs(l, vlen)).sum();
            let points2 = gk * v * v; // one (i-tile, j, k-group) iteration
            let mut w2 = UnitWork::default();
            w2.lsu += 2.0 * gk * v; // tile-row RMW loads + stores
            w2.lsu += (v + 2.0 * r) * gk; // shared A blocks
            w2.lsu += cv_lead; // CV loads
            w2.opu += cv_lead * gk;
            per_point.add(w2, 1.0 / points2);
        }
    }
    Ok(per_point)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::OuterParams;
    use crate::scatter::CoverOption;
    use crate::tune::space::enumerate;

    fn est(spec: StencilSpec, n: usize, plan: &TunePlan) -> CostEstimate {
        estimate(&SimConfig::default(), spec, n, plan).unwrap()
    }

    #[test]
    fn estimates_are_finite_and_positive_over_the_space() {
        let cfg = SimConfig::default();
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(3),
            StencilSpec::diag2d(1),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
        ] {
            for plan in enumerate(&cfg, spec, 64).unwrap() {
                let e = est(spec, 64, &plan);
                assert!(
                    e.cycles_per_point.is_finite() && e.cycles_per_point > 0.0,
                    "{spec} {plan:?}: {e:?}"
                );
            }
        }
    }

    #[test]
    fn scheduling_and_unrolling_amortize_loads() {
        let spec = StencilSpec::box2d(1);
        let p = |uk, scheduled| {
            TunePlan::outer(OuterParams { option: CoverOption::Parallel, ui: 1, uk, scheduled })
        };
        let wide = est(spec, 64, &p(8, true));
        let narrow = est(spec, 64, &p(1, true));
        let naive = est(spec, 64, &p(1, false));
        assert!(wide.cycles_per_point < narrow.cycles_per_point);
        assert!(narrow.cycles_per_point < naive.cycles_per_point);
    }

    #[test]
    fn fmopa_count_matches_cover_algebra() {
        // box2d parallel: (2r+1)(2r+n) outer products per n×n tile
        let spec = StencilSpec::box2d(2);
        let e = est(spec, 64, &TunePlan::paper_default(spec));
        let n = SimConfig::default().vlen;
        let want = ((2 * 2 + 1) * (2 * 2 + n)) as f64 / (n * n) as f64;
        assert!((e.fmopa_per_point - want).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_star_needs_fewer_outer_products_than_parallel() {
        let spec = StencilSpec::star2d(3);
        let o = est(
            spec,
            64,
            &TunePlan::outer(OuterParams {
                option: CoverOption::Orthogonal,
                ui: 1,
                uk: 4,
                scheduled: true,
            }),
        );
        let p = est(
            spec,
            64,
            &TunePlan::outer(OuterParams {
                option: CoverOption::Parallel,
                ui: 1,
                uk: 4,
                scheduled: true,
            }),
        );
        assert!(o.fmopa_per_point < p.fmopa_per_point);
    }

    #[test]
    fn outer_beats_the_autovec_estimate() {
        let spec = StencilSpec::box2d(1);
        let ours = est(spec, 64, &TunePlan::paper_default(spec));
        let base = est(spec, 64, &TunePlan { method: Method::AutoVec });
        assert!(ours.cycles_per_point < base.cycles_per_point);
    }

    #[test]
    fn large_grids_hit_the_bandwidth_floor() {
        let spec = StencilSpec::box2d(1);
        let small = est(spec, 64, &TunePlan::paper_default(spec));
        let large = est(spec, 2048, &TunePlan::paper_default(spec));
        assert!(!small.mem_bound);
        assert!(large.mem_bound);
        assert!(large.cycles_per_point >= small.cycles_per_point);
    }
}
