//! Analytic cost model: ranks candidate plans *before* any simulation.
//!
//! For the paper's outer-product method the per-point operation counts
//! are no longer re-derived from the cover algebra: the generator itself
//! emits the kernel IR for one steady-state unrolled group (the smallest
//! domain that realizes the plan's effective unroll), and
//! [`crate::kir::OpStats`] counts exactly what was emitted — outer
//! products, loads/stores with gathers expanded, EXT assembly and
//! tile↔vector moves. The model then takes the binding-unit bottleneck
//! under the machine's issue width:
//!
//! ```text
//! cyc/pt ≈ max(opu/OPU, mem/LSU, valu/VALU, total/issue_width)
//! ```
//!
//! with a DRAM-bandwidth floor (`mem_line_interval`) once the working set
//! spills L2. Register pressure enters through the effective-unroll
//! normalization of [`super::space::effective_outer`]: a plan that asks
//! for more tiles than the machine has matrix registers is costed (and
//! later run) at its clamped shape.
//!
//! This is a *pruning heuristic*, not a cycle predictor: the search
//! (`super::search`) re-ranks every surviving candidate on the functional
//! + timing simulator, so model error can waste budget but never corrupt
//! results.

use super::space::{effective_outer, TunePlan};
use crate::codegen::common::{CoeffTable, Layout};
use crate::codegen::{outer, Method};
use crate::kir::{HostMachine, OpStats};
use crate::scatter::build_cover;
use crate::stencil::{CoeffTensor, DenseGrid, StencilSpec};
use crate::sim::SimConfig;
use crate::util::json::{obj, Json};

/// Modelled per-point cost of one candidate plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    /// Estimated cycles per output point per time step.
    pub cycles_per_point: f64,
    /// Outer products per output point (exact for outer plans, 0 for the
    /// vector baselines).
    pub fmopa_per_point: f64,
    /// Load/store-unit operations per output point (gathers expanded).
    pub mem_per_point: f64,
    /// True when the DRAM-bandwidth floor is the binding constraint.
    pub mem_bound: bool,
}

impl CostEstimate {
    /// Machine-readable form — the cost-model accuracy auditor
    /// ([`crate::obs::audit`]) stores these predictions next to measured
    /// serving throughput in `cost-audit.json`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("cycles_per_point", Json::Num(self.cycles_per_point)),
            ("fmopa_per_point", Json::Num(self.fmopa_per_point)),
            ("mem_per_point", Json::Num(self.mem_per_point)),
            ("mem_bound", Json::Bool(self.mem_bound)),
        ])
    }
}

/// Per-unit work accumulated per output point.
#[derive(Debug, Default, Clone, Copy)]
struct UnitWork {
    opu: f64,
    lsu: f64,
    valu: f64,
}

/// Estimate the cost of `plan` for `spec` at domain extent `n` on `cfg`.
pub fn estimate(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    plan: &TunePlan,
) -> anyhow::Result<CostEstimate> {
    let nz = spec.nonzero_points() as f64;
    let v = cfg.vlen as f64;
    let (work, fmopa_pt, mem_scale) = match plan.method {
        Method::Outer(p) => {
            let (w, fmopa) = outer_work(cfg, spec, n, p)?;
            (w, fmopa, 1.0)
        }
        Method::AutoVec => {
            // one mostly-unaligned load + one indexed FMA per tap per
            // output vector, plus the store
            let unaligned = 1.0 + cfg.split_line_penalty as f64 * 0.5;
            let w = UnitWork { opu: 0.0, lsu: (nz * unaligned + 1.0) / v, valu: nz / v };
            (w, 0.0, 1.0)
        }
        Method::Dlt => {
            // all loads aligned after the dimension-lifted transpose, at
            // the price of the in/out layout transformation each step
            let w = UnitWork { opu: 0.0, lsu: (nz + 5.0) / v, valu: (nz + 2.0) / v };
            (w, 0.0, 1.0)
        }
        Method::Tv => {
            // temporal blocking over 4 steps: slightly more register
            // shuffling per step, a quarter of the memory traffic
            let w = UnitWork { opu: 0.0, lsu: (nz * 1.1 + 1.0) / v, valu: nz * 1.3 / v };
            (w, 0.0, 0.25)
        }
        Method::Scalar => {
            let w = UnitWork { opu: 0.0, lsu: nz + 1.0, valu: nz };
            (w, 0.0, 1.0)
        }
    };
    let total = work.opu + work.lsu + work.valu;
    let mut cpp = (work.opu / cfg.opu_units as f64)
        .max(work.lsu / cfg.lsu_units as f64)
        .max(work.valu / cfg.valu_units as f64)
        .max(total / cfg.issue_width as f64);
    // fused-halo traffic model (temporal blocking at depth T): serving
    // pays one embed/extract + halo round-trip per T steps instead of
    // per step, at the price of redundantly recomputing the ghost band
    // as it shrinks by r rows per fused step — on a slab decomposition
    // the band averages (T-1)·r/2 extra rows per side and step, modelled
    // against the domain extent as the compute inflation below. Of the
    // ~3 DRAM streams the floor charges (read A, write-allocate B,
    // write back B), the input read and the write-back amortize over T
    // in serving while the per-step store stream persists, so the fused
    // floor shrinks to (1 + 2/T)/3 — deliberately less generous than
    // 1/T, since the sim measurement the search re-ranks with still
    // streams the full grid every step. (Serving-oriented, like the
    // rest of this heuristic: the measured ranking always decides.)
    let t = plan.steps.max(1) as f64;
    let mut mem_scale = mem_scale;
    if t > 1.0 {
        let inflation = 1.0 + (t - 1.0) * spec.order as f64 / n as f64;
        cpp *= inflation;
        mem_scale *= inflation * (1.0 + 2.0 / t) / 3.0;
    }
    // DRAM-bandwidth floor once A and B no longer fit in L2: ~3 streams
    // of 8 B/pt (read A, write-allocate + write back B)
    let ext = n + 2 * spec.order;
    let grid_bytes = 2 * ext.pow(spec.dims as u32) * 8;
    let floor = 24.0 / cfg.cache.line_bytes as f64 * cfg.cache.mem_line_interval as f64;
    let mut mem_bound = false;
    if grid_bytes > cfg.cache.l2_bytes {
        let floor = floor * mem_scale;
        if floor > cpp {
            cpp = floor;
            mem_bound = true;
        }
    }
    Ok(CostEstimate {
        cycles_per_point: cpp,
        fmopa_per_point: fmopa_pt,
        mem_per_point: work.lsu,
        mem_bound,
    })
}

/// Per-point unit work of the outer-product generator, counted from the
/// kernel IR it actually emits.
///
/// The program is generated (into a streaming [`OpStats`] sink — no
/// buffering) for the smallest domain that realizes one steady-state
/// unrolled group: `d = vlen · uk` after register-pressure clamping,
/// rounded up so the 3D `ui` unroll divides it (no partial groups in
/// the sample). Every group of such a domain is identical, so counts
/// normalized by `d^dims` are exact per-point steady-state numbers; in
/// particular the outer-product count reproduces the cover algebra of
/// Table 1/2 to the last operation. Gathers are expanded to `vlen` memory-pipe slots
/// (both backends element-serialize them). Returns the per-point unit
/// work and the outer products per point.
fn outer_work(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    params: crate::codegen::OuterParams,
) -> anyhow::Result<(UnitWork, f64)> {
    let p = effective_outer(cfg, spec, n, params)?;
    let mut d = (cfg.vlen * p.uk.max(1)).max(cfg.vlen);
    if spec.dims == 3 {
        // keep the 3D `ui` unroll dividing the probe domain, so the
        // sample contains no partial row groups the real (much larger)
        // domain would amortize away
        while d % p.ui.max(1) != 0 {
            d += cfg.vlen;
        }
    }
    let coeffs = CoeffTensor::paper_default(spec);
    let cover = build_cover(&coeffs, p.option)?;
    let shape = vec![d + 2 * spec.order; spec.dims];
    let zero = DenseGrid::zeros(&shape);
    let mut arena = HostMachine::from_config(cfg);
    let layout = Layout::alloc(&mut arena, spec, &zero);
    let table = CoeffTable::install_full(&mut arena, &coeffs, &cover);
    let mut stats = OpStats::default();
    outer::generate(cfg, &layout, &cover, &table, p, &mut stats)?;
    let points = (d as f64).powi(spec.dims as i32);
    let work = UnitWork {
        opu: stats.opu_ops() as f64 / points,
        lsu: stats.lsu_slots(cfg.vlen) as f64 / points,
        valu: stats.valu_ops() as f64 / points,
    };
    Ok((work, stats.outer_products as f64 / points))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::OuterParams;
    use crate::scatter::CoverOption;
    use crate::tune::space::enumerate;

    fn est(spec: StencilSpec, n: usize, plan: &TunePlan) -> CostEstimate {
        estimate(&SimConfig::default(), spec, n, plan).unwrap()
    }

    #[test]
    fn estimates_are_finite_and_positive_over_the_space() {
        let cfg = SimConfig::default();
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(3),
            StencilSpec::diag2d(1),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
        ] {
            for plan in enumerate(&cfg, spec, 64).unwrap() {
                let e = est(spec, 64, &plan);
                assert!(
                    e.cycles_per_point.is_finite() && e.cycles_per_point > 0.0,
                    "{spec} {plan:?}: {e:?}"
                );
            }
        }
    }

    #[test]
    fn scheduling_and_unrolling_amortize_loads() {
        let spec = StencilSpec::box2d(1);
        let p = |uk, scheduled| {
            TunePlan::outer(OuterParams { option: CoverOption::Parallel, ui: 1, uk, scheduled })
        };
        let wide = est(spec, 64, &p(8, true));
        let narrow = est(spec, 64, &p(1, true));
        let naive = est(spec, 64, &p(1, false));
        assert!(wide.cycles_per_point < narrow.cycles_per_point);
        assert!(narrow.cycles_per_point < naive.cycles_per_point);
    }

    #[test]
    fn fmopa_count_matches_cover_algebra() {
        // box2d parallel: (2r+1)(2r+n) outer products per n×n tile
        let spec = StencilSpec::box2d(2);
        let e = est(spec, 64, &TunePlan::paper_default(spec));
        let n = SimConfig::default().vlen;
        let want = ((2 * 2 + 1) * (2 * 2 + n)) as f64 / (n * n) as f64;
        assert!((e.fmopa_per_point - want).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_star_needs_fewer_outer_products_than_parallel() {
        let spec = StencilSpec::star2d(3);
        let o = est(
            spec,
            64,
            &TunePlan::outer(OuterParams {
                option: CoverOption::Orthogonal,
                ui: 1,
                uk: 4,
                scheduled: true,
            }),
        );
        let p = est(
            spec,
            64,
            &TunePlan::outer(OuterParams {
                option: CoverOption::Parallel,
                ui: 1,
                uk: 4,
                scheduled: true,
            }),
        );
        assert!(o.fmopa_per_point < p.fmopa_per_point);
    }

    #[test]
    fn outer_beats_the_autovec_estimate() {
        let spec = StencilSpec::box2d(1);
        let ours = est(spec, 64, &TunePlan::paper_default(spec));
        let base = est(spec, 64, &TunePlan::new(Method::AutoVec));
        assert!(ours.cycles_per_point < base.cycles_per_point);
    }

    #[test]
    fn large_grids_hit_the_bandwidth_floor() {
        let spec = StencilSpec::box2d(1);
        let small = est(spec, 64, &TunePlan::paper_default(spec));
        let large = est(spec, 2048, &TunePlan::paper_default(spec));
        assert!(!small.mem_bound);
        assert!(large.mem_bound);
        assert!(large.cycles_per_point >= small.cycles_per_point);
    }

    #[test]
    fn estimate_json_carries_every_field() {
        let spec = StencilSpec::box2d(1);
        let e = est(spec, 64, &TunePlan::paper_default(spec));
        let j = e.to_json();
        assert_eq!(j.get("cycles_per_point").unwrap().as_f64(), Some(e.cycles_per_point));
        assert_eq!(j.get("fmopa_per_point").unwrap().as_f64(), Some(e.fmopa_per_point));
        assert_eq!(j.get("mem_per_point").unwrap().as_f64(), Some(e.mem_per_point));
        assert_eq!(j.get("mem_bound").unwrap().as_bool(), Some(e.mem_bound));
    }

    #[test]
    fn temporal_blocking_trades_ghost_compute_for_dram_traffic() {
        let spec = StencilSpec::box2d(1);
        // in-cache: fusing only adds redundant ghost compute
        let small = est(spec, 64, &TunePlan::paper_default(spec));
        let small_fused = est(spec, 64, &TunePlan::paper_default(spec).fused(4));
        assert!(small_fused.cycles_per_point > small.cycles_per_point);
        assert!(
            small_fused.cycles_per_point < small.cycles_per_point * 1.2,
            "ghost-band inflation stays modest: {} vs {}",
            small_fused.cycles_per_point,
            small.cycles_per_point
        );
        // memory-bound: the amortized DRAM floor wins
        let large = est(spec, 2048, &TunePlan::paper_default(spec));
        let large_fused = est(spec, 2048, &TunePlan::paper_default(spec).fused(4));
        assert!(large.mem_bound);
        assert!(
            large_fused.cycles_per_point < large.cycles_per_point,
            "fusion must beat the unfused DRAM floor: {} vs {}",
            large_fused.cycles_per_point,
            large.cycles_per_point
        );
    }
}
