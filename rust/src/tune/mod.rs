//! Sim-in-the-loop autotuning: search the paper's optimization space,
//! verify every candidate against the scalar oracle, and persist the
//! winners for the rest of the stack to consume.
//!
//! The paper's speedups come from *choices* — which coefficient-line
//! cover (§4.1), which unroll factors (§4.2), whether to schedule outer
//! products (§4.3), which data layout — and the best choice depends on
//! the stencil, the grid size, and the machine. This subsystem closes the
//! loop:
//!
//! - [`space`] — the [`space::TunePlan`] search space (cover option ×
//!   unroll × scheduling × layout × method × time-tile depth `T`),
//!   normalized to what the generator's register-pressure clamping
//!   actually runs;
//! - [`cost`] — an analytic per-point cost model derived from
//!   [`crate::sim::SimConfig`] and, for outer plans, from
//!   [`crate::kir::OpStats`] over the kernel IR the generator actually
//!   emits (exact outer-product/load/EXT counts for one steady-state
//!   unrolled group, plus a DRAM-bandwidth floor) used to prune the
//!   space;
//! - [`search`] — measures every surviving candidate on the functional +
//!   timing simulator via [`crate::codegen::run_method`]; a candidate
//!   whose generated program does not reproduce the scalar oracle aborts
//!   the search. The paper-default plan is always measured, so the tuned
//!   winner is **never worse than the paper default**;
//! - [`db`] — the versioned JSON tuning database;
//! - [`report`] — markdown/JSON tuning reports.
//!
//! Consumers: the `tune` CLI subcommand drives searches and maintains the
//! database; `serve`'s plan cache consults the database when compiling
//! shard kernels for the `tuned` kernel method; `coordinator::sweep` can
//! run a `tuned` method cell resolved from a database; the bench
//! harness's tuned-vs-default ablation quantifies what tuning buys.
//!
//! # Tuning-database schema (version 1)
//!
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {
//!       "spec": {"dims": 2, "order": 1, "kind": "star"},
//!       "n": 64,
//!       "fingerprint": "9f86d081884c7d65",
//!       "plan": {"method": "outer", "option": "parallel",
//!                "ui": 1, "uk": 8, "scheduled": true},
//!       "cycles": 9216,
//!       "cycles_per_point": 2.25,
//!       "default_cycles_per_point": 2.25,
//!       "speedup_vs_default": 1.0,
//!       "searched": 18,
//!       "measured": 12
//!     }
//!   ]
//! }
//! ```
//!
//! - `spec.kind` is one of `"box"`, `"star"`, `"diag"`.
//! - `plan.method` is one of `"outer"`, `"autovec"`, `"dlt"`, `"tv"`,
//!   `"scalar"`; the `option`/`ui`/`uk`/`scheduled` fields are present
//!   only for `"outer"` (`option` is a [`crate::scatter::CoverOption`]
//!   name: `parallel`, `orthogonal`, `hybrid`, `minimalaxis`,
//!   `diagonals`).
//! - `plan.steps` is the time-tile depth `T` (temporal blocking: `T`
//!   fused steps per kernel application), present only when `> 1`;
//!   databases written before the field existed load as single-sweep
//!   plans.
//! - `fingerprint` is [`crate::sim::SimConfig::fingerprint`]: a 16-hex-
//!   digit FNV-1a hash over **every** machine parameter (vector length,
//!   register counts, issue width, unit counts, latencies, MSHRs, split-
//!   line penalty, and the full cache hierarchy). Entries only apply to
//!   the machine they were measured on; a changed config yields a new
//!   fingerprint and tuning starts fresh.
//! - Database keys are `(spec, n, fingerprint)`; recording an outcome for
//!   an existing key replaces the entry. Loading a file whose `version`
//!   differs from [`db::TUNE_DB_VERSION`] is an error (re-run `tune`).

pub mod cost;
pub mod db;
pub mod report;
pub mod search;
pub mod space;

pub use cost::{estimate, CostEstimate};
pub use db::{TuneDb, TuneEntry, TUNE_DB_VERSION};
pub use search::{tune, tune_with_engine, Measurement, Strategy, TuneOutcome};
pub use space::{enumerate, TunePlan};
