//! The tuning search space: every knob the paper exposes, as data.
//!
//! A [`TunePlan`] names one complete configuration of the stack:
//!
//! - **method** — the paper's outer-product algorithm or one of the
//!   baselines (`autovec`, `dlt`, `tv`); the DLT baseline doubles as the
//!   *layout* axis of the space (dimension-lifted transposed storage vs.
//!   the standard padded row-major layout every other method uses);
//! - for the outer method: **cover option** (§4.1), **unroll factors**
//!   `ui × uk` (§4.2) and **outer-product scheduling** on/off (§4.3);
//! - the **time-tile depth** `T` ([`TunePlan::steps`], explored at
//!   [`TIME_TILES`] for every scheduled outer plan): how many time steps
//!   one kernel application fuses behind deep halos (temporal
//!   blocking) — trading redundant ghost-band compute for `1/T` of the
//!   halo exchanges and DRAM round-trips.
//!
//! [`enumerate`] expands the full space for a stencil on a machine,
//! normalizing unroll factors to what the generator's register-pressure
//! clamping would actually run (`n_mregs`, minus a scratch tile when the
//! cover needs the §4.1 transpose trick) and deduplicating configurations
//! that clamp to the same effective plan — so every candidate in the
//! space is *distinct* work for the simulator.

use crate::codegen::{Method, OuterParams};
use crate::scatter::{build_cover, CoverOption};
use crate::stencil::{CoeffTensor, StencilSpec};
use crate::sim::SimConfig;
use crate::util::json::{obj, Json};

/// One point of the search space: an execution [`Method`] plus the
/// time-tile depth `steps` (temporal blocking; 1 = classic single
/// sweep).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunePlan {
    /// The execution method this plan selects.
    pub method: Method,
    /// Fused time steps per kernel application (the temporal-blocking
    /// axis of the space; only in-place single-sweep methods support
    /// `steps > 1`).
    pub steps: usize,
}

impl TunePlan {
    /// Single-sweep plan for a method.
    pub fn new(method: Method) -> TunePlan {
        TunePlan { method, steps: 1 }
    }

    /// Plan for the paper's outer method with explicit parameters.
    pub fn outer(params: OuterParams) -> TunePlan {
        TunePlan::new(Method::Outer(params))
    }

    /// The paper's default plan for a spec (the tuning baseline).
    pub fn paper_default(spec: StencilSpec) -> TunePlan {
        TunePlan::outer(OuterParams::paper_best(spec))
    }

    /// This plan with a time-tile depth of `steps`.
    pub fn fused(self, steps: usize) -> TunePlan {
        TunePlan { steps: steps.max(1), ..self }
    }

    /// The wrapped method.
    pub fn to_method(&self) -> Method {
        self.method
    }

    /// Short Table-3-style label: `p-j8`, `o-i4`, `autovec`, ... with a
    /// `-tT` suffix for temporally blocked plans (e.g. `p-j8-t4`).
    pub fn label(&self, dims: usize) -> String {
        let mut l = match self.method {
            Method::Outer(p) => {
                let mut l = p.label(dims);
                if !p.scheduled {
                    l.push_str("-ns");
                }
                l
            }
            Method::AutoVec => "autovec".to_string(),
            Method::Dlt => "dlt".to_string(),
            Method::Tv => "tv".to_string(),
            Method::Scalar => "scalar".to_string(),
        };
        if self.steps > 1 {
            l.push_str(&format!("-t{}", self.steps));
        }
        l
    }

    /// Serialize for the tuning database (`steps` omitted when 1, so
    /// single-sweep entries keep the pre-temporal-blocking shape).
    pub fn to_json(&self) -> Json {
        let mut pairs = match self.method {
            Method::Outer(p) => vec![
                ("method", Json::Str("outer".into())),
                ("option", Json::Str(p.option.to_string())),
                ("ui", Json::Num(p.ui as f64)),
                ("uk", Json::Num(p.uk as f64)),
                ("scheduled", Json::Bool(p.scheduled)),
            ],
            Method::AutoVec => vec![("method", Json::Str("autovec".into()))],
            Method::Dlt => vec![("method", Json::Str("dlt".into()))],
            Method::Tv => vec![("method", Json::Str("tv".into()))],
            Method::Scalar => vec![("method", Json::Str("scalar".into()))],
        };
        if self.steps > 1 {
            pairs.push(("steps", Json::Num(self.steps as f64)));
        }
        obj(pairs)
    }

    /// Deserialize from the tuning database (a missing `steps` field
    /// means 1 — databases written before temporal blocking load
    /// unchanged).
    pub fn from_json(v: &Json) -> anyhow::Result<TunePlan> {
        let name = v
            .get("method")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("plan is missing the 'method' field"))?;
        let method = match name {
            "outer" => {
                let option: CoverOption = v
                    .get("option")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("outer plan is missing 'option'"))?
                    .parse()?;
                let ui = v.get("ui").and_then(Json::as_usize).unwrap_or(1).max(1);
                let uk = v.get("uk").and_then(Json::as_usize).unwrap_or(1).max(1);
                let scheduled = v.get("scheduled").and_then(Json::as_bool).unwrap_or(true);
                Method::Outer(OuterParams { option, ui, uk, scheduled })
            }
            "autovec" => Method::AutoVec,
            "dlt" => Method::Dlt,
            "tv" => Method::Tv,
            "scalar" => Method::Scalar,
            other => anyhow::bail!("unknown plan method '{other}'"),
        };
        let steps = v.get("steps").and_then(Json::as_usize).unwrap_or(1).max(1);
        Ok(TunePlan { method, steps })
    }
}

/// The effective outer parameters after the generator's register-pressure
/// clamping (see `codegen::outer::gen2d`/`gen3d`): unroll factors are
/// limited by `n_mregs`, minus one scratch tile when the cover contains
/// unit-stride-dimension lines (the §4.1 transpose trick), and by the
/// number of tiles the domain actually has. Unscheduled plans share
/// nothing across tiles, so their unroll factors normalize to 1.
pub fn effective_outer(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    p: OuterParams,
) -> anyhow::Result<OuterParams> {
    let coeffs = CoeffTensor::paper_default(spec);
    let cover = build_cover(&coeffs, p.option)?;
    // unit-stride-dim axis lines need the scratch matrix register for the
    // transpose trick (2D diagonal lines use vector scratch instead)
    let last = spec.dims - 1;
    let needs_scratch = cover
        .lines
        .iter()
        .any(|l| l.dir.iter().filter(|&&d| d != 0).count() == 1 && l.dir[last] != 0);
    anyhow::ensure!(
        cfg.n_mregs > needs_scratch as usize,
        "machine has {} matrix register(s), but the {:?} cover needs at least {} \
         (one output tile{})",
        cfg.n_mregs,
        p.option,
        1 + needs_scratch as usize,
        if needs_scratch { " plus the transpose scratch tile" } else { "" },
    );
    let max_tiles = if needs_scratch { cfg.n_mregs - 1 } else { cfg.n_mregs };
    let tiles_unit = (n / cfg.vlen).max(1);
    if !p.scheduled {
        return Ok(OuterParams { option: p.option, ui: 1, uk: 1, scheduled: false });
    }
    if spec.dims == 2 {
        let uk = p.uk.clamp(1, max_tiles).min(tiles_unit);
        Ok(OuterParams { option: p.option, ui: 1, uk, scheduled: true })
    } else {
        let ui = p.ui.clamp(1, max_tiles).min(n);
        let uk = p.uk.clamp(1, max_tiles / ui).min(tiles_unit);
        Ok(OuterParams { option: p.option, ui, uk, scheduled: true })
    }
}

/// Time-tile depths the space explores for fusable plans (beyond the
/// implicit `T = 1`).
pub const TIME_TILES: &[usize] = &[2, 4];

/// Expand the full (deduplicated) search space for `spec` at domain size
/// `n` on machine `cfg`. The paper-default plan is always a member;
/// every scheduled outer plan also appears temporally blocked at the
/// [`TIME_TILES`] depths (the `T` axis).
pub fn enumerate(cfg: &SimConfig, spec: StencilSpec, n: usize) -> anyhow::Result<Vec<TunePlan>> {
    let mut out: Vec<TunePlan> = Vec::new();
    let push = |plan: TunePlan, out: &mut Vec<TunePlan>| {
        if !out.contains(&plan) {
            out.push(plan);
        }
    };
    for option in CoverOption::applicable(spec) {
        // an option whose cover the machine cannot host (not enough
        // matrix registers for a tile + scratch) is skipped, not fatal
        let probe = OuterParams { option, ui: 1, uk: 1, scheduled: true };
        if effective_outer(cfg, spec, n, probe).is_err() {
            continue;
        }
        // scheduled plans: the unroll grid, normalized + deduplicated
        let unrolls: Vec<(usize, usize)> = if spec.dims == 2 {
            [1usize, 2, 4, 8].iter().map(|&uk| (1, uk)).collect()
        } else {
            let mut v = Vec::new();
            for ui in [1usize, 2, 4, 8] {
                for uk in [1usize, 2, 4] {
                    if ui * uk <= cfg.n_mregs {
                        v.push((ui, uk));
                    }
                }
            }
            v
        };
        for (ui, uk) in unrolls {
            let p = OuterParams { option, ui, uk, scheduled: true };
            let plan = TunePlan::outer(effective_outer(cfg, spec, n, p)?);
            push(plan, &mut out);
            // the temporal-blocking axis: same plan at depth T
            for &t in TIME_TILES {
                push(plan.fused(t), &mut out);
            }
        }
        // the §4.3 naive strawman (no cross-tile sharing)
        let naive = OuterParams { option, ui: 1, uk: 1, scheduled: false };
        push(TunePlan::outer(naive), &mut out);
    }
    // the baselines: autovec (the speedup reference), DLT (the layout
    // axis), and temporal vectorization
    for m in [Method::AutoVec, Method::Dlt, Method::Tv] {
        push(TunePlan::new(m), &mut out);
    }
    // the paper default is a scheduled config the grid above covers, but
    // make the invariant explicit in case paper_best ever moves outside it
    let default = TunePlan::outer(effective_outer(
        cfg,
        spec,
        n,
        OuterParams::paper_best(spec),
    )?);
    push(default, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_contains_paper_default_and_baselines() {
        let cfg = SimConfig::default();
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(2),
            StencilSpec::diag2d(1),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
        ] {
            let space = enumerate(&cfg, spec, 64).unwrap();
            let default = TunePlan::outer(
                effective_outer(&cfg, spec, 64, OuterParams::paper_best(spec)).unwrap(),
            );
            assert!(space.contains(&default), "{spec}");
            assert!(space.contains(&TunePlan::new(Method::AutoVec)));
            assert!(space.contains(&TunePlan::new(Method::Dlt)));
            assert!(space.contains(&TunePlan::new(Method::Tv)));
            // deduplicated
            for (i, a) in space.iter().enumerate() {
                assert!(!space[i + 1..].contains(a), "{spec}: duplicate {a:?}");
            }
        }
    }

    #[test]
    fn effective_unrolls_respect_register_pressure() {
        let cfg = SimConfig::default(); // 8 matrix registers
        // 2D orthogonal star needs the transpose scratch → at most 7 tiles
        let p = OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 8, scheduled: true };
        let e = effective_outer(&cfg, StencilSpec::star2d(1), 64, p).unwrap();
        assert_eq!(e.uk, 7);
        // 2D parallel covers only use row lines → all 8 tiles available
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 8, scheduled: true };
        let e = effective_outer(&cfg, StencilSpec::box2d(1), 64, p).unwrap();
        assert_eq!(e.uk, 8);
        // 3D: ui×uk bounded by the tile budget
        let p = OuterParams { option: CoverOption::Parallel, ui: 8, uk: 4, scheduled: true };
        let e = effective_outer(&cfg, StencilSpec::box3d(1), 64, p).unwrap();
        assert!(e.ui * e.uk <= cfg.n_mregs);
        // small domains clamp the unit-stride unroll to the tile count
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 8, scheduled: true };
        let e = effective_outer(&cfg, StencilSpec::box2d(1), 16, p).unwrap();
        assert_eq!(e.uk, 2);
    }

    #[test]
    fn too_few_matrix_registers_is_an_error_not_a_panic() {
        // 1 mreg + a cover needing the transpose scratch: no tile left
        let tiny = SimConfig::default().with_mregs(1);
        let p = OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 1, scheduled: true };
        assert!(effective_outer(&tiny, StencilSpec::star2d(1), 64, p).is_err());
        // 1 mreg with a scratch-free cover is still (just) runnable
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk: 4, scheduled: true };
        let e = effective_outer(&tiny, StencilSpec::box2d(1), 64, p).unwrap();
        assert_eq!(e.uk, 1);
    }

    #[test]
    fn unscheduled_normalizes_unrolls() {
        let cfg = SimConfig::default();
        let p = OuterParams { option: CoverOption::Parallel, ui: 4, uk: 8, scheduled: false };
        let e = effective_outer(&cfg, StencilSpec::box2d(1), 64, p).unwrap();
        assert_eq!((e.ui, e.uk, e.scheduled), (1, 1, false));
    }

    #[test]
    fn plans_roundtrip_through_json() {
        let cfg = SimConfig::default();
        for spec in [StencilSpec::star2d(1), StencilSpec::box3d(1), StencilSpec::diag2d(2)] {
            for plan in enumerate(&cfg, spec, 64).unwrap() {
                let back = TunePlan::from_json(&plan.to_json()).unwrap();
                assert_eq!(back, plan, "{spec}");
            }
        }
        assert!(TunePlan::from_json(&Json::Null).is_err());
        assert!(TunePlan::from_json(&obj(vec![("method", Json::Str("warp".into()))])).is_err());
    }

    #[test]
    fn space_explores_the_time_tile_axis() {
        let cfg = SimConfig::default();
        let space = enumerate(&cfg, StencilSpec::box2d(1), 64).unwrap();
        let default = TunePlan::outer(
            effective_outer(&cfg, StencilSpec::box2d(1), 64, OuterParams::paper_best(StencilSpec::box2d(1)))
                .unwrap(),
        );
        for &t in TIME_TILES {
            assert!(space.contains(&default.fused(t)), "T={t} variant of the default");
        }
        // baselines and the naive strawman stay single-sweep
        for p in &space {
            if matches!(p.method, Method::AutoVec | Method::Dlt | Method::Tv) {
                assert_eq!(p.steps, 1, "{p:?}");
            }
            if let Method::Outer(op) = p.method {
                if !op.scheduled {
                    assert_eq!(p.steps, 1, "{p:?}");
                }
            }
        }
    }

    #[test]
    fn fused_plans_roundtrip_and_label() {
        let plan = TunePlan::paper_default(StencilSpec::box2d(1)).fused(4);
        assert_eq!(plan.label(2), "p-j8-t4");
        let back = TunePlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        // a plan serialized before temporal blocking (no 'steps' field)
        // deserializes as single-sweep
        let old = TunePlan::paper_default(StencilSpec::box2d(1));
        assert!(!old.to_json().to_string_compact().contains("steps"));
        assert_eq!(TunePlan::from_json(&old.to_json()).unwrap().steps, 1);
    }

    #[test]
    fn labels_are_compact() {
        assert_eq!(TunePlan::paper_default(StencilSpec::box2d(1)).label(2), "p-j8");
        let naive = TunePlan::outer(OuterParams {
            option: CoverOption::Parallel,
            ui: 1,
            uk: 1,
            scheduled: false,
        });
        assert_eq!(naive.label(2), "p-j1-ns");
        assert_eq!(TunePlan::new(Method::Dlt).label(3), "dlt");
    }
}
