//! Human- and machine-readable tuning reports (markdown + JSON).

use super::search::TuneOutcome;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// Render a tuning outcome as a markdown report.
///
/// Rows are sorted best-first; the winner is marked `*` and the paper
/// default `(default)`, mirroring the bench harness's table style.
pub fn to_markdown(out: &TuneOutcome) -> String {
    let mut table = Table::new(&[
        "rank",
        "plan",
        "est cyc/pt",
        "cyc/pt",
        "cycles",
        "vs default",
        "host Mpts/s",
        "verified",
    ]);
    let default_cpp = out.paper_default().cycles_per_point;
    for (rank, &i) in out.ranking().iter().enumerate() {
        let m = &out.measurements[i];
        let mut label = m.plan.label(out.spec.dims);
        if i == out.best_idx {
            label.push('*');
        }
        if i == out.default_idx {
            label.push_str(" (default)");
        }
        table.row(vec![
            (rank + 1).to_string(),
            label,
            format!("{:.3}", m.est.cycles_per_point),
            format!("{:.3}", m.cycles_per_point),
            m.cycles.to_string(),
            format!("{:.2}x", default_cpp / m.cycles_per_point),
            // advisory compiled-engine wall-clock, winner + default only
            m.host_mpts_per_s.map_or("-".to_string(), |h| format!("{h:.1}")),
            "yes".to_string(), // unverified candidates abort the search
        ]);
    }
    format!(
        "# tune — {} N={} ({} strategy)\n\n\
         machine fingerprint `{}`; space {} plan(s), {} pruned by the cost \
         model, {} measured (all oracle-verified).\n\n{}\n\
         best: **{}** at {:.3} cyc/pt — {:.2}x vs the paper default\n",
        out.spec,
        out.n,
        out.strategy,
        out.fingerprint,
        out.space_size,
        out.pruned,
        out.measurements.len(),
        table.to_markdown(),
        out.best().plan.label(out.spec.dims),
        out.best().cycles_per_point,
        out.speedup_vs_default(),
    )
}

/// Render a tuning outcome as JSON (every measurement included).
pub fn to_json(out: &TuneOutcome) -> Json {
    let measurements: Vec<Json> = out
        .measurements
        .iter()
        .enumerate()
        .map(|(i, m)| {
            obj(vec![
                ("plan", m.plan.to_json()),
                ("label", Json::Str(m.plan.label(out.spec.dims))),
                ("est_cycles_per_point", Json::Num(m.est.cycles_per_point)),
                ("cycles", Json::Num(m.cycles as f64)),
                ("cycles_per_point", Json::Num(m.cycles_per_point)),
                ("max_err", Json::Num(m.max_err)),
                ("host_seconds", m.host_seconds.map_or(Json::Null, Json::Num)),
                ("host_mpts_per_s", m.host_mpts_per_s.map_or(Json::Null, Json::Num)),
                ("best", Json::Bool(i == out.best_idx)),
                ("default", Json::Bool(i == out.default_idx)),
            ])
        })
        .collect();
    obj(vec![
        ("stencil", Json::Str(out.spec.name())),
        ("n", Json::Num(out.n as f64)),
        ("fingerprint", Json::Str(out.fingerprint.clone())),
        ("strategy", Json::Str(out.strategy.to_string())),
        ("space_size", Json::Num(out.space_size as f64)),
        ("pruned", Json::Num(out.pruned as f64)),
        ("speedup_vs_default", Json::Num(out.speedup_vs_default())),
        ("measurements", Json::Arr(measurements)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::search::{tune, Strategy};
    use crate::stencil::StencilSpec;
    use crate::sim::SimConfig;

    #[test]
    fn report_renders_and_marks_best() {
        let out =
            tune(&SimConfig::default(), StencilSpec::box2d(1), 16, 3, Strategy::CostGuided)
                .unwrap();
        let md = to_markdown(&out);
        assert!(md.contains("(default)"), "{md}");
        assert!(md.contains('*'));
        assert!(md.contains("vs the paper default"));
        assert!(md.contains("host Mpts/s"), "{md}");
        let j = to_json(&out);
        assert_eq!(j.get("stencil").and_then(Json::as_str), Some("2d9p-box-r1"));
        let ms = j.get("measurements").and_then(Json::as_arr).unwrap();
        assert_eq!(ms.len(), out.measurements.len());
        assert_eq!(ms.iter().filter(|m| m.get("best").and_then(Json::as_bool) == Some(true)).count(), 1);
        // JSON output parses back
        let rt = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(rt.get("n").and_then(Json::as_usize), Some(16));
    }
}
