//! Sim-in-the-loop search: prune with the cost model, then measure every
//! surviving candidate on the functional + timing simulator.
//!
//! Every measurement goes through [`crate::codegen::run_method`], which
//! executes the generated program *functionally* and compares the full
//! output grid against the scalar oracle — a candidate that does not
//! reproduce the oracle aborts the search instead of entering the
//! ranking, so the tuning database can only ever contain plans whose
//! generated code is correct.
//!
//! The paper-default plan ([`crate::codegen::OuterParams::paper_best`])
//! is force-included in every search, which gives the headline guarantee:
//! the tuned plan is **never worse than the paper default** on the
//! simulator, because the ranking minimum is taken over a set containing
//! it.

use super::cost::{estimate, CostEstimate};
use super::space::{enumerate, TunePlan};
use crate::codegen::{run_host_fused, run_method_fused};
use crate::kir::Engine;
use crate::stencil::StencilSpec;
use crate::sim::SimConfig;
use std::fmt;
use std::str::FromStr;

/// How aggressively to prune the space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Measure every candidate in the space (budget ignored).
    Exhaustive,
    /// Measure the `budget` candidates the cost model ranks cheapest
    /// (plus the paper default).
    CostGuided,
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Strategy::Exhaustive => write!(f, "exhaustive"),
            Strategy::CostGuided => write!(f, "guided"),
        }
    }
}

impl FromStr for Strategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Strategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "exhaustive" | "full" | "all" => Strategy::Exhaustive,
            "guided" | "cost" | "greedy" => Strategy::CostGuided,
            other => anyhow::bail!("unknown strategy '{other}' (guided|exhaustive)"),
        })
    }
}

/// One measured (and oracle-verified) candidate.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// The plan that ran.
    pub plan: TunePlan,
    /// The cost model's prediction for it.
    pub est: CostEstimate,
    /// Measured simulated cycles (one pass, warm caches).
    pub cycles: u64,
    /// Measured cycles per output point per time step.
    pub cycles_per_point: f64,
    /// Max |error| vs. the scalar oracle (`< 1e-9` by construction —
    /// unverified candidates abort the search).
    pub max_err: f64,
    /// Compiled-engine host wall-clock seconds for the same program
    /// (advisory, measured for the winner and the paper default only;
    /// the ranking key stays simulated cycles).
    pub host_seconds: Option<f64>,
    /// Host throughput in Mpoints/s matching `host_seconds`.
    pub host_mpts_per_s: Option<f64>,
}

/// The result of one tuning run.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// Stencil tuned.
    pub spec: StencilSpec,
    /// Domain extent tuned at.
    pub n: usize,
    /// Fingerprint of the machine config the measurements ran on.
    pub fingerprint: String,
    /// Strategy used.
    pub strategy: Strategy,
    /// Size of the full (deduplicated) space.
    pub space_size: usize,
    /// Candidates the cost model pruned away.
    pub pruned: usize,
    /// All measured candidates, in measurement order.
    pub measurements: Vec<Measurement>,
    /// Index of the winning measurement (minimum cycles per point).
    pub best_idx: usize,
    /// Index of the paper-default measurement.
    pub default_idx: usize,
}

impl TuneOutcome {
    /// The winning measurement.
    pub fn best(&self) -> &Measurement {
        &self.measurements[self.best_idx]
    }

    /// The paper-default measurement.
    pub fn paper_default(&self) -> &Measurement {
        &self.measurements[self.default_idx]
    }

    /// Speedup of the tuned plan over the paper default (≥ 1 by
    /// construction).
    pub fn speedup_vs_default(&self) -> f64 {
        self.paper_default().cycles_per_point / self.best().cycles_per_point
    }

    /// Measurement indices sorted best-first.
    pub fn ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.measurements.len()).collect();
        idx.sort_by(|&a, &b| {
            self.measurements[a]
                .cycles_per_point
                .total_cmp(&self.measurements[b].cycles_per_point)
        });
        idx
    }
}

/// Tune `spec` at domain extent `n` on machine `cfg`.
///
/// `budget` bounds the number of simulator runs under
/// [`Strategy::CostGuided`] (the paper-default plan is always measured,
/// even if the model would prune it). The advisory host wall-clock
/// columns use the compiled engine; [`tune_with_engine`] selects a
/// different one.
pub fn tune(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    budget: usize,
    strategy: Strategy,
) -> anyhow::Result<TuneOutcome> {
    tune_with_engine(cfg, spec, n, budget, strategy, Engine::Compiled)
}

/// [`tune`] with an explicit host engine for the advisory wall-clock
/// measurement (the simulated ranking itself is engine-independent —
/// only the real-CPU columns in the report change).
pub fn tune_with_engine(
    cfg: &SimConfig,
    spec: StencilSpec,
    n: usize,
    budget: usize,
    strategy: Strategy,
    host_engine: Engine,
) -> anyhow::Result<TuneOutcome> {
    anyhow::ensure!(
        n >= cfg.vlen && n % cfg.vlen == 0,
        "domain extent {n} must be a positive multiple of the vector length {}",
        cfg.vlen
    );
    anyhow::ensure!(
        spec.order <= cfg.vlen,
        "stencil order {} exceeds the vector length {}",
        spec.order,
        cfg.vlen
    );
    let space = enumerate(cfg, spec, n)?;
    let space_size = space.len();
    let default_plan = {
        let p = crate::codegen::OuterParams::paper_best(spec);
        TunePlan::outer(super::space::effective_outer(cfg, spec, n, p)?)
    };

    // rank the space by modelled cost
    let mut ranked: Vec<(TunePlan, CostEstimate)> = space
        .into_iter()
        .map(|plan| estimate(cfg, spec, n, &plan).map(|e| (plan, e)))
        .collect::<anyhow::Result<_>>()?;
    ranked.sort_by(|a, b| a.1.cycles_per_point.total_cmp(&b.1.cycles_per_point));

    let keep = match strategy {
        Strategy::Exhaustive => ranked.len(),
        Strategy::CostGuided => budget.max(1).min(ranked.len()),
    };
    let mut survivors: Vec<(TunePlan, CostEstimate)> = ranked[..keep].to_vec();
    if !survivors.iter().any(|(p, _)| *p == default_plan) {
        // force the baseline in, displacing the model's worst survivor
        let est = ranked
            .iter()
            .find(|(p, _)| *p == default_plan)
            .map(|(_, e)| *e)
            .expect("enumerate always includes the paper default");
        if survivors.len() == keep && keep == budget.max(1) && !survivors.is_empty() {
            survivors.pop();
        }
        survivors.push((default_plan, est));
    }
    let pruned = space_size - survivors.len();

    // ---- sim-in-the-loop: measure + verify every survivor ----
    // (temporally blocked candidates run their fused T-step program and
    // are verified against T oracle steps; cycles_per_point normalizes
    // per step, so depths compete fairly)
    let mut measurements = Vec::with_capacity(survivors.len());
    for (ci, (plan, est)) in survivors.into_iter().enumerate() {
        let _m = crate::obs::span::span_arg("tune.measure", "tune", ("candidate", ci as f64));
        let res = run_method_fused(cfg, spec, n, plan.to_method(), true, plan.steps)?;
        anyhow::ensure!(
            res.verified(),
            "candidate {} failed oracle verification (max_err {:.3e}) — refusing to rank it",
            plan.label(spec.dims),
            res.max_err
        );
        measurements.push(Measurement {
            plan,
            est,
            cycles: res.stats.cycles,
            cycles_per_point: res.cycles_per_point(),
            max_err: res.max_err,
            host_seconds: None,
            host_mpts_per_s: None,
        });
    }
    // first minimum wins ties, consistent with the stable sort in
    // `TuneOutcome::ranking`
    let best_idx = (1..measurements.len()).fold(0usize, |best, i| {
        if measurements[i].cycles_per_point < measurements[best].cycles_per_point {
            i
        } else {
            best
        }
    });
    let default_idx = measurements
        .iter()
        .position(|m| m.plan == default_plan)
        .expect("paper default is always measured");
    // advisory: host wall-clock on the selected engine for the winner
    // and the baseline, so the report shows real CPU throughput next to
    // the simulated ranking
    let mut host_idx = vec![best_idx];
    if default_idx != best_idx {
        host_idx.push(default_idx);
    }
    for idx in host_idx {
        let method = measurements[idx].plan.to_method();
        let host = run_host_fused(cfg, spec, n, method, host_engine, measurements[idx].plan.steps)?;
        anyhow::ensure!(
            host.verified(),
            "host run of {} failed verification (max_err {:.3e})",
            measurements[idx].plan.label(spec.dims),
            host.max_err
        );
        let points = n.pow(spec.dims as u32);
        measurements[idx].host_seconds = Some(host.seconds);
        measurements[idx].host_mpts_per_s = Some(host.mpts_per_s(points));
    }
    Ok(TuneOutcome {
        spec,
        n,
        fingerprint: cfg.fingerprint(),
        strategy,
        space_size,
        pruned,
        measurements,
        best_idx,
        default_idx,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parses() {
        assert_eq!("guided".parse::<Strategy>().unwrap(), Strategy::CostGuided);
        assert_eq!("FULL".parse::<Strategy>().unwrap(), Strategy::Exhaustive);
        assert!("genetic".parse::<Strategy>().is_err());
    }

    #[test]
    fn guided_tune_respects_budget_and_never_loses_to_default() {
        let cfg = SimConfig::default();
        let out = tune(&cfg, StencilSpec::box2d(1), 16, 4, Strategy::CostGuided).unwrap();
        assert!(out.measurements.len() <= 4);
        assert!(out.best().cycles_per_point <= out.paper_default().cycles_per_point);
        assert!(out.speedup_vs_default() >= 1.0);
        assert!(out.measurements.iter().all(|m| m.max_err < 1e-9));
        assert_eq!(out.pruned, out.space_size - out.measurements.len());
        // winner and baseline carry advisory compiled-host wall-clock
        assert!(out.best().host_seconds.is_some());
        assert!(out.paper_default().host_mpts_per_s.unwrap() > 0.0);
    }

    #[test]
    fn exhaustive_tune_measures_the_whole_space() {
        let cfg = SimConfig::default();
        let out = tune(&cfg, StencilSpec::diag2d(1), 16, 1, Strategy::Exhaustive).unwrap();
        assert_eq!(out.measurements.len(), out.space_size);
        assert_eq!(out.pruned, 0);
        let ranking = out.ranking();
        assert_eq!(ranking[0], out.best_idx);
    }

    #[test]
    fn fused_candidates_are_measured_and_verified() {
        let cfg = SimConfig::default();
        let out = tune(&cfg, StencilSpec::box2d(1), 16, 1, Strategy::Exhaustive).unwrap();
        let fused: Vec<_> =
            out.measurements.iter().filter(|m| m.plan.steps > 1).collect();
        assert!(!fused.is_empty(), "the space explores the time-tile axis");
        for m in &fused {
            assert!(m.max_err < 1e-9, "{}: fused candidate verified", m.plan.label(2));
            assert!(m.cycles > 0);
            assert!(m.plan.label(2).contains("-t"), "{}", m.plan.label(2));
        }
        // per-step normalization keeps depths comparable: a fused run's
        // raw cycles cover T steps
        let default_cpp = out.paper_default().cycles_per_point;
        for m in &fused {
            assert!(
                m.cycles_per_point < default_cpp * 4.0,
                "{}: fused cyc/pt is per-step-normalized",
                m.plan.label(2)
            );
        }
    }

    #[test]
    fn rejects_bad_domain_sizes() {
        let cfg = SimConfig::default();
        assert!(tune(&cfg, StencilSpec::box2d(1), 12, 4, Strategy::CostGuided).is_err());
        assert!(tune(&cfg, StencilSpec::box2d(1), 0, 4, Strategy::CostGuided).is_err());
    }
}
