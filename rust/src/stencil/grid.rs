//! Dense row-major grids (the paper's arrays `A` and `B`).
//!
//! C-style storage (paper footnote 1): the rightmost index is the
//! unit-stride one — `j` for 2D grids, `k` for 3D grids.



/// A dense row-major `f64` grid of 2 or 3 dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseGrid {
    /// Extent per dimension (len 2 or 3).
    pub shape: Vec<usize>,
    /// Row-major data, `shape.iter().product()` elements.
    pub data: Vec<f64>,
}

impl DenseGrid {
    /// All-zero grid.
    pub fn zeros(shape: &[usize]) -> Self {
        assert!(shape.len() == 2 || shape.len() == 3, "grids are 2D or 3D");
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Grid filled by `f(index)` over row-major indices.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> f64) -> Self {
        let mut g = Self::zeros(shape);
        let mut idx = vec![0usize; shape.len()];
        for lin in 0..g.data.len() {
            g.unravel(lin, &mut idx);
            g.data[lin] = f(&idx);
        }
        g
    }

    /// Deterministic pseudo-random grid used across the repo for
    /// verification (replicated by the Python layer): a cheap LCG-ish hash
    /// of the linear index mapped into `[-1, 1)`.
    pub fn verification_input(shape: &[usize], seed: u64) -> Self {
        let mut g = Self::zeros(shape);
        for (lin, v) in g.data.iter_mut().enumerate() {
            let mut h = (lin as u64).wrapping_add(seed).wrapping_mul(0x9E3779B97F4A7C15);
            h ^= h >> 29;
            h = h.wrapping_mul(0xBF58476D1CE4E5B9);
            h ^= h >> 32;
            // 21 bits of mantissa are plenty and keep exact f64 values small.
            let u = (h >> 43) as f64 / (1u64 << 21) as f64; // [0, 1)
            *v = 2.0 * u - 1.0;
        }
        g
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the grid has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major linear index of `idx`.
    #[inline]
    pub fn lin(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut l = 0usize;
        for (d, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.shape[d]);
            l = l * self.shape[d] + i;
        }
        l
    }

    /// Convert a linear index back to a multi-index (into `out`).
    #[inline]
    pub fn unravel(&self, mut lin: usize, out: &mut [usize]) {
        for d in (0..self.shape.len()).rev() {
            out[d] = lin % self.shape[d];
            lin /= self.shape[d];
        }
    }

    /// Element access by multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> f64 {
        self.data[self.lin(idx)]
    }

    /// Mutable element access by multi-index.
    #[inline]
    pub fn at_mut(&mut self, idx: &mut [usize]) -> &mut f64 {
        let l = self.lin(idx);
        &mut self.data[l]
    }

    /// Maximum absolute difference against another grid on the *interior*
    /// (all indices at distance >= `halo` from every boundary). The halo is
    /// excluded because stencil methods only define interior outputs.
    pub fn max_abs_diff_interior(&self, other: &DenseGrid, halo: usize) -> f64 {
        assert_eq!(self.shape, other.shape);
        let mut idx = vec![0usize; self.shape.len()];
        let mut worst = 0.0f64;
        for lin in 0..self.data.len() {
            self.unravel(lin, &mut idx);
            let interior = idx
                .iter()
                .zip(&self.shape)
                .all(|(&i, &n)| i >= halo && i + halo < n);
            if interior {
                let d = (self.data[lin] - other.data[lin]).abs();
                if d > worst {
                    worst = d;
                }
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lin_unravel_roundtrip_2d() {
        let g = DenseGrid::zeros(&[5, 7]);
        let mut idx = [0usize; 2];
        for lin in 0..g.len() {
            g.unravel(lin, &mut idx);
            assert_eq!(g.lin(&idx), lin);
        }
    }

    #[test]
    fn lin_unravel_roundtrip_3d() {
        let g = DenseGrid::zeros(&[3, 4, 5]);
        let mut idx = [0usize; 3];
        for lin in 0..g.len() {
            g.unravel(lin, &mut idx);
            assert_eq!(g.lin(&idx), lin);
        }
    }

    #[test]
    fn rightmost_index_is_unit_stride() {
        let g = DenseGrid::zeros(&[4, 6]);
        assert_eq!(g.lin(&[2, 3]) + 1, g.lin(&[2, 4]));
        let g3 = DenseGrid::zeros(&[2, 3, 4]);
        assert_eq!(g3.lin(&[1, 2, 0]) + 1, g3.lin(&[1, 2, 1]));
    }

    #[test]
    fn verification_input_is_deterministic_and_bounded() {
        let a = DenseGrid::verification_input(&[16, 16], 7);
        let b = DenseGrid::verification_input(&[16, 16], 7);
        let c = DenseGrid::verification_input(&[16, 16], 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data.iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn interior_diff_ignores_halo() {
        let mut a = DenseGrid::zeros(&[6, 6]);
        let b = DenseGrid::zeros(&[6, 6]);
        a.data[0] = 100.0; // corner: outside any halo >= 1
        assert_eq!(a.max_abs_diff_interior(&b, 1), 0.0);
        let l = a.lin(&[3, 3]);
        a.data[l] = 2.5;
        assert_eq!(a.max_abs_diff_interior(&b, 1), 2.5);
    }
}
