//! Stencil fundamentals: specifications, coefficient algebra, grids, and the
//! scalar gather-mode reference implementation.
//!
//! Everything downstream (the scatter/outer-product algorithm, the code
//! generators, the Pallas artifacts) is validated against
//! [`reference::apply`], which is a direct transcription of the paper's
//! Equation (1) generalized over dimension, shape and order.

pub mod coeff;
pub mod grid;
pub mod reference;
pub mod spec;

pub use coeff::CoeffTensor;
pub use grid::DenseGrid;
pub use spec::{StencilKind, StencilSpec};
