//! Stencil specifications: dimension, shape and order.
//!
//! The paper classifies stencils by the dimension of the space grid (2D, 3D),
//! the shape (box, star, and "other" shapes such as the diagonal stencil of
//! Eq. (15)), and the order `r`. A `StencilSpec` pins all three down and is
//! the single identifier threaded through the scatter algebra, the code
//! generators, the simulator harness and the AOT artifact naming.


use std::fmt;

/// Shape of the stencil footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StencilKind {
    /// Full `(2r+1)^d` footprint (e.g. 2D9P, 3D27P for r = 1).
    Box,
    /// Axis-aligned cross with `2rd + 1` points (e.g. 2D5P, 3D7P for r = 1).
    Star,
    /// 2D-only: non-zeros on the main diagonal and anti-diagonal (Eq. (15)).
    Diagonal,
}

impl fmt::Display for StencilKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StencilKind::Box => write!(f, "box"),
            StencilKind::Star => write!(f, "star"),
            StencilKind::Diagonal => write!(f, "diag"),
        }
    }
}

/// A concrete stencil: `dims`-dimensional, `kind`-shaped, order `r`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StencilSpec {
    /// Spatial dimension of the grid: 2 or 3.
    pub dims: usize,
    /// Stencil order `r`: the footprint reaches `r` points from the centre.
    pub order: usize,
    /// Footprint shape.
    pub kind: StencilKind,
}

impl StencilSpec {
    /// Construct a spec, validating the (dims, kind, order) combination.
    pub fn new(dims: usize, order: usize, kind: StencilKind) -> anyhow::Result<Self> {
        anyhow::ensure!(dims == 2 || dims == 3, "only 2D and 3D stencils are supported");
        anyhow::ensure!(order >= 1, "stencil order must be >= 1");
        anyhow::ensure!(
            !(kind == StencilKind::Diagonal && dims != 2),
            "diagonal stencils are 2D-only (paper Eq. (15))"
        );
        Ok(Self { dims, order, kind })
    }

    /// 2D box stencil of order `r`.
    pub fn box2d(r: usize) -> Self {
        Self { dims: 2, order: r, kind: StencilKind::Box }
    }

    /// 2D star stencil of order `r`.
    pub fn star2d(r: usize) -> Self {
        Self { dims: 2, order: r, kind: StencilKind::Star }
    }

    /// 3D box stencil of order `r`.
    pub fn box3d(r: usize) -> Self {
        Self { dims: 3, order: r, kind: StencilKind::Box }
    }

    /// 3D star stencil of order `r`.
    pub fn star3d(r: usize) -> Self {
        Self { dims: 3, order: r, kind: StencilKind::Star }
    }

    /// 2D diagonal stencil of order `r` (Eq. (15) generalized to order r).
    pub fn diag2d(r: usize) -> Self {
        Self { dims: 2, order: r, kind: StencilKind::Diagonal }
    }

    /// Footprint side length `2r + 1`.
    pub fn side(&self) -> usize {
        2 * self.order + 1
    }

    /// Number of points in the *dense* `(2r+1)^d` footprint (incl. zeros).
    pub fn dense_points(&self) -> usize {
        self.side().pow(self.dims as u32)
    }

    /// Number of non-zero weights for this shape.
    ///
    /// Box: `(2r+1)^d`; star: `2rd + 1` (§3.4); diagonal: `4r + 1`
    /// (both diagonals of length `2r+1` sharing the centre).
    pub fn nonzero_points(&self) -> usize {
        match self.kind {
            StencilKind::Box => self.dense_points(),
            StencilKind::Star => 2 * self.order * self.dims + 1,
            StencilKind::Diagonal => 4 * self.order + 1,
        }
    }

    /// Whether the dense-footprint offset `off` (each component in
    /// `-r..=r`) carries a non-zero weight for this shape.
    pub fn mask(&self, off: &[isize]) -> bool {
        debug_assert_eq!(off.len(), self.dims);
        let r = self.order as isize;
        debug_assert!(off.iter().all(|&o| -r <= o && o <= r));
        match self.kind {
            StencilKind::Box => true,
            StencilKind::Star => off.iter().filter(|&&o| o != 0).count() <= 1,
            StencilKind::Diagonal => off[0] == off[1] || off[0] == -off[1],
        }
    }

    /// Conventional name, e.g. `2d9p-box-r1`, `3d7p-star-r1`.
    pub fn name(&self) -> String {
        format!("{}d{}p-{}-r{}", self.dims, self.nonzero_points(), self.kind, self.order)
    }

    /// Iterate over all dense footprint offsets (row-major, each component
    /// in `-r..=r`), including masked-out (zero) positions.
    pub fn dense_offsets(&self) -> Vec<Vec<isize>> {
        let r = self.order as isize;
        let mut out = Vec::with_capacity(self.dense_points());
        match self.dims {
            2 => {
                for i in -r..=r {
                    for j in -r..=r {
                        out.push(vec![i, j]);
                    }
                }
            }
            3 => {
                for i in -r..=r {
                    for j in -r..=r {
                        for k in -r..=r {
                            out.push(vec![i, j, k]);
                        }
                    }
                }
            }
            _ => unreachable!("spec validated at construction"),
        }
        out
    }

    /// FLOPs per interior output point: one multiply + one add per non-zero
    /// tap (§3.4 counts multiplies only; we report both conventions).
    pub fn flops_per_point(&self) -> usize {
        2 * self.nonzero_points()
    }
}

impl fmt::Display for StencilSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_counts_match_paper() {
        assert_eq!(StencilSpec::box2d(1).nonzero_points(), 9); // 2D9P
        assert_eq!(StencilSpec::star2d(1).nonzero_points(), 5); // 2D5P
        assert_eq!(StencilSpec::box3d(1).nonzero_points(), 27); // 3D27P
        assert_eq!(StencilSpec::star3d(1).nonzero_points(), 7); // 3D7P
        assert_eq!(StencilSpec::star2d(2).nonzero_points(), 9);
        assert_eq!(StencilSpec::star3d(2).nonzero_points(), 13);
        assert_eq!(StencilSpec::diag2d(1).nonzero_points(), 5);
    }

    #[test]
    fn names() {
        assert_eq!(StencilSpec::box2d(1).name(), "2d9p-box-r1");
        assert_eq!(StencilSpec::star3d(1).name(), "3d7p-star-r1");
    }

    #[test]
    fn star_mask_is_axis_cross() {
        let s = StencilSpec::star2d(1);
        assert!(s.mask(&[0, 0]));
        assert!(s.mask(&[1, 0]));
        assert!(s.mask(&[0, -1]));
        assert!(!s.mask(&[1, 1]));
    }

    #[test]
    fn diagonal_mask_matches_eq15() {
        let s = StencilSpec::diag2d(1);
        assert!(s.mask(&[-1, -1]) && s.mask(&[1, 1]));
        assert!(s.mask(&[-1, 1]) && s.mask(&[1, -1]));
        assert!(s.mask(&[0, 0]));
        assert!(!s.mask(&[0, 1]) && !s.mask(&[1, 0]));
    }

    #[test]
    fn mask_count_equals_nonzero_points() {
        for spec in [
            StencilSpec::box2d(2),
            StencilSpec::star2d(3),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
            StencilSpec::diag2d(2),
        ] {
            let n = spec.dense_offsets().iter().filter(|o| spec.mask(o)).count();
            assert_eq!(n, spec.nonzero_points(), "{spec}");
        }
    }

    #[test]
    fn invalid_specs_rejected() {
        assert!(StencilSpec::new(1, 1, StencilKind::Box).is_err());
        assert!(StencilSpec::new(4, 1, StencilKind::Star).is_err());
        assert!(StencilSpec::new(2, 0, StencilKind::Box).is_err());
        assert!(StencilSpec::new(3, 1, StencilKind::Diagonal).is_err());
        assert!(StencilSpec::new(3, 2, StencilKind::Star).is_ok());
    }
}
