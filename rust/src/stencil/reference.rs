//! Scalar gather-mode reference implementation (the paper's Eq. (1),
//! generalized over dimension / shape / order).
//!
//! This is the correctness oracle for every other execution path in the
//! repo: simulator programs from all five code generators, the scatter-mode
//! outer-product plans, and the PJRT-executed Pallas artifacts are all
//! compared element-wise against [`apply`].

use super::coeff::CoeffTensor;
use super::grid::DenseGrid;

/// Apply one stencil time-step in gather mode.
///
/// Interior points (at distance >= `r` from every boundary) of the output
/// are computed per Eq. (1); boundary points are copied from the input
/// (Dirichlet-style frozen boundary, the convention used by all code paths
/// in this repo and by the Python oracle).
pub fn apply(coeffs: &CoeffTensor, a: &DenseGrid) -> DenseGrid {
    let spec = coeffs.spec;
    assert_eq!(a.shape.len(), spec.dims, "grid/stencil dimension mismatch");
    let r = spec.order;
    assert!(
        a.shape.iter().all(|&n| n > 2 * r),
        "grid too small for order-{r} stencil"
    );
    let mut b = a.clone(); // boundary = copy of input
    let offsets = spec.dense_offsets();
    let mut idx = vec![0usize; spec.dims];
    let mut nb = vec![0usize; spec.dims];
    for lin in 0..a.len() {
        a.unravel(lin, &mut idx);
        let interior = idx.iter().zip(&a.shape).all(|(&i, &n)| i >= r && i + r < n);
        if !interior {
            continue;
        }
        let mut acc = 0.0f64;
        for (oi, off) in offsets.iter().enumerate() {
            let c = coeffs.data[oi];
            if c == 0.0 {
                continue;
            }
            for d in 0..spec.dims {
                nb[d] = (idx[d] as isize + off[d]) as usize;
            }
            acc += c * a.at(&nb);
        }
        b.data[lin] = acc;
    }
    b
}

/// Apply `steps` time-steps, ping-ponging two copies (§2.2).
pub fn evolve(coeffs: &CoeffTensor, a: &DenseGrid, steps: usize) -> DenseGrid {
    let mut cur = a.clone();
    for _ in 0..steps {
        cur = apply(coeffs, &cur);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec::StencilSpec;

    #[test]
    fn identity_stencil_is_identity() {
        // Only the centre weight set: B must equal A everywhere.
        let spec = StencilSpec::box2d(1);
        let c = CoeffTensor::from_fn(spec, |off| {
            if off.iter().all(|&o| o == 0) {
                1.0
            } else {
                0.0
            }
        });
        let a = DenseGrid::verification_input(&[12, 9], 1);
        assert_eq!(apply(&c, &a), a);
    }

    #[test]
    fn constant_field_is_fixed_point_of_normalized_weights() {
        // paper_default sums to 1, so a constant field is invariant.
        for spec in [StencilSpec::box2d(2), StencilSpec::star3d(1), StencilSpec::diag2d(1)] {
            let c = CoeffTensor::paper_default(spec);
            let shape: Vec<usize> = vec![10; spec.dims];
            let a = DenseGrid::from_fn(&shape, |_| 3.25);
            let b = apply(&c, &a);
            let d = b.data.iter().map(|v| (v - 3.25).abs()).fold(0.0, f64::max);
            assert!(d < 1e-12, "{spec}: {d}");
        }
    }

    #[test]
    fn boundary_is_copied() {
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let a = DenseGrid::verification_input(&[8, 8], 3);
        let b = apply(&c, &a);
        for j in 0..8 {
            assert_eq!(b.at(&[0, j]), a.at(&[0, j]));
            assert_eq!(b.at(&[7, j]), a.at(&[7, j]));
            assert_eq!(b.at(&[j, 0]), a.at(&[j, 0]));
            assert_eq!(b.at(&[j, 7]), a.at(&[j, 7]));
        }
    }

    #[test]
    fn hand_computed_2d5p_point() {
        // Star r=1: B[i][j] = cN*A[i-1][j] + cW*A[i][j-1] + cC*A[i][j]
        //                    + cE*A[i][j+1] + cS*A[i+1][j]
        let spec = StencilSpec::star2d(1);
        let c = CoeffTensor::paper_default(spec);
        let a = DenseGrid::verification_input(&[6, 6], 11);
        let b = apply(&c, &a);
        let (i, j) = (2, 3);
        let expect = c.at(&[-1, 0]) * a.at(&[i - 1, j])
            + c.at(&[0, -1]) * a.at(&[i, j - 1])
            + c.at(&[0, 0]) * a.at(&[i, j])
            + c.at(&[0, 1]) * a.at(&[i, j + 1])
            + c.at(&[1, 0]) * a.at(&[i + 1, j]);
        assert!((b.at(&[i, j]) - expect).abs() < 1e-15);
    }

    #[test]
    fn hand_computed_3d7p_point() {
        let spec = StencilSpec::star3d(1);
        let c = CoeffTensor::paper_default(spec);
        let a = DenseGrid::verification_input(&[5, 5, 5], 2);
        let b = apply(&c, &a);
        let p = [2usize, 2, 2];
        let mut expect = c.at(&[0, 0, 0]) * a.at(&p);
        for (off, sign) in [(0usize, -1isize), (0, 1), (1, -1), (1, 1), (2, -1), (2, 1)] {
            let mut q = p;
            q[off] = (q[off] as isize + sign) as usize;
            let mut o = [0isize; 3];
            o[off] = sign;
            expect += c.at(&o) * a.at(&q);
        }
        assert!((b.at(&p) - expect).abs() < 1e-15);
    }

    #[test]
    fn evolve_composes_apply() {
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let a = DenseGrid::verification_input(&[9, 9], 5);
        assert_eq!(evolve(&c, &a, 3), apply(&c, &apply(&c, &apply(&c, &a))));
    }

    #[test]
    fn scatter_equivalence() {
        // Computing B in scatter mode (each input scattered to neighbours
        // with C^s) must equal gather mode with C^g — the core identity
        // behind the paper's Eq. (3)-(5).
        let spec = StencilSpec::box2d(1);
        let cg = CoeffTensor::paper_default(spec);
        let cs = cg.scatter();
        let a = DenseGrid::verification_input(&[10, 10], 9);
        let gather = apply(&cg, &a);

        let mut scat = a.clone();
        // zero interior, then scatter every input element
        for i in 1..9usize {
            for j in 1..9usize {
                *scat.at_mut(&mut [i, j]) = 0.0;
            }
        }
        for i in 0..10usize {
            for j in 0..10usize {
                for oi in -1..=1isize {
                    for oj in -1..=1isize {
                        let (ti, tj) = (i as isize + oi, j as isize + oj);
                        // target must be interior
                        if (1..9).contains(&ti) && (1..9).contains(&tj) {
                            // scatter weight for displacement (oi,oj) is
                            // C^s at (oi,oj) == C^g at (-oi,-oj)
                            *scat.at_mut(&mut [ti as usize, tj as usize]) +=
                                cs.at(&[oi, oj]) * a.at(&[i, j]);
                        }
                    }
                }
            }
        }
        assert!(gather.max_abs_diff_interior(&scat, 1) < 1e-12);
    }
}
