//! Coefficient algebra: dense coefficient tensors, the gather ↔ scatter
//! conversion of Eq. (5), and per-line extraction used by the scatter
//! formulation.
//!
//! A `CoeffTensor` always stores the *dense* `(2r+1)^d` footprint in
//! **gather** orientation (`C^g`): element at per-dim index `idx` (each in
//! `0..2r`, centre at `r`) is the weight multiplying `A[p + idx - r]` when
//! computing `B[p]` (Eq. (1)). The scatter tensor `C^s = J C^g J` (Eq. (5))
//! is the index-reversed view.

use super::spec::StencilSpec;


/// Dense coefficient tensor in gather orientation.
#[derive(Debug, Clone, PartialEq)]
pub struct CoeffTensor {
    /// The stencil this tensor belongs to.
    pub spec: StencilSpec,
    /// Row-major dense `(2r+1)^d` weights (gather view, zeros where masked).
    pub data: Vec<f64>,
}

impl CoeffTensor {
    /// Side length `2r+1`.
    pub fn side(&self) -> usize {
        self.spec.side()
    }

    /// Build from a closure giving the weight for each *dense* offset
    /// (components in `-r..=r`); positions masked out by the shape are
    /// forced to zero.
    pub fn from_fn(spec: StencilSpec, mut f: impl FnMut(&[isize]) -> f64) -> Self {
        let data = spec
            .dense_offsets()
            .iter()
            .map(|off| if spec.mask(off) { f(off) } else { 0.0 })
            .collect();
        Self { spec, data }
    }

    /// The deterministic default weights used across the whole repo
    /// (Rust reference, simulator programs, and the Python/Pallas layer —
    /// `python/compile/kernels/ref.py` replicates this formula exactly).
    ///
    /// Weights are asymmetric (to catch gather/scatter reversal bugs) and
    /// normalized to sum 1 (so multi-step evolutions stay bounded).
    pub fn paper_default(spec: StencilSpec) -> Self {
        let mut lin = 0usize;
        let mut t = Self::from_fn(spec, |_| {
            let v = ((3 * lin + 5) % 11 + 1) as f64;
            lin += 1;
            v
        });
        // `from_fn` only advanced `lin` on unmasked points; recompute with
        // the dense linear index instead so the formula depends purely on
        // position (replicable layout-first in Python).
        let offsets = spec.dense_offsets();
        for (i, off) in offsets.iter().enumerate() {
            t.data[i] = if spec.mask(off) { ((3 * i + 5) % 11 + 1) as f64 } else { 0.0 };
        }
        let sum: f64 = t.data.iter().sum();
        for v in &mut t.data {
            *v /= sum;
        }
        t
    }

    /// Weight at dense offset `off` (components in `-r..=r`), gather view.
    pub fn at(&self, off: &[isize]) -> f64 {
        self.data[self.dense_index(off)]
    }

    /// Row-major linear index of a dense offset.
    pub fn dense_index(&self, off: &[isize]) -> usize {
        debug_assert_eq!(off.len(), self.spec.dims);
        let r = self.spec.order as isize;
        let s = self.side() as isize;
        let mut idx = 0isize;
        for &o in off {
            debug_assert!((-r..=r).contains(&o));
            idx = idx * s + (o + r);
        }
        idx as usize
    }

    /// The scatter-mode tensor `C^s = J C^g J` of Eq. (5): all indices
    /// reversed. `C^s[idx] = C^g[2r - idx]` per dimension.
    pub fn scatter(&self) -> CoeffTensor {
        let mut out = self.clone();
        for (i, off) in self.spec.dense_offsets().iter().enumerate() {
            let rev: Vec<isize> = off.iter().map(|&o| -o).collect();
            out.data[i] = self.at(&rev);
        }
        out
    }

    /// Extract the gather-view *coefficient line* running along dimension
    /// `line_dim`, at fixed offsets `fixed` in the remaining dimensions
    /// (in order of increasing dimension index, each in `-r..=r`).
    ///
    /// Returns the `2r+1` weights indexed by the line-dim offset `-r..=r`.
    pub fn line(&self, line_dim: usize, fixed: &[isize]) -> Vec<f64> {
        let r = self.spec.order as isize;
        assert!(line_dim < self.spec.dims);
        assert_eq!(fixed.len(), self.spec.dims - 1);
        (-r..=r)
            .map(|o| {
                let mut off = Vec::with_capacity(self.spec.dims);
                let mut fi = 0;
                for d in 0..self.spec.dims {
                    if d == line_dim {
                        off.push(o);
                    } else {
                        off.push(fixed[fi]);
                        fi += 1;
                    }
                }
                self.at(&off)
            })
            .collect()
    }

    /// Extract a diagonal line of the (2D) tensor. `anti == false` walks the
    /// main diagonal (offset `(o, o)`), `anti == true` the anti-diagonal
    /// (offset `(o, -o)`), for `o` in `-r..=r` (Eq. (16)).
    pub fn diag_line(&self, anti: bool) -> Vec<f64> {
        assert_eq!(self.spec.dims, 2, "diagonal lines are 2D-only");
        let r = self.spec.order as isize;
        (-r..=r)
            .map(|o| self.at(&[o, if anti { -o } else { o }]))
            .collect()
    }

    /// Sum of all weights (1.0 for `paper_default`).
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec::StencilKind;

    #[test]
    fn default_is_normalized_and_masked() {
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(2),
            StencilSpec::box3d(1),
            StencilSpec::star3d(1),
            StencilSpec::diag2d(1),
        ] {
            let c = CoeffTensor::paper_default(spec);
            assert!((c.sum() - 1.0).abs() < 1e-12, "{spec}");
            let nz = c.data.iter().filter(|v| **v != 0.0).count();
            assert_eq!(nz, spec.nonzero_points(), "{spec}");
        }
    }

    #[test]
    fn scatter_is_involution() {
        // C^s = J C^g J, and J is an involution, so scatter() twice is id.
        for spec in [StencilSpec::box2d(2), StencilSpec::box3d(1), StencilSpec::star3d(2)] {
            let c = CoeffTensor::paper_default(spec);
            assert_eq!(c.scatter().scatter(), c, "{spec}");
        }
    }

    #[test]
    fn scatter_matches_eq3_for_2d9p() {
        // Eq. (3)/(4): C^s is C^g with rows and columns reversed.
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let s = c.scatter();
        for i in -1..=1isize {
            for j in -1..=1isize {
                assert_eq!(s.at(&[i, j]), c.at(&[-i, -j]));
            }
        }
    }

    #[test]
    fn line_extraction_middle_column_2d() {
        // The middle (j = 0) gather line of the 2D9P tensor is
        // (C_{01}, C_{11}, C_{21}) in the paper's numbering.
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let l = c.line(0, &[0]);
        assert_eq!(l, vec![c.at(&[-1, 0]), c.at(&[0, 0]), c.at(&[1, 0])]);
    }

    #[test]
    fn line_extraction_3d() {
        let c = CoeffTensor::paper_default(StencilSpec::box3d(1));
        // Line along j (dim 1) at fixed (i, k) = (1, -1).
        let l = c.line(1, &[1, -1]);
        assert_eq!(l, vec![c.at(&[1, -1, -1]), c.at(&[1, 0, -1]), c.at(&[1, 1, -1])]);
    }

    #[test]
    fn diag_lines_match_eq15() {
        let c = CoeffTensor::paper_default(StencilSpec::diag2d(1));
        assert_eq!(c.diag_line(false), vec![c.at(&[-1, -1]), c.at(&[0, 0]), c.at(&[1, 1])]);
        assert_eq!(c.diag_line(true), vec![c.at(&[-1, 1]), c.at(&[0, 0]), c.at(&[1, -1])]);
    }

    #[test]
    fn star_lines_share_only_centre() {
        let c = CoeffTensor::paper_default(StencilSpec::new(2, 1, StencilKind::Star).unwrap());
        let col = c.line(0, &[0]);
        let row = c.line(1, &[0]);
        assert_eq!(col[1], row[1]); // both contain the centre weight
        assert_ne!(col, row); // but differ elsewhere (asymmetric defaults)
    }
}
