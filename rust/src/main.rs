//! `stencil-matrix` — CLI for the Stencil Matrixization reproduction.
//!
//! ```text
//! stencil-matrix analyze     --stencil 2d-box --order 2 [--n 8]
//! stencil-matrix cover       --stencil 2d-star --order 2 --option minimalaxis
//! stencil-matrix simulate    --stencil 2d-box --order 1 --size 64 \
//!                            --method outer [--option parallel] [--ui 1] \
//!                            [--uk 8] [--no-sched] [--cold]
//! stencil-matrix tune        --stencil 2d-star --order 2 --size 64 \
//!                            [--budget 12] [--strategy guided] \
//!                            [--db target/tune/tune_db.json] [--out target/tune]
//! stencil-matrix bench       fig3|fig4|fig5|table3|ablations|all
//! stencil-matrix bench-json  [--out BENCH_8.json] [--size2d 64] [--size3d 16]
//! stencil-matrix bench-compare [--baseline bench/baseline.json] \
//!                            [--current BENCH_8.json] [--self-test]
//! stencil-matrix engine-bench --stencil 2d-star --order 2 --size 512
//! stencil-matrix dump-ir     --stencil 2d-box --order 1 --size 16 \
//!                            --method outer [--limit 120]
//! stencil-matrix serve       --workers 4 --shards 8 --queue-depth 32 \
//!                            --size 256 --steps 8 --requests 32 \
//!                            [--engine compiled|interpret|simd] [--fuse-steps 4] \
//!                            [--trace-out trace.json] [--metrics-out serve.prom] \
//!                            [--listen-metrics 127.0.0.1:9184] [--linger-secs 0] \
//!                            [--cost-audit cost-audit.json] \
//!                            [--kernel tuned --tune-db target/tune/tune_db.json]
//! stencil-matrix serve       --artifact evolve_2d5p_n256_t4 --executions 25
//! stencil-matrix shard-bench --size 512 --steps 8 --max-workers 4
//! stencil-matrix serve-node  --listen 127.0.0.1:0 [--workers 0] [--max-secs 0]
//! stencil-matrix serve-cluster --nodes HOST:PORT,HOST:PORT --size 64 \
//!                            --steps 8 [--exchange peer|mediated]
//! stencil-matrix cluster-bench --max-nodes 2 [--out cluster_bench.json]
//! stencil-matrix list        [--artifacts-dir artifacts]
//! ```
//!
//! Every subcommand prints its usage on `--help`/`-h` (or via
//! `stencil-matrix help <subcommand>`).

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{
    kernel_for, kernel_for_fused, run_host_fused_threads, run_method, HostRun, Method,
    OuterParams,
};
use stencil_matrix::coordinator::{run_experiment, EvolutionService, Experiment};
use stencil_matrix::kir::Engine;
use stencil_matrix::obs;
use stencil_matrix::scatter::{analysis, build_cover, CoverOption};
use stencil_matrix::serve::{
    KernelMethod, PlanCache, ServeConfig, ShardRequest, ShardedEvolver, StencilServer, WorkerPool,
};
use stencil_matrix::stencil::{CoeffTensor, DenseGrid, StencilKind, StencilSpec};
use stencil_matrix::sim::SimConfig;
use stencil_matrix::tune::{self, TuneDb};
use stencil_matrix::util::json::{obj, Json};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed command-line arguments: positionals, `--key value` /
/// `--key=value` flags, and bare `--switch`es.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

/// Parse `argv` (without the subcommand). Both `--key value` and
/// `--key=value` are accepted; `=` values may be empty, contain further
/// `=`, or begin with any number of dashes. Space-separated values may
/// begin with a single `-` (e.g. `--offset -3`); a following `--token`
/// is never consumed as a value (use `--key=--token` for that).
fn parse_args(argv: &[String]) -> Args {
    let mut a = Args { positional: Vec::new(), flags: HashMap::new(), switches: Vec::new() };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if let Some(body) = arg.strip_prefix("--") {
            if let Some((key, value)) = body.split_once('=') {
                a.flags.insert(key.to_string(), value.to_string());
                i += 1;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                a.flags.insert(body.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                a.switches.push(body.to_string());
                i += 1;
            }
        } else {
            a.positional.push(arg.clone());
            i += 1;
        }
    }
    a
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn parse_spec(args: &Args) -> anyhow::Result<StencilSpec> {
    let st = args.get("stencil").unwrap_or("2d-box");
    let order = args.usize_or("order", 1)?;
    let (dims, kind) = match st {
        "2d-box" => (2, StencilKind::Box),
        "2d-star" => (2, StencilKind::Star),
        "2d-diag" => (2, StencilKind::Diagonal),
        "3d-box" => (3, StencilKind::Box),
        "3d-star" => (3, StencilKind::Star),
        other => anyhow::bail!("unknown --stencil '{other}' (2d-box|2d-star|2d-diag|3d-box|3d-star)"),
    };
    StencilSpec::new(dims, order, kind)
}

fn parse_option(s: &str) -> anyhow::Result<CoverOption> {
    s.parse()
}

/// Parse `--method`/`--option`/`--ui`/`--uk`/`--no-sched` into a
/// [`Method`] (shared by `simulate` and `dump-ir`).
fn parse_method(args: &Args, spec: StencilSpec) -> anyhow::Result<Method> {
    Ok(match args.get("method").unwrap_or("outer") {
        "outer" => {
            let mut p = OuterParams::paper_best(spec);
            if let Some(o) = args.get("option") {
                p.option = parse_option(o)?;
            }
            p.ui = args.usize_or("ui", p.ui)?;
            p.uk = args.usize_or("uk", p.uk)?;
            if args.has("no-sched") {
                p.scheduled = false;
            }
            Method::Outer(p)
        }
        "autovec" => Method::AutoVec,
        "dlt" => Method::Dlt,
        "tv" => Method::Tv,
        "scalar" => Method::Scalar,
        other => anyhow::bail!("unknown --method '{other}'"),
    })
}

fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    // `<cmd> --help` / `<cmd> -h` prints that subcommand's usage;
    // `help <cmd>` does the same.
    if argv[1..].iter().any(|a| a == "--help" || a == "-h") {
        match usage_for(cmd) {
            Some(u) => println!("{u}"),
            None => print_help(),
        }
        return Ok(());
    }
    if cmd == "help" {
        match argv.get(1).and_then(|topic| usage_for(topic)) {
            Some(u) => println!("{u}"),
            None => print_help(),
        }
        return Ok(());
    }
    let args = parse_args(&argv[1..]);
    let cfg = SimConfig::default();
    match cmd.as_str() {
        "--help" | "-h" => print_help(),
        "analyze" => {
            let spec = parse_spec(&args)?;
            let n = args.usize_or("n", cfg.vlen)?;
            println!("§3.4 analysis for {spec}, block extent n = {n}:");
            for option in CoverOption::applicable(spec) {
                let a = analysis::analyze(spec, option, n)?;
                println!(
                    "  {:12} lines→ vec FMA/outvec {:5.1} | outer/outvec {:6.2} | instr ratio {:5.2}x",
                    format!("{option:?}"),
                    a.vec_fma_per_outvec,
                    a.outer_per_outvec,
                    a.instr_ratio
                );
            }
            let (before, after) = analysis::box_per_line_reduction(spec.order, n);
            println!("  per-line reduction (box): {before} → {after} instructions/output vector");
        }
        "cover" => {
            let spec = parse_spec(&args)?;
            let option = parse_option(args.get("option").unwrap_or("parallel"))?;
            let coeffs = CoeffTensor::paper_default(spec);
            let cover = build_cover(&coeffs, option)?;
            println!("{spec} with {option:?}: {} line(s)", cover.len());
            for (i, line) in cover.lines.iter().enumerate() {
                println!(
                    "  line {i}: dir {:?} base {:?} weights {:?} ({} nonzero)",
                    line.dir,
                    line.base,
                    line.weights,
                    line.nonzeros()
                );
            }
            println!("outer products per n=8 block: {}", cover.outer_products(8));
        }
        "simulate" => {
            let spec = parse_spec(&args)?;
            let n = args.usize_or("size", 64)?;
            let method = parse_method(&args, spec)?;
            let warm = !args.has("cold");
            let res = run_method(&cfg, spec, n, method, warm)?;
            println!(
                "{spec} N={n} {method}: {} cycles, {:.3} cyc/pt, verified={} (max err {:.2e})",
                res.stats.cycles,
                res.cycles_per_point(),
                res.verified(),
                res.max_err
            );
            println!("{}", res.stats);
            println!("{}", stencil_matrix::sim::trace::roofline(&cfg, &res.stats));
            anyhow::ensure!(res.verified(), "simulation output did not match the oracle");
        }
        "disasm" => {
            use stencil_matrix::sim::isa::Program;
            let spec = parse_spec(&args)?;
            let n = args.usize_or("size", 16)?;
            let limit = args.usize_or("limit", 80)?;
            let mut p = OuterParams::paper_best(spec);
            if let Some(o) = args.get("option") {
                p.option = parse_option(o)?;
            }
            let kernel = kernel_for(&cfg, spec, n, Method::Outer(p))?;
            let mut prog = Program::default();
            stencil_matrix::kir::lower::lower(&kernel, &mut prog);
            println!(
                "# {spec} N={n} {} — {} instructions, {} fmopa",
                p.label(spec.dims),
                prog.0.len(),
                prog.fmopa_count()
            );
            print!("{}", stencil_matrix::sim::trace::disassemble(&prog, limit));
        }
        "dump-ir" => {
            let spec = parse_spec(&args)?;
            let n = args.usize_or("size", 16)?;
            let limit = args.usize_or("limit", 120)?;
            let fuse = args.usize_or("fuse-steps", 1)?.max(1);
            let method = parse_method(&args, spec)?;
            let kernel = kernel_for_fused(&cfg, spec, n, method, fuse)?;
            let stats = kernel.stats();
            println!(
                "# {spec} N={n} {method} — {} op(s), {} outer product(s), {} marker(s), {} fused step(s)",
                stats.total(),
                stats.outer_products,
                stats.markers,
                kernel.steps
            );
            print!("{}", stencil_matrix::kir::dump(&kernel, limit));
            // per-step op subtotals (fused programs only): step
            // boundaries are rendered as `==== step t/T ====` above,
            // distinctly from the unroll-group markers
            let per_step = stencil_matrix::kir::step_stats(&kernel);
            if !per_step.is_empty() {
                println!("# per-step op subtotals:");
                for (t, s) in per_step.iter().enumerate() {
                    println!(
                        "#   step {}/{}: {} op(s), {} outer product(s), {} load(s), {} store(s)",
                        t + 1,
                        per_step.len(),
                        s.total(),
                        s.outer_products,
                        s.loads + s.gathers + s.splats + s.row_loads,
                        s.stores + s.lane_stores + s.row_stores
                    );
                }
            }
            // `--engine simd`: append the SIMD lowering plan — per block,
            // how many FOps became vector microkernels vs scalar
            // fallback, and which ISA runtime dispatch selected
            if let Some(engine) = args.get("engine") {
                if engine.parse::<Engine>()? == Engine::Simd {
                    let plan = stencil_matrix::kir::ExecPlan::from_config(&cfg, &kernel.ops);
                    let splan = stencil_matrix::kir::SimdPlan::new(&plan);
                    print!("{}", splan.describe());
                }
            }
        }
        "bench" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all")
                .parse::<Experiment>()?;
            run_experiment(&cfg, which)?;
        }
        "bench-json" => {
            let out = PathBuf::from(args.get("out").unwrap_or("BENCH_8.json"));
            let n2d = args.usize_or("size2d", 64)?;
            let n3d = args.usize_or("size3d", 16)?;
            let snap = stencil_matrix::bench_harness::snapshot::run(&cfg, n2d, n3d)?;
            std::fs::write(&out, snap.to_string_compact())?;
            let rows = snap.get("results").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
            println!(
                "wrote {} ({} stencil row(s) at {n2d}²/{n3d}³, fingerprint {})",
                out.display(),
                rows,
                cfg.fingerprint()
            );
        }
        "bench-compare" => {
            bench_compare_cmd(&args)?;
        }
        "engine-bench" => {
            engine_bench_cmd(&cfg, &args)?;
        }
        "tune" => {
            tune_cmd(&cfg, &args)?;
        }
        "serve" => {
            // --backend picks explicitly; otherwise any artifact-flavoured
            // flag keeps the pre-existing PJRT path (including
            // `serve --executions N`, which used to serve the default
            // artifact)
            let backend = match args.get("backend") {
                Some(b) => b.to_string(),
                None => {
                    if args.get("artifact").is_some()
                        || args.get("artifacts-dir").is_some()
                        || args.get("executions").is_some()
                    {
                        "artifact".to_string()
                    } else {
                        "native".to_string()
                    }
                }
            };
            match backend.as_str() {
                "artifact" | "pjrt" => serve_artifact(&args)?,
                "native" => serve_native(&args)?,
                other => anyhow::bail!("unknown --backend '{other}' (native|artifact)"),
            }
        }
        "shard-bench" => {
            shard_bench(&args)?;
        }
        "serve-node" => {
            serve_node_cmd(&args)?;
        }
        "serve-cluster" => {
            serve_cluster_cmd(&args)?;
        }
        "cluster-bench" => {
            cluster_bench_cmd(&args)?;
        }
        "list" => {
            let dir = PathBuf::from(args.get("artifacts-dir").unwrap_or("artifacts"));
            let reg = stencil_matrix::runtime::Registry::load(&dir)?;
            for a in &reg.artifacts {
                println!(
                    "{:24} {} N={} steps={} ({})",
                    a.name,
                    a.spec,
                    a.n,
                    a.steps,
                    a.path.display()
                );
            }
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

/// `bench-compare`: the perf-regression gate — compare a fresh
/// `BENCH_8.json` against `bench/baseline.json` and fail on >2% sim-cycle
/// drift or >10% host wall-clock / serving-throughput drift
/// (`--self-test` proves the gate trips on injected regressions).
fn bench_compare_cmd(args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::bench_harness::compare;

    let tolerance = match args.get("tolerance-pct") {
        Some(s) => s.parse::<f64>()? / 100.0,
        None => compare::DEFAULT_TOLERANCE,
    };
    let current_path = PathBuf::from(args.get("current").unwrap_or("BENCH_8.json"));
    let current = Json::parse(&std::fs::read_to_string(&current_path)?)?;
    if args.has("self-test") {
        let cmp = compare::self_test(&current, tolerance)?;
        println!(
            "perf-gate self-test passed: injected cycle (>{:.1}%), host wall-clock and serving \
             Mpts/s (>{:.0}%) regressions all trip the gate ({} cycle cell(s))",
            tolerance * 100.0,
            compare::HOST_FAIL_TOLERANCE * 100.0,
            cmp.regressions.len()
        );
        return Ok(());
    }
    let baseline_path = PathBuf::from(args.get("baseline").unwrap_or("bench/baseline.json"));
    if args.has("write-baseline") {
        if let Some(dir) = baseline_path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&baseline_path, current.to_string_compact())?;
        println!("promoted {} to {}", current_path.display(), baseline_path.display());
        return Ok(());
    }
    let baseline = Json::parse(&std::fs::read_to_string(&baseline_path)?)?;
    let cmp = compare::compare(&baseline, &current, tolerance)?;
    let md = cmp.to_markdown();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &md)?;
    }
    print!("{md}");
    if cmp.pending {
        // bootstrap-only path: CI normally swaps in the latest green
        // main run's baseline-candidate artifact before gating, so a
        // pending placeholder here means no candidate existed yet
        println!(
            "note: {} is a pending placeholder (gate advisory); CI auto-fetches the latest \
             green baseline-candidate artifact, see CONTRIBUTING.md",
            baseline_path.display()
        );
    }
    anyhow::ensure!(
        cmp.passed(),
        "perf gate failed: {} cell(s) regressed more than {:.1}% in simulated cycles, {} host \
         wall-clock regression(s) beyond {:.0}%",
        cmp.regressions.len(),
        tolerance * 100.0,
        cmp.host_regressions.len(),
        compare::HOST_FAIL_TOLERANCE * 100.0
    );
    Ok(())
}

/// `engine-bench`: interpreter vs compiled vs explicit-SIMD wall-clock
/// on one stencil — the engine throughput table CI puts in the job
/// summary (simd rows carry the runtime-dispatched ISA). With
/// `--fuse-steps T > 1` the temporally blocked T-step program is
/// measured alongside the unfused one (per-step-normalized columns).
/// All runs are oracle-verified and checked bitwise-equal across
/// engines and thread counts.
fn engine_bench_cmd(cfg: &SimConfig, args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::util::bench::Table;

    let spec = parse_spec(args)?;
    let n = args.usize_or("size", 512)?;
    let method = parse_method(args, spec)?;
    let threads = args.usize_or("threads", 0)?;
    let reps = args.usize_or("reps", 3)?.max(1);
    let fuse = args.usize_or("fuse-steps", 1)?.max(1);
    let min_speedup = match args.get("min-speedup") {
        Some(s) => Some(s.parse::<f64>()?),
        None => None,
    };
    let min_simd_speedup = match args.get("min-simd-speedup") {
        Some(s) => Some(s.parse::<f64>()?),
        None => None,
    };

    let best_of = |engine: Engine, fuse_steps: usize, t: usize| -> anyhow::Result<HostRun> {
        let mut best: Option<HostRun> = None;
        for _ in 0..reps {
            let run = run_host_fused_threads(cfg, spec, n, method, engine, fuse_steps, t)?;
            anyhow::ensure!(run.verified(), "{spec} {method} {engine}: max_err {}", run.max_err);
            if best.as_ref().map(|b| run.seconds < b.seconds).unwrap_or(true) {
                best = Some(run);
            }
        }
        Ok(best.expect("reps >= 1"))
    };
    let interp = best_of(Engine::Interpret, 1, 1)?;
    let compiled_1t = best_of(Engine::Compiled, 1, 1)?;
    let compiled = best_of(Engine::Compiled, 1, threads)?;
    let simd_1t = best_of(Engine::Simd, 1, 1)?;
    let simd = best_of(Engine::Simd, 1, threads)?;
    for (name, run) in [
        ("compiled-1t", &compiled_1t),
        ("compiled", &compiled),
        ("simd-1t", &simd_1t),
        ("simd", &simd),
    ] {
        anyhow::ensure!(
            run.grid.data == interp.grid.data,
            "{name} output diverged bitwise from the interpreter"
        );
    }
    let fused = if fuse > 1 {
        let fi = best_of(Engine::Interpret, fuse, 1)?;
        let fc = best_of(Engine::Compiled, fuse, threads)?;
        let fs = best_of(Engine::Simd, fuse, threads)?;
        anyhow::ensure!(
            fc.grid.data == fi.grid.data,
            "fused compiled output diverged bitwise from the fused interpreter"
        );
        anyhow::ensure!(
            fs.grid.data == fi.grid.data,
            "fused simd output diverged bitwise from the fused interpreter"
        );
        Some((fi, fc, fs))
    } else {
        None
    };

    let points = n.pow(spec.dims as u32);
    // per-step-normalized columns keep fused and unfused rows comparable
    let mpts = |r: &HostRun| r.mpts_per_s(points);
    let per_step = |r: &HostRun| r.seconds / r.steps as f64;
    println!(
        "# engine-bench — {spec} N={n} {method} (best of {reps}, {} host op(s))\n",
        interp.ops
    );
    let isa = stencil_matrix::kir::simd::active_isa();
    let mut rows: Vec<(&str, &str, &HostRun)> = vec![
        ("interpret", "—", &interp),
        ("compiled", "—", &compiled_1t),
        ("compiled", "—", &compiled),
        ("simd", isa.as_str(), &simd_1t),
        ("simd", isa.as_str(), &simd),
    ];
    if let Some((fi, fc, fs)) = &fused {
        rows.push(("interpret-fused", "—", fi));
        rows.push(("compiled-fused", "—", fc));
        rows.push(("simd-fused", isa.as_str(), fs));
    }
    let mut table =
        Table::new(&["engine", "ISA", "T", "threads", "s/step", "Mpts/s", "vs interpret"]);
    for &(name, row_isa, run) in &rows {
        table.row(vec![
            name.to_string(),
            row_isa.to_string(),
            run.steps.to_string(),
            run.threads.to_string(),
            format!("{:.4}", per_step(run)),
            format!("{:.1}", mpts(run)),
            format!("{:.2}x", per_step(&interp) / per_step(run).max(1e-12)),
        ]);
    }
    let md = table.to_markdown();
    print!("{md}");
    let speedup = interp.seconds / compiled.seconds.max(1e-12);
    let simd_speedup = compiled_1t.seconds / simd_1t.seconds.max(1e-12);
    let mut summary = format!(
        "\ncompiled engine: {speedup:.2}x the interpreter at {} thread(s) \
         (bitwise-identical output)\n",
        compiled.threads
    );
    summary.push_str(&format!(
        "simd engine ({isa}): {simd_speedup:.2}x the compiled engine single-thread \
         (bitwise-identical output)\n"
    ));
    if let Some((_, fc, fs)) = &fused {
        summary.push_str(&format!(
            "temporal blocking: fused T={} compiled runs at {:.2}x the unfused compiled \
             per-step throughput (bitwise-identical across engines)\n",
            fc.steps,
            per_step(&compiled) / per_step(fc).max(1e-12)
        ));
        summary.push_str(&format!(
            "fused simd T={}: {:.2}x the fused compiled per-step throughput\n",
            fs.steps,
            per_step(fc) / per_step(fs).max(1e-12)
        ));
    }
    print!("{summary}");

    // one extra traced run per configuration, after all timing, so span
    // recording can never perturb the measured numbers above
    let profile_of = |engine: Engine, fuse_steps: usize, t: usize| {
        let (run, spans) = obs::span::trace(|| {
            run_host_fused_threads(cfg, spec, n, method, engine, fuse_steps, t)
        });
        run.map(|_| (obs::profile::aggregate(&spans), spans))
    };
    let (interp_prof, _) = profile_of(Engine::Interpret, 1, 1)?;
    let (compiled_prof, compiled_spans) = profile_of(Engine::Compiled, 1, threads)?;
    let mut prof_rows = vec![
        ("interpret".to_string(), interp_prof),
        (format!("compiled x{}", compiled.threads), compiled_prof),
    ];
    let (simd_prof, _) = profile_of(Engine::Simd, 1, threads)?;
    prof_rows.push((format!("simd[{isa}] x{}", simd.threads), simd_prof));
    let mut trace_spans = compiled_spans;
    if let Some((_, fc, _)) = &fused {
        let (fused_prof, fused_spans) = profile_of(Engine::Compiled, fuse, threads)?;
        prof_rows.push((format!("compiled-fused T={} x{}", fuse, fc.threads), fused_prof));
        trace_spans = fused_spans;
    }
    let prof_md = format!(
        "\n## per-phase breakdown (one traced run per row)\n\n{}",
        obs::profile::to_markdown(&prof_rows)
    );
    print!("{prof_md}");
    if let Some(path) = args.get("trace-out") {
        let doc = obs::chrome::to_chrome_json(&trace_spans);
        obs::chrome::validate(&doc)?;
        std::fs::write(path, doc.to_string_compact())?;
        println!("trace → {path}");
    }

    if let Some(out) = args.get("out") {
        let mut text = format!(
            "# engine-bench — {spec} N={n} {method} (best of {reps})\n\n{md}{summary}{prof_md}"
        );
        text.push_str(&format!(
            "\ninterpreter: {:.4}s · compiled: {:.4}s · simd[{isa}]: {:.4}s · host ops: {}\n",
            interp.seconds, compiled.seconds, simd.seconds, interp.ops
        ));
        std::fs::write(out, text)?;
    }
    if let Some(min) = min_speedup {
        anyhow::ensure!(
            speedup >= min,
            "compiled engine speedup {speedup:.2}x is below the required {min:.2}x"
        );
    }
    if let Some(min) = min_simd_speedup {
        anyhow::ensure!(
            simd_speedup >= min,
            "simd engine speedup {simd_speedup:.2}x over the single-thread compiled engine is \
             below the required {min:.2}x"
        );
    }
    Ok(())
}

/// `tune`: search the optimization space for one stencil, verify and rank
/// candidates on the simulator, report, and update the tuning database.
fn tune_cmd(cfg: &SimConfig, args: &Args) -> anyhow::Result<()> {
    let spec = parse_spec(args)?;
    let default_n = if spec.dims == 2 { 64 } else { 16 };
    let n = args.usize_or("size", default_n)?;
    let budget = args.usize_or("budget", 12)?;
    let strategy: tune::Strategy = args.get("strategy").unwrap_or("guided").parse()?;
    let engine: Engine = args.get("engine").unwrap_or("compiled").parse()?;
    let db_path = PathBuf::from(args.get("db").unwrap_or("target/tune/tune_db.json"));
    let out_dir = PathBuf::from(args.get("out").unwrap_or("target/tune"));

    let outcome = tune::tune_with_engine(cfg, spec, n, budget, strategy, engine)?;
    let md = tune::report::to_markdown(&outcome);
    print!("{md}");
    std::fs::create_dir_all(&out_dir)?;
    let stem = format!("tune-{}-n{n}", spec.name());
    std::fs::write(out_dir.join(format!("{stem}.md")), &md)?;
    std::fs::write(
        out_dir.join(format!("{stem}.json")),
        tune::report::to_json(&outcome).to_string_compact(),
    )?;

    let mut db = TuneDb::load_or_new(&db_path)?;
    db.record(&outcome);
    db.save(&db_path)?;
    println!(
        "recorded {} → {} ({} entr{}); reports in {}",
        outcome.best().plan.label(spec.dims),
        db_path.display(),
        db.len(),
        if db.len() == 1 { "y" } else { "ies" },
        out_dir.display()
    );
    Ok(())
}

/// `serve` with `--artifact`: the PJRT compiled-artifact path.
fn serve_artifact(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.get("artifacts-dir").unwrap_or("artifacts"));
    let mut svc = EvolutionService::new(&dir)?;
    println!("platform: {}", svc.platform());
    let artifact = args.get("artifact").unwrap_or("evolve_2d5p_n64_t8").to_string();
    let executions = args.usize_or("executions", 10)?;
    let req = stencil_matrix::coordinator::service::EvolveRequest {
        artifact,
        executions,
        verify: !args.has("no-verify"),
    };
    let (_, report) = svc.serve(&req)?;
    println!(
        "{}: {} executions / {} steps in {:.3}s → {:.2} Mpoints/s (max err {:?})",
        req.artifact,
        report.executions,
        report.steps,
        report.seconds,
        report.points_per_sec / 1e6,
        report.max_err
    );
    if let Some(err) = report.max_err {
        anyhow::ensure!(err < 1e-9, "PJRT output did not match the oracle");
    }
    Ok(())
}

/// `serve --backend native` (the default without artifact flags): the
/// native sharded multi-threaded server.
///
/// Simulates a client fleet: `--clients` threads submit `--requests`
/// requests total (seeds cycling over `--distinct` values, so identical
/// requests that are still queued coalesce), then prints the metrics
/// snapshot as JSON.
fn serve_native(args: &Args) -> anyhow::Result<()> {
    let spec = parse_spec(args)?;
    let n = args.usize_or("size", 64)?;
    let steps = args.usize_or("steps", 8)?;
    let workers = args.usize_or("workers", default_workers())?;
    let shards = args.usize_or("shards", 0)?; // 0 = one per worker
    let queue_depth = args.usize_or("queue-depth", 32)?.max(1);
    let requests = args.usize_or("requests", 16)?;
    let clients = args.usize_or("clients", 4)?.max(1);
    let distinct = args.usize_or("distinct", 4)?.max(1);
    let method: KernelMethod = args.get("kernel").unwrap_or("outer").parse()?;
    let engine: Engine = args.get("engine").unwrap_or("compiled").parse()?;
    let fuse_steps = args.usize_or("fuse-steps", 1)?.max(1);
    let verify = !args.has("no-verify");
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let listen_metrics = args.get("listen-metrics").map(str::to_string);
    let cost_audit_out = args.get("cost-audit").map(PathBuf::from);
    let linger_secs = args.usize_or("linger-secs", 0)?;

    let serve_cfg =
        ServeConfig { workers, shards, queue_depth, plan_cache: 32, engine, fuse_steps };
    let server = match args.get("tune-db") {
        Some(path) => {
            let db = TuneDb::load(&PathBuf::from(path))?;
            println!("tuning DB: {path} ({} entr{})", db.len(), if db.len() == 1 { "y" } else { "ies" });
            Arc::new(StencilServer::with_tune_db(
                serve_cfg,
                Arc::new(db),
                SimConfig::default().fingerprint(),
            ))
        }
        None => Arc::new(StencilServer::new(serve_cfg)),
    };
    server.start();
    println!(
        "serving {requests} request(s) from {clients} client(s): {spec} N={n} steps={steps} \
         kernel={method} engine={engine} workers={workers} shards={} queue-depth={queue_depth} \
         fuse-steps={fuse_steps}",
        server.effective_shards()
    );

    // live observability listener: /metrics (global registry + the JSON
    // snapshot rendered as Prometheus text), /healthz, /profile
    let live = match &listen_metrics {
        Some(addr) => {
            let snap_server = Arc::clone(&server);
            let health_server = Arc::clone(&server);
            let sources = obs::live::LiveSources {
                metrics_text: Arc::new(move || {
                    obs::prom::render(&snap_server.metrics_json(), "stencil_serve")
                }),
                health_json: Arc::new(move || health_server.health_json()),
                profile_json: Arc::new(obs::profile::latest_json),
            };
            let live = obs::live::serve(addr, sources)?;
            println!("live metrics on http://{}", live.addr());
            Some(live)
        }
        None => None,
    };

    // flush an atomic metrics snapshot every FLUSH_EVERY completions, so
    // a crash or early exit still leaves a fresh exposition file behind
    const FLUSH_EVERY: usize = 64;
    let flushed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let run_fleet = || -> anyhow::Result<usize> {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = Arc::clone(&server);
            let flushed = Arc::clone(&flushed);
            let flush_path = metrics_out.clone();
            handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
                let mut served = 0usize;
                let mut i = c;
                while i < requests {
                    let req = ShardRequest {
                        spec,
                        n,
                        steps,
                        seed: (i % distinct) as u64,
                        method,
                        verify,
                    };
                    let resp = server.submit(req)?.wait()?;
                    if verify {
                        // the server enforces the kernel's bar (bitwise for
                        // oracle/taps, 1e-9 for the KIR host kernels); here we
                        // only insist verification actually ran and passed it
                        anyhow::ensure!(
                            matches!(resp.report.max_err, Some(e) if e < 1e-9),
                            "request {i} failed verification (max_err {:?})",
                            resp.report.max_err
                        );
                    }
                    served += 1;
                    let done = flushed.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                    if done % FLUSH_EVERY == 0 {
                        if let Some(path) = &flush_path {
                            let text = obs::prom::render(&server.metrics_json(), "stencil_serve");
                            let _ = stencil_matrix::util::fsx::write_atomic(path, &text);
                        }
                    }
                    i += clients;
                }
                Ok(served)
            }));
        }
        let mut served = 0usize;
        for h in handles {
            served += h
                .join()
                .map_err(|_| anyhow::anyhow!("client thread panicked"))??;
        }
        // shutting down inside the (possibly traced) region joins the
        // dispatcher thread, so every span guard has dropped before the
        // trace session drains and the exported document stays balanced
        server.shutdown();
        Ok(served)
    };
    let (fleet, spans) = if trace_out.is_some() {
        obs::span::trace(run_fleet)
    } else {
        (run_fleet(), Vec::new())
    };
    // flush once unconditionally before propagating a fleet error, so an
    // early exit still leaves the latest snapshot on disk
    let metrics = server.metrics_json();
    if let Some(path) = &metrics_out {
        let text = obs::prom::render(&metrics, "stencil_serve");
        stencil_matrix::util::fsx::write_atomic(path, &text)?;
        println!("metrics exposition → {}", path.display());
    }
    if let Some(path) = &cost_audit_out {
        let audit = obs::audit::global();
        stencil_matrix::util::fsx::write_atomic(path, &audit.to_json().to_string_compact())?;
        let s = audit.summary();
        println!(
            "cost-model audit: {} key(s), {} observation(s), mean rel err {:.1}% → {}",
            s.keys,
            s.observations,
            s.mean_rel_error * 100.0,
            path.display()
        );
    }
    let served = fleet?;
    println!("{}", metrics.to_string_compact());
    if let Some(path) = &trace_out {
        let doc = obs::chrome::to_chrome_json(&spans);
        let counts = obs::chrome::validate(&doc)?;
        std::fs::write(path, doc.to_string_compact())?;
        let pairs: usize = counts.values().sum();
        println!(
            "trace: {pairs} span(s) across {} name(s) on {} thread track(s) → {}",
            counts.len(),
            spans.len(),
            path.display()
        );
        let prof = obs::profile::aggregate(&spans);
        obs::profile::publish(&prof);
        print!("{}", obs::profile::to_markdown(&[(format!("serve {method}"), prof)]));
    }
    if verify {
        println!("served {served}/{requests} request(s), all verified against the scalar oracle");
    } else {
        println!("served {served}/{requests} request(s) (verification disabled)");
    }
    if let Some(mut live) = live {
        if linger_secs > 0 {
            println!("lingering {linger_secs}s for live scrapes on http://{}", live.addr());
            std::thread::sleep(std::time::Duration::from_secs(linger_secs as u64));
        }
        live.shutdown();
    }
    Ok(())
}

/// `shard-bench`: wall-clock scaling of sharded evolution over worker
/// counts (1, 2, 4, …, `--max-workers`) on one large grid.
fn shard_bench(args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::util::bench::{fmt_secs, time_it, Table};

    let spec = parse_spec(args)?;
    let n = args.usize_or("size", 512)?;
    let steps = args.usize_or("steps", 8)?;
    let max_workers = args.usize_or("max-workers", default_workers().max(4))?.max(1);
    let method: KernelMethod = args.get("kernel").unwrap_or("taps").parse()?;
    let engine: Engine = args.get("engine").unwrap_or("compiled").parse()?;
    let fuse = args.usize_or("fuse-steps", 1)?.max(1);

    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
    let point_steps = (n.pow(spec.dims as u32) * steps) as f64;
    println!(
        "shard-bench: {spec} N={n} steps={steps} kernel={method} engine={engine} \
         fuse-steps={fuse} (host parallelism: {})",
        default_workers()
    );

    let mut workers_list = Vec::new();
    let mut w = 1usize;
    while w < max_workers {
        workers_list.push(w);
        w *= 2;
    }
    workers_list.push(max_workers);
    workers_list.dedup();

    let mut table = Table::new(&["workers", "shards", "best", "Mpts/s", "speedup"]);
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut prof_rows: Vec<(String, obs::PhaseProfile)> = Vec::new();
    let mut base_secs = None;
    for &w in &workers_list {
        let mut cache = PlanCache::new(32);
        cache.set_engine(engine);
        let ev =
            ShardedEvolver::with_parts(Arc::new(WorkerPool::new(w)), Arc::new(cache));
        let shards = 2 * w; // oversubscribe so stealing levels uneven slabs
        // warm the plan cache with the full run (compiles every chunk
        // depth the fused step loop will use)
        ev.evolve_fused(spec, &grid, steps, shards, method, fuse)?;
        let (best, _) = time_it(3, || {
            ev.evolve_fused(spec, &grid, steps, shards, method, fuse).unwrap();
        });
        let base = *base_secs.get_or_insert(best);
        let speedup = base / best;
        speedups.push(speedup);
        table.row(vec![
            w.to_string(),
            shards.to_string(),
            fmt_secs(best),
            format!("{:.1}", point_steps / best / 1e6),
            format!("{speedup:.2}x"),
        ]);
        // one traced run after timing: spans feed the per-phase table
        // without touching the measured wall-clocks above
        let (traced, spans) =
            obs::span::trace(|| ev.evolve_fused(spec, &grid, steps, shards, method, fuse));
        traced?;
        prof_rows.push((format!("w={w} s={shards}"), obs::profile::aggregate(&spans)));
        rows.push(obj(vec![
            ("workers", Json::Num(w as f64)),
            ("shards", Json::Num(shards as f64)),
            ("seconds", Json::Num(best)),
            ("mpts_per_s", Json::Num(point_steps / best / 1e6)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    print!("{}", table.to_markdown());
    println!("\n## per-phase breakdown (one traced run per row)\n");
    print!("{}", obs::profile::to_markdown(&prof_rows));
    println!("{}", Json::Arr(rows).to_string_compact());

    let peak = speedups.iter().copied().fold(1.0f64, f64::max);
    let top_workers = *workers_list.last().unwrap();
    println!("peak speedup: {peak:.2}x at up to {top_workers} worker(s)");
    if peak < top_workers as f64 * 0.5 && default_workers() < 2 * top_workers {
        println!(
            "note: host exposes {} hardware thread(s); scaling is capped by physical parallelism",
            default_workers()
        );
    }
    Ok(())
}

/// `serve-node`: run one cluster worker node until shutdown (a
/// `Shutdown` frame, `--max-secs`, or process kill).
fn serve_node_cmd(args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::serve::cluster::node;
    use stencil_matrix::serve::NodeConfig;

    let listen = args.get("listen").unwrap_or("127.0.0.1:0").to_string();
    let cfg = NodeConfig {
        workers: args.usize_or("workers", 0)?,
        shards: args.usize_or("shards", 0)?,
        engine: args.get("engine").unwrap_or("compiled").parse()?,
        fail_after: match args.get("fail-after") {
            Some(s) => Some(s.parse()?),
            None => None,
        },
    };
    let max_secs = args.usize_or("max-secs", 0)?;
    let mut handle = node::serve(&listen, cfg)?;
    // exact line the CI cluster smoke greps for the bound ephemeral port
    println!("cluster node listening on {}", handle.addr());
    if max_secs == 0 {
        handle.join();
    } else {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(max_secs as u64);
        while handle.is_running() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        handle.shutdown();
    }
    println!("cluster node on {} stopped", handle.addr());
    Ok(())
}

/// `serve-cluster`: drive one fleet evolution on the requested exchange
/// path (peer-to-peer bands by default, coordinator-mediated otherwise),
/// then run the single-process twin with identical parameters and assert
/// the results are bitwise identical (plus the scalar oracle for bitwise
/// kernels).
fn serve_cluster_cmd(args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::serve::cluster::node;
    use stencil_matrix::serve::{Coordinator, ExchangeMode, NodeConfig};

    let spec = parse_spec(args)?;
    let n = args.usize_or("size", 64)?;
    let steps = args.usize_or("steps", 8)?;
    let shards = args.usize_or("shards", 4)?.max(1);
    let method: KernelMethod = args.get("kernel").unwrap_or("taps").parse()?;
    let engine: Engine = args.get("engine").unwrap_or("compiled").parse()?;
    let fuse = args.usize_or("fuse-steps", 4)?.max(1);
    let seed = args.usize_or("seed", 0xC0FFEE)? as u64;
    let mode: ExchangeMode = args.get("exchange").unwrap_or("peer").parse()?;

    // the fleet: remote addresses via --nodes, or --local-nodes
    // in-process nodes on loopback ephemeral ports
    let mut local: Vec<stencil_matrix::serve::NodeHandle> = Vec::new();
    let addrs: Vec<String> = match args.get("nodes") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => {
            let count = args.usize_or("local-nodes", 2)?.max(1);
            for _ in 0..count {
                local.push(node::spawn_local(NodeConfig { engine, ..NodeConfig::default() })?);
            }
            local.iter().map(|h| h.addr().to_string()).collect()
        }
    };
    let mut cluster = Coordinator::connect(&addrs, engine)?;
    println!(
        "cluster: {}/{} node(s) up [{}]",
        cluster.nodes_alive(),
        addrs.len(),
        addrs.join(", ")
    );
    println!("health: {}", cluster.health_json().to_string_compact());

    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, seed);
    let (fleet, report) = cluster.evolve_exchange(mode, spec, &grid, steps, shards, method, fuse)?;

    // the single-process twin, identical parameters — the tentpole's
    // non-negotiable: the fleet result must be bitwise equal
    let mut cache = PlanCache::new(32);
    cache.set_engine(engine);
    let ev =
        ShardedEvolver::with_parts(Arc::new(WorkerPool::new(default_workers())), Arc::new(cache));
    let (twin, _, _) = ev.evolve_fused(spec, &grid, steps, shards, method, fuse)?;
    anyhow::ensure!(
        fleet.data == twin.data,
        "cluster evolution diverged bitwise from the single-process evolver"
    );
    match method {
        KernelMethod::Oracle | KernelMethod::Taps => {
            let coeffs = CoeffTensor::paper_default(spec);
            let want = stencil_matrix::stencil::reference::evolve(&coeffs, &grid, steps);
            anyhow::ensure!(
                fleet.data == want.data,
                "cluster evolution diverged bitwise from the scalar oracle"
            );
        }
        KernelMethod::Outer | KernelMethod::Tuned => {
            let coeffs = CoeffTensor::paper_default(spec);
            let want = stencil_matrix::stencil::reference::evolve(&coeffs, &grid, steps);
            let max_err = fleet
                .data
                .iter()
                .zip(&want.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            anyhow::ensure!(max_err < 1e-9, "cluster evolution off the oracle by {max_err:.2e}");
        }
    }
    // exact line the CI cluster smoke greps to assert bitwise equality
    println!(
        "cluster evolution bitwise-identical to single-process evolver \
         ({spec} N={n} steps={steps} kernel={method} engine={engine})"
    );
    println!(
        "nodes={} alive={} shards={} T={} chunks={} replacements={} halo-exchanges={} \
         sent={}B recv={}B",
        report.nodes,
        report.nodes_alive,
        report.shards,
        report.fuse.fuse_steps,
        report.chunks,
        report.replacements,
        report.fuse.halo_exchanges,
        report.bytes_sent,
        report.bytes_recv
    );
    // exact line the CI cluster smoke parses for the exchange path and
    // fallback status
    println!(
        "exchange: path={} fell-back={} band-bytes={}B exchange-seconds={:.6} \
         hidden-seconds={:.6} overlap-ratio={:.3}",
        report.path,
        if report.fell_back { "yes" } else { "no" },
        report.band_bytes,
        report.exchange_seconds(),
        report.exchange_hidden_us as f64 / 1e6,
        report.overlap_ratio()
    );
    // the coordinator-side exchange metric families, Prometheus text —
    // CI asserts the path=\"peer\" family is nonzero after a peer run
    for line in stencil_matrix::obs::registry::global().render().lines() {
        if (line.starts_with("stencil_cluster_exchange_seconds_count")
            || line.starts_with("stencil_cluster_exchange_bytes_total")
            || line.starts_with("stencil_cluster_overlap_ratio")
            || line.starts_with("stencil_cluster_peer_fallbacks_total"))
            && !line.starts_with("# ")
        {
            println!("{line}");
        }
    }
    // only tear the fleet down when this process owns it
    if !local.is_empty() {
        cluster.shutdown_nodes();
        for h in &mut local {
            h.shutdown();
        }
    }
    Ok(())
}

/// `cluster-bench`: multi-node scaling of fleet evolution over in-process
/// loopback nodes (real sockets, real frames), each node count measured
/// on both exchange paths (coordinator-mediated and peer-to-peer), each
/// row verified bitwise against the single-process evolver; markdown
/// table + JSON artifact with per-path exchange seconds, bytes moved,
/// and the compute/communication overlap ratio.
fn cluster_bench_cmd(args: &Args) -> anyhow::Result<()> {
    use stencil_matrix::serve::cluster::node;
    use stencil_matrix::serve::{Coordinator, ExchangeMode, NodeConfig};
    use stencil_matrix::util::bench::{fmt_secs, time_it, Table};

    let spec = parse_spec(args)?;
    let n = args.usize_or("size", 128)?;
    let steps = args.usize_or("steps", 8)?;
    let max_nodes = args.usize_or("max-nodes", 2)?.max(1);
    let reps = args.usize_or("reps", 3)?.max(1);
    let method: KernelMethod = args.get("kernel").unwrap_or("taps").parse()?;
    let engine: Engine = args.get("engine").unwrap_or("compiled").parse()?;
    let fuse = args.usize_or("fuse-steps", 4)?.max(1);
    let out = args.get("out").unwrap_or("cluster_bench.json").to_string();

    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 7);
    let point_steps = (n.pow(spec.dims as u32) * steps) as f64;
    println!(
        "cluster-bench: {spec} N={n} steps={steps} kernel={method} engine={engine} \
         fuse-steps={fuse} (best of {reps})"
    );

    let mut cache = PlanCache::new(32);
    cache.set_engine(engine);
    let ev =
        ShardedEvolver::with_parts(Arc::new(WorkerPool::new(default_workers())), Arc::new(cache));

    let mut table = Table::new(&[
        "nodes",
        "path",
        "shards",
        "T",
        "best",
        "Mpts/s",
        "coord bytes",
        "band bytes",
        "exch",
        "overlap",
        "vs base",
    ]);
    let mut rows = Vec::new();
    let mut base_secs = None;
    for nodes in 1..=max_nodes {
        let mut handles = Vec::new();
        for _ in 0..nodes {
            handles.push(node::spawn_local(NodeConfig { engine, ..NodeConfig::default() })?);
        }
        let mut cluster = Coordinator::connect_local(&handles, engine)?;
        let shards = match args.usize_or("shards", 0)? {
            0 => 2 * nodes, // two slabs per node so re-placement has room
            s => s,
        };
        // mediated first: its 1-node row is the speedup baseline
        for mode in [ExchangeMode::Mediated, ExchangeMode::Peer] {
            // verify the row bitwise against the single-process twin,
            // warm every node's plan cache along the way
            let (fleet, report) =
                cluster.evolve_exchange(mode, spec, &grid, steps, shards, method, fuse)?;
            let (twin, _, _) = ev.evolve_fused(spec, &grid, steps, shards, method, fuse)?;
            anyhow::ensure!(
                fleet.data == twin.data,
                "{nodes}-node {mode} cluster evolution diverged bitwise from the \
                 single-process evolver"
            );
            anyhow::ensure!(
                !report.fell_back,
                "{nodes}-node peer exchange fell back to mediated on a healthy fleet"
            );
            let (best, _) = time_it(reps, || {
                cluster.evolve_exchange(mode, spec, &grid, steps, shards, method, fuse).unwrap();
            });
            let base = *base_secs.get_or_insert(best);
            let coord_bytes = report.bytes_sent + report.bytes_recv;
            table.row(vec![
                nodes.to_string(),
                mode.to_string(),
                shards.to_string(),
                report.fuse.fuse_steps.to_string(),
                fmt_secs(best),
                format!("{:.1}", point_steps / best / 1e6),
                format!("{coord_bytes}B"),
                format!("{}B", report.band_bytes),
                fmt_secs(report.exchange_seconds()),
                format!("{:.2}", report.overlap_ratio()),
                format!("{:.2}x", base / best),
            ]);
            rows.push(obj(vec![
                ("nodes", Json::Num(nodes as f64)),
                ("path", Json::Str(mode.to_string())),
                ("shards", Json::Num(shards as f64)),
                ("fuse_steps", Json::Num(report.fuse.fuse_steps as f64)),
                ("halo_exchanges", Json::Num(report.fuse.halo_exchanges as f64)),
                ("chunks", Json::Num(report.chunks as f64)),
                ("replacements", Json::Num(report.replacements as f64)),
                ("bytes_sent", Json::Num(report.bytes_sent as f64)),
                ("bytes_recv", Json::Num(report.bytes_recv as f64)),
                ("coordinator_bytes", Json::Num(coord_bytes as f64)),
                ("band_bytes", Json::Num(report.band_bytes as f64)),
                ("exchange_seconds", Json::Num(report.exchange_seconds())),
                ("overlap_ratio", Json::Num(report.overlap_ratio())),
                ("seconds", Json::Num(best)),
                ("mpts_per_s", Json::Num(point_steps / best / 1e6)),
                ("speedup", Json::Num(base / best)),
                ("bitwise_vs_single_process", Json::Bool(true)),
            ]));
        }
        cluster.shutdown_nodes();
        for h in &mut handles {
            h.shutdown();
        }
    }
    print!("{}", table.to_markdown());
    println!(
        "\nnote: loopback nodes share this host's cores, so scaling here measures protocol + \
         placement overhead, not extra hardware"
    );
    let doc = obj(vec![
        ("spec", Json::Str(spec.to_string())),
        ("n", Json::Num(n as f64)),
        ("steps", Json::Num(steps as f64)),
        ("kernel", Json::Str(method.to_string())),
        ("engine", Json::Str(engine.to_string())),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(&out, doc.to_string_compact())?;
    println!("wrote {out}");
    Ok(())
}

/// `(subcommand, usage text)` — one entry per subcommand, used by both
/// the general help and `<subcommand> --help`.
const USAGES: &[(&str, &str)] = &[
    (
        "analyze",
        "stencil-matrix analyze — §3.4 instruction-count analysis per cover option

USAGE:
  stencil-matrix analyze [--stencil 2d-box] [--order 1] [--n 8]

  --stencil   2d-box|2d-star|2d-diag|3d-box|3d-star (default 2d-box)
  --order     stencil order r, 1..4 (default 1)
  --n         output-block extent for the counts (default: vector length)",
    ),
    (
        "cover",
        "stencil-matrix cover — print a coefficient-line cover (§4.1/§3.5)

USAGE:
  stencil-matrix cover [--stencil 2d-star] [--order 2] [--option parallel]

  --option    parallel|orthogonal|hybrid|minimalaxis|diagonals
              (must be applicable to the stencil shape)",
    ),
    (
        "simulate",
        "stencil-matrix simulate — run one verified kernel on the SME-like simulator

USAGE:
  stencil-matrix simulate [--stencil 2d-box] [--order 1] [--size 64]
                          [--method outer] [--option parallel]
                          [--ui 1] [--uk 8] [--no-sched] [--cold]

  --method    outer|autovec|dlt|tv|scalar (default outer)
  --size      domain extent N (multiple of the vector length)
  --ui/--uk   unroll factors for the outer method (§4.2)
  --no-sched  disable outer-product scheduling (§4.3)
  --cold      measure with cold caches (default: warm)",
    ),
    (
        "disasm",
        "stencil-matrix disasm — disassemble the outer method's generated program

USAGE:
  stencil-matrix disasm [--stencil 2d-box] [--order 1] [--size 16]
                        [--option parallel] [--limit 80]",
    ),
    (
        "dump-ir",
        "stencil-matrix dump-ir — print a method's kernel-IR program

The backend-agnostic kernel IR all five generators emit, rendered with
its loop/unroll structure markers (tile groups, passes) and an op-count
summary. The same program lowers 1:1 to the simulator ISA and executes
natively on the host.

USAGE:
  stencil-matrix dump-ir [--stencil 2d-box] [--order 1] [--size 16]
                         [--method outer|autovec|dlt|tv|scalar]
                         [--option parallel] [--ui 1] [--uk 8]
                         [--no-sched] [--limit 120] [--fuse-steps 1]
                         [--engine simd]

  --fuse-steps T  dump the temporally blocked T-step program: fused
                  steps are delimited by '==== step t/T ====' barrier
                  markers (distinct from the unroll-group markers) and
                  per-step op subtotals are appended
  --engine simd   append the SIMD lowering plan: per block, how many
                  resolved ops became vector microkernels (outer-product
                  runs, vector FMA/ALU loops) vs scalar fallback, plus
                  the ISA runtime dispatch selected on this machine",
    ),
    (
        "tune",
        "stencil-matrix tune — sim-in-the-loop autotuning for one stencil

Searches cover option × unroll × scheduling × layout × method, prunes with
the analytic cost model, verifies + ranks survivors on the simulator, and
records the winner in the tuning database (keyed by stencil, size, and
machine fingerprint). The tuned plan is never worse than the paper default.

USAGE:
  stencil-matrix tune [--stencil 2d-box] [--order 1] [--size 64]
                      [--budget 12] [--strategy guided|exhaustive]
                      [--engine compiled|interpret|simd]
                      [--db target/tune/tune_db.json] [--out target/tune]

  --budget    simulator runs the guided strategy may spend (default 12)
  --engine    host engine for the advisory wall-clock columns in the
              report (default compiled; the simulated ranking itself is
              engine-independent)
  --db        tuning-database path (created/updated; versioned JSON)
  --out       report directory (markdown + JSON per run)",
    ),
    (
        "bench",
        "stencil-matrix bench — regenerate the paper's figures and tables

USAGE:
  stencil-matrix bench [fig3|fig4|fig5|table3|ablations|all]

Reports land in target/bench-reports/ as markdown + JSON (default: all).",
    ),
    (
        "bench-json",
        "stencil-matrix bench-json — machine-readable perf snapshot (BENCH_8.json)

Per-method simulated cycles, speedups, and KIR-host wall-clock on all
three engines (interpreter + compiled + simd, with the engine speedups
and simd bitwise-checked against the interpreter) for scalar,
autovec, dlt, tv and outer on every Table-3 stencil row at one size per
dimensionality, plus a fused-vs-unfused sharded-serving measurement per
row (temporal blocking at T=4, bitwise-checked). Each fused-serve row
also carries a traced per-phase profile (embed/compute/freeze/exchange/
extract seconds) so bench-compare can say which phase moved. Sim cycles
and op counts are deterministic — they are what bench-compare gates
against bench/baseline.json; wall-clock (including the fused columns
and the profiles) is advisory.

USAGE:
  stencil-matrix bench-json [--out BENCH_8.json] [--size2d 64] [--size3d 16]",
    ),
    (
        "bench-compare",
        "stencil-matrix bench-compare — the CI perf-regression gate

Compares a fresh BENCH_8.json against the checked-in baseline and exits
non-zero when any method's simulated cycles regressed beyond the
tolerance (default 2%), or any host wall-clock / serving-throughput
cell regressed beyond the hard band (advisory band below it).
CI fetches the latest green main run's baseline-candidate artifact and
gates against it; the checked-in baseline marked \"pending\": true is
only the bootstrap fallback and makes the gate advisory (see
CONTRIBUTING.md).

USAGE:
  stencil-matrix bench-compare [--baseline bench/baseline.json]
                               [--current BENCH_8.json] [--tolerance-pct 2]
                               [--out bench_compare.md]
                               [--write-baseline] [--self-test]

  --write-baseline  promote --current to the baseline path and exit
  --self-test       verify the gate trips on an injected >2% regression",
    ),
    (
        "engine-bench",
        "stencil-matrix engine-bench — interpret vs compiled vs simd throughput

Runs one method on the KIR host backend with the op-by-op interpreter,
the compiling engine, and the explicit-SIMD engine (1 thread and
--threads each), verifies every run against the oracle, checks the
outputs are bitwise identical across engines, and reports wall-clock +
Mpoints/s + speedup with an ISA column showing what runtime dispatch
selected for the simd rows (what CI appends to the job summary). After
timing, one traced run per configuration feeds a per-phase breakdown
table (embed/compute/freeze/exchange/extract), so spans never perturb
the measured numbers.

USAGE:
  stencil-matrix engine-bench [--stencil 2d-star] [--order 2] [--size 512]
                              [--method outer] [--threads 0] [--reps 3]
                              [--fuse-steps 1] [--out engine_bench.md]
                              [--trace-out trace.json] [--min-speedup X]
                              [--min-simd-speedup X]

  --threads      worker threads for the threaded rows (0 = one per core)
  --fuse-steps   also measure the temporally blocked T-step program on
                 every engine (fused-vs-unfused rows, per-step columns)
  --trace-out    write the traced run as Chrome trace-event JSON
                 (validated structurally before the write)
  --min-speedup  fail unless compiled/interpret speedup reaches X
  --min-simd-speedup
                 fail unless the single-thread simd/compiled speedup
                 reaches X",
    ),
    (
        "serve",
        "stencil-matrix serve — the sharded multi-threaded stencil server

USAGE:
  stencil-matrix serve [--backend native] [--workers N] [--shards M]
                       [--queue-depth D] [--size 256] [--steps 8]
                       [--requests 32] [--clients 4] [--distinct 4]
                       [--kernel taps|oracle|outer|tuned]
                       [--engine compiled|interpret|simd] [--fuse-steps 1]
                       [--trace-out trace.json] [--metrics-out serve.prom]
                       [--listen-metrics 127.0.0.1:9184] [--linger-secs 0]
                       [--cost-audit cost-audit.json]
                       [--no-verify] [--tune-db target/tune/tune_db.json]
  stencil-matrix serve --artifact evolve_2d5p_n256_t4 --executions 25

--kernel outer (the default) runs the paper's outer-product algorithm
compiled through the kernel IR natively on the host (verified within
1e-9; oracle/taps stay bitwise). --engine picks the host execution
engine for those kernels: 'compiled' (default; fused loop nests,
threaded row groups), 'interpret' (the op-by-op reference twin, bitwise
identical) or 'simd' (explicit vector microkernels behind runtime ISA
dispatch — AVX2, NEON or scalar fallback — still bitwise identical).
With --tune-db, the kernel LRU consults the tuning
database before compiling shard kernels; --kernel tuned requests
compile the matched plan to a real host kernel and report its label.
--fuse-steps T enables temporal blocking: up to T time steps fused per
kernel application behind order*T-deep ghosts, halo exchanges only
every T steps (capped so deep halos never starve the shard count;
results are bitwise independent of T, and the metrics JSON reports
halo_exchanges / fused_steps). --trace-out records the whole run as
spans (enqueue → dispatch → shard kernels → halo exchanges → fused
sections) and writes validated Chrome trace-event JSON plus a per-phase
breakdown; traced outputs stay bitwise identical to untraced runs.
--metrics-out writes the metrics snapshot as Prometheus text
exposition (refreshed atomically every 64 completions and on exit, even
early exits). --listen-metrics ADDR starts a live HTTP listener (port 0
= ephemeral; the bound address is printed as 'live metrics on
http://…') serving GET /metrics (Prometheus text: cumulative registry
counters/gauges/histograms plus the snapshot), /healthz (queue depth,
worker liveness, last-request age, shard-imbalance verdict) and
/profile (per-phase breakdown of the most recent traced window);
--linger-secs keeps it up after the fleet finishes so external scrapers
can read the final state. --cost-audit PATH dumps the cost-model
accuracy audit (predicted vs measured per (spec, size, plan) key) as
JSON.
The artifact form serves AOT PJRT artifacts (requires the pjrt feature).",
    ),
    (
        "shard-bench",
        "stencil-matrix shard-bench — worker-scaling benchmark of sharded evolution

USAGE:
  stencil-matrix shard-bench [--stencil 2d-box] [--order 1] [--size 512]
                             [--steps 8] [--max-workers 4]
                             [--kernel taps|oracle|outer]
                             [--engine compiled|interpret|simd]
                             [--fuse-steps 1]

Each worker-count row is timed untraced, then traced once more for the
per-phase breakdown table (embed/compute/freeze/exchange/extract).",
    ),
    (
        "serve-node",
        "stencil-matrix serve-node — run one distributed-serving worker node

Binds a TCP listener speaking the framed cluster protocol (STCF frames,
version 2) and evolves slab tiles with the in-process sharded evolver.
Nodes serve both exchange paths: coordinator-mediated chunk RPCs and
peer-to-peer halo band exchange (HaloPush/HaloAck between nodes).
The bound address is printed as 'cluster node listening on <addr>'
(port 0 picks an ephemeral port). The node runs until a coordinator
sends Shutdown, --max-secs elapses, or the process is killed.

USAGE:
  stencil-matrix serve-node [--listen 127.0.0.1:0] [--workers 0]
                            [--shards 0] [--engine compiled|interpret|simd]
                            [--max-secs 0] [--fail-after N]

  --listen      address to bind (default 127.0.0.1:0 = ephemeral port)
  --workers     worker threads in the node's pool (0 = one per core)
  --shards      local shards per tile (0 = one per worker; results are
                bitwise independent of this)
  --max-secs    stop after this many seconds (0 = run until shutdown)
  --fail-after  fault injection: after N chunks the node goes silent,
                simulating a node lost mid-evolution (tests/CI only)",
    ),
    (
        "serve-cluster",
        "stencil-matrix serve-cluster — fused fleet evolution + bitwise check

Connects to worker nodes (remote --nodes, or --local-nodes in-process
nodes on loopback), places grid slabs across them, and drives a fused
T-step evolution on one of two data paths (--exchange):

  peer      (default) the coordinator distributes one exchange plan up
            front, then drops out of the per-round loop: each round,
            nodes compute their slab interiors while pushing order*T-deep
            boundary bands directly to neighbour nodes (HaloPush), then
            finish the boundary rows once bands arrive — the exchange
            hides behind compute. Any peer failure or plan rejection
            falls back automatically to the mediated path.
  mediated  tiles round-trip through the coordinator each fused round,
            which runs the deep-halo exchange itself. A node lost
            mid-evolution is detected by reply deadline and its slabs
            are re-placed on the survivors.

After the fleet run, the single-process sharded evolver runs the same
evolution with identical parameters and the outputs are asserted
bitwise identical ('cluster evolution bitwise-identical to
single-process evolver' on success); oracle/taps kernels are also
asserted bitwise against the scalar oracle, outer/tuned within 1e-9.

USAGE:
  stencil-matrix serve-cluster [--nodes HOST:PORT,HOST:PORT | --local-nodes 2]
                               [--stencil 2d-box] [--order 1] [--size 64]
                               [--steps 8] [--shards 4]
                               [--kernel taps|oracle|outer|tuned]
                               [--engine compiled|interpret|simd]
                               [--fuse-steps 4] [--seed 12648430]
                               [--exchange peer|mediated]

  --nodes        comma-separated worker addresses (from serve-node logs)
  --local-nodes  spawn N in-process loopback nodes instead (default 2)
  --fuse-steps   T, halo depth order*T; capped so shards keep interior
  --exchange     data path: peer (default, overlapped node-to-node bands)
                 or mediated (coordinator round-trips every tile)

The 'exchange:' stats line reports the path taken, whether the run fell
back to mediated, band bytes moved node-to-node, exchange seconds, and
the compute/communication overlap ratio (hidden / total exchange time).",
    ),
    (
        "cluster-bench",
        "stencil-matrix cluster-bench — multi-node scaling of fleet evolution

Spawns 1..=--max-nodes in-process loopback worker nodes (real sockets,
real frames) and measures every node count on BOTH exchange paths —
mediated (coordinator round-trips tiles) and peer (direct node-to-node
bands overlapped with compute) — verifying each row bitwise against the
single-process evolver before timing it. Reports a markdown table and a
JSON artifact (per-row seconds, Mpts/s, speedup, chunks, replacements,
halo exchanges, coordinator wire bytes, peer band bytes,
exchange_seconds, overlap_ratio). Loopback nodes share one host's
cores, so the numbers measure protocol + placement overhead, not extra
hardware; peer rows should still move strictly fewer coordinator bytes
and hide most exchange time behind compute (overlap_ratio).

USAGE:
  stencil-matrix cluster-bench [--stencil 2d-box] [--order 1] [--size 128]
                               [--steps 8] [--max-nodes 2] [--shards 0]
                               [--kernel taps|oracle|outer|tuned]
                               [--engine compiled|interpret|simd]
                               [--fuse-steps 4] [--reps 3]
                               [--out cluster_bench.json]

  --max-nodes  benchmark every fleet size from 1 to this (default 2)
  --shards     slabs per evolution (0 = two per node)",
    ),
    (
        "list",
        "stencil-matrix list — list AOT-compiled PJRT artifacts

USAGE:
  stencil-matrix list [--artifacts-dir artifacts]",
    ),
];

/// Usage text for one subcommand.
fn usage_for(cmd: &str) -> Option<&'static str> {
    USAGES.iter().find(|(name, _)| *name == cmd).map(|(_, text)| *text)
}

fn print_help() {
    println!(
        "stencil-matrix — Stencil Matrixization (CS.DC 2023) reproduction

USAGE:
  stencil-matrix analyze     --stencil 2d-box --order 2 [--n 8]
  stencil-matrix cover       --stencil 2d-star --order 2 --option orthogonal
  stencil-matrix simulate    --stencil 2d-box --order 1 --size 64 --method outer
                             [--option parallel] [--ui 1] [--uk 8] [--no-sched] [--cold]
  stencil-matrix disasm      --stencil 2d-box --order 1 --size 16 [--limit 80]
  stencil-matrix tune        --stencil 2d-star --order 2 --size 64 [--budget 12]
                             [--strategy guided] [--db target/tune/tune_db.json]
  stencil-matrix bench       fig3|fig4|fig5|table3|ablations|all
  stencil-matrix bench-json  [--out BENCH_8.json] [--size2d 64] [--size3d 16]
  stencil-matrix bench-compare [--baseline bench/baseline.json]
                             [--current BENCH_8.json] [--tolerance-pct 2]
                             [--write-baseline] [--self-test]
  stencil-matrix engine-bench [--stencil 2d-star] [--order 2] [--size 512]
                             [--threads 0] [--fuse-steps 1] [--trace-out t.json]
                             [--min-speedup X]
  stencil-matrix dump-ir     --stencil 2d-box --order 1 --size 16 --method outer
  stencil-matrix serve       [--backend native] [--workers N] [--shards M]
                             [--queue-depth D] [--size 256] [--steps 8]
                             [--requests 32] [--clients 4] [--distinct 4]
                             [--kernel taps|oracle|outer|tuned]
                             [--engine compiled|interpret|simd] [--fuse-steps 1]
                             [--trace-out trace.json] [--metrics-out serve.prom]
                             [--listen-metrics 127.0.0.1:9184] [--linger-secs 0]
                             [--cost-audit cost-audit.json]
                             [--no-verify] [--tune-db target/tune/tune_db.json]
  stencil-matrix serve       --artifact evolve_2d5p_n256_t4 --executions 25
  stencil-matrix shard-bench [--size 512] [--steps 8] [--max-workers 4]
                             [--kernel taps|oracle|outer]
                             [--engine compiled|interpret|simd] [--fuse-steps 1]
  stencil-matrix serve-node  [--listen 127.0.0.1:0] [--workers 0] [--max-secs 0]
  stencil-matrix serve-cluster [--nodes HOST:PORT,... | --local-nodes 2]
                             [--size 64] [--steps 8] [--shards 4] [--fuse-steps 4]
                             [--exchange peer|mediated]
  stencil-matrix cluster-bench [--max-nodes 2] [--size 128] [--steps 8]
                             [--out cluster_bench.json]
  stencil-matrix list        [--artifacts-dir artifacts]

Run 'stencil-matrix help <subcommand>' (or '<subcommand> --help') for
details. Flags accept both '--key value' and '--key=value'; '=' values may
begin with '-'. Methods: outer (the paper's), autovec, dlt, tv, scalar.
Stencils: 2d-box 2d-star 2d-diag 3d-box 3d-star; --order 1..4."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn space_separated_flags() {
        let a = parse_args(&argv(&["--size", "64", "--stencil", "2d-box"]));
        assert_eq!(a.get("size"), Some("64"));
        assert_eq!(a.get("stencil"), Some("2d-box"));
        assert!(a.positional.is_empty() && a.switches.is_empty());
    }

    #[test]
    fn equals_syntax() {
        let a = parse_args(&argv(&["--size=128", "--label=a=b", "--empty="]));
        assert_eq!(a.get("size"), Some("128"));
        assert_eq!(a.get("label"), Some("a=b")); // only first '=' splits
        assert_eq!(a.get("empty"), Some(""));
    }

    #[test]
    fn values_beginning_with_dash() {
        let a = parse_args(&argv(&["--offset", "-7", "--delta=-3", "--raw=--switch"]));
        assert_eq!(a.get("offset"), Some("-7"));
        assert_eq!(a.get("delta"), Some("-3"));
        assert_eq!(a.get("raw"), Some("--switch")); // '=' can smuggle '--'
    }

    #[test]
    fn switches_and_positionals() {
        let a = parse_args(&argv(&["run", "--cold", "--size", "64", "extra"]));
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert!(a.has("cold"));
        assert_eq!(a.usize_or("size", 0).unwrap(), 64);
        assert!(!a.has("size"));
    }

    #[test]
    fn trailing_flag_is_a_switch() {
        let a = parse_args(&argv(&["--no-verify"]));
        assert!(a.has("no-verify"));
        let b = parse_args(&argv(&["--cold", "--size", "32"]));
        assert!(b.has("cold"));
        assert_eq!(b.get("size"), Some("32"));
    }

    #[test]
    fn usize_or_defaults_and_parses() {
        let a = parse_args(&argv(&["--size=24"]));
        assert_eq!(a.usize_or("size", 64).unwrap(), 24);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        let bad = parse_args(&argv(&["--size=nope"]));
        assert!(bad.usize_or("size", 64).is_err());
    }

    /// Every dispatched subcommand must appear in [`USAGES`] so that
    /// `<cmd> --help` and `help <cmd>` print real usage rather than the
    /// generic banner.
    #[test]
    fn every_subcommand_has_usage_text() {
        let subcommands = [
            "analyze",
            "cover",
            "simulate",
            "disasm",
            "dump-ir",
            "tune",
            "bench",
            "bench-json",
            "bench-compare",
            "engine-bench",
            "serve",
            "shard-bench",
            "serve-node",
            "serve-cluster",
            "cluster-bench",
            "list",
        ];
        for cmd in subcommands {
            let text = usage_for(cmd).unwrap_or_else(|| panic!("no usage for '{cmd}'"));
            assert!(text.contains(cmd), "usage for '{cmd}' does not mention it");
            assert!(text.contains("USAGE:"), "usage for '{cmd}' has no USAGE section");
        }
        assert!(usage_for("no-such-command").is_none());
        assert_eq!(USAGES.len(), subcommands.len());
    }

    #[test]
    fn usage_texts_mention_key_flags() {
        assert!(usage_for("tune").unwrap().contains("--budget"));
        assert!(usage_for("tune").unwrap().contains("--strategy"));
        assert!(usage_for("tune").unwrap().contains("--db"));
        assert!(usage_for("serve").unwrap().contains("--tune-db"));
        assert!(usage_for("serve").unwrap().contains("tuned"));
        assert!(usage_for("serve").unwrap().contains("outer"));
        assert!(usage_for("serve").unwrap().contains("--engine"));
        assert!(usage_for("serve").unwrap().contains("--fuse-steps"));
        assert!(usage_for("dump-ir").unwrap().contains("--method"));
        assert!(usage_for("dump-ir").unwrap().contains("--limit"));
        assert!(usage_for("dump-ir").unwrap().contains("--fuse-steps"));
        assert!(usage_for("engine-bench").unwrap().contains("--fuse-steps"));
        assert!(usage_for("shard-bench").unwrap().contains("--fuse-steps"));
        assert!(usage_for("bench-json").unwrap().contains("fused"));
        // the snapshot moved to BENCH_8.json with the simd columns
        assert!(usage_for("bench-json").unwrap().contains("BENCH_8.json"));
        assert!(!usage_for("bench-json").unwrap().contains("BENCH_5.json"));
        assert!(!usage_for("bench-json").unwrap().contains("BENCH_6.json"));
        // the simd engine is selectable everywhere compiled|interpret is
        assert!(usage_for("serve").unwrap().contains("simd"));
        assert!(usage_for("shard-bench").unwrap().contains("simd"));
        assert!(usage_for("engine-bench").unwrap().contains("--min-simd-speedup"));
        assert!(usage_for("dump-ir").unwrap().contains("--engine simd"));
        assert!(usage_for("tune").unwrap().contains("--engine"));
        assert!(usage_for("serve").unwrap().contains("--trace-out"));
        assert!(usage_for("serve").unwrap().contains("--metrics-out"));
        assert!(usage_for("serve").unwrap().contains("--listen-metrics"));
        assert!(usage_for("serve").unwrap().contains("--cost-audit"));
        assert!(usage_for("serve").unwrap().contains("--linger-secs"));
        assert!(usage_for("serve").unwrap().contains("/healthz"));
        assert!(usage_for("engine-bench").unwrap().contains("--trace-out"));
        assert!(usage_for("bench-compare").unwrap().contains("--self-test"));
        assert!(usage_for("bench-compare").unwrap().contains("baseline"));
        assert!(usage_for("engine-bench").unwrap().contains("--min-speedup"));
        assert!(usage_for("shard-bench").unwrap().contains("--engine"));
        assert!(usage_for("bench").unwrap().contains("table3"));
        assert!(usage_for("simulate").unwrap().contains("--method"));
        assert!(usage_for("serve-node").unwrap().contains("--listen"));
        assert!(usage_for("serve-node").unwrap().contains("--fail-after"));
        assert!(usage_for("serve-cluster").unwrap().contains("--nodes"));
        assert!(usage_for("serve-cluster").unwrap().contains("--local-nodes"));
        assert!(usage_for("serve-cluster").unwrap().contains("bitwise"));
        assert!(usage_for("serve-cluster").unwrap().contains("--exchange"));
        assert!(usage_for("serve-cluster").unwrap().contains("mediated"));
        assert!(usage_for("serve-node").unwrap().contains("version 2"));
        assert!(usage_for("cluster-bench").unwrap().contains("--max-nodes"));
        assert!(usage_for("cluster-bench").unwrap().contains("cluster_bench.json"));
        assert!(usage_for("cluster-bench").unwrap().contains("overlap_ratio"));
        assert!(usage_for("cluster-bench").unwrap().contains("peer"));
    }
}
