//! `stencil-matrix` — CLI for the Stencil Matrixization reproduction.
//!
//! ```text
//! stencil-matrix analyze  --stencil 2d-box --order 2 [--n 8]
//! stencil-matrix cover    --stencil 2d-star --order 2 --option minimalaxis
//! stencil-matrix simulate --stencil 2d-box --order 1 --size 64 \
//!                         --method outer [--option parallel] [--ui 1] \
//!                         [--uk 8] [--no-sched] [--cold]
//! stencil-matrix bench    fig3|fig4|fig5|table3|ablations|all
//! stencil-matrix serve    --artifact evolve_2d5p_n256_t4 --executions 25
//! stencil-matrix list     [--artifacts-dir artifacts]
//! ```

use stencil_matrix::codegen::{run_method, Method, OuterParams};
use stencil_matrix::coordinator::{run_experiment, EvolutionService, Experiment};
use stencil_matrix::scatter::{analysis, build_cover, CoverOption};
use stencil_matrix::stencil::{CoeffTensor, StencilKind, StencilSpec};
use stencil_matrix::sim::SimConfig;
use std::collections::HashMap;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` arguments plus positionals.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut a = Args { positional: Vec::new(), flags: HashMap::new(), switches: Vec::new() };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if let Some(key) = arg.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                a.flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                a.switches.push(key.to_string());
                i += 1;
            }
        } else {
            a.positional.push(arg.clone());
            i += 1;
        }
    }
    a
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn parse_spec(args: &Args) -> anyhow::Result<StencilSpec> {
    let st = args.get("stencil").unwrap_or("2d-box");
    let order = args.usize_or("order", 1)?;
    let (dims, kind) = match st {
        "2d-box" => (2, StencilKind::Box),
        "2d-star" => (2, StencilKind::Star),
        "2d-diag" => (2, StencilKind::Diagonal),
        "3d-box" => (3, StencilKind::Box),
        "3d-star" => (3, StencilKind::Star),
        other => anyhow::bail!("unknown --stencil '{other}' (2d-box|2d-star|2d-diag|3d-box|3d-star)"),
    };
    StencilSpec::new(dims, order, kind)
}

fn parse_option(s: &str) -> anyhow::Result<CoverOption> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "parallel" | "p" => CoverOption::Parallel,
        "orthogonal" | "o" => CoverOption::Orthogonal,
        "hybrid" | "h" => CoverOption::Hybrid,
        "minimalaxis" | "minimal" | "m" => CoverOption::MinimalAxis,
        "diagonals" | "d" => CoverOption::Diagonals,
        other => anyhow::bail!("unknown --option '{other}'"),
    })
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = parse_args(&argv[1..]);
    let cfg = SimConfig::default();
    match cmd.as_str() {
        "help" | "--help" | "-h" => print_help(),
        "analyze" => {
            let spec = parse_spec(&args)?;
            let n = args.usize_or("n", cfg.vlen)?;
            println!("§3.4 analysis for {spec}, block extent n = {n}:");
            for option in CoverOption::applicable(spec) {
                let a = analysis::analyze(spec, option, n)?;
                println!(
                    "  {:12} lines→ vec FMA/outvec {:5.1} | outer/outvec {:6.2} | instr ratio {:5.2}x",
                    format!("{option:?}"),
                    a.vec_fma_per_outvec,
                    a.outer_per_outvec,
                    a.instr_ratio
                );
            }
            let (before, after) = analysis::box_per_line_reduction(spec.order, n);
            println!("  per-line reduction (box): {before} → {after} instructions/output vector");
        }
        "cover" => {
            let spec = parse_spec(&args)?;
            let option = parse_option(args.get("option").unwrap_or("parallel"))?;
            let coeffs = CoeffTensor::paper_default(spec);
            let cover = build_cover(&coeffs, option)?;
            println!("{spec} with {option:?}: {} line(s)", cover.len());
            for (i, line) in cover.lines.iter().enumerate() {
                println!(
                    "  line {i}: dir {:?} base {:?} weights {:?} ({} nonzero)",
                    line.dir,
                    line.base,
                    line.weights,
                    line.nonzeros()
                );
            }
            println!("outer products per n=8 block: {}", cover.outer_products(8));
        }
        "simulate" => {
            let spec = parse_spec(&args)?;
            let n = args.usize_or("size", 64)?;
            let method = match args.get("method").unwrap_or("outer") {
                "outer" => {
                    let mut p = OuterParams::paper_best(spec);
                    if let Some(o) = args.get("option") {
                        p.option = parse_option(o)?;
                    }
                    p.ui = args.usize_or("ui", p.ui)?;
                    p.uk = args.usize_or("uk", p.uk)?;
                    if args.has("no-sched") {
                        p.scheduled = false;
                    }
                    Method::Outer(p)
                }
                "autovec" => Method::AutoVec,
                "dlt" => Method::Dlt,
                "tv" => Method::Tv,
                "scalar" => Method::Scalar,
                other => anyhow::bail!("unknown --method '{other}'"),
            };
            let warm = !args.has("cold");
            let res = run_method(&cfg, spec, n, method, warm)?;
            println!(
                "{spec} N={n} {method}: {} cycles, {:.3} cyc/pt, verified={} (max err {:.2e})",
                res.stats.cycles,
                res.cycles_per_point(),
                res.verified(),
                res.max_err
            );
            println!("{}", res.stats);
            println!("{}", stencil_matrix::sim::trace::roofline(&cfg, &res.stats));
            anyhow::ensure!(res.verified(), "simulation output did not match the oracle");
        }
        "disasm" => {
            use stencil_matrix::codegen::common::{CoeffTable, Layout};
            use stencil_matrix::sim::isa::Program;
            use stencil_matrix::sim::Machine;
            use stencil_matrix::stencil::DenseGrid;
            let spec = parse_spec(&args)?;
            let n = args.usize_or("size", 16)?;
            let limit = args.usize_or("limit", 80)?;
            let mut p = OuterParams::paper_best(spec);
            if let Some(o) = args.get("option") {
                p.option = parse_option(o)?;
            }
            let coeffs = CoeffTensor::paper_default(spec);
            let cover = build_cover(&coeffs, p.option)?;
            let mut machine = Machine::new(cfg.clone());
            let shape = vec![n + 2 * spec.order; spec.dims];
            let grid = DenseGrid::verification_input(&shape, 1);
            let layout = Layout::alloc(&mut machine, spec, &grid);
            let table = CoeffTable::install_full(&mut machine, &coeffs, &cover);
            let mut prog = Program::default();
            stencil_matrix::codegen::outer::generate(&cfg, &layout, &cover, &table, p, &mut prog)?;
            println!(
                "# {spec} N={n} {} — {} instructions, {} fmopa",
                p.label(spec.dims),
                prog.0.len(),
                prog.fmopa_count()
            );
            print!("{}", stencil_matrix::sim::trace::disassemble(&prog, limit));
        }
        "bench" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all")
                .parse::<Experiment>()?;
            run_experiment(&cfg, which)?;
        }
        "serve" => {
            let dir = PathBuf::from(args.get("artifacts-dir").unwrap_or("artifacts"));
            let mut svc = EvolutionService::new(&dir)?;
            println!("platform: {}", svc.platform());
            let artifact = args.get("artifact").unwrap_or("evolve_2d5p_n64_t8").to_string();
            let executions = args.usize_or("executions", 10)?;
            let req = stencil_matrix::coordinator::service::EvolveRequest {
                artifact,
                executions,
                verify: !args.has("no-verify"),
            };
            let (_, report) = svc.serve(&req)?;
            println!(
                "{}: {} executions / {} steps in {:.3}s → {:.2} Mpoints/s (max err {:?})",
                req.artifact,
                report.executions,
                report.steps,
                report.seconds,
                report.points_per_sec / 1e6,
                report.max_err
            );
            if let Some(err) = report.max_err {
                anyhow::ensure!(err < 1e-9, "PJRT output did not match the oracle");
            }
        }
        "list" => {
            let dir = PathBuf::from(args.get("artifacts-dir").unwrap_or("artifacts"));
            let reg = stencil_matrix::runtime::Registry::load(&dir)?;
            for a in &reg.artifacts {
                println!(
                    "{:24} {} N={} steps={} ({})",
                    a.name,
                    a.spec,
                    a.n,
                    a.steps,
                    a.path.display()
                );
            }
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "stencil-matrix — Stencil Matrixization (CS.DC 2023) reproduction

USAGE:
  stencil-matrix analyze  --stencil 2d-box --order 2 [--n 8]
  stencil-matrix cover    --stencil 2d-star --order 2 --option orthogonal
  stencil-matrix simulate --stencil 2d-box --order 1 --size 64 --method outer
                          [--option parallel] [--ui 1] [--uk 8] [--no-sched] [--cold]
  stencil-matrix disasm   --stencil 2d-box --order 1 --size 16 [--limit 80]
  stencil-matrix bench    fig3|fig4|fig5|table3|ablations|all
  stencil-matrix serve    --artifact evolve_2d5p_n256_t4 --executions 25
  stencil-matrix list     [--artifacts-dir artifacts]

Methods: outer (the paper's), autovec, dlt, tv, scalar.
Stencils: 2d-box 2d-star 2d-diag 3d-box 3d-star; --order 1..4."
    );
}
