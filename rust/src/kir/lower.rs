//! KIR → simulator-ISA lowering.
//!
//! The mapping is 1:1 for every computational op (the sim ISA was
//! designed around the same instruction classes the paper relies on);
//! structure markers lower to nothing. Because generators stream ops,
//! lowering is streaming too: [`crate::sim::Machine`] implements
//! [`KirSink`] directly (execute-on-emit, no program buffer), and
//! [`lower`] converts a captured [`Kernel`] into any [`crate::sim::Sink`]
//! (e.g. a [`crate::sim::isa::Program`] for disassembly).

use super::ir::{Kernel, KirSink, Op};
use crate::sim::isa::{Instr, Sink};

/// Lower one op to its simulator instruction (`None` for markers).
pub fn to_instr(op: &Op) -> Option<Instr> {
    Some(match *op {
        Op::Load { dst, addr } => Instr::LdVec { dst, addr },
        Op::Store { src, addr } => Instr::StVec { src, addr },
        Op::Gather { dst, base, stride } => Instr::LdVecStrided { dst, base, stride },
        Op::Splat { dst, addr } => Instr::LdSplat { dst, addr },
        Op::StoreLane { src, lane, addr } => Instr::StLane { src, lane, addr },
        Op::Ext { dst, lo, hi, shift } => Instr::Ext { dst, lo, hi, shift },
        Op::Dup { dst, src, lane } => Instr::Dup { dst, src, lane },
        Op::Fma { acc, a, b } => Instr::VFma { acc, a, b },
        Op::FmaLane { acc, a, b, lane } => Instr::VFmaLane { acc, a, b, lane },
        Op::Add { dst, a, b } => Instr::VAdd { dst, a, b },
        Op::Mul { dst, a, b } => Instr::VMul { dst, a, b },
        Op::Zero { dst } => Instr::VZero { dst },
        Op::TileZero { m } => Instr::MZero { m },
        Op::Outer { m, a, b } => Instr::Fmopa { m, a, b },
        Op::RowIn { m, row, src } => Instr::MovVToMRow { m, row, src },
        Op::RowOut { dst, m, row } => Instr::MovMRowToV { dst, m, row },
        Op::ColIn { m, col, src } => Instr::MovVToMCol { m, col, src },
        Op::ColOut { dst, m, col } => Instr::MovMColToV { dst, m, col },
        Op::RowLoad { m, row, addr } => Instr::LdMRow { m, row, addr },
        Op::RowStore { m, row, addr } => Instr::StMRow { m, row, addr },
        Op::Begin(_) | Op::End(_) => return None,
    })
}

/// Lower a captured kernel into a simulator instruction sink.
pub fn lower(kernel: &Kernel, sink: &mut impl Sink) {
    for op in &kernel.ops {
        if let Some(i) = to_instr(op) {
            sink.emit(i);
        }
    }
}

/// Streaming adapter: wrap any simulator sink as a KIR sink.
pub struct SimLower<'a, S: Sink> {
    sink: &'a mut S,
}

impl<'a, S: Sink> SimLower<'a, S> {
    /// Wrap `sink`.
    pub fn new(sink: &'a mut S) -> Self {
        SimLower { sink }
    }
}

impl<S: Sink> KirSink for SimLower<'_, S> {
    fn emit(&mut self, op: Op) {
        if let Some(i) = to_instr(&op) {
            self.sink.emit(i);
        }
    }
}

/// The simulator executes KIR by lowering each op on emission — this is
/// what keeps `codegen::run_method` buffer-free after the generators
/// moved to the IR.
impl KirSink for crate::sim::Machine {
    fn emit(&mut self, op: Op) {
        if let Some(i) = to_instr(&op) {
            self.exec(&i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::ir::{Marker, MReg, VReg};
    use crate::sim::isa::Program;

    #[test]
    fn every_computational_op_lowers_and_markers_vanish() {
        let v = VReg(1);
        let m = MReg(0);
        let ops = [
            Op::Load { dst: v, addr: 0 },
            Op::Store { src: v, addr: 0 },
            Op::Gather { dst: v, base: 0, stride: 4 },
            Op::Splat { dst: v, addr: 0 },
            Op::StoreLane { src: v, lane: 2, addr: 0 },
            Op::Ext { dst: v, lo: v, hi: v, shift: 3 },
            Op::Dup { dst: v, src: v, lane: 1 },
            Op::Fma { acc: v, a: v, b: v },
            Op::FmaLane { acc: v, a: v, b: v, lane: 0 },
            Op::Add { dst: v, a: v, b: v },
            Op::Mul { dst: v, a: v, b: v },
            Op::Zero { dst: v },
            Op::TileZero { m },
            Op::Outer { m, a: v, b: v },
            Op::RowIn { m, row: 0, src: v },
            Op::RowOut { dst: v, m, row: 0 },
            Op::ColIn { m, col: 0, src: v },
            Op::ColOut { dst: v, m, col: 0 },
            Op::RowLoad { m, row: 0, addr: 0 },
            Op::RowStore { m, row: 0, addr: 0 },
        ];
        for op in ops {
            let i = to_instr(&op).expect("computational op must lower");
            // mnemonic sanity: memory ops stay memory ops
            assert_eq!(op.flops(8) > 0, i.flops(8) > 0, "{op:?}");
        }
        assert!(to_instr(&Op::Begin(Marker::Phase("x"))).is_none());
        assert!(to_instr(&Op::End(Marker::Phase("x"))).is_none());
    }

    #[test]
    fn lower_into_program_drops_markers() {
        let mut k = Kernel::default();
        k.emit(Op::Begin(Marker::Phase("p")));
        k.emit(Op::Zero { dst: VReg(0) });
        k.emit(Op::End(Marker::Phase("p")));
        let mut p = Program::default();
        lower(&k, &mut p);
        assert_eq!(p.0, vec![Instr::VZero { dst: VReg(0) }]);
        // the adapter behaves the same
        let mut p2 = Program::default();
        {
            let mut ad = SimLower::new(&mut p2);
            for op in &k.ops {
                ad.emit(*op);
            }
        }
        assert_eq!(p2.0, p.0);
    }
}
