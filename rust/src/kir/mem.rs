//! The memory-plan abstraction both backends implement.
//!
//! Kernel-IR addresses are element indices into a flat f64 memory whose
//! layout is decided at *generation* time (grids with halos and padded
//! strides, coefficient tables). [`Arena`] is the small surface the
//! layout/planning code needs: allocation with guard bands and raw
//! element reads/writes. [`crate::sim::Machine`] implements it (the sim
//! backend), and so does [`crate::kir::HostMachine`] (the host backend) —
//! which is what makes `codegen::common::Layout` and the coefficient
//! tables backend-agnostic.

/// A flat f64 memory arena with vector-aligned, guard-banded allocation.
///
/// Implementations must mirror each other's allocation discipline (same
/// alignment, same guard bands) so that a program generated against one
/// arena's layout executes identically on another arena prepared the
/// same way.
pub trait Arena {
    /// Vector length in f64 lanes (allocation alignment unit).
    fn vlen(&self) -> usize;

    /// Allocate `n` f64 elements (zero-initialized, guard-banded) and
    /// return the base element address.
    fn alloc(&mut self, n: usize) -> usize;

    /// Copy a slice into memory at `addr`.
    fn write_mem(&mut self, addr: usize, data: &[f64]);

    /// Read `n` elements from memory at `addr`.
    fn read_mem(&self, addr: usize, n: usize) -> &[f64];
}
