//! The memory-plan abstraction both backends implement.
//!
//! Kernel-IR addresses are element indices into a flat f64 memory whose
//! layout is decided at *generation* time (grids with halos and padded
//! strides, coefficient tables). [`Arena`] is the small surface the
//! layout/planning code needs: allocation with guard bands and raw
//! element reads/writes. [`crate::sim::Machine`] implements it (the sim
//! backend), and so does [`crate::kir::HostMachine`] (the host backend) —
//! which is what makes `codegen::common::Layout` and the coefficient
//! tables backend-agnostic.
//!
//! # The ping-pong double-buffer plan
//!
//! Temporally blocked programs fuse `T` time steps into one kernel
//! application. Every step still reads one grid image and writes the
//! other, but the *roles* alternate: step 0 reads the front buffer and
//! writes the back buffer, step 1 reads the back and writes the front,
//! and so on — the classic ping-pong. [`PingPong`] is that plan as data:
//! given the two buffer base addresses it answers, per fused step, which
//! base is read and which is written, and which buffer holds the final
//! result after `T` steps. Both the kernel compiler
//! ([`crate::kir::HostKernel`], which extracts the output tile from
//! `result_base`) and the codegen method runners (which pick `read_a` vs
//! `read_b` after a fused run) derive their buffer choices from it, so
//! the parity arithmetic lives in exactly one place. Addresses are plain
//! element indices, so the plan is backend-agnostic like everything else
//! here.

/// A flat f64 memory arena with vector-aligned, guard-banded allocation.
///
/// Implementations must mirror each other's allocation discipline (same
/// alignment, same guard bands) so that a program generated against one
/// arena's layout executes identically on another arena prepared the
/// same way.
pub trait Arena {
    /// Vector length in f64 lanes (allocation alignment unit).
    fn vlen(&self) -> usize;

    /// Allocate `n` f64 elements (zero-initialized, guard-banded) and
    /// return the base element address.
    fn alloc(&mut self, n: usize) -> usize;

    /// Copy a slice into memory at `addr`.
    fn write_mem(&mut self, addr: usize, data: &[f64]);

    /// Read `n` elements from memory at `addr`.
    fn read_mem(&self, addr: usize, n: usize) -> &[f64];
}

/// Ping-pong double-buffer plan for temporally blocked programs: which
/// of the two grid buffers each fused step reads and writes, and where
/// the final result lands (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PingPong {
    /// Base address of the buffer step 0 reads (the input image).
    pub front: usize,
    /// Base address of the buffer step 0 writes.
    pub back: usize,
}

impl PingPong {
    /// Plan over a front (input) and back buffer.
    pub fn new(front: usize, back: usize) -> PingPong {
        PingPong { front, back }
    }

    /// Base address the given (zero-based) fused step reads.
    pub fn read_base(&self, step: usize) -> usize {
        if step % 2 == 0 {
            self.front
        } else {
            self.back
        }
    }

    /// Base address the given (zero-based) fused step writes.
    pub fn write_base(&self, step: usize) -> usize {
        if step % 2 == 0 {
            self.back
        } else {
            self.front
        }
    }

    /// Base address of the buffer holding the result after `steps` fused
    /// steps (`steps >= 1`).
    pub fn result_base(&self, steps: usize) -> usize {
        self.write_base(steps.max(1) - 1)
    }

    /// True when the result after `steps` fused steps lands in the back
    /// buffer (the classic `B` grid) — i.e. after an odd number of steps.
    pub fn result_in_back(steps: usize) -> bool {
        steps.max(1) % 2 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong_alternates_and_lands_correctly() {
        let p = PingPong::new(100, 200);
        assert_eq!((p.read_base(0), p.write_base(0)), (100, 200));
        assert_eq!((p.read_base(1), p.write_base(1)), (200, 100));
        // every step reads what the previous one wrote
        for s in 1..6 {
            assert_eq!(p.read_base(s), p.write_base(s - 1));
            assert_ne!(p.read_base(s), p.write_base(s));
        }
        assert_eq!(p.result_base(1), 200);
        assert_eq!(p.result_base(2), 100);
        assert_eq!(p.result_base(4), 100);
        assert_eq!(p.result_base(5), 200);
        assert!(PingPong::result_in_back(1));
        assert!(!PingPong::result_in_back(2));
        assert!(PingPong::result_in_back(3));
        // degenerate: 0 steps behaves like 1 (no program runs twice)
        assert_eq!(p.result_base(0), p.result_base(1));
    }
}
