//! Backend-agnostic kernel IR: one outer-product program, two targets.
//!
//! The paper's optimizations — cover choice (§4.1), multi-dimensional
//! unrolling (§4.2), outer-product scheduling and inter-register data
//! reorganization (§4.3) — are *instruction-stream transformations*. This
//! module gives those streams a home of their own: all five generators in
//! [`crate::codegen`] emit typed KIR operations, and two backends lower
//! them, inverting the old generators → simulator dependency into
//! generators → IR → {simulator, host}:
//!
//! - [`ir`] — the IR: register ids ([`VReg`]/[`MReg`], shared with the
//!   simulator ISA), the [`Op`] set (vector loads/stores/gather/splat,
//!   `EXT`-style reorganization, FMA forms, tile outer-product
//!   accumulate and row/column moves), [`Marker`] structure ops
//!   recording the loop/unroll shape, streaming [`KirSink`] consumers,
//!   captured [`Kernel`] programs, and [`OpStats`] counters (what the
//!   autotuner's cost model is derived from);
//! - [`mem`] — the [`Arena`](mem::Arena) memory-plan trait both backends
//!   implement, which makes grid layouts and coefficient tables
//!   backend-agnostic, plus the [`PingPong`] double-buffer plan
//!   temporally blocked (multi-step) programs alternate their grid
//!   buffers with;
//! - [`lower`] — KIR → simulator ISA, 1:1 per computational op, markers
//!   dropped; [`crate::sim::Machine`] consumes KIR directly
//!   (execute-on-emit), so every benchmark and verification path flows
//!   through the IR with unchanged programs;
//! - [`host`] — KIR → host execution: [`HostMachine`] interprets the
//!   same programs natively over flat f64 buffers, with functional
//!   semantics kept operation-for-operation identical to the simulator
//!   (host output is bitwise equal to sim output —
//!   `rust/tests/kir_equivalence.rs`);
//! - [`fuse`] — loop-nest reconstruction from the `Marker` structure
//!   plus exact independence analysis (register self-containment,
//!   memory-footprint disjointness) deciding which unrolled tile groups
//!   may execute in any order;
//! - [`exec`] — the **compiling host engine** ([`ExecPlan`], selected by
//!   [`Engine::Compiled`], the default): each fused block lowered once
//!   into resolved straight-line instructions over flat f64 slices,
//!   gathers turned into precomputed index tables, and independent row
//!   groups split across a scoped thread pool — bitwise equal to the
//!   interpreter at any thread count, several times faster;
//! - [`simd`] — the **explicit-SIMD host engine** ([`SimdPlan`],
//!   selected by [`Engine::Simd`]): the compiled plan re-lowered to
//!   runtime-dispatched vector microkernels (AVX2 on x86-64, NEON on
//!   aarch64, scalar fallback elsewhere), with consecutive outer
//!   products fused into register-tile runs — still bitwise equal to
//!   the interpreter on every dispatch target, because accumulations
//!   stay multiply-then-add (two roundings), never fused FMA;
//! - [`kernel`] — [`HostKernel`]: a (spec, tile shape, method, time-tile
//!   depth) compiled once into a KIR program + execution plan + memory
//!   image, applied per tile by the serving subsystem (`serve --kernel
//!   outer`, and `tuned` plans compiled to real host kernels). With a
//!   time-tile depth `T > 1` the program fuses `T` time steps behind
//!   [`Marker::Step`] barriers against the ping-pong buffers, with an
//!   inter-step freeze phase keeping the per-step frozen-boundary
//!   contract exact — a fused application is bitwise identical to `T`
//!   single-step applications.
//!
//! Consumers: `codegen::run_method` (sim backend, timing),
//! `codegen::verify::run_host` (host backend, wall-clock),
//! `serve::scheduler` (tile host kernels), `tune::cost` (op statistics),
//! and the `dump-ir` CLI subcommand (human-readable programs).

pub mod exec;
pub mod fuse;
pub mod host;
pub mod ir;
pub mod kernel;
pub mod lower;
pub mod mem;
pub mod simd;

pub use exec::{Engine, ExecPlan};
pub use host::HostMachine;
pub use ir::{dump, step_stats, Kernel, KirSink, Marker, MReg, Op, OpStats, VReg};
pub use kernel::HostKernel;
pub use mem::{Arena, PingPong};
pub use simd::{SimdIsa, SimdPlan};
