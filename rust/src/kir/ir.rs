//! The IR itself: registers, typed operations, structure markers, and
//! per-program operation statistics.
//!
//! Field names deliberately mirror the simulator ISA's so the two stay
//! easy to diff; only the *names* of the operations are backend-neutral
//! (`Load`/`Outer`/`RowIn` rather than SME mnemonics). Addresses are
//! element indices into the kernel's flat f64 memory plan (see
//! [`crate::kir::mem::Arena`]); both backends interpret them identically.

use std::fmt;

/// A vector register id (`z0..`). Shared by the IR and every backend —
/// the simulator ISA re-exports this type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VReg(pub u8);

/// A matrix (tile) register id (`za0..`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MReg(pub u8);

impl fmt::Display for VReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "z{}", self.0)
    }
}

impl fmt::Display for MReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "za{}", self.0)
    }
}

/// Structure markers: the loop/unroll shape of the generated program.
///
/// Markers carry no semantics — both backends skip them — but they make
/// the IR inspectable (`dump-ir` indents on them) and let tools reason
/// about the §4.2 unroll structure without re-deriving it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Marker {
    /// An unrolled group of output tiles (§4.2): domain origin plus the
    /// group's tile counts along the unrolled dimensions (`ui × uk`; 2D
    /// groups have `ui = 1` and `k0 = 0`).
    TileGroup { i0: isize, j0: isize, k0: isize, ui: usize, uk: usize },
    /// A named program phase (e.g. the 3D orthogonal cover's second pass
    /// over `i`-lines).
    Phase(&'static str),
    /// One fused time step of a temporally blocked program (`t` of `of`,
    /// zero-based). Like [`Marker::Phase`], a `Step` boundary is a
    /// barrier: step `t + 1` reads what step `t` wrote, so no scheduling
    /// freedom crosses it.
    Step { t: usize, of: usize },
}

/// One kernel-IR operation.
///
/// The op set captures exactly what the paper's algorithm needs: vector
/// loads/stores (contiguous, gather, broadcast), inter-register
/// reorganization (`Ext`/`Dup`), vector FMA forms, and the matrix-tile
/// operations (outer-product accumulate, row/column moves, row
/// loads/stores). `Begin`/`End` are structure markers, not computation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    // ---- memory, vector granularity ----
    /// `dst <- mem[addr .. addr+vlen]` (contiguous, aligned by layout).
    Load { dst: VReg, addr: usize },
    /// `mem[addr .. addr+vlen] <- src`.
    Store { src: VReg, addr: usize },
    /// Gather: `dst[k] <- mem[base + k*stride]` (one access per lane).
    Gather { dst: VReg, base: usize, stride: usize },
    /// Broadcast load: `dst[k] <- mem[addr]` for all lanes.
    Splat { dst: VReg, addr: usize },
    /// Store one lane: `mem[addr] <- src[lane]`.
    StoreLane { src: VReg, lane: usize, addr: usize },

    // ---- inter-register reorganization (§4.3) ----
    /// `dst <- (lo ++ hi)[shift .. shift+vlen]`.
    Ext { dst: VReg, lo: VReg, hi: VReg, shift: usize },
    /// Broadcast one lane: `dst[k] <- src[lane]`.
    Dup { dst: VReg, src: VReg, lane: usize },

    // ---- vector arithmetic ----
    /// `acc[k] += a[k] * b[k]`.
    Fma { acc: VReg, a: VReg, b: VReg },
    /// `acc[k] += a[k] * b[lane]` (indexed FMA).
    FmaLane { acc: VReg, a: VReg, b: VReg, lane: usize },
    /// `dst[k] = a[k] + b[k]`.
    Add { dst: VReg, a: VReg, b: VReg },
    /// `dst[k] = a[k] * b[k]`.
    Mul { dst: VReg, a: VReg, b: VReg },
    /// `dst[k] = 0`.
    Zero { dst: VReg },

    // ---- matrix-tile operations ----
    /// Zero the whole tile.
    TileZero { m: MReg },
    /// Outer-product accumulate: `m[i][j] += a[i] * b[j]` (Eq. (12)).
    Outer { m: MReg, a: VReg, b: VReg },
    /// `m[row][*] <- src`.
    RowIn { m: MReg, row: usize, src: VReg },
    /// `dst <- m[row][*]`.
    RowOut { dst: VReg, m: MReg, row: usize },
    /// `m[*][col] <- src` (transpose building block, §4.1).
    ColIn { m: MReg, col: usize, src: VReg },
    /// `dst <- m[*][col]`.
    ColOut { dst: VReg, m: MReg, col: usize },
    /// `m[row][*] <- mem[addr .. addr+vlen]`.
    RowLoad { m: MReg, row: usize, addr: usize },
    /// `mem[addr .. addr+vlen] <- m[row][*]`.
    RowStore { m: MReg, row: usize, addr: usize },

    // ---- structure (no computation; backends skip these) ----
    /// Open a structural region.
    Begin(Marker),
    /// Close a structural region.
    End(Marker),
}

impl Op {
    /// True for structure markers (no computation, lowered to nothing).
    pub fn is_marker(&self) -> bool {
        matches!(self, Op::Begin(_) | Op::End(_))
    }

    /// Floating-point operations this op performs at vector length `vlen`.
    pub fn flops(&self, vlen: usize) -> u64 {
        match self {
            Op::Fma { .. } | Op::FmaLane { .. } => 2 * vlen as u64,
            Op::Add { .. } | Op::Mul { .. } => vlen as u64,
            Op::Outer { .. } => 2 * (vlen * vlen) as u64,
            _ => 0,
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Op::Load { dst, addr } => write!(f, "load    {dst} <- [{addr}]"),
            Op::Store { src, addr } => write!(f, "store   [{addr}] <- {src}"),
            Op::Gather { dst, base, stride } => {
                write!(f, "gather  {dst} <- [{base} +k*{stride}]")
            }
            Op::Splat { dst, addr } => write!(f, "splat   {dst} <- [{addr}]"),
            Op::StoreLane { src, lane, addr } => {
                write!(f, "store   [{addr}] <- {src}[{lane}]")
            }
            Op::Ext { dst, lo, hi, shift } => {
                write!(f, "ext     {dst} <- ({lo} ++ {hi}) >> {shift}")
            }
            Op::Dup { dst, src, lane } => write!(f, "dup     {dst} <- {src}[{lane}]"),
            Op::Fma { acc, a, b } => write!(f, "fma     {acc} += {a} * {b}"),
            Op::FmaLane { acc, a, b, lane } => {
                write!(f, "fma     {acc} += {a} * {b}[{lane}]")
            }
            Op::Add { dst, a, b } => write!(f, "add     {dst} = {a} + {b}"),
            Op::Mul { dst, a, b } => write!(f, "mul     {dst} = {a} * {b}"),
            Op::Zero { dst } => write!(f, "zero    {dst}"),
            Op::TileZero { m } => write!(f, "zero    {m}"),
            Op::Outer { m, a, b } => write!(f, "outer   {m} += {a} (x) {b}"),
            Op::RowIn { m, row, src } => write!(f, "mov     {m}.row[{row}] <- {src}"),
            Op::RowOut { dst, m, row } => write!(f, "mov     {dst} <- {m}.row[{row}]"),
            Op::ColIn { m, col, src } => write!(f, "mov     {m}.col[{col}] <- {src}"),
            Op::ColOut { dst, m, col } => write!(f, "mov     {dst} <- {m}.col[{col}]"),
            Op::RowLoad { m, row, addr } => {
                write!(f, "load    {m}.row[{row}] <- [{addr}]")
            }
            Op::RowStore { m, row, addr } => {
                write!(f, "store   [{addr}] <- {m}.row[{row}]")
            }
            Op::Begin(m) => write!(f, "{} {{", marker_label(&m)),
            Op::End(_) => write!(f, "}}"),
        }
    }
}

fn marker_label(m: &Marker) -> String {
    match *m {
        Marker::TileGroup { i0, j0, k0, ui, uk } => {
            format!("group @({i0},{j0},{k0}) ui={ui} uk={uk}")
        }
        Marker::Phase(name) => format!("phase {name}"),
        Marker::Step { t, of } => format!("==== step {}/{} ====", t + 1, of),
    }
}

/// Consumer of generated kernel-IR operations.
///
/// Code generators emit into a `KirSink`, so a program can be captured
/// ([`Kernel`]), lowered straight onto the simulator
/// ([`crate::sim::Machine`] implements this via the
/// [`crate::kir::lower`] mapping), executed natively on the host
/// ([`crate::kir::HostMachine`]), or merely counted ([`OpStats`]) — all
/// without multi-megabyte buffers when streaming.
pub trait KirSink {
    /// Consume one operation.
    fn emit(&mut self, op: Op);
}

/// A captured kernel-IR program.
#[derive(Debug, Clone)]
pub struct Kernel {
    /// The operations, markers included, in emission order.
    pub ops: Vec<Op>,
    /// Time steps one execution of the program advances (1 for classic
    /// single-sweep programs; T for temporally blocked programs whose
    /// fused steps are delimited by [`Marker::Step`] boundaries).
    pub steps: usize,
}

impl Default for Kernel {
    fn default() -> Kernel {
        Kernel { ops: Vec::new(), steps: 1 }
    }
}

impl KirSink for Kernel {
    fn emit(&mut self, op: Op) {
        self.ops.push(op);
    }
}

impl Kernel {
    /// Number of operations (markers included).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the kernel holds no operations.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Count operations matching a predicate.
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.ops.iter().filter(|o| pred(o)).count()
    }

    /// Number of outer-product accumulates (what Table 1/2 count).
    pub fn outer_count(&self) -> usize {
        self.count(|o| matches!(o, Op::Outer { .. }))
    }

    /// Operation statistics over the whole program.
    pub fn stats(&self) -> OpStats {
        let mut s = OpStats::default();
        for op in &self.ops {
            s.add(op);
        }
        s
    }
}

/// Per-class operation counters; also usable as a streaming [`KirSink`]
/// (the cost model counts programs without buffering them).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpStats {
    /// Contiguous vector loads.
    pub loads: u64,
    /// Contiguous vector stores.
    pub stores: u64,
    /// Gather loads (each occupies the memory pipe for `vlen` accesses).
    pub gathers: u64,
    /// Broadcast loads.
    pub splats: u64,
    /// Single-lane stores.
    pub lane_stores: u64,
    /// Tile-row loads from memory.
    pub row_loads: u64,
    /// Tile-row stores to memory.
    pub row_stores: u64,
    /// `Ext` reorganizations.
    pub exts: u64,
    /// `Dup` broadcasts.
    pub dups: u64,
    /// Vector FMAs (plain + indexed).
    pub fmas: u64,
    /// Vector adds + muls.
    pub alu: u64,
    /// Vector zeroings.
    pub zeros: u64,
    /// Tile ↔ vector row/column moves.
    pub moves: u64,
    /// Tile zeroings.
    pub tile_zeros: u64,
    /// Outer-product accumulates.
    pub outer_products: u64,
    /// Structure markers (not computation).
    pub markers: u64,
}

impl OpStats {
    /// Account one operation.
    pub fn add(&mut self, op: &Op) {
        match op {
            Op::Load { .. } => self.loads += 1,
            Op::Store { .. } => self.stores += 1,
            Op::Gather { .. } => self.gathers += 1,
            Op::Splat { .. } => self.splats += 1,
            Op::StoreLane { .. } => self.lane_stores += 1,
            Op::RowLoad { .. } => self.row_loads += 1,
            Op::RowStore { .. } => self.row_stores += 1,
            Op::Ext { .. } => self.exts += 1,
            Op::Dup { .. } => self.dups += 1,
            Op::Fma { .. } | Op::FmaLane { .. } => self.fmas += 1,
            Op::Add { .. } | Op::Mul { .. } => self.alu += 1,
            Op::Zero { .. } => self.zeros += 1,
            Op::RowIn { .. } | Op::RowOut { .. } | Op::ColIn { .. } | Op::ColOut { .. } => {
                self.moves += 1
            }
            Op::TileZero { .. } => self.tile_zeros += 1,
            Op::Outer { .. } => self.outer_products += 1,
            Op::Begin(_) | Op::End(_) => self.markers += 1,
        }
    }

    /// Total non-marker operations.
    pub fn total(&self) -> u64 {
        self.loads
            + self.stores
            + self.gathers
            + self.splats
            + self.lane_stores
            + self.row_loads
            + self.row_stores
            + self.exts
            + self.dups
            + self.fmas
            + self.alu
            + self.zeros
            + self.moves
            + self.tile_zeros
            + self.outer_products
    }

    /// Load/store-pipe slots, with gathers expanded to one slot per lane
    /// (the element-serialized behaviour both backends share).
    pub fn lsu_slots(&self, vlen: usize) -> u64 {
        self.loads
            + self.stores
            + self.splats
            + self.lane_stores
            + self.row_loads
            + self.row_stores
            + self.gathers * vlen as u64
    }

    /// Vector-ALU operations (reorganization, FMA, moves, zeroing).
    pub fn valu_ops(&self) -> u64 {
        self.exts + self.dups + self.fmas + self.alu + self.zeros + self.moves
    }

    /// Outer-product-unit operations (tile zero + outer accumulate).
    pub fn opu_ops(&self) -> u64 {
        self.tile_zeros + self.outer_products
    }

    /// Floating-point operations at vector length `vlen`.
    pub fn flops(&self, vlen: usize) -> u64 {
        self.fmas * 2 * vlen as u64
            + self.alu * vlen as u64
            + self.outer_products * 2 * (vlen * vlen) as u64
    }
}

impl KirSink for OpStats {
    fn emit(&mut self, op: Op) {
        self.add(&op);
    }
}

/// Per-step operation statistics of a temporally blocked program: one
/// [`OpStats`] per `Begin(Step)..End(Step)` region, in step order
/// (everything inside the region counts, including the inter-step
/// freeze phases nested in it). Programs without step markers return an
/// empty vector.
pub fn step_stats(kernel: &Kernel) -> Vec<OpStats> {
    let mut out = Vec::new();
    let mut current: Option<OpStats> = None;
    for op in &kernel.ops {
        match op {
            Op::Begin(Marker::Step { .. }) => current = Some(OpStats::default()),
            Op::End(Marker::Step { .. }) => {
                if let Some(s) = current.take() {
                    out.push(s);
                }
            }
            other => {
                if let Some(s) = &mut current {
                    s.add(other);
                }
            }
        }
    }
    out
}

/// Render a kernel as indented text (markers open/close blocks), up to
/// `limit` operations — the `dump-ir` CLI output.
pub fn dump(kernel: &Kernel, limit: usize) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let mut depth = 0usize;
    for (i, op) in kernel.ops.iter().enumerate() {
        if i >= limit {
            let _ = writeln!(out, "{:indent$}... ({} more)", "", kernel.ops.len() - i, indent = 2 * depth);
            break;
        }
        if matches!(op, Op::End(_)) {
            depth = depth.saturating_sub(1);
        }
        let _ = writeln!(out, "{:indent$}{op}", "", indent = 2 * depth);
        if matches!(op, Op::Begin(_)) {
            depth += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_classify_and_total() {
        let mut k = Kernel::default();
        k.emit(Op::Begin(Marker::Phase("t")));
        k.emit(Op::Load { dst: VReg(0), addr: 0 });
        k.emit(Op::Gather { dst: VReg(1), base: 0, stride: 8 });
        k.emit(Op::TileZero { m: MReg(0) });
        k.emit(Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
        k.emit(Op::RowStore { m: MReg(0), row: 0, addr: 64 });
        k.emit(Op::End(Marker::Phase("t")));
        let s = k.stats();
        assert_eq!(s.total(), 5);
        assert_eq!(s.markers, 2);
        assert_eq!(s.opu_ops(), 2);
        assert_eq!(s.lsu_slots(8), 1 + 8 + 1);
        assert_eq!(s.flops(8), 2 * 64);
        assert_eq!(k.outer_count(), 1);
    }

    #[test]
    fn stats_sink_matches_kernel_stats() {
        let mut k = Kernel::default();
        let mut s = OpStats::default();
        for op in [
            Op::Zero { dst: VReg(0) },
            Op::Fma { acc: VReg(0), a: VReg(1), b: VReg(2) },
            Op::Store { src: VReg(0), addr: 3 },
        ] {
            k.emit(op);
            s.emit(op);
        }
        assert_eq!(k.stats(), s);
        assert_eq!(s.valu_ops(), 2);
    }

    #[test]
    fn dump_indents_on_markers() {
        let mut k = Kernel::default();
        k.emit(Op::Begin(Marker::TileGroup { i0: 0, j0: 8, k0: 0, ui: 1, uk: 2 }));
        k.emit(Op::TileZero { m: MReg(0) });
        k.emit(Op::End(Marker::TileGroup { i0: 0, j0: 8, k0: 0, ui: 1, uk: 2 }));
        let text = dump(&k, 100);
        assert!(text.contains("group @(0,8,0) ui=1 uk=2 {"), "{text}");
        assert!(text.contains("  zero    za0"), "{text}");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[2], "}");
        // truncation note
        let short = dump(&k, 1);
        assert!(short.contains("(2 more)"), "{short}");
    }

    #[test]
    fn step_markers_render_distinctly_and_subtotal() {
        let mut k = Kernel::default();
        assert_eq!(k.steps, 1, "default programs advance one step");
        k.steps = 2;
        for t in 0..2usize {
            k.emit(Op::Begin(Marker::Step { t, of: 2 }));
            k.emit(Op::Load { dst: VReg(0), addr: 64 * t });
            if t == 0 {
                // inter-step freeze phase is charged to its step
                k.emit(Op::Begin(Marker::Phase("freeze")));
                k.emit(Op::Store { src: VReg(0), addr: 0 });
                k.emit(Op::End(Marker::Phase("freeze")));
            }
            k.emit(Op::End(Marker::Step { t, of: 2 }));
        }
        let text = dump(&k, 100);
        assert!(text.contains("==== step 1/2 ===="), "{text}");
        assert!(text.contains("==== step 2/2 ===="), "{text}");
        let per_step = step_stats(&k);
        assert_eq!(per_step.len(), 2);
        assert_eq!(per_step[0].total(), 2);
        assert_eq!(per_step[1].total(), 1);
        // markerless programs have no step breakdown
        assert!(step_stats(&Kernel::default()).is_empty());
    }

    #[test]
    fn marker_ops_are_markers() {
        assert!(Op::Begin(Marker::Phase("x")).is_marker());
        assert!(!Op::Zero { dst: VReg(0) }.is_marker());
        assert_eq!(Op::Outer { m: MReg(0), a: VReg(0), b: VReg(0) }.flops(8), 128);
    }
}
