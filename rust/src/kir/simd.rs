//! The explicit-SIMD host engine (`Engine::Simd`): resolved [`FOp`]
//! streams re-lowered to vector microkernels dispatched at runtime.
//!
//! The compiled engine ([`ExecPlan`]) lowers every op to scalar slice
//! loops and relies on auto-vectorization, which stops at the baseline
//! target ISA (128-bit SSE2 on x86-64). This module lowers the same
//! resolved stream once more, into:
//!
//! - **register-tile outer-product runs**: consecutive `Outer` ops on
//!   the same tile register become one microkernel that loads each
//!   accumulator chunk once, applies every broadcast × vector
//!   multiply-add pair in program order, and stores once — cutting tile
//!   traffic by the run length;
//! - **vector ALU loops** for `Fma`/`FmaLane`/`Add`/`Mul` chunks;
//! - everything else delegates to [`exec_fop`], the exact routine the
//!   compiled engine executes, so the portable fallback is
//!   byte-identical to `Engine::Compiled` by construction.
//!
//! **Dispatch** happens once per [`SimdPlan::run`]:
//! `is_x86_feature_detected!` selects 256-bit AVX2 (requires the
//! `avx2` and `fma` CPUID bits), aarch64 uses baseline NEON, and
//! everything else — or a `STENCIL_SIMD=scalar` / [`force_scalar`]
//! override — takes the scalar fallback. AVX-512F is detected and
//! reported (metrics, `dump-ir`) but executed through the AVX2 path:
//! the pinned stable toolchain does not yet expose AVX-512 intrinsics.
//! Each dispatch bumps the `stencil_engine_dispatch_total{isa=...}`
//! counter family so `/metrics` shows which ISA actually ran.
//!
//! **Bitwise contract**: the interpreter accumulates with a multiply
//! *then* an add — two IEEE roundings per lane. The microkernels
//! therefore issue separate vector multiply and add instructions
//! (`vmulpd`+`vaddpd`, `fmul`+`fadd`) and never a fused multiply-add,
//! whose single rounding would diverge. Per output element the
//! operand sequence is exactly the interpreter's, threading reuses the
//! fuser's disjointness proof, and the dispatch choice only selects how
//! many lanes move per instruction — so Simd == Interpret bitwise at
//! any thread count on any ISA (`rust/tests/kir_equivalence.rs`).
//!
//! **Unsafe boundary**: every `#[target_feature]` fn is `unsafe fn`
//! (the module denies `unsafe_op_in_unsafe_fn`) and is only reachable
//! through the safe [`SimdPlan::run`] dispatcher, which checked the
//! CPUID bits. Register offsets are validated against the register
//! file shape once at lowering time ([`SimdPlan::new`]), making the
//! raw-pointer microkernels in-bounds by the same argument the
//! compiled engine enforces with slice indexing.
#![deny(unsafe_op_in_unsafe_fn)]

use super::exec::{
    exec_fop, row_groups_counter, Block, ExecPlan, ExecState, FOp, PlanSection, SharedMem,
};
use super::fuse::SectionMeta;
use super::ir::Op;
use crate::obs::registry;
use crate::obs::span::{span, span_arg};
use crate::sim::SimConfig;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Instruction set the SIMD engine dispatches to at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimdIsa {
    /// 256-bit AVX2 vectors (x86-64 with the `avx2`+`fma` CPUID bits).
    Avx2,
    /// 128-bit NEON vectors (aarch64 baseline).
    Neon,
    /// Portable scalar fallback, byte-identical to the compiled engine.
    Scalar,
}

impl SimdIsa {
    /// Label used in reports and in the
    /// `stencil_engine_dispatch_total{isa=...}` counter family.
    pub fn as_str(self) -> &'static str {
        match self {
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Neon => "neon",
            SimdIsa::Scalar => "scalar",
        }
    }
}

impl fmt::Display for SimdIsa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Process-global scalar-fallback override (see [`force_scalar`]).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Pin every subsequent SIMD-engine run to the scalar fallback (`true`)
/// or restore runtime dispatch (`false`). Test/debug hook: the dispatch
/// choice never changes results, which `rust/tests/kir_equivalence.rs`
/// proves by flipping this around full equivalence sweeps.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether a `STENCIL_SIMD` value pins the scalar fallback
/// (`scalar`/`off`/`0`; anything else keeps runtime dispatch).
fn env_forces_scalar(value: Option<&str>) -> bool {
    matches!(value.map(str::trim), Some("scalar") | Some("off") | Some("0"))
}

/// The ISA [`SimdPlan::run`] dispatches to right now: the strongest
/// supported extension, unless the `STENCIL_SIMD` environment variable
/// or [`force_scalar`] pins the portable fallback.
pub fn active_isa() -> SimdIsa {
    if FORCE_SCALAR.load(Ordering::SeqCst) {
        return SimdIsa::Scalar;
    }
    let env = std::env::var("STENCIL_SIMD").ok().map(|v| v.to_ascii_lowercase());
    if env_forces_scalar(env.as_deref()) {
        return SimdIsa::Scalar;
    }
    detect()
}

/// Detect the strongest ISA this host supports.
fn detect() -> SimdIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            SimdIsa::Avx2
        } else {
            SimdIsa::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdIsa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdIsa::Scalar
    }
}

/// Human-readable list of the vector features detected on this host,
/// for CI logs and `dump-ir --engine simd`. AVX-512F shows up here when
/// present even though execution goes through the AVX2 path.
pub fn feature_summary() -> String {
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, on) in [
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ] {
            if on {
                feats.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        feats.push("neon");
    }
    if feats.is_empty() {
        feats.push("none");
    }
    feats.join(" ")
}

/// Bump the `stencil_engine_dispatch_total{isa=...}` counter family.
fn count_dispatch(isa: SimdIsa) {
    let labels = match isa {
        SimdIsa::Avx2 => "isa=\"avx2\"",
        SimdIsa::Neon => "isa=\"neon\"",
        SimdIsa::Scalar => "isa=\"scalar\"",
    };
    registry::global().counter_with("stencil_engine_dispatch_total", labels).inc();
}

/// A lowered SIMD instruction: either a pass-through [`FOp`] or a fused
/// run of consecutive outer products.
#[derive(Debug, Clone)]
enum SOp {
    /// Executed by a vector ALU loop, or by the shared scalar helper.
    Plain(FOp),
    /// `pairs.len()` consecutive `Outer { m, .. }` ops on one tile
    /// register: per accumulator chunk, load once, apply every `(a, b)`
    /// broadcast × vector multiply-add pair in program order, store
    /// once.
    OuterRun { m: u32, pairs: Vec<(u32, u32)> },
}

/// A straight-line block of lowered SIMD instructions.
#[derive(Debug, Clone)]
struct SimdBlock {
    code: Vec<SOp>,
}

#[derive(Debug, Clone)]
enum SimdSection {
    Par(Vec<SimdBlock>),
    Seq(SimdBlock),
}

/// Fuse consecutive `Outer` ops on the same tile register into
/// [`SOp::OuterRun`]s. Adjacency in program order means nothing
/// executes between the fused ops, and the microkernel preserves the
/// per-element pair order, so the fusion is bitwise-neutral.
fn lower_block(block: &Block) -> SimdBlock {
    let mut code: Vec<SOp> = Vec::with_capacity(block.code.len());
    for fop in &block.code {
        if let FOp::Outer { m, a, b } = *fop {
            if let Some(SOp::OuterRun { m: prev, pairs }) = code.last_mut() {
                if *prev == m {
                    pairs.push((a, b));
                    continue;
                }
            }
            code.push(SOp::OuterRun { m, pairs: vec![(a, b)] });
        } else {
            code.push(SOp::Plain(*fop));
        }
    }
    SimdBlock { code }
}

/// Per-plan lowering statistics (for [`SimdPlan::describe`]).
#[derive(Debug, Default, Clone, Copy)]
struct LowerStats {
    /// Register-tile outer-product microkernels emitted.
    runs: usize,
    /// Original `Outer` ops covered by those runs.
    outers: usize,
    /// `Fma`/`FmaLane` ops lowered to vector multiply-add loops.
    vfma: usize,
    /// `Add`/`Mul` ops lowered to vector ALU loops.
    valu: usize,
    /// Bulk-move ops (loads, stores, shifts, broadcasts) left to the
    /// compiler's vector memmove/memset.
    vmov: usize,
    /// Inherently lane-serial ops (strided gathers, column walks)
    /// executed by the shared scalar helper.
    scalar: usize,
}

impl LowerStats {
    fn add_block(&mut self, block: &SimdBlock) {
        for sop in &block.code {
            match sop {
                SOp::OuterRun { pairs, .. } => {
                    self.runs += 1;
                    self.outers += pairs.len();
                }
                SOp::Plain(fop) => match fop {
                    FOp::Fma { .. } | FOp::FmaLane { .. } => self.vfma += 1,
                    FOp::Add { .. } | FOp::Mul { .. } => self.valu += 1,
                    FOp::Gather { .. }
                    | FOp::StoreLane { .. }
                    | FOp::ColIn { .. }
                    | FOp::ColOut { .. } => self.scalar += 1,
                    _ => self.vmov += 1,
                },
            }
        }
    }

    fn accumulate(&mut self, other: &LowerStats) {
        self.runs += other.runs;
        self.outers += other.outers;
        self.vfma += other.vfma;
        self.valu += other.valu;
        self.vmov += other.vmov;
        self.scalar += other.scalar;
    }

    fn total_ops(&self) -> usize {
        self.outers + self.vfma + self.valu + self.vmov + self.scalar
    }

    /// Ops executed by explicit vector microkernels.
    fn vector_ops(&self) -> usize {
        self.outers + self.vfma + self.valu
    }

    fn line(&self) -> String {
        format!(
            "{} op(s) -> {} outer-run ({} outers), {} vfma, {} valu, {} vmov, {} scalar",
            self.total_ops(),
            self.runs,
            self.outers,
            self.vfma,
            self.valu,
            self.vmov,
            self.scalar
        )
    }
}

/// A compiled [`ExecPlan`] re-lowered for the SIMD engine.
///
/// Shares the plan's section structure (and therefore its threading
/// and span behavior) but owns its own instruction stream with outer
/// runs fused.
#[derive(Debug, Clone)]
pub struct SimdPlan {
    vlen: usize,
    n_vregs: usize,
    n_mregs: usize,
    sections: Vec<SimdSection>,
    labels: Vec<SectionMeta>,
    tables: Vec<Vec<u32>>,
    mem_hwm: usize,
    ops: u64,
    par_blocks: usize,
}

impl SimdPlan {
    /// Re-lower a compiled plan for SIMD execution.
    ///
    /// Panics if any register offset exceeds the register file the
    /// plan was compiled for — the dynamic bounds check the compiled
    /// engine gets from slice indexing, paid once here instead so the
    /// microkernels can run on raw pointers.
    pub fn new(plan: &ExecPlan) -> SimdPlan {
        validate_register_extents(plan);
        let sections = plan
            .sections
            .iter()
            .map(|s| match s {
                PlanSection::Par(blocks) => {
                    SimdSection::Par(blocks.iter().map(lower_block).collect())
                }
                PlanSection::Seq(block) => SimdSection::Seq(lower_block(block)),
            })
            .collect();
        SimdPlan {
            vlen: plan.vlen,
            n_vregs: plan.n_vregs,
            n_mregs: plan.n_mregs,
            sections,
            labels: plan.labels.clone(),
            tables: plan.tables.clone(),
            mem_hwm: plan.mem_hwm,
            ops: plan.ops,
            par_blocks: plan.par_blocks,
        }
    }

    /// Compile and re-lower `ops` for the machine shape of `cfg`.
    pub fn from_config(cfg: &SimConfig, ops: &[Op]) -> SimdPlan {
        SimdPlan::new(&ExecPlan::from_config(cfg, ops))
    }

    /// Non-marker operations in the plan.
    pub fn op_count(&self) -> u64 {
        self.ops
    }

    /// Blocks the fuser proved independent (0 ⇒ fully sequential plan).
    pub fn par_blocks(&self) -> usize {
        self.par_blocks
    }

    /// Threads `run` will actually use for `threads` requested (0 = all
    /// available cores), given the plan's parallel structure.
    pub fn effective_threads(&self, threads: usize) -> usize {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        t.min(self.par_blocks.max(1))
    }

    /// Execute the plan over `mem` with up to `threads` worker threads
    /// (0 = one per available core). Dispatches once per call to
    /// [`active_isa`]; the result in `mem` is bitwise independent of
    /// both the thread count and the dispatch choice.
    pub fn run(&self, mem: &mut [f64], threads: usize) {
        assert!(
            mem.len() >= self.mem_hwm,
            "memory image too small for plan: {} < {}",
            mem.len(),
            self.mem_hwm
        );
        let isa = active_isa();
        count_dispatch(isa);
        let threads = self.effective_threads(threads);
        let shared = SharedMem { ptr: mem.as_mut_ptr(), len: mem.len() };
        let mut main_state = ExecState::new(self.vlen, self.n_vregs, self.n_mregs);
        for (si, section) in self.sections.iter().enumerate() {
            let meta = self.labels.get(si).copied().unwrap_or_default();
            let name = if meta.phase == Some("freeze") { "kir.freeze" } else { "kir.compute" };
            let _section_span = match meta.step {
                Some((t, _)) => span_arg(name, "kir", ("step", t as f64)),
                None => span(name, "kir"),
            };
            match section {
                SimdSection::Seq(block) => {
                    self.run_block(block, &shared, &mut main_state, isa);
                }
                SimdSection::Par(blocks) => {
                    row_groups_counter().add(blocks.len() as u64);
                    if threads <= 1 || blocks.len() <= 1 {
                        for (bi, block) in blocks.iter().enumerate() {
                            let _g = span_arg("kir.row_group", "kir", ("block", bi as f64));
                            self.run_block(block, &shared, &mut main_state, isa);
                        }
                    } else {
                        let next = AtomicUsize::new(0);
                        let workers = threads.min(blocks.len());
                        std::thread::scope(|scope| {
                            for w in 0..workers {
                                std::thread::Builder::new()
                                    .name(format!("kir-simd-{w}"))
                                    .spawn_scoped(scope, || {
                                        let mut state =
                                            ExecState::new(self.vlen, self.n_vregs, self.n_mregs);
                                        loop {
                                            let i = next.fetch_add(1, Ordering::Relaxed);
                                            let Some(block) = blocks.get(i) else { break };
                                            let _g = span_arg(
                                                "kir.row_group",
                                                "kir",
                                                ("block", i as f64),
                                            );
                                            self.run_block(block, &shared, &mut state, isa);
                                        }
                                    })
                                    .expect("spawn kir simd worker thread");
                            }
                        });
                    }
                }
            }
        }
    }

    /// Safe dispatch wrapper around the per-ISA block executors.
    fn run_block(&self, block: &SimdBlock, mem: &SharedMem, st: &mut ExecState, isa: SimdIsa) {
        match isa {
            #[cfg(target_arch = "x86_64")]
            SimdIsa::Avx2 => {
                // SAFETY: `isa` is Avx2 only when `detect` saw the
                // avx2+fma CPUID bits on this host, and `SimdPlan::new`
                // validated every register offset against the register
                // file shape `ExecState::new` allocates.
                unsafe { self.run_block_avx2(block, mem, st) }
            }
            #[cfg(target_arch = "aarch64")]
            SimdIsa::Neon => {
                // SAFETY: NEON is part of the aarch64 baseline, and the
                // register-extent argument above holds unchanged.
                unsafe { self.run_block_neon(block, mem, st) }
            }
            _ => self.run_block_scalar(block, mem, st),
        }
    }

    /// Portable fallback: every op goes through [`exec_fop`] — the
    /// routine the compiled engine runs — so the fallback is
    /// byte-identical to `Engine::Compiled` by construction. Outer runs
    /// unfuse back into their original op sequence.
    fn run_block_scalar(&self, block: &SimdBlock, mem: &SharedMem, st: &mut ExecState) {
        let n = self.vlen;
        let ExecState { vregs, mregs, scratch } = st;
        let v = vregs.as_mut_slice();
        let t = mregs.as_mut_slice();
        for sop in &block.code {
            match sop {
                SOp::Plain(fop) => exec_fop(fop, &self.tables, n, mem, v, t, scratch),
                SOp::OuterRun { m, pairs } => {
                    for &(a, b) in pairs {
                        let fop = FOp::Outer { m: *m, a, b };
                        exec_fop(&fop, &self.tables, n, mem, v, t, scratch);
                    }
                }
            }
        }
    }

    /// AVX2 block executor.
    ///
    /// # Safety
    /// The host must support avx2, and `st` must have the register file
    /// shape this plan was validated against in [`SimdPlan::new`].
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn run_block_avx2(&self, block: &SimdBlock, mem: &SharedMem, st: &mut ExecState) {
        let n = self.vlen;
        let ExecState { vregs, mregs, scratch } = st;
        let v = vregs.as_mut_slice();
        let t = mregs.as_mut_slice();
        for sop in &block.code {
            match sop {
                SOp::OuterRun { m, pairs } => {
                    // SAFETY: tile `m + n*n` and every vector operand
                    // `a/b + n` were validated in-bounds at lowering.
                    unsafe { avx2::outer_run(v.as_ptr(), t.as_mut_ptr(), *m as usize, pairs, n) }
                }
                SOp::Plain(fop) => match *fop {
                    FOp::Fma { acc, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: validated offsets; register ranges are
                        // multiples of n apart, so they are identical or
                        // disjoint, and each chunk loads before it
                        // stores — matching the scalar read/write order.
                        unsafe {
                            avx2::fma(
                                p.add(acc as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    FOp::FmaLane { acc, a, bl } => {
                        let c = v[bl as usize];
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma; the lane operand is
                        // latched before the loop, as the interpreter
                        // does.
                        unsafe {
                            avx2::fma_lane(p.add(acc as usize), p.add(a as usize).cast_const(), c, n)
                        }
                    }
                    FOp::Add { d, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma.
                        unsafe {
                            avx2::add(
                                p.add(d as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    FOp::Mul { d, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma.
                        unsafe {
                            avx2::mul(
                                p.add(d as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    ref other => exec_fop(other, &self.tables, n, mem, v, t, scratch),
                },
            }
        }
    }

    /// NEON block executor.
    ///
    /// # Safety
    /// NEON must be available (aarch64 baseline), and `st` must have
    /// the register file shape this plan was validated against.
    #[cfg(target_arch = "aarch64")]
    #[target_feature(enable = "neon")]
    unsafe fn run_block_neon(&self, block: &SimdBlock, mem: &SharedMem, st: &mut ExecState) {
        let n = self.vlen;
        let ExecState { vregs, mregs, scratch } = st;
        let v = vregs.as_mut_slice();
        let t = mregs.as_mut_slice();
        for sop in &block.code {
            match sop {
                SOp::OuterRun { m, pairs } => {
                    // SAFETY: tile `m + n*n` and every vector operand
                    // `a/b + n` were validated in-bounds at lowering.
                    unsafe { neon::outer_run(v.as_ptr(), t.as_mut_ptr(), *m as usize, pairs, n) }
                }
                SOp::Plain(fop) => match *fop {
                    FOp::Fma { acc, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: validated offsets; register ranges are
                        // multiples of n apart, so they are identical or
                        // disjoint, and each chunk loads before it
                        // stores — matching the scalar read/write order.
                        unsafe {
                            neon::fma(
                                p.add(acc as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    FOp::FmaLane { acc, a, bl } => {
                        let c = v[bl as usize];
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma; the lane operand is
                        // latched before the loop, as the interpreter
                        // does.
                        unsafe {
                            neon::fma_lane(p.add(acc as usize), p.add(a as usize).cast_const(), c, n)
                        }
                    }
                    FOp::Add { d, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma.
                        unsafe {
                            neon::add(
                                p.add(d as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    FOp::Mul { d, a, b } => {
                        let p = v.as_mut_ptr();
                        // SAFETY: as for Fma.
                        unsafe {
                            neon::mul(
                                p.add(d as usize),
                                p.add(a as usize).cast_const(),
                                p.add(b as usize).cast_const(),
                                n,
                            )
                        }
                    }
                    ref other => exec_fop(other, &self.tables, n, mem, v, t, scratch),
                },
            }
        }
    }

    /// Render the lowering report `dump-ir --engine simd` prints: the
    /// detected dispatch target and, per section, how many ops became
    /// vector microkernels vs bulk moves vs the scalar helper.
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "simd plan: dispatch={} (features: {}), vlen={}, {} op(s), {} par block(s)",
            active_isa(),
            feature_summary(),
            self.vlen,
            self.ops,
            self.par_blocks
        );
        let mut total = LowerStats::default();
        for (si, section) in self.sections.iter().enumerate() {
            let mut s = LowerStats::default();
            let (kind, nblocks) = match section {
                SimdSection::Seq(block) => {
                    s.add_block(block);
                    ("seq", 1)
                }
                SimdSection::Par(blocks) => {
                    for block in blocks {
                        s.add_block(block);
                    }
                    ("par", blocks.len())
                }
            };
            let phase = match self.labels.get(si).and_then(|m| m.phase) {
                Some(p) => format!(" [{p}]"),
                None => String::new(),
            };
            let _ = writeln!(out, "  section {si} {kind}x{nblocks}{phase}: {}", s.line());
            total.accumulate(&s);
        }
        let pct = 100.0 * total.vector_ops() as f64 / total.total_ops().max(1) as f64;
        let _ = writeln!(
            out,
            "  totals: {}; vector-lowered {}/{} ({pct:.0}%)",
            total.line(),
            total.vector_ops(),
            total.total_ops()
        );
        out
    }
}

/// Check every register offset in `plan` against the register file
/// shape its `ExecState` will allocate, so the raw-pointer microkernels
/// are in-bounds without per-access checks.
fn validate_register_extents(plan: &ExecPlan) {
    let n = plan.vlen;
    let mut vmax = 0usize;
    let mut mmax = 0usize;
    let mut blocks: Vec<&Block> = Vec::new();
    for section in &plan.sections {
        match section {
            PlanSection::Par(bs) => blocks.extend(bs.iter()),
            PlanSection::Seq(b) => blocks.push(b),
        }
    }
    let mut vreg = |off: u32, len: usize| vmax = vmax.max(off as usize + len);
    let mut mreg = |off: u32, len: usize| mmax = mmax.max(off as usize + len);
    for block in blocks {
        for fop in &block.code {
            match *fop {
                FOp::Load { d, .. } | FOp::Gather { d, .. } | FOp::Splat { d, .. } => vreg(d, n),
                FOp::Store { s, .. } => vreg(s, n),
                FOp::StoreLane { sl, .. } => vreg(sl, 1),
                FOp::Ext { d, lo, hi, .. } => {
                    vreg(d, n);
                    vreg(lo, n);
                    vreg(hi, n);
                }
                FOp::Dup { d, sl } => {
                    vreg(d, n);
                    vreg(sl, 1);
                }
                FOp::Fma { acc, a, b } => {
                    vreg(acc, n);
                    vreg(a, n);
                    vreg(b, n);
                }
                FOp::FmaLane { acc, a, bl } => {
                    vreg(acc, n);
                    vreg(a, n);
                    vreg(bl, 1);
                }
                FOp::Add { d, a, b } | FOp::Mul { d, a, b } => {
                    vreg(d, n);
                    vreg(a, n);
                    vreg(b, n);
                }
                FOp::Zero { d } => vreg(d, n),
                FOp::TileZero { m } => mreg(m, n * n),
                FOp::Outer { m, a, b } => {
                    vreg(a, n);
                    vreg(b, n);
                    mreg(m, n * n);
                }
                FOp::RowIn { mr, s } => {
                    vreg(s, n);
                    mreg(mr, n);
                }
                FOp::RowOut { d, mr } => {
                    vreg(d, n);
                    mreg(mr, n);
                }
                FOp::ColIn { m, s, .. } => {
                    vreg(s, n);
                    mreg(m, n * n);
                }
                FOp::ColOut { d, m, .. } => {
                    vreg(d, n);
                    mreg(m, n * n);
                }
                FOp::RowLoad { mr, .. } | FOp::RowStore { mr, .. } => mreg(mr, n),
            }
        }
    }
    assert!(
        vmax <= n * plan.n_vregs,
        "vector register offset out of range for plan: {} > {}",
        vmax,
        n * plan.n_vregs
    );
    assert!(
        mmax <= n * n * plan.n_mregs,
        "tile register offset out of range for plan: {} > {}",
        mmax,
        n * n * plan.n_mregs
    );
}

/// AVX2 microkernels. Every fn is `unsafe` + `#[target_feature]` and
/// reachable only through [`SimdPlan::run_block`]'s checked dispatch.
///
/// Accumulations issue a vector multiply followed by a vector add —
/// two IEEE roundings per lane, exactly the interpreter's semantics —
/// never a fused `vfmadd`, whose single rounding would diverge
/// bitwise.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
    };

    /// f64 lanes per 256-bit vector.
    const LANES: usize = 4;

    /// `acc[k] += a[k] * b[k]` for `k < n`.
    ///
    /// # Safety
    /// avx2 available; all three `n`-element ranges in bounds. `acc`
    /// may equal `a`/`b` (chunk loads precede the chunk store).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn fma(acc: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = _mm256_loadu_pd(a.add(k));
                let vb = _mm256_loadu_pd(b.add(k));
                let vc = _mm256_loadu_pd(acc.add(k));
                _mm256_storeu_pd(acc.add(k), _mm256_add_pd(vc, _mm256_mul_pd(va, vb)));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe {
                let prod = *a.add(k) * *b.add(k);
                *acc.add(k) += prod;
            }
            k += 1;
        }
    }

    /// `acc[k] += a[k] * c` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`] (two ranges plus a broadcast scalar).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn fma_lane(acc: *mut f64, a: *const f64, c: f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps both chunks in range.
            unsafe {
                let vcst = _mm256_set1_pd(c);
                let va = _mm256_loadu_pd(a.add(k));
                let vc = _mm256_loadu_pd(acc.add(k));
                _mm256_storeu_pd(acc.add(k), _mm256_add_pd(vc, _mm256_mul_pd(va, vcst)));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe {
                let prod = *a.add(k) * c;
                *acc.add(k) += prod;
            }
            k += 1;
        }
    }

    /// `d[k] = a[k] + b[k]` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`].
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add(d: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = _mm256_loadu_pd(a.add(k));
                let vb = _mm256_loadu_pd(b.add(k));
                _mm256_storeu_pd(d.add(k), _mm256_add_pd(va, vb));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe { *d.add(k) = *a.add(k) + *b.add(k) }
            k += 1;
        }
    }

    /// `d[k] = a[k] * b[k]` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`].
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul(d: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = _mm256_loadu_pd(a.add(k));
                let vb = _mm256_loadu_pd(b.add(k));
                _mm256_storeu_pd(d.add(k), _mm256_mul_pd(va, vb));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe { *d.add(k) = *a.add(k) * *b.add(k) }
            k += 1;
        }
    }

    /// Register-tile outer-product run:
    /// `t[m + i*n + j] += v[a + i] * v[b + j]` for every `(a, b)` pair
    /// in program order. Each accumulator chunk is loaded once per run
    /// and stored once, so tile traffic shrinks by the run length; per
    /// element the pair sequence matches the interpreter exactly.
    ///
    /// # Safety
    /// avx2 available; `m + n*n` in bounds of `t`, every `a + n` /
    /// `b + n` in bounds of `v`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn outer_run(
        v: *const f64,
        t: *mut f64,
        m: usize,
        pairs: &[(u32, u32)],
        n: usize,
    ) {
        let mut j = 0;
        while j + LANES <= n {
            for i in 0..n {
                // SAFETY: chunk `[j, j + LANES)` of tile row `i` and of
                // every `b` vector is in range; all loads precede the
                // single store.
                unsafe {
                    let row = t.add(m + i * n + j);
                    let mut acc = _mm256_loadu_pd(row);
                    for &(a, b) in pairs {
                        let ai = _mm256_set1_pd(*v.add(a as usize + i));
                        let vb = _mm256_loadu_pd(v.add(b as usize + j));
                        acc = _mm256_add_pd(acc, _mm256_mul_pd(ai, vb));
                    }
                    _mm256_storeu_pd(row, acc);
                }
            }
            j += LANES;
        }
        while j < n {
            for i in 0..n {
                // SAFETY: scalar tail element `(i, j)` is in range.
                unsafe {
                    let e = t.add(m + i * n + j);
                    for &(a, b) in pairs {
                        let prod = *v.add(a as usize + i) * *v.add(b as usize + j);
                        *e += prod;
                    }
                }
            }
            j += 1;
        }
    }
}

/// NEON microkernels (aarch64 baseline). Same shapes and the same
/// two-rounding multiply-then-add contract as the AVX2 set — `fmul` +
/// `fadd`, never a fused `fmla`.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{vaddq_f64, vdupq_n_f64, vld1q_f64, vmulq_f64, vst1q_f64};

    /// f64 lanes per 128-bit vector.
    const LANES: usize = 2;

    /// `acc[k] += a[k] * b[k]` for `k < n`.
    ///
    /// # Safety
    /// All three `n`-element ranges in bounds. `acc` may equal `a`/`b`
    /// (chunk loads precede the chunk store).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn fma(acc: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = vld1q_f64(a.add(k));
                let vb = vld1q_f64(b.add(k));
                let vc = vld1q_f64(acc.add(k));
                vst1q_f64(acc.add(k), vaddq_f64(vc, vmulq_f64(va, vb)));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe {
                let prod = *a.add(k) * *b.add(k);
                *acc.add(k) += prod;
            }
            k += 1;
        }
    }

    /// `acc[k] += a[k] * c` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`].
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn fma_lane(acc: *mut f64, a: *const f64, c: f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps both chunks in range.
            unsafe {
                let vcst = vdupq_n_f64(c);
                let va = vld1q_f64(a.add(k));
                let vc = vld1q_f64(acc.add(k));
                vst1q_f64(acc.add(k), vaddq_f64(vc, vmulq_f64(va, vcst)));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe {
                let prod = *a.add(k) * c;
                *acc.add(k) += prod;
            }
            k += 1;
        }
    }

    /// `d[k] = a[k] + b[k]` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`].
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn add(d: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = vld1q_f64(a.add(k));
                let vb = vld1q_f64(b.add(k));
                vst1q_f64(d.add(k), vaddq_f64(va, vb));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe { *d.add(k) = *a.add(k) + *b.add(k) }
            k += 1;
        }
    }

    /// `d[k] = a[k] * b[k]` for `k < n`.
    ///
    /// # Safety
    /// As for [`fma`].
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mul(d: *mut f64, a: *const f64, b: *const f64, n: usize) {
        let mut k = 0;
        while k + LANES <= n {
            // SAFETY: `k + LANES <= n` keeps all three chunks in range.
            unsafe {
                let va = vld1q_f64(a.add(k));
                let vb = vld1q_f64(b.add(k));
                vst1q_f64(d.add(k), vmulq_f64(va, vb));
            }
            k += LANES;
        }
        while k < n {
            // SAFETY: `k < n`.
            unsafe { *d.add(k) = *a.add(k) * *b.add(k) }
            k += 1;
        }
    }

    /// Register-tile outer-product run (see the AVX2 twin for the
    /// traffic and ordering argument).
    ///
    /// # Safety
    /// `m + n*n` in bounds of `t`, every `a + n` / `b + n` in bounds
    /// of `v`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn outer_run(
        v: *const f64,
        t: *mut f64,
        m: usize,
        pairs: &[(u32, u32)],
        n: usize,
    ) {
        let mut j = 0;
        while j + LANES <= n {
            for i in 0..n {
                // SAFETY: chunk `[j, j + LANES)` of tile row `i` and of
                // every `b` vector is in range; all loads precede the
                // single store.
                unsafe {
                    let row = t.add(m + i * n + j);
                    let mut acc = vld1q_f64(row);
                    for &(a, b) in pairs {
                        let ai = vdupq_n_f64(*v.add(a as usize + i));
                        let vb = vld1q_f64(v.add(b as usize + j));
                        acc = vaddq_f64(acc, vmulq_f64(ai, vb));
                    }
                    vst1q_f64(row, acc);
                }
            }
            j += LANES;
        }
        while j < n {
            for i in 0..n {
                // SAFETY: scalar tail element `(i, j)` is in range.
                unsafe {
                    let e = t.add(m + i * n + j);
                    for &(a, b) in pairs {
                        let prod = *v.add(a as usize + i) * *v.add(b as usize + j);
                        *e += prod;
                    }
                }
            }
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::host::HostMachine;
    use crate::kir::ir::{Kernel, KirSink, Marker, MReg, VReg};
    use crate::kir::mem::Arena as _;

    /// The two-group program the compiled-engine tests use, with
    /// adjacent outer products so the run fusion has work to do.
    fn marked_program() -> (HostMachine, Kernel) {
        let mut host = HostMachine::new(8, 16, 2);
        let a = host.alloc(64);
        let b = host.alloc(64);
        let input: Vec<f64> = (0..64).map(|x| 0.25 + x as f64 * 0.75).collect();
        host.write_mem(a, &input);
        let mut k = Kernel::default();
        for g in 0..2usize {
            let marker = Marker::TileGroup { i0: 8 * g as isize, j0: 0, k0: 0, ui: 1, uk: 1 };
            k.emit(Op::Begin(marker));
            k.emit(Op::TileZero { m: MReg(0) });
            k.emit(Op::Load { dst: VReg(0), addr: a + 32 * g });
            k.emit(Op::Load { dst: VReg(1), addr: a + 32 * g + 8 });
            k.emit(Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
            k.emit(Op::Outer { m: MReg(0), a: VReg(1), b: VReg(0) });
            k.emit(Op::Ext { dst: VReg(2), lo: VReg(0), hi: VReg(1), shift: 3 });
            k.emit(Op::Outer { m: MReg(0), a: VReg(2), b: VReg(1) });
            k.emit(Op::Zero { dst: VReg(4) });
            k.emit(Op::Fma { acc: VReg(4), a: VReg(0), b: VReg(1) });
            k.emit(Op::FmaLane { acc: VReg(4), a: VReg(2), b: VReg(1), lane: 5 });
            k.emit(Op::Add { dst: VReg(5), a: VReg(4), b: VReg(2) });
            k.emit(Op::Mul { dst: VReg(5), a: VReg(5), b: VReg(0) });
            k.emit(Op::Store { src: VReg(5), addr: b + 32 * g });
            k.emit(Op::RowStore { m: MReg(0), row: 1, addr: b + 32 * g + 8 });
            k.emit(Op::RowOut { dst: VReg(3), m: MReg(0), row: 2 });
            k.emit(Op::Store { src: VReg(3), addr: b + 32 * g + 16 });
            k.emit(Op::End(marker));
        }
        (host, k)
    }

    #[test]
    fn lowering_fuses_consecutive_outer_runs() {
        let (_, k) = marked_program();
        let plan = SimdPlan::new(&ExecPlan::new(&k.ops, 8, 16, 2));
        let SimdSection::Par(blocks) = &plan.sections[0] else {
            panic!("expected a Par section");
        };
        let runs: Vec<usize> = blocks[0]
            .code
            .iter()
            .filter_map(|sop| match sop {
                SOp::OuterRun { pairs, .. } => Some(pairs.len()),
                SOp::Plain(_) => None,
            })
            .collect();
        // three Outer ops on MReg(0): two adjacent (fused) + one after
        // an Ext (its own run)
        assert_eq!(runs, vec![2, 1]);
    }

    #[test]
    fn simd_matches_interpreter_on_marked_program_at_any_thread_count() {
        let (host, k) = marked_program();
        let mut interp = host.clone();
        interp.run(&k.ops);
        let plan = SimdPlan::new(&ExecPlan::new(&k.ops, 8, 16, 2));
        assert_eq!(plan.par_blocks(), 2);
        for threads in [1usize, 2, 4] {
            let mut mem = host.mem.clone();
            plan.run(&mut mem, threads);
            assert_eq!(mem, interp.mem, "threads={threads}");
        }
    }

    #[test]
    fn forced_scalar_fallback_is_bitwise_identical() {
        let (host, k) = marked_program();
        let plan = SimdPlan::new(&ExecPlan::new(&k.ops, 8, 16, 2));
        let mut native = host.mem.clone();
        plan.run(&mut native, 2);
        force_scalar(true);
        assert_eq!(active_isa(), SimdIsa::Scalar);
        let mut fallback = host.mem.clone();
        plan.run(&mut fallback, 2);
        force_scalar(false);
        assert_eq!(native, fallback);
    }

    #[test]
    fn dispatch_is_counted_per_isa() {
        let (host, k) = marked_program();
        let plan = SimdPlan::new(&ExecPlan::new(&k.ops, 8, 16, 2));
        let isa = active_isa();
        let labels = format!("isa=\"{isa}\"");
        let counter = registry::global().counter_with("stencil_engine_dispatch_total", &labels);
        let before = counter.get();
        let mut mem = host.mem.clone();
        plan.run(&mut mem, 1);
        assert!(counter.get() >= before + 1);
    }

    #[test]
    fn env_override_values_parse() {
        assert!(env_forces_scalar(Some("scalar")));
        assert!(env_forces_scalar(Some(" off ")));
        assert!(env_forces_scalar(Some("0")));
        assert!(!env_forces_scalar(Some("avx2")));
        assert!(!env_forces_scalar(Some("")));
        assert!(!env_forces_scalar(None));
    }

    #[test]
    fn describe_reports_dispatch_and_coverage() {
        let (_, k) = marked_program();
        let plan = SimdPlan::new(&ExecPlan::new(&k.ops, 8, 16, 2));
        let report = plan.describe();
        assert!(report.contains("dispatch="), "{report}");
        assert!(report.contains("outer-run"), "{report}");
        assert!(report.contains("vector-lowered"), "{report}");
        assert!(report.contains(&format!("dispatch={}", active_isa())), "{report}");
    }
}
