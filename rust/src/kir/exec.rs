//! The compiling host engine: KIR programs specialized into fused
//! execution plans instead of interpreted op-by-op.
//!
//! [`HostMachine`](super::host::HostMachine) pays a dispatch (match +
//! field decode + index arithmetic + bounds checks) on **every op for
//! every point**. [`ExecPlan`] removes that overhead while keeping the
//! floating-point work bit-for-bit identical:
//!
//! - the [`fuse`](super::fuse) pass reconstructs the loop nest from the
//!   `Marker` structure and proves which unrolled tile groups are
//!   independent;
//! - every op is lowered once into a resolved [`FOp`] with register
//!   offsets pre-scaled and addresses pre-added, so the hot loop is a
//!   dense jump over small structs whose slice bodies the compiler
//!   auto-vectorizes (contiguous ops become `copy_from_slice` /
//!   chunked mul-add loops);
//! - gather reorganizations become index tables built once per plan (per
//!   (spec, shape) when cached in the serve `PlanCache`) — execution is
//!   a table walk, not per-lane address arithmetic;
//! - independent tile groups of a `Par` section are split across a
//!   scoped thread pool, so a single shard can use every core.
//!
//! **Bitwise contract**: within a block, ops execute in program order
//! with the exact FP operation sequence of the interpreter (same
//! multiply-then-accumulate shapes, same loop orders). Across blocks of
//! a `Par` section, the fuser proved writes disjoint and reads
//! unaffected, so any schedule — any thread count — produces the same
//! memory image. `rust/tests/kir_equivalence.rs` enforces
//! Compiled == Interpret across methods, specs, sizes and 1–4 threads.

use super::fuse::{fuse, Section, SectionMeta};
use super::ir::Op;
use crate::obs::registry::{self, Counter};
use crate::obs::span::{span, span_arg};
use crate::sim::SimConfig;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Live counter of row-group blocks dispatched by `Par` sections —
/// together with `stencil_pool_jobs_total` this shows how much
/// intra-shard parallelism the compiled engine actually exposes.
pub(crate) fn row_groups_counter() -> &'static Counter {
    static C: OnceLock<Counter> = OnceLock::new();
    C.get_or_init(|| registry::global().counter("stencil_kir_row_groups_total"))
}

/// Which host execution engine to use for a KIR program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Engine {
    /// Op-by-op functional interpretation ([`super::host::HostMachine`]);
    /// the reference twin every compiled result is checked against.
    Interpret,
    /// Fused loop nests + precomputed index tables + threaded row groups
    /// ([`ExecPlan`]); bitwise equal to `Interpret`, several times
    /// faster.
    #[default]
    Compiled,
    /// Explicit vector microkernels with runtime ISA dispatch
    /// ([`super::simd::SimdPlan`]): the compiled plan re-lowered to
    /// AVX2 / NEON register-tile kernels, with a scalar fallback
    /// byte-identical to `Compiled`. Bitwise equal to `Interpret` on
    /// every dispatch target.
    Simd,
}

impl fmt::Display for Engine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Engine::Interpret => write!(f, "interpret"),
            Engine::Compiled => write!(f, "compiled"),
            Engine::Simd => write!(f, "simd"),
        }
    }
}

impl FromStr for Engine {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Engine> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "interpret" | "interp" | "interpreter" => Engine::Interpret,
            "compiled" | "compile" | "fused" => Engine::Compiled,
            "simd" | "vector" => Engine::Simd,
            other => anyhow::bail!("unknown engine '{other}' (interpret|compiled|simd)"),
        })
    }
}

/// A resolved instruction: register ids pre-scaled to flat offsets
/// (`d`/`s`/`a`/`b`/`acc` index the vector file, `m*` the tile file),
/// addresses absolute, gathers redirected to index tables.
#[derive(Debug, Clone, Copy)]
pub(crate) enum FOp {
    Load { d: u32, addr: u32 },
    Store { s: u32, addr: u32 },
    Gather { d: u32, tbl: u32 },
    Splat { d: u32, addr: u32 },
    StoreLane { sl: u32, addr: u32 },
    Ext { d: u32, lo: u32, hi: u32, shift: u32 },
    Dup { d: u32, sl: u32 },
    Fma { acc: u32, a: u32, b: u32 },
    FmaLane { acc: u32, a: u32, bl: u32 },
    Add { d: u32, a: u32, b: u32 },
    Mul { d: u32, a: u32, b: u32 },
    Zero { d: u32 },
    TileZero { m: u32 },
    Outer { m: u32, a: u32, b: u32 },
    RowIn { mr: u32, s: u32 },
    RowOut { d: u32, mr: u32 },
    ColIn { m: u32, col: u32, s: u32 },
    ColOut { d: u32, m: u32, col: u32 },
    RowLoad { mr: u32, addr: u32 },
    RowStore { mr: u32, addr: u32 },
}

/// A fused straight-line block.
#[derive(Debug, Clone)]
pub(crate) struct Block {
    pub(crate) code: Vec<FOp>,
}

#[derive(Debug, Clone)]
pub(crate) enum PlanSection {
    /// Independent blocks, executed by a scoped thread pool.
    Par(Vec<Block>),
    /// One block executed in program order.
    Seq(Block),
}

/// A KIR program compiled into a host execution plan.
///
/// Internals are crate-visible so [`super::simd`] can re-lower the
/// resolved stream into vector microkernels without a second builder.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub(crate) vlen: usize,
    pub(crate) n_vregs: usize,
    pub(crate) n_mregs: usize,
    pub(crate) sections: Vec<PlanSection>,
    /// Per-section phase/step labels (parallel to `sections`), carried
    /// from the fuser so spans can name freeze phases and fused steps.
    pub(crate) labels: Vec<SectionMeta>,
    /// Gather index tables (absolute element addresses), deduplicated.
    pub(crate) tables: Vec<Vec<u32>>,
    /// One past the highest element address any op touches.
    pub(crate) mem_hwm: usize,
    /// Non-marker operations in the plan.
    pub(crate) ops: u64,
    /// Blocks eligible for parallel execution.
    pub(crate) par_blocks: usize,
}

impl ExecPlan {
    /// Compile `ops` for a machine with `vlen` lanes and the given
    /// register-file shape.
    pub fn new(ops: &[Op], vlen: usize, n_vregs: usize, n_mregs: usize) -> ExecPlan {
        let fused = fuse(ops, vlen);
        let par_blocks = fused.par_blocks();
        let mut b = Builder {
            vlen,
            tables: Vec::new(),
            table_index: std::collections::HashMap::new(),
            mem_hwm: 0,
            ops: 0,
        };
        let sections = fused
            .sections
            .into_iter()
            .map(|s| match s {
                Section::Par(blocks) => {
                    PlanSection::Par(blocks.iter().map(|ops| b.block(ops)).collect())
                }
                Section::Seq(ops) => PlanSection::Seq(b.block(&ops)),
            })
            .collect();
        ExecPlan {
            vlen,
            n_vregs,
            n_mregs,
            sections,
            labels: fused.labels,
            tables: b.tables,
            mem_hwm: b.mem_hwm,
            ops: b.ops,
            par_blocks,
        }
    }

    /// Compile for the machine shape of `cfg` (the shape
    /// [`super::host::HostMachine::from_config`] builds).
    pub fn from_config(cfg: &SimConfig, ops: &[Op]) -> ExecPlan {
        ExecPlan::new(ops, cfg.vlen, cfg.n_vregs, cfg.n_mregs)
    }

    /// Non-marker operations in the plan.
    pub fn op_count(&self) -> u64 {
        self.ops
    }

    /// Blocks the fuser proved independent (0 ⇒ fully sequential plan).
    pub fn par_blocks(&self) -> usize {
        self.par_blocks
    }

    /// Threads `run` will actually use for `threads` requested (0 = all
    /// available cores), given the plan's parallel structure.
    pub fn effective_threads(&self, threads: usize) -> usize {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        t.min(self.par_blocks.max(1))
    }

    /// Execute the plan over `mem` with up to `threads` worker threads
    /// (0 = one per available core). The result in `mem` is bitwise
    /// independent of the thread count.
    pub fn run(&self, mem: &mut [f64], threads: usize) {
        assert!(
            mem.len() >= self.mem_hwm,
            "memory image too small for plan: {} < {}",
            mem.len(),
            self.mem_hwm
        );
        let threads = self.effective_threads(threads);
        let shared = SharedMem { ptr: mem.as_mut_ptr(), len: mem.len() };
        let mut main_state = ExecState::new(self.vlen, self.n_vregs, self.n_mregs);
        for (si, section) in self.sections.iter().enumerate() {
            let meta = self.labels.get(si).copied().unwrap_or_default();
            let name =
                if meta.phase == Some("freeze") { "kir.freeze" } else { "kir.compute" };
            let _section_span = match meta.step {
                Some((t, _)) => span_arg(name, "kir", ("step", t as f64)),
                None => span(name, "kir"),
            };
            match section {
                PlanSection::Seq(block) => {
                    self.run_block(block, &shared, &mut main_state);
                }
                PlanSection::Par(blocks) => {
                    row_groups_counter().add(blocks.len() as u64);
                    if threads <= 1 || blocks.len() <= 1 {
                        for (bi, block) in blocks.iter().enumerate() {
                            let _g = span_arg("kir.row_group", "kir", ("block", bi as f64));
                            self.run_block(block, &shared, &mut main_state);
                        }
                    } else {
                        let next = AtomicUsize::new(0);
                        let workers = threads.min(blocks.len());
                        std::thread::scope(|scope| {
                            for w in 0..workers {
                                std::thread::Builder::new()
                                    .name(format!("kir-worker-{w}"))
                                    .spawn_scoped(scope, || {
                                        let mut state =
                                            ExecState::new(self.vlen, self.n_vregs, self.n_mregs);
                                        loop {
                                            let i = next.fetch_add(1, Ordering::Relaxed);
                                            let Some(block) = blocks.get(i) else { break };
                                            let _g = span_arg(
                                                "kir.row_group",
                                                "kir",
                                                ("block", i as f64),
                                            );
                                            self.run_block(block, &shared, &mut state);
                                        }
                                    })
                                    .expect("spawn kir worker thread");
                            }
                        });
                    }
                }
            }
        }
    }

    /// Execute one block. All memory accesses are in-bounds (checked
    /// against `mem_hwm` on entry to `run`); concurrent calls only happen
    /// for blocks of one `Par` section, whose memory writes the fuser
    /// proved disjoint from each other and from the other blocks' reads.
    fn run_block(&self, block: &Block, mem: &SharedMem, st: &mut ExecState) {
        let n = self.vlen;
        let ExecState { vregs, mregs, scratch } = st;
        let v = vregs.as_mut_slice();
        let t = mregs.as_mut_slice();
        for fop in &block.code {
            exec_fop(fop, &self.tables, n, mem, v, t, scratch);
        }
    }
}

/// Execute one resolved op with the interpreter's exact FP semantics
/// (multiply then accumulate — two roundings — and the interpreter's
/// loop orders).
///
/// Shared between the compiled engine's block loop and the SIMD
/// engine's scalar fallback ([`super::simd`]), so "the fallback is
/// byte-identical to the compiled path" holds by construction.
#[inline(always)]
pub(crate) fn exec_fop(
    fop: &FOp,
    tables: &[Vec<u32>],
    n: usize,
    mem: &SharedMem,
    v: &mut [f64],
    t: &mut [f64],
    scratch: &mut [f64],
) {
    match *fop {
        FOp::Load { d, addr } => {
            let d = d as usize;
            v[d..d + n].copy_from_slice(mem.read(addr as usize, n));
        }
        FOp::Store { s, addr } => {
            let s = s as usize;
            mem.write(addr as usize, &v[s..s + n]);
        }
        FOp::Gather { d, tbl } => {
            let d = d as usize;
            for (k, &a) in tables[tbl as usize].iter().enumerate() {
                v[d + k] = mem.get(a as usize);
            }
        }
        FOp::Splat { d, addr } => {
            let d = d as usize;
            v[d..d + n].fill(mem.get(addr as usize));
        }
        FOp::StoreLane { sl, addr } => {
            mem.set(addr as usize, v[sl as usize]);
        }
        FOp::Ext { d, lo, hi, shift } => {
            let (d, lo, hi, sh) = (d as usize, lo as usize, hi as usize, shift as usize);
            let sc = &mut scratch[..n];
            sc[..n - sh].copy_from_slice(&v[lo + sh..lo + n]);
            sc[n - sh..].copy_from_slice(&v[hi..hi + sh]);
            v[d..d + n].copy_from_slice(sc);
        }
        FOp::Dup { d, sl } => {
            let d = d as usize;
            let x = v[sl as usize];
            v[d..d + n].fill(x);
        }
        FOp::Fma { acc, a, b } => {
            let (acc, a, b) = (acc as usize, a as usize, b as usize);
            for k in 0..n {
                let prod = v[a + k] * v[b + k];
                v[acc + k] += prod;
            }
        }
        FOp::FmaLane { acc, a, bl } => {
            let (acc, a) = (acc as usize, a as usize);
            let c = v[bl as usize];
            for k in 0..n {
                let prod = v[a + k] * c;
                v[acc + k] += prod;
            }
        }
        FOp::Add { d, a, b } => {
            let (d, a, b) = (d as usize, a as usize, b as usize);
            for k in 0..n {
                v[d + k] = v[a + k] + v[b + k];
            }
        }
        FOp::Mul { d, a, b } => {
            let (d, a, b) = (d as usize, a as usize, b as usize);
            for k in 0..n {
                v[d + k] = v[a + k] * v[b + k];
            }
        }
        FOp::Zero { d } => {
            let d = d as usize;
            v[d..d + n].fill(0.0);
        }
        FOp::TileZero { m } => {
            let m = m as usize;
            t[m..m + n * n].fill(0.0);
        }
        FOp::Outer { m, a, b } => {
            let (m, a, b) = (m as usize, a as usize, b as usize);
            let bv = &v[b..b + n];
            for i in 0..n {
                let ai = v[a + i];
                let row = &mut t[m + i * n..m + (i + 1) * n];
                for (r, &x) in row.iter_mut().zip(bv) {
                    *r += ai * x;
                }
            }
        }
        FOp::RowIn { mr, s } => {
            let (mr, s) = (mr as usize, s as usize);
            t[mr..mr + n].copy_from_slice(&v[s..s + n]);
        }
        FOp::RowOut { d, mr } => {
            let (d, mr) = (d as usize, mr as usize);
            v[d..d + n].copy_from_slice(&t[mr..mr + n]);
        }
        FOp::ColIn { m, col, s } => {
            let (m, col, s) = (m as usize, col as usize, s as usize);
            for i in 0..n {
                t[m + i * n + col] = v[s + i];
            }
        }
        FOp::ColOut { d, m, col } => {
            let (d, m, col) = (d as usize, m as usize, col as usize);
            for i in 0..n {
                v[d + i] = t[m + i * n + col];
            }
        }
        FOp::RowLoad { mr, addr } => {
            let mr = mr as usize;
            t[mr..mr + n].copy_from_slice(mem.read(addr as usize, n));
        }
        FOp::RowStore { mr, addr } => {
            let mr = mr as usize;
            mem.write(addr as usize, &t[mr..mr + n]);
        }
    }
}

/// Per-thread register files (+ EXT scratch).
pub(crate) struct ExecState {
    pub(crate) vregs: Vec<f64>,
    pub(crate) mregs: Vec<f64>,
    pub(crate) scratch: Vec<f64>,
}

impl ExecState {
    pub(crate) fn new(vlen: usize, n_vregs: usize, n_mregs: usize) -> ExecState {
        ExecState {
            vregs: vec![0.0; vlen * n_vregs],
            mregs: vec![0.0; vlen * vlen * n_mregs],
            scratch: vec![0.0; vlen],
        }
    }
}

/// Shared view of the memory image for the duration of one `run`.
///
/// Safety argument: `run` holds the unique `&mut [f64]`, so no other
/// reference to the buffer exists while `SharedMem` is live. All
/// accesses are bounds-checked (debug) and below `mem_hwm ≤ len`
/// (asserted on entry). Concurrent accesses only occur while executing
/// one `Par` section, whose blocks the fuser proved write-disjoint with
/// no cross-block read-write overlap; the transient slices created here
/// therefore never alias a concurrently written region.
pub(crate) struct SharedMem {
    pub(crate) ptr: *mut f64,
    pub(crate) len: usize,
}

unsafe impl Send for SharedMem {}
unsafe impl Sync for SharedMem {}

impl SharedMem {
    #[inline]
    fn read(&self, addr: usize, n: usize) -> &[f64] {
        debug_assert!(addr + n <= self.len);
        unsafe { std::slice::from_raw_parts(self.ptr.add(addr), n) }
    }

    #[inline]
    fn write(&self, addr: usize, src: &[f64]) {
        debug_assert!(addr + src.len() <= self.len);
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.add(addr), src.len()).copy_from_slice(src)
        }
    }

    #[inline]
    fn get(&self, addr: usize) -> f64 {
        debug_assert!(addr < self.len);
        unsafe { *self.ptr.add(addr) }
    }

    #[inline]
    fn set(&self, addr: usize, x: f64) {
        debug_assert!(addr < self.len);
        unsafe { *self.ptr.add(addr) = x }
    }
}

/// Lowers ops to `FOp`s, interning gather tables and tracking the
/// address high-water mark.
struct Builder {
    vlen: usize,
    tables: Vec<Vec<u32>>,
    table_index: std::collections::HashMap<(usize, usize), u32>,
    mem_hwm: usize,
    ops: u64,
}

impl Builder {
    fn block(&mut self, ops: &[Op]) -> Block {
        let code = ops.iter().filter_map(|op| self.lower(op)).collect();
        Block { code }
    }

    fn touch(&mut self, addr: usize, n: usize) -> u32 {
        self.mem_hwm = self.mem_hwm.max(addr + n);
        u32::try_from(addr).expect("element address exceeds u32 range")
    }

    fn table(&mut self, base: usize, stride: usize) -> u32 {
        if let Some(&i) = self.table_index.get(&(base, stride)) {
            return i;
        }
        let last = base + (self.vlen - 1) * stride;
        self.mem_hwm = self.mem_hwm.max(last + 1);
        let table: Vec<u32> = (0..self.vlen)
            .map(|k| u32::try_from(base + k * stride).expect("gather address exceeds u32 range"))
            .collect();
        let i = u32::try_from(self.tables.len()).expect("too many gather tables");
        self.tables.push(table);
        self.table_index.insert((base, stride), i);
        i
    }

    fn lower(&mut self, op: &Op) -> Option<FOp> {
        let n = self.vlen;
        let vr = |r: super::ir::VReg| r.0 as u32 * n as u32;
        let mb = |m: super::ir::MReg| m.0 as u32 * (n * n) as u32;
        if !op.is_marker() {
            self.ops += 1;
        }
        Some(match *op {
            Op::Load { dst, addr } => FOp::Load { d: vr(dst), addr: self.touch(addr, n) },
            Op::Store { src, addr } => FOp::Store { s: vr(src), addr: self.touch(addr, n) },
            Op::Gather { dst, base, stride } => {
                FOp::Gather { d: vr(dst), tbl: self.table(base, stride) }
            }
            Op::Splat { dst, addr } => FOp::Splat { d: vr(dst), addr: self.touch(addr, 1) },
            Op::StoreLane { src, lane, addr } => {
                FOp::StoreLane { sl: vr(src) + lane as u32, addr: self.touch(addr, 1) }
            }
            Op::Ext { dst, lo, hi, shift } => {
                debug_assert!(shift <= n);
                FOp::Ext { d: vr(dst), lo: vr(lo), hi: vr(hi), shift: shift as u32 }
            }
            Op::Dup { dst, src, lane } => FOp::Dup { d: vr(dst), sl: vr(src) + lane as u32 },
            Op::Fma { acc, a, b } => FOp::Fma { acc: vr(acc), a: vr(a), b: vr(b) },
            Op::FmaLane { acc, a, b, lane } => {
                FOp::FmaLane { acc: vr(acc), a: vr(a), bl: vr(b) + lane as u32 }
            }
            Op::Add { dst, a, b } => FOp::Add { d: vr(dst), a: vr(a), b: vr(b) },
            Op::Mul { dst, a, b } => FOp::Mul { d: vr(dst), a: vr(a), b: vr(b) },
            Op::Zero { dst } => FOp::Zero { d: vr(dst) },
            Op::TileZero { m } => FOp::TileZero { m: mb(m) },
            Op::Outer { m, a, b } => FOp::Outer { m: mb(m), a: vr(a), b: vr(b) },
            Op::RowIn { m, row, src } => {
                FOp::RowIn { mr: mb(m) + (row * n) as u32, s: vr(src) }
            }
            Op::RowOut { dst, m, row } => {
                FOp::RowOut { d: vr(dst), mr: mb(m) + (row * n) as u32 }
            }
            Op::ColIn { m, col, src } => FOp::ColIn { m: mb(m), col: col as u32, s: vr(src) },
            Op::ColOut { dst, m, col } => FOp::ColOut { d: vr(dst), m: mb(m), col: col as u32 },
            Op::RowLoad { m, row, addr } => {
                FOp::RowLoad { mr: mb(m) + (row * n) as u32, addr: self.touch(addr, n) }
            }
            Op::RowStore { m, row, addr } => {
                FOp::RowStore { mr: mb(m) + (row * n) as u32, addr: self.touch(addr, n) }
            }
            Op::Begin(_) | Op::End(_) => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::host::HostMachine;
    use crate::kir::ir::{Kernel, KirSink, Marker, MReg, VReg};
    use crate::kir::mem::Arena as _;

    fn engine_roundtrip(s: &str) -> Engine {
        s.parse().unwrap()
    }

    #[test]
    fn engine_parses_and_displays() {
        assert_eq!(engine_roundtrip("interpret"), Engine::Interpret);
        assert_eq!(engine_roundtrip("compiled"), Engine::Compiled);
        assert_eq!(engine_roundtrip("fused"), Engine::Compiled);
        assert_eq!(engine_roundtrip("simd"), Engine::Simd);
        assert_eq!(engine_roundtrip("vector"), Engine::Simd);
        assert_eq!(Engine::Compiled.to_string(), "compiled");
        assert_eq!(Engine::Interpret.to_string(), "interpret");
        assert_eq!(Engine::Simd.to_string(), "simd");
        assert_eq!(Engine::default(), Engine::Compiled);
        assert!("jit".parse::<Engine>().is_err());
    }

    /// Build a tiny program with two independent tile groups, run it on
    /// the interpreter and the plan (1 and 2 threads), compare bitwise.
    #[test]
    fn plan_matches_interpreter_on_marked_program() {
        let mut host = HostMachine::new(8, 16, 2);
        let a = host.alloc(64);
        let b = host.alloc(64);
        let input: Vec<f64> = (0..64).map(|x| 0.25 + x as f64).collect();
        host.write_mem(a, &input);
        let mut k = Kernel::default();
        for g in 0..2usize {
            let marker = Marker::TileGroup { i0: 8 * g as isize, j0: 0, k0: 0, ui: 1, uk: 1 };
            k.emit(Op::Begin(marker));
            k.emit(Op::TileZero { m: MReg(0) });
            k.emit(Op::Load { dst: VReg(0), addr: a + 32 * g });
            k.emit(Op::Load { dst: VReg(1), addr: a + 32 * g + 8 });
            k.emit(Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
            k.emit(Op::Ext { dst: VReg(2), lo: VReg(0), hi: VReg(1), shift: 3 });
            k.emit(Op::Outer { m: MReg(0), a: VReg(2), b: VReg(1) });
            k.emit(Op::RowStore { m: MReg(0), row: 1, addr: b + 32 * g });
            k.emit(Op::RowOut { dst: VReg(3), m: MReg(0), row: 2 });
            k.emit(Op::Store { src: VReg(3), addr: b + 32 * g + 8 });
            k.emit(Op::End(marker));
        }
        let mut interp = host.clone();
        interp.run(&k.ops);

        let plan = ExecPlan::new(&k.ops, 8, 16, 2);
        assert_eq!(plan.par_blocks(), 2);
        assert_eq!(plan.op_count(), 18);
        // registry counter is process-global: assert the delta across
        // these three runs (one Par section × 2 blocks each)
        let groups_before = row_groups_counter().get();
        for threads in [1usize, 2, 4] {
            let mut mem = host.mem.clone();
            plan.run(&mut mem, threads);
            assert_eq!(mem, interp.mem, "threads={threads}");
        }
        assert!(row_groups_counter().get() >= groups_before + 6);
        assert_eq!(plan.effective_threads(2), 2);
        assert_eq!(plan.effective_threads(16), 2); // capped by par blocks
    }

    #[test]
    fn plan_matches_interpreter_on_markerless_program() {
        let mut host = HostMachine::new(8, 8, 1);
        let a = host.alloc(16);
        let out = host.alloc(16);
        host.write_mem(a, &(0..16).map(|x| x as f64 * 0.5).collect::<Vec<_>>());
        let mut k = Kernel::default();
        k.emit(Op::Load { dst: VReg(0), addr: a });
        k.emit(Op::Load { dst: VReg(1), addr: a + 8 });
        k.emit(Op::Zero { dst: VReg(2) });
        k.emit(Op::Fma { acc: VReg(2), a: VReg(0), b: VReg(1) });
        k.emit(Op::Gather { dst: VReg(3), base: a, stride: 2 });
        k.emit(Op::Mul { dst: VReg(3), a: VReg(3), b: VReg(0) });
        k.emit(Op::Add { dst: VReg(2), a: VReg(2), b: VReg(3) });
        k.emit(Op::Splat { dst: VReg(4), addr: a + 3 });
        k.emit(Op::FmaLane { acc: VReg(2), a: VReg(4), b: VReg(1), lane: 5 });
        k.emit(Op::Dup { dst: VReg(5), src: VReg(2), lane: 1 });
        k.emit(Op::Store { src: VReg(2), addr: out });
        k.emit(Op::StoreLane { src: VReg(5), lane: 0, addr: out + 8 });
        let mut interp = host.clone();
        interp.run(&k.ops);
        let plan = ExecPlan::new(&k.ops, 8, 8, 1);
        assert_eq!(plan.par_blocks(), 0);
        let mut mem = host.mem.clone();
        plan.run(&mut mem, 4); // threads irrelevant for a Seq plan
        assert_eq!(mem, interp.mem);
    }

    #[test]
    fn gather_tables_are_interned() {
        let mut k = Kernel::default();
        k.emit(Op::Gather { dst: VReg(0), base: 100, stride: 4 });
        k.emit(Op::Gather { dst: VReg(1), base: 100, stride: 4 });
        k.emit(Op::Gather { dst: VReg(2), base: 200, stride: 4 });
        let plan = ExecPlan::new(&k.ops, 8, 8, 1);
        assert_eq!(plan.tables.len(), 2);
        assert_eq!(plan.tables[0][7], 100 + 7 * 4);
        assert!(plan.mem_hwm > 200 + 7 * 4);
    }

    #[test]
    #[should_panic(expected = "memory image too small")]
    fn undersized_memory_is_rejected() {
        let mut k = Kernel::default();
        k.emit(Op::Load { dst: VReg(0), addr: 100 });
        let plan = ExecPlan::new(&k.ops, 8, 8, 1);
        let mut mem = vec![0.0; 64];
        plan.run(&mut mem, 1);
    }
}
