//! KIR → host execution: a functional interpreter over flat f64 buffers.
//!
//! This is the second backend: the same programs the simulator times are
//! executed *natively* on the CPU — real register files as plain arrays,
//! no cache model, no scoreboard — which is what lets the serving hot
//! path run the paper's outer-product scatter algorithm for real (and
//! what the host wall-clock columns of the bench snapshot measure).
//!
//! Functional semantics are kept operation-for-operation identical to
//! [`crate::sim::Machine::exec`] (same loop orders, same accumulation
//! order), so a program's host output is **bitwise identical** to its
//! simulated output — `rust/tests/kir_equivalence.rs` enforces this
//! across all five generators.

use super::ir::{KirSink, Op};
use super::mem::Arena;
use crate::sim::SimConfig;

/// Guard band in elements around every allocation (mirrors the simulator
/// machine's allocator, so halo reads just outside an array stay mapped
/// and read zeros on both backends).
const GUARD: usize = 64;

/// The host execution backend: memory + register files, no timing.
#[derive(Debug, Clone)]
pub struct HostMachine {
    /// Vector length in f64 lanes.
    pub vlen: usize,
    /// Flat data memory (f64 elements).
    pub mem: Vec<f64>,
    next_alloc: usize,
    /// Flat vector register file (`n_vregs × vlen`).
    vregs: Vec<f64>,
    /// Flat matrix register file (`n_mregs × vlen²`).
    mregs: Vec<f64>,
    /// Scratch for aliasing-safe `Ext`.
    tmp: Vec<f64>,
    /// Non-marker operations executed.
    pub executed: u64,
}

impl HostMachine {
    /// Fresh host machine with explicit register-file shape.
    pub fn new(vlen: usize, n_vregs: usize, n_mregs: usize) -> HostMachine {
        HostMachine {
            vlen,
            mem: Vec::new(),
            next_alloc: 0,
            vregs: vec![0.0; vlen * n_vregs],
            mregs: vec![0.0; vlen * vlen * n_mregs],
            tmp: vec![0.0; vlen.max(8)],
            executed: 0,
        }
    }

    /// Host machine shaped like the simulated machine (`vlen`, register
    /// counts) — programs generated for one run on the other.
    pub fn from_config(cfg: &SimConfig) -> HostMachine {
        HostMachine::new(cfg.vlen, cfg.n_vregs, cfg.n_mregs)
    }

    /// Execute a whole program.
    pub fn run(&mut self, ops: &[Op]) {
        for op in ops {
            self.exec(op);
        }
    }

    /// Execute one operation functionally (markers are skipped).
    pub fn exec(&mut self, op: &Op) {
        let vlen = self.vlen;
        if !op.is_marker() {
            self.executed += 1;
        }
        match *op {
            Op::Load { dst, addr } => {
                let d0 = dst.0 as usize * vlen;
                self.vregs[d0..d0 + vlen].copy_from_slice(&self.mem[addr..addr + vlen]);
            }
            Op::Store { src, addr } => {
                let s0 = src.0 as usize * vlen;
                self.mem[addr..addr + vlen].copy_from_slice(&self.vregs[s0..s0 + vlen]);
            }
            Op::Gather { dst, base, stride } => {
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] = self.mem[base + k * stride];
                }
            }
            Op::Splat { dst, addr } => {
                let v = self.mem[addr];
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(v);
            }
            Op::StoreLane { src, lane, addr } => {
                self.mem[addr] = self.vregs[src.0 as usize * vlen + lane];
            }
            Op::Ext { dst, lo, hi, shift } => {
                debug_assert!(shift <= vlen);
                for k in 0..vlen {
                    let pos = k + shift;
                    self.tmp[k] = if pos < vlen {
                        self.vregs[lo.0 as usize * vlen + pos]
                    } else {
                        self.vregs[hi.0 as usize * vlen + pos - vlen]
                    };
                }
                let d0 = dst.0 as usize * vlen;
                self.vregs[d0..d0 + vlen].copy_from_slice(&self.tmp[..vlen]);
            }
            Op::Dup { dst, src, lane } => {
                let v = self.vregs[src.0 as usize * vlen + lane];
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(v);
            }
            Op::Fma { acc, a, b } => {
                for k in 0..vlen {
                    let prod =
                        self.vregs[a.0 as usize * vlen + k] * self.vregs[b.0 as usize * vlen + k];
                    self.vregs[acc.0 as usize * vlen + k] += prod;
                }
            }
            Op::FmaLane { acc, a, b, lane } => {
                let c = self.vregs[b.0 as usize * vlen + lane];
                for k in 0..vlen {
                    let prod = self.vregs[a.0 as usize * vlen + k] * c;
                    self.vregs[acc.0 as usize * vlen + k] += prod;
                }
            }
            Op::Add { dst, a, b } => {
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] =
                        self.vregs[a.0 as usize * vlen + k] + self.vregs[b.0 as usize * vlen + k];
                }
            }
            Op::Mul { dst, a, b } => {
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] =
                        self.vregs[a.0 as usize * vlen + k] * self.vregs[b.0 as usize * vlen + k];
                }
            }
            Op::Zero { dst } => {
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(0.0);
            }
            Op::TileZero { m } => {
                self.mregs[m.0 as usize * vlen * vlen..(m.0 as usize + 1) * vlen * vlen].fill(0.0);
            }
            Op::Outer { m, a, b } => {
                for i in 0..vlen {
                    let ai = self.vregs[a.0 as usize * vlen + i];
                    for j in 0..vlen {
                        self.mregs[m.0 as usize * vlen * vlen + (i * vlen + j)] +=
                            ai * self.vregs[b.0 as usize * vlen + j];
                    }
                }
            }
            Op::RowIn { m, row, src } => {
                for k in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)] =
                        self.vregs[src.0 as usize * vlen + k];
                }
            }
            Op::RowOut { dst, m, row } => {
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] =
                        self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)];
                }
            }
            Op::ColIn { m, col, src } => {
                for i in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (i * vlen + col)] =
                        self.vregs[src.0 as usize * vlen + i];
                }
            }
            Op::ColOut { dst, m, col } => {
                for i in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + i] =
                        self.mregs[m.0 as usize * vlen * vlen + (i * vlen + col)];
                }
            }
            Op::RowLoad { m, row, addr } => {
                for k in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)] = self.mem[addr + k];
                }
            }
            Op::RowStore { m, row, addr } => {
                for k in 0..vlen {
                    self.mem[addr + k] = self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)];
                }
            }
            Op::Begin(_) | Op::End(_) => {}
        }
    }
}

impl Arena for HostMachine {
    fn vlen(&self) -> usize {
        self.vlen
    }

    /// Same formula as the simulator machine's allocator: vector-aligned
    /// base, `GUARD` elements of zero padding on both sides.
    fn alloc(&mut self, n: usize) -> usize {
        let base = (self.next_alloc + GUARD).div_ceil(self.vlen) * self.vlen;
        self.next_alloc = base + n + GUARD;
        if self.mem.len() < self.next_alloc {
            self.mem.resize(self.next_alloc, 0.0);
        }
        base
    }

    fn write_mem(&mut self, addr: usize, data: &[f64]) {
        self.mem[addr..addr + data.len()].copy_from_slice(data);
    }

    fn read_mem(&self, addr: usize, n: usize) -> &[f64] {
        &self.mem[addr..addr + n]
    }
}

impl KirSink for HostMachine {
    /// Execute-on-emit: generators can stream straight into the host
    /// backend, exactly as they stream into the simulator.
    fn emit(&mut self, op: Op) {
        self.exec(&op);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::ir::{MReg, VReg};

    fn hm() -> HostMachine {
        HostMachine::new(8, 32, 8)
    }

    #[test]
    fn load_fma_store_roundtrip() {
        let mut m = hm();
        let a = m.alloc(8);
        let b = m.alloc(8);
        m.write_mem(a, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        m.exec(&Op::Load { dst: VReg(0), addr: a });
        m.exec(&Op::Load { dst: VReg(1), addr: a });
        m.exec(&Op::Zero { dst: VReg(2) });
        m.exec(&Op::Fma { acc: VReg(2), a: VReg(0), b: VReg(1) });
        m.exec(&Op::Store { src: VReg(2), addr: b });
        assert_eq!(m.read_mem(b, 8), &[1., 4., 9., 16., 25., 36., 49., 64.]);
        assert_eq!(m.executed, 5);
    }

    #[test]
    fn outer_product_accumulates_and_transposes() {
        let mut m = hm();
        let a = m.alloc(8);
        let b = m.alloc(8);
        m.write_mem(a, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        m.write_mem(b, &[10., 20., 30., 40., 50., 60., 70., 80.]);
        m.exec(&Op::Load { dst: VReg(0), addr: a });
        m.exec(&Op::Load { dst: VReg(1), addr: b });
        m.exec(&Op::TileZero { m: MReg(0) });
        m.exec(&Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
        m.exec(&Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
        m.exec(&Op::RowOut { dst: VReg(2), m: MReg(0), row: 2 });
        let c = m.alloc(8);
        m.exec(&Op::Store { src: VReg(2), addr: c });
        let expect: Vec<f64> =
            [10., 20., 30., 40., 50., 60., 70., 80.].iter().map(|x| 6.0 * x).collect();
        assert_eq!(m.read_mem(c, 8), &expect[..]);
        // column read-back transposes
        m.exec(&Op::ColOut { dst: VReg(3), m: MReg(0), col: 1 });
        m.exec(&Op::Store { src: VReg(3), addr: c });
        let expect: Vec<f64> = (1..=8).map(|x| 2.0 * (x as f64) * 20.0).collect();
        assert_eq!(m.read_mem(c, 8), &expect[..]);
    }

    #[test]
    fn ext_assembles_shifted_vectors() {
        let mut m = hm();
        let a = m.alloc(16);
        m.write_mem(a, &(0..16).map(|x| x as f64).collect::<Vec<_>>());
        m.exec(&Op::Load { dst: VReg(0), addr: a });
        m.exec(&Op::Load { dst: VReg(1), addr: a + 8 });
        m.exec(&Op::Ext { dst: VReg(0), lo: VReg(0), hi: VReg(1), shift: 3 });
        let out = m.alloc(8);
        m.exec(&Op::Store { src: VReg(0), addr: out });
        // aliasing-safe: dst == lo
        assert_eq!(m.read_mem(out, 8), &[3., 4., 5., 6., 7., 8., 9., 10.]);
    }

    #[test]
    fn alloc_mirrors_sim_machine() {
        // same allocation sequence → same base addresses on both backends
        use crate::sim::Machine;
        let cfg = SimConfig::default();
        let mut sim = Machine::new(cfg.clone());
        let mut host = HostMachine::from_config(&cfg);
        for n in [100usize, 17, 64, 1000] {
            assert_eq!(Machine::alloc(&mut sim, n), host.alloc(n));
        }
        assert!(host.read_mem(0, 64).iter().all(|&v| v == 0.0));
    }
}
