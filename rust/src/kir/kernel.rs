//! Compiled host kernels: a (stencil, tile shape, method) baked into a
//! KIR program plus the memory image it runs against.
//!
//! This is what `serve` executes for `KernelMethod::Outer` (and for
//! `Tuned` plans the host backend supports): the tile's interior is
//! embedded into a vector-aligned cubic domain, the generator emits the
//! paper's program once at compile time, and every `apply` writes the
//! tile in, executes the program on a clone of the template memory
//! image, and copies the interior back out. The per-output accumulation
//! order of the generated programs depends only on relative offsets —
//! never on where a tile sits in the global grid — so sharded execution
//! is bitwise identical to single-shard execution of the same kernel
//! (enforced in `rust/tests/shard_correctness.rs`).
//!
//! Three engines execute the program ([`Engine`]): the op-by-op
//! interpreter ([`HostMachine`]), the compiling engine
//! ([`super::exec::ExecPlan`], the default), which fuses the unrolled
//! loop nest into straight-line blocks and can split independent row
//! groups across threads, and the explicit-SIMD engine
//! ([`super::simd::SimdPlan`]), which re-lowers the compiled plan to
//! runtime-dispatched vector microkernels. Their outputs are bitwise
//! identical at any thread count.

use super::exec::{Engine, ExecPlan};
use super::host::HostMachine;
use super::ir::{Kernel, Marker, Op, VReg};
use super::mem::PingPong;
use super::simd::SimdPlan;
use crate::codegen::common::{CoeffTable, Layout};
use crate::codegen::{outer, scalar, vectorize, Method};
use crate::obs::span::span;
use crate::scatter::build_cover;
use crate::stencil::{CoeffTensor, DenseGrid, StencilSpec};
use crate::sim::SimConfig;
use std::sync::OnceLock;

/// A host kernel compiled for one (spec, tile shape, method, time-tile
/// depth).
#[derive(Debug, Clone)]
pub struct HostKernel {
    spec: StencilSpec,
    /// Padded cubic domain extent the program was generated for.
    d: usize,
    /// Fused time steps one `apply` advances (1 = classic single sweep).
    steps: usize,
    /// Generated program (markers included; `steps` step regions).
    ops: Vec<Op>,
    /// Grid layout inside the template machine's memory, in the *last*
    /// step's orientation (its `B` side is where the result lands).
    layout: Layout,
    /// The ping-pong plan over the two grid buffers (original
    /// orientation); `layout`'s final orientation is derived from it.
    pong: PingPong,
    /// Memory image with coefficient tables installed and zeroed grids;
    /// cloned per `apply`.
    template: HostMachine,
    /// Compiled execution plan for the (trimmed) program.
    plan: ExecPlan,
    /// SIMD twin of `plan`, lowered lazily on the first `Engine::Simd`
    /// application (clones carry the already-lowered value along).
    simd: OnceLock<SimdPlan>,
    /// Engine `apply` uses (compiled by default).
    engine: Engine,
    /// Plan label (method + parameters) for reports.
    label: String,
}

impl HostKernel {
    /// Compile a single-step host kernel for tiles of storage shape
    /// `tile_shape` (see [`HostKernel::compile_fused`]).
    pub fn compile(
        cfg: &SimConfig,
        spec: StencilSpec,
        tile_shape: &[usize],
        method: Method,
    ) -> anyhow::Result<HostKernel> {
        HostKernel::compile_fused(cfg, spec, tile_shape, method, 1)
    }

    /// Compile a host kernel for tiles of storage shape `tile_shape`
    /// whose every application advances `steps` fused time steps
    /// (temporal blocking).
    ///
    /// The tile's interior (`shape - 2r` per dimension) is embedded in a
    /// cubic domain rounded up to the vector length; `Dlt`/`Tv` are not
    /// compilable as tile kernels (they restructure whole grids) and
    /// return an error.
    ///
    /// For `steps > 1` the generator emits one program per step against
    /// the alternating ping-pong buffer ([`PingPong`]), each step wrapped
    /// in [`Marker::Step`] barriers, and an inter-step *freeze phase*
    /// restores every non-interior location the step may have dirtied
    /// from the read buffer. That keeps the per-step frozen-boundary
    /// contract exact, so a fused `T`-step application is **bitwise
    /// identical** to `T` single-step applications of the same kernel
    /// (property-tested in this module and in
    /// `rust/tests/shard_correctness.rs`).
    pub fn compile_fused(
        cfg: &SimConfig,
        spec: StencilSpec,
        tile_shape: &[usize],
        method: Method,
        steps: usize,
    ) -> anyhow::Result<HostKernel> {
        let r = spec.order;
        anyhow::ensure!(steps >= 1, "a kernel application must advance at least one step");
        anyhow::ensure!(tile_shape.len() == spec.dims, "tile shape does not match {spec}");
        anyhow::ensure!(
            tile_shape.iter().all(|&s| s > 2 * r),
            "degenerate tile {tile_shape:?} for order-{r} stencil"
        );
        anyhow::ensure!(r <= cfg.vlen, "order {r} exceeds the vector length {}", cfg.vlen);
        let interior = tile_shape.iter().map(|&s| s - 2 * r).max().unwrap();
        let d = interior.div_ceil(cfg.vlen) * cfg.vlen;
        let storage = vec![d + 2 * r; spec.dims];
        let zero = DenseGrid::zeros(&storage);
        let mut template = HostMachine::from_config(cfg);
        let mut layout = Layout::alloc(&mut template, spec, &zero);
        let pong = PingPong::new(layout.a_base, layout.b_base);
        let coeffs = CoeffTensor::paper_default(spec);
        // one-time setup: coefficient tables are step-invariant
        let outer_setup = if let Method::Outer(params) = method {
            let cover = build_cover(&coeffs, params.option)?;
            let table = CoeffTable::install_full(&mut template, &coeffs, &cover);
            Some((cover, table, params))
        } else {
            None
        };
        let splat_table = match method {
            Method::AutoVec | Method::Scalar => {
                Some(CoeffTable::install_splats(&mut template, &coeffs))
            }
            Method::Outer(_) => None,
            Method::Dlt | Method::Tv => {
                anyhow::bail!("{method} restructures whole grids and has no tile host kernel")
            }
        };
        let rows = tile_shape[0] - 2 * r;
        let mut ops: Vec<Op> = Vec::new();
        for step in 0..steps {
            if step > 0 {
                layout.swap();
            }
            debug_assert_eq!(layout.a_base, pong.read_base(step));
            debug_assert_eq!(layout.b_base, pong.write_base(step));
            let mut kernel = Kernel::default();
            match method {
                Method::Outer(_) => {
                    let (cover, table, params) = outer_setup.as_ref().unwrap();
                    outer::generate(cfg, &layout, cover, table, *params, &mut kernel)?;
                }
                Method::AutoVec => {
                    vectorize::generate(cfg, &layout, &coeffs, splat_table.as_ref().unwrap(), &mut kernel)?;
                }
                Method::Scalar => {
                    scalar::generate(cfg, &layout, &coeffs, splat_table.as_ref().unwrap(), &mut kernel)?;
                }
                Method::Dlt | Method::Tv => unreachable!("rejected above"),
            }
            // drop the cubic embedding's padded row groups: slab tiles are
            // usually much shorter (dim 0) than the full-width domain, and
            // without trimming every shard would execute the whole d×d(×d)
            // program — total work growing with the shard count
            let step_ops = trim_row_groups(kernel.ops, rows);
            let written = written_row_extent(&step_ops, &layout);
            if steps > 1 {
                ops.push(Op::Begin(Marker::Step { t: step, of: steps }));
            }
            ops.extend(step_ops);
            if step + 1 < steps {
                emit_freeze(&mut ops, cfg, &layout, tile_shape, written);
            }
            if steps > 1 {
                ops.push(Op::End(Marker::Step { t: step, of: steps }));
            }
        }
        let mut label = match method {
            Method::Outer(p) => p.label(spec.dims),
            other => other.to_string(),
        };
        if steps > 1 {
            label.push_str(&format!("-t{steps}"));
        }
        let plan = ExecPlan::from_config(cfg, &ops);
        Ok(HostKernel {
            spec,
            d,
            steps,
            ops,
            layout,
            pong,
            template,
            plan,
            simd: OnceLock::new(),
            engine: Engine::default(),
            label,
        })
    }

    /// Select the engine `apply` uses (compiled by default; the
    /// interpreter is the bitwise-identical reference twin).
    pub fn set_engine(&mut self, engine: Engine) {
        self.engine = engine;
    }

    /// The engine `apply` uses.
    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// Row-group blocks of the compiled plan that may run in parallel.
    pub fn par_blocks(&self) -> usize {
        self.plan.par_blocks()
    }

    /// Non-marker operations in the compiled program.
    pub fn op_count(&self) -> usize {
        self.ops.iter().filter(|o| !o.is_marker()).count()
    }

    /// Padded domain extent the program runs over.
    pub fn domain(&self) -> usize {
        self.d
    }

    /// Fused time steps one `apply` advances.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Plan label (e.g. `p-j8`, `autovec`).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Apply the kernel's `steps` fused time steps to a tile (storage
    /// shape, `r`-deep boundary band frozen per step): interior points
    /// get the stencil result, everything else is copied from the input
    /// — the same per-step contract as the taps kernel, so a fused
    /// application is bitwise identical to `steps` single-step
    /// applications. Tiles too small to have an interior are returned
    /// unchanged. Uses the kernel's configured engine; the compiled
    /// engine picks one thread per available core (see
    /// [`HostKernel::apply_with`] for explicit control).
    ///
    /// Each application clones the template memory image (grids +
    /// tables); for realistic tiles that memcpy is small next to
    /// executing the program itself, and it is what guarantees the
    /// zero padding beyond the tile is fresh every step.
    pub fn apply(&self, a: &DenseGrid) -> DenseGrid {
        self.apply_with(a, self.engine, 0)
    }

    /// [`HostKernel::apply`] with an explicit engine and thread budget
    /// (`threads` = 0 ⇒ one per available core; ignored by the
    /// interpreter). The output is bitwise identical across engines and
    /// thread counts.
    pub fn apply_with(&self, a: &DenseGrid, engine: Engine, threads: usize) -> DenseGrid {
        let r = self.spec.order;
        if a.shape.iter().any(|&s| s <= 2 * r) {
            return a.clone();
        }
        debug_assert_eq!(a.shape.len(), self.spec.dims, "tile does not match kernel");
        match engine {
            Engine::Interpret => {
                let mut m = self.template.clone();
                {
                    let _e = span("kernel.embed", "kernel");
                    self.embed(&mut m.mem, a);
                }
                {
                    // the interpreter runs the whole program as one
                    // compute region (no per-section plan to attribute)
                    let _c = span("kir.compute", "kir");
                    m.run(&self.ops);
                }
                let _x = span("kernel.extract", "kernel");
                self.extract(&m.mem, a)
            }
            Engine::Compiled => {
                let mut mem = self.template.mem.clone();
                {
                    let _e = span("kernel.embed", "kernel");
                    self.embed(&mut mem, a);
                }
                self.plan.run(&mut mem, threads);
                let _x = span("kernel.extract", "kernel");
                self.extract(&mem, a)
            }
            Engine::Simd => {
                let plan = self.simd_plan();
                let mut mem = self.template.mem.clone();
                {
                    let _e = span("kernel.embed", "kernel");
                    self.embed(&mut mem, a);
                }
                plan.run(&mut mem, threads);
                let _x = span("kernel.extract", "kernel");
                self.extract(&mem, a)
            }
        }
    }

    /// The SIMD lowering of the compiled plan, built on first use.
    fn simd_plan(&self) -> &SimdPlan {
        self.simd.get_or_init(|| SimdPlan::new(&self.plan))
    }

    /// Embed the tile: tile storage index t maps to padded storage index
    /// t (domain index t - r); the region beyond stays zero and only
    /// feeds outputs that are discarded on extraction.
    fn embed(&self, mem: &mut [f64], a: &DenseGrid) {
        let ri = self.spec.order as isize;
        let write = |mem: &mut [f64], addr: usize, src: &[f64]| {
            mem[addr..addr + src.len()].copy_from_slice(src);
        };
        match *a.shape.as_slice() {
            [n0, n1] => {
                for i in 0..n0 {
                    let row = &a.data[i * n1..(i + 1) * n1];
                    write(mem, self.layout.a_addr(&[i as isize - ri, -ri]), row);
                    write(mem, self.layout.b_addr(&[i as isize - ri, -ri]), row);
                }
            }
            [n0, n1, n2] => {
                for i in 0..n0 {
                    for j in 0..n1 {
                        let row = &a.data[(i * n1 + j) * n2..(i * n1 + j + 1) * n2];
                        let idx = [i as isize - ri, j as isize - ri, -ri];
                        write(mem, self.layout.a_addr(&idx), row);
                        write(mem, self.layout.b_addr(&idx), row);
                    }
                }
            }
            _ => unreachable!("grids are 2D or 3D"),
        }
    }

    /// Copy the interior back out of the buffer the last fused step
    /// wrote (the layout's `B` side — the ping-pong plan's result
    /// buffer), boundary band taken from the input tile.
    fn extract(&self, mem: &[f64], a: &DenseGrid) -> DenseGrid {
        debug_assert_eq!(self.layout.b_base, self.pong.result_base(self.steps));
        let r = self.spec.order;
        let ri = r as isize;
        let mut b = a.clone();
        match *a.shape.as_slice() {
            [n0, n1] => {
                for i in r..n0 - r {
                    let addr = self.layout.b_addr(&[i as isize - ri, 0]);
                    b.data[i * n1 + r..(i + 1) * n1 - r]
                        .copy_from_slice(&mem[addr..addr + n1 - 2 * r]);
                }
            }
            [n0, n1, n2] => {
                for i in r..n0 - r {
                    for j in r..n1 - r {
                        let addr = self.layout.b_addr(&[i as isize - ri, j as isize - ri, 0]);
                        let base = (i * n1 + j) * n2;
                        b.data[base + r..base + n2 - r]
                            .copy_from_slice(&mem[addr..addr + n2 - 2 * r]);
                    }
                }
            }
            _ => unreachable!(),
        }
        b
    }
}

/// Drop tile groups whose output rows (dimension 0) lie entirely at or
/// beyond `rows`, the tile's real interior extent — the rows only the
/// cubic padding added. Groups are self-contained (every register they
/// consume is loaded inside them, and they touch disjoint output rows),
/// so removing whole groups cannot change the outputs that remain.
/// Generators without structure markers (autovec/scalar) are returned
/// unchanged.
fn trim_row_groups(ops: Vec<Op>, rows: usize) -> Vec<Op> {
    let mut out = Vec::with_capacity(ops.len());
    let mut skip_until: Option<Op> = None;
    for op in ops {
        if let Some(end) = skip_until {
            if op == end {
                skip_until = None;
            }
            continue;
        }
        if let Op::Begin(m) = op {
            if let Marker::TileGroup { i0, .. } = m {
                if i0 >= rows as isize {
                    skip_until = Some(Op::End(m));
                    continue;
                }
            }
        }
        out.push(op);
    }
    out
}

/// One past the highest dim-0 storage row any store in `ops` touches
/// inside the layout's `B` grid (the buffer this step's program writes),
/// or 0 when nothing is written. The inter-step freeze pass only
/// restores rows the program could actually have dirtied — exact, not
/// structural, so it stays correct for markerless generators and for any
/// trimming.
fn written_row_extent(ops: &[Op], layout: &Layout) -> usize {
    let span = if layout.spec.dims == 2 {
        layout.row_stride()
    } else {
        layout.plane_stride()
    };
    let lo = layout.b_base;
    let hi = lo + span * layout.ext;
    let mut w = 0usize;
    for op in ops {
        let addr = match *op {
            Op::Store { addr, .. } | Op::StoreLane { addr, .. } | Op::RowStore { addr, .. } => addr,
            _ => continue,
        };
        if (lo..hi).contains(&addr) {
            w = w.max((addr - lo) / span + 1);
        }
    }
    w
}

/// Emit the inter-step *freeze phase*: restore, in the buffer the step
/// just wrote (`layout.b`), every location the program may have dirtied
/// that is **not** tile interior, copying from the step's read buffer
/// (`layout.a`). Non-interior locations hold their original embed-time
/// values in the read buffer by induction (the previous freeze restored
/// them there), so after this pass the write buffer is exactly what a
/// fresh single-step `embed` would produce: evolved interior, original
/// boundary band, original zero padding. That is what makes a fused
/// application bitwise identical to repeated single-step applications —
/// including for multi-pass programs that read-modify-write `B`, since
/// their pre-step `B` content matches the single-step case everywhere it
/// is read before being written.
///
/// Rows entirely outside the tile interior are restored across the full
/// written width; interior rows only need their tail beyond the tile's
/// unit-stride interior. Copies are whole vectors; overshoot past the
/// written region lands in never-written padding where source and
/// destination already agree. When the tile interior exactly fills the
/// cubic domain in every dimension, nothing is ever dirtied and this
/// emits no ops at all.
fn emit_freeze(
    ops: &mut Vec<Op>,
    cfg: &SimConfig,
    layout: &Layout,
    tile_shape: &[usize],
    written_rows: usize,
) {
    let r = layout.spec.order;
    let d = layout.n;
    let vlen = cfg.vlen;
    // start addresses (domain coordinates) of the ranges to restore
    let mut ranges: Vec<(Vec<isize>, usize)> = Vec::new();
    let mut row_ranges = |idx_prefix: Vec<isize>, tail_only: bool| {
        let last = tile_shape.len() - 1;
        let c0 = if tail_only { tile_shape[last] - r } else { r };
        if c0 < d + r {
            let mut idx = idx_prefix;
            idx.push(c0 as isize - r as isize);
            ranges.push((idx, d + r - c0));
        }
    };
    if layout.spec.dims == 2 {
        for i in r..written_rows {
            let interior_row = i < tile_shape[0] - r;
            row_ranges(vec![i as isize - r as isize], interior_row);
        }
    } else {
        for i in r..written_rows {
            for j in r..d + r {
                let interior_row = i < tile_shape[0] - r && j < tile_shape[1] - r;
                row_ranges(
                    vec![i as isize - r as isize, j as isize - r as isize],
                    interior_row,
                );
            }
        }
    }
    if ranges.is_empty() {
        return;
    }
    // a barrier-separated, self-contained block: the fuser schedules it
    // strictly between this step's compute and the next step's
    let scratch = VReg((cfg.n_vregs - 1) as u8);
    let group = Marker::TileGroup { i0: 0, j0: 0, k0: 0, ui: 1, uk: 1 };
    ops.push(Op::Begin(Marker::Phase("freeze")));
    ops.push(Op::Begin(group));
    for (idx, len) in ranges {
        let mut off = 0usize;
        while off < len {
            let mut at = idx.clone();
            *at.last_mut().unwrap() += off as isize;
            ops.push(Op::Load { dst: scratch, addr: layout.a_addr(&at) });
            ops.push(Op::Store { src: scratch, addr: layout.b_addr(&at) });
            off += vlen;
        }
    }
    ops.push(Op::End(group));
    ops.push(Op::End(Marker::Phase("freeze")));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::OuterParams;
    use crate::stencil::reference;

    fn check_close(spec: StencilSpec, shape: &[usize], method: Method) {
        let cfg = SimConfig::default();
        let k = HostKernel::compile(&cfg, spec, shape, method).unwrap();
        assert!(k.op_count() > 0);
        let a = DenseGrid::verification_input(shape, 42);
        let got = k.apply(&a);
        let want = reference::apply(&CoeffTensor::paper_default(spec), &a);
        let err = got.max_abs_diff_interior(&want, 0);
        assert!(err < 1e-9, "{spec} {method} {shape:?}: max err {err:e}");
        // boundary band is copied, bitwise
        assert_eq!(got.data[0], a.data[0]);
    }

    #[test]
    fn outer_tile_kernel_matches_oracle_2d() {
        check_close(
            StencilSpec::box2d(1),
            &[14, 23],
            Method::Outer(OuterParams::paper_best(StencilSpec::box2d(1))),
        );
        check_close(
            StencilSpec::star2d(2),
            &[17, 12],
            Method::Outer(OuterParams::paper_best(StencilSpec::star2d(2))),
        );
        check_close(
            StencilSpec::diag2d(1),
            &[11, 11],
            Method::Outer(OuterParams::paper_best(StencilSpec::diag2d(1))),
        );
    }

    #[test]
    fn outer_tile_kernel_matches_oracle_3d() {
        check_close(
            StencilSpec::box3d(1),
            &[9, 12, 10],
            Method::Outer(OuterParams::paper_best(StencilSpec::box3d(1))),
        );
        check_close(
            StencilSpec::star3d(2),
            &[11, 9, 13],
            Method::Outer(OuterParams::paper_best(StencilSpec::star3d(2))),
        );
    }

    #[test]
    fn autovec_and_scalar_tile_kernels_work() {
        check_close(StencilSpec::box2d(1), &[12, 19], Method::AutoVec);
        check_close(StencilSpec::star2d(1), &[9, 9], Method::Scalar);
    }

    #[test]
    fn grid_restructuring_methods_are_rejected() {
        let cfg = SimConfig::default();
        assert!(HostKernel::compile(&cfg, StencilSpec::box2d(1), &[12, 12], Method::Dlt).is_err());
        assert!(HostKernel::compile(&cfg, StencilSpec::box2d(1), &[12, 12], Method::Tv).is_err());
        // degenerate tiles are rejected at compile (serve skips them)
        assert!(HostKernel::compile(
            &cfg,
            StencilSpec::box2d(2),
            &[4, 12],
            Method::Outer(OuterParams::paper_best(StencilSpec::box2d(2)))
        )
        .is_err());
    }

    #[test]
    fn padded_row_groups_are_trimmed() {
        // a short, wide slab tile must not pay for the full-width cubic
        // embedding: its kernel keeps only the row groups it needs
        let spec = StencilSpec::box2d(1);
        let cfg = SimConfig::default();
        let method = Method::Outer(OuterParams::paper_best(spec));
        let short = HostKernel::compile(&cfg, spec, &[12, 66], method).unwrap();
        let tall = HostKernel::compile(&cfg, spec, &[66, 66], method).unwrap();
        assert_eq!(short.domain(), tall.domain());
        // 10 interior rows → 2 of 8 row blocks kept
        assert!(
            short.op_count() * 3 < tall.op_count(),
            "short {} vs tall {}",
            short.op_count(),
            tall.op_count()
        );
        // and the trimmed kernel is still correct
        let a = DenseGrid::verification_input(&[12, 66], 3);
        let got = short.apply(&a);
        let want = reference::apply(&CoeffTensor::paper_default(spec), &a);
        assert!(got.max_abs_diff_interior(&want, 0) < 1e-9);
    }

    #[test]
    fn engines_agree_bitwise_across_thread_counts() {
        let cfg = SimConfig::default();
        for (spec, shape) in [
            (StencilSpec::box2d(1), vec![14usize, 23]),
            (StencilSpec::star2d(2), vec![17, 12]),
            (StencilSpec::box3d(1), vec![9, 12, 10]),
        ] {
            let k = HostKernel::compile(
                &cfg,
                spec,
                &shape,
                Method::Outer(OuterParams::paper_best(spec)),
            )
            .unwrap();
            assert_eq!(k.engine(), Engine::Compiled, "compiled is the default");
            assert!(k.par_blocks() > 0, "{spec}: outer kernels carry parallel row groups");
            let a = DenseGrid::verification_input(&shape, 11);
            let want = k.apply_with(&a, Engine::Interpret, 1);
            assert_eq!(k.apply(&a).data, want.data, "{spec}: default apply path");
            for threads in 1..=4usize {
                let got = k.apply_with(&a, Engine::Compiled, threads);
                assert_eq!(got.data, want.data, "{spec} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_apply_is_bitwise_t_single_steps() {
        // the temporal-blocking contract: one fused T-step application ==
        // T single-step applications of the same kernel, bit for bit —
        // across methods, awkward tile shapes (interior != padded domain,
        // which exercises the inter-step freeze phase), and T
        let cfg = SimConfig::default();
        let cases: &[(StencilSpec, &[usize], Method)] = &[
            (
                StencilSpec::box2d(1),
                &[14, 23],
                Method::Outer(OuterParams::paper_best(StencilSpec::box2d(1))),
            ),
            (
                StencilSpec::star2d(2),
                &[17, 12],
                Method::Outer(OuterParams::paper_best(StencilSpec::star2d(2))),
            ),
            (
                StencilSpec::star3d(2),
                &[11, 9, 13],
                Method::Outer(OuterParams::paper_best(StencilSpec::star3d(2))),
            ),
            (
                StencilSpec::box3d(1),
                &[9, 12, 10],
                Method::Outer(OuterParams::paper_best(StencilSpec::box3d(1))),
            ),
            (StencilSpec::box2d(1), &[12, 19], Method::AutoVec),
            (StencilSpec::star2d(1), &[9, 9], Method::Scalar),
        ];
        for &(spec, shape, method) in cases {
            let single = HostKernel::compile(&cfg, spec, shape, method).unwrap();
            let a = DenseGrid::verification_input(shape, 23);
            for t in [2usize, 3, 4] {
                let fused = HostKernel::compile_fused(&cfg, spec, shape, method, t).unwrap();
                assert_eq!(fused.steps(), t);
                let mut want = a.clone();
                for _ in 0..t {
                    want = single.apply(&want);
                }
                let got = fused.apply(&a);
                assert_eq!(got.data, want.data, "{spec} {method} {shape:?} T={t}");
                // both engines, several thread counts: still bitwise
                let interp = fused.apply_with(&a, Engine::Interpret, 1);
                assert_eq!(interp.data, want.data, "{spec} {method} T={t} interp");
                for threads in 1..=4usize {
                    let c = fused.apply_with(&a, Engine::Compiled, threads);
                    assert_eq!(c.data, want.data, "{spec} {method} T={t} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fused_kernels_advertise_steps_and_label() {
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let method = Method::Outer(OuterParams::paper_best(spec));
        let k = HostKernel::compile_fused(&cfg, spec, &[14, 14], method, 4).unwrap();
        assert_eq!(k.steps(), 4);
        assert_eq!(k.label(), "p-j8-t4");
        assert!(k.par_blocks() > 0, "fused outer programs keep their parallel row groups");
        // the single-step compile is untouched
        let k1 = HostKernel::compile(&cfg, spec, &[14, 14], method).unwrap();
        assert_eq!((k1.steps(), k1.label()), (1, "p-j8"));
        assert!(HostKernel::compile_fused(&cfg, spec, &[14, 14], method, 0).is_err());
    }

    #[test]
    fn exact_fit_tiles_need_no_freeze_ops() {
        // when the tile interior exactly fills the cubic domain, the
        // program never dirties a non-interior location, so the fused
        // kernel carries no freeze loads/stores at all: its op count is
        // exactly T × the single-step program
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let method = Method::Outer(OuterParams::paper_best(spec));
        let shape = [18usize, 18]; // interior 16 = 2 × vlen on both dims
        let single = HostKernel::compile(&cfg, spec, &shape, method).unwrap();
        let fused = HostKernel::compile_fused(&cfg, spec, &shape, method, 3).unwrap();
        assert_eq!(fused.op_count(), 3 * single.op_count());
        // an awkward width does need the freeze pass
        let ragged = HostKernel::compile_fused(&cfg, spec, &[18, 15], method, 3).unwrap();
        let ragged1 = HostKernel::compile(&cfg, spec, &[18, 15], method).unwrap();
        assert!(ragged.op_count() > 3 * ragged1.op_count());
    }

    #[test]
    fn apply_is_position_independent() {
        // the same physical subgrid produces bitwise-identical interior
        // results whether applied as a whole or as an embedded tile —
        // the property sharding relies on
        let spec = StencilSpec::box2d(1);
        let cfg = SimConfig::default();
        let full_shape = [20usize, 14];
        let a = DenseGrid::verification_input(&full_shape, 7);
        let kf = HostKernel::compile(
            &cfg,
            spec,
            &full_shape,
            Method::Outer(OuterParams::paper_best(spec)),
        )
        .unwrap();
        let whole = kf.apply(&a);
        // slab rows 6..14 with 1-deep ghost rows = rows 5..15
        let tile_shape = [10usize, 14];
        let mut tile = DenseGrid::zeros(&tile_shape);
        tile.data.copy_from_slice(&a.data[5 * 14..15 * 14]);
        let kt =
            HostKernel::compile(&cfg, spec, &tile_shape, Method::Outer(OuterParams::paper_best(spec)))
                .unwrap();
        let tout = kt.apply(&tile);
        // interior rows of the tile (1..9) line up with whole rows 6..14
        for ti in 1..9usize {
            let wi = ti + 5;
            assert_eq!(
                &tout.data[ti * 14 + 1..(ti + 1) * 14 - 1],
                &whole.data[wi * 14 + 1..(wi + 1) * 14 - 1],
                "row {wi}"
            );
        }
    }
}
