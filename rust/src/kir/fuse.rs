//! Loop-nest reconstruction and independence analysis for the compiling
//! host engine.
//!
//! The generators record their §4.2 unroll structure in the IR as
//! [`Marker`] ops: every unrolled body is a `Begin(TileGroup) .. End`
//! block, and multi-pass programs separate passes with `Phase` markers.
//! This module turns a flat op stream back into that loop nest — a
//! sequence of [`Section`]s, where a `Par` section holds tile-group
//! blocks **proven independent** and a `Seq` section holds ops that must
//! run in program order.
//!
//! Independence is *verified*, never assumed. Every address in a KIR
//! program is a compile-time constant, so the checks are exact:
//!
//! 1. **Register self-containment** — within each block, every vector /
//!    tile register is fully written before it is read (tile registers
//!    tracked per row, so read-modify-write `Outer` accumulation is only
//!    accepted after a `TileZero` / full set of row loads). A block that
//!    passes consumes no register state from outside itself, so it can
//!    run on a private register file.
//! 2. **Memory disjointness** — across the blocks of one candidate
//!    section, write intervals are pairwise disjoint and no block reads
//!    another block's writes (reading your own writes is fine). Gather
//!    footprints are widened to the full `[first, last]` element span,
//!    which is conservative in the safe direction.
//!
//! If any block anywhere fails check 1, the whole program degrades to a
//! single `Seq` section (it may depend on cross-block register flow, so
//! only program order on one register file is safe — exactly the
//! interpreter's execution). If a candidate section fails check 2, that
//! section alone degrades to `Seq`. Either way the engine stays bitwise
//! equal to the interpreter; `Par` is purely a scheduling freedom: its
//! blocks touch disjoint state, so *any* interleaving — including
//! parallel execution across threads — produces bit-identical memory.

use super::ir::{Marker, Op};

/// One executable section of a fused program.
#[derive(Debug, Clone)]
pub enum Section {
    /// Independent blocks: safe to execute in any order or concurrently,
    /// each on a private register file.
    Par(Vec<Vec<Op>>),
    /// Ops executed in program order on one register file.
    Seq(Vec<Op>),
}

/// Structural provenance of one section, captured from the `Phase` /
/// `Step` barrier markers while the loop nest was reconstructed. Purely
/// observational — execution ignores it, but the engine's tracing layer
/// uses it to attribute a section's wall-clock to a named phase
/// (`freeze`) and fused step without re-scanning the op stream.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SectionMeta {
    /// Name of the enclosing `Marker::Phase`, if any (e.g. `"freeze"`).
    pub phase: Option<&'static str>,
    /// Enclosing fused step as `(t, of)`, if the program is temporally
    /// blocked.
    pub step: Option<(usize, usize)>,
}

/// A program reorganized into barrier-separated sections.
#[derive(Debug, Clone)]
pub struct FusedProgram {
    /// Sections in program order (barriers between them).
    pub sections: Vec<Section>,
    /// Per-section provenance, parallel to `sections` (same length).
    pub labels: Vec<SectionMeta>,
}

impl FusedProgram {
    /// Blocks eligible for parallel execution, across all sections.
    pub fn par_blocks(&self) -> usize {
        self.sections
            .iter()
            .map(|s| match s {
                Section::Par(blocks) => blocks.len(),
                Section::Seq(_) => 0,
            })
            .sum()
    }
}

/// Reconstruct the loop nest of `ops` and classify it into sections.
///
/// Programs without tile-group markers (the scalar / autovec / DLT / TV
/// generators), with loose computational ops between groups, or failing
/// the register check collapse to one `Seq` section.
pub fn fuse(ops: &[Op], vlen: usize) -> FusedProgram {
    let whole_seq = || FusedProgram {
        sections: vec![Section::Seq(ops.to_vec())],
        labels: vec![SectionMeta::default()],
    };
    // row masks are u64 bitmaps; wider vectors fall back to the
    // interpreter-order section (none of the supported configs hit this)
    if vlen == 0 || vlen > 64 {
        return whole_seq();
    }
    let Some(candidates) = split_into_group_runs(ops) else {
        return whole_seq();
    };
    if candidates.is_empty() {
        return whole_seq();
    }
    // check 1: every block everywhere must be register-self-contained
    for (run, _) in &candidates {
        for block in run {
            if !self_contained(block, vlen) {
                return whole_seq();
            }
        }
    }
    // check 2: per candidate run, memory disjointness decides Par vs Seq
    let mut sections = Vec::with_capacity(candidates.len());
    let mut labels = Vec::with_capacity(candidates.len());
    for (run, meta) in candidates {
        sections.push(if blocks_memory_disjoint(&run, vlen) {
            Section::Par(run)
        } else {
            Section::Seq(run.concat())
        });
        labels.push(meta);
    }
    FusedProgram { sections, labels }
}

/// Split a marker-structured stream into runs of top-level tile-group
/// blocks, with `Phase` markers acting as barriers between runs; each
/// run is labeled with the phase/step state it was collected under.
/// Returns `None` when the stream has no groups at all or carries
/// computational ops outside any group (those programs run as one
/// `Seq`).
fn split_into_group_runs(ops: &[Op]) -> Option<Vec<(Vec<Vec<Op>>, SectionMeta)>> {
    let mut runs: Vec<(Vec<Vec<Op>>, SectionMeta)> = Vec::new();
    let mut current: Vec<Vec<Op>> = Vec::new();
    let mut meta = SectionMeta::default();
    let mut saw_group = false;
    let mut i = 0;
    let close =
        |current: &mut Vec<Vec<Op>>, runs: &mut Vec<(Vec<Vec<Op>>, SectionMeta)>, meta: SectionMeta| {
            if !current.is_empty() {
                runs.push((std::mem::take(current), meta));
            }
        };
    while i < ops.len() {
        match ops[i] {
            Op::Begin(Marker::TileGroup { .. }) => {
                let end = matching_end(ops, i)?;
                current.push(ops[i..=end].to_vec());
                saw_group = true;
                i = end + 1;
            }
            // phase and fused-step boundaries are barriers: close the
            // current run (step t+1 reads what step t wrote), then track
            // the new phase/step state for the next run's label
            Op::Begin(Marker::Phase(name)) => {
                close(&mut current, &mut runs, meta);
                meta.phase = Some(name);
                i += 1;
            }
            Op::End(Marker::Phase(_)) => {
                close(&mut current, &mut runs, meta);
                meta.phase = None;
                i += 1;
            }
            Op::Begin(Marker::Step { t, of }) => {
                close(&mut current, &mut runs, meta);
                meta.step = Some((t, of));
                i += 1;
            }
            Op::End(Marker::Step { .. }) => {
                close(&mut current, &mut runs, meta);
                meta.step = None;
                i += 1;
            }
            // a computational op outside any group: program order only
            _ => return None,
        }
    }
    close(&mut current, &mut runs, meta);
    saw_group.then_some(runs)
}

/// Index of the `End` matching the `Begin` at `start` (depth-counted).
fn matching_end(ops: &[Op], start: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (i, op) in ops.iter().enumerate().skip(start) {
        match op {
            Op::Begin(_) => depth += 1,
            Op::End(_) => {
                depth = depth.checked_sub(1)?;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Check 1: every register read inside `block` is preceded by a full
/// in-block write of that register (tile registers per row).
fn self_contained(block: &[Op], vlen: usize) -> bool {
    let full: u64 = if vlen == 64 { u64::MAX } else { (1u64 << vlen) - 1 };
    let mut vw = [false; 256]; // vector register fully written
    let mut mw = [0u64; 256]; // tile register written-row bitmap
    let v = |w: &[bool; 256], r: super::ir::VReg| w[r.0 as usize];
    for op in block {
        let ok = match *op {
            Op::Load { dst, .. } | Op::Gather { dst, .. } | Op::Splat { dst, .. } => {
                vw[dst.0 as usize] = true;
                true
            }
            Op::Store { src, .. } | Op::StoreLane { src, .. } => v(&vw, src),
            Op::Ext { dst, lo, hi, .. } => {
                let ok = v(&vw, lo) && v(&vw, hi);
                vw[dst.0 as usize] = true;
                ok
            }
            Op::Dup { dst, src, .. } => {
                let ok = v(&vw, src);
                vw[dst.0 as usize] = true;
                ok
            }
            // FMA forms read-modify-write the accumulator
            Op::Fma { acc, a, b } | Op::FmaLane { acc, a, b, .. } => {
                v(&vw, a) && v(&vw, b) && v(&vw, acc)
            }
            Op::Add { dst, a, b } | Op::Mul { dst, a, b } => {
                let ok = v(&vw, a) && v(&vw, b);
                vw[dst.0 as usize] = true;
                ok
            }
            Op::Zero { dst } => {
                vw[dst.0 as usize] = true;
                true
            }
            Op::TileZero { m } => {
                mw[m.0 as usize] = full;
                true
            }
            // outer accumulation reads and writes the whole tile
            Op::Outer { m, a, b } => v(&vw, a) && v(&vw, b) && mw[m.0 as usize] == full,
            Op::RowIn { m, row, src } => {
                let ok = v(&vw, src);
                mw[m.0 as usize] |= 1 << row;
                ok
            }
            Op::RowOut { dst, m, row } => {
                let ok = mw[m.0 as usize] & (1 << row) != 0;
                vw[dst.0 as usize] = true;
                ok
            }
            // column writes don't complete any row: treat as unsupported
            Op::ColIn { .. } => false,
            Op::ColOut { dst, m, .. } => {
                let ok = mw[m.0 as usize] == full;
                vw[dst.0 as usize] = true;
                ok
            }
            Op::RowLoad { m, row, .. } => {
                mw[m.0 as usize] |= 1 << row;
                true
            }
            Op::RowStore { m, row, .. } => mw[m.0 as usize] & (1 << row) != 0,
            Op::Begin(_) | Op::End(_) => true,
        };
        if !ok {
            return false;
        }
    }
    true
}

/// `[start, end)` memory footprints of one block, merged and sorted.
#[derive(Debug, Default)]
struct Footprint {
    reads: Vec<(usize, usize)>,
    writes: Vec<(usize, usize)>,
}

fn merge(mut v: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
    v.sort_unstable();
    let mut out: Vec<(usize, usize)> = Vec::with_capacity(v.len());
    for (s, e) in v {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

fn footprint(block: &[Op], vlen: usize) -> Footprint {
    let mut f = Footprint::default();
    for op in block {
        match *op {
            Op::Load { addr, .. } | Op::Splat { addr, .. } | Op::RowLoad { addr, .. } => {
                let n = if matches!(op, Op::Splat { .. }) { 1 } else { vlen };
                f.reads.push((addr, addr + n));
            }
            // conservative: the full first..last element span
            Op::Gather { base, stride, .. } => {
                f.reads.push((base, base + (vlen - 1) * stride + 1));
            }
            Op::Store { addr, .. } | Op::RowStore { addr, .. } => {
                f.writes.push((addr, addr + vlen));
            }
            Op::StoreLane { addr, .. } => f.writes.push((addr, addr + 1)),
            _ => {}
        }
    }
    f.reads = merge(f.reads);
    f.writes = merge(f.writes);
    f
}

/// Check 2: writes pairwise disjoint across blocks, and no block reads
/// another block's writes.
fn blocks_memory_disjoint(blocks: &[Vec<Op>], vlen: usize) -> bool {
    let foots: Vec<Footprint> = blocks.iter().map(|b| footprint(b, vlen)).collect();
    // global write list tagged by block
    let mut writes: Vec<(usize, usize, usize)> = Vec::new();
    for (bi, f) in foots.iter().enumerate() {
        writes.extend(f.writes.iter().map(|&(s, e)| (s, e, bi)));
    }
    writes.sort_unstable();
    // overlap scan: per-block lists are merged, so any overlap involves
    // the running maximum-end interval
    let mut max_end = 0usize;
    let mut owner = usize::MAX;
    for &(s, e, bi) in &writes {
        if s < max_end && owner != bi {
            return false;
        }
        if e > max_end {
            max_end = e;
            owner = bi;
        }
    }
    // writes are now known pairwise disjoint → sorted by start implies
    // sorted by end; binary-search reads against them
    for (bi, f) in foots.iter().enumerate() {
        for &(rs, re) in &f.reads {
            // first write with end > rs
            let mut i = writes.partition_point(|&(_, we, _)| we <= rs);
            while i < writes.len() && writes[i].0 < re {
                if writes[i].2 != bi {
                    return false;
                }
                i += 1;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::ir::{MReg, VReg};

    fn group(i0: isize, body: Vec<Op>) -> Vec<Op> {
        let m = Marker::TileGroup { i0, j0: 0, k0: 0, ui: 1, uk: 1 };
        let mut ops = vec![Op::Begin(m)];
        ops.extend(body);
        ops.push(Op::End(m));
        ops
    }

    /// A minimal self-contained group writing `[addr, addr+8)`.
    fn tile_body(addr: usize) -> Vec<Op> {
        vec![
            Op::TileZero { m: MReg(0) },
            Op::Load { dst: VReg(0), addr: addr + 64 },
            Op::Load { dst: VReg(1), addr: addr + 128 },
            Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) },
            Op::RowStore { m: MReg(0), row: 0, addr },
        ]
    }

    #[test]
    fn markerless_program_is_one_seq_section() {
        let ops = vec![Op::Zero { dst: VReg(0) }, Op::Store { src: VReg(0), addr: 0 }];
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 1);
        assert!(matches!(f.sections[0], Section::Seq(ref s) if s.len() == 2));
        assert_eq!(f.par_blocks(), 0);
    }

    #[test]
    fn disjoint_groups_become_one_par_section() {
        let mut ops = group(0, tile_body(1000));
        ops.extend(group(8, tile_body(2000)));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 1);
        match &f.sections[0] {
            Section::Par(blocks) => assert_eq!(blocks.len(), 2),
            Section::Seq(_) => panic!("expected Par"),
        }
        assert_eq!(f.par_blocks(), 2);
    }

    #[test]
    fn phase_markers_are_barriers() {
        let mut ops = group(0, tile_body(1000));
        ops.push(Op::Begin(Marker::Phase("p2")));
        ops.extend(group(0, tile_body(1000))); // overlaps run 1, but barrier-separated
        ops.push(Op::End(Marker::Phase("p2")));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 2);
        assert!(matches!(f.sections[0], Section::Par(ref b) if b.len() == 1));
        assert!(matches!(f.sections[1], Section::Par(ref b) if b.len() == 1));
    }

    #[test]
    fn step_markers_are_barriers() {
        // a fused two-step program: step 2 reads step 1's output row, but
        // the step boundary keeps the runs separate (and ordered) instead
        // of collapsing the program to Seq
        let mut ops = vec![Op::Begin(Marker::Step { t: 0, of: 2 })];
        ops.extend(group(0, tile_body(1000)));
        ops.push(Op::End(Marker::Step { t: 0, of: 2 }));
        ops.push(Op::Begin(Marker::Step { t: 1, of: 2 }));
        let mut body = tile_body(2000);
        body[1] = Op::Load { dst: VReg(0), addr: 1000 }; // reads step 1's write
        ops.extend(group(0, body));
        ops.push(Op::End(Marker::Step { t: 1, of: 2 }));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 2);
        assert!(matches!(f.sections[0], Section::Par(ref b) if b.len() == 1));
        assert!(matches!(f.sections[1], Section::Par(ref b) if b.len() == 1));
        assert_eq!(f.par_blocks(), 2);
    }

    #[test]
    fn section_labels_carry_phase_and_step() {
        let mut ops = vec![Op::Begin(Marker::Step { t: 0, of: 2 })];
        ops.extend(group(0, tile_body(1000)));
        ops.push(Op::Begin(Marker::Phase("freeze")));
        ops.extend(group(8, tile_body(2000)));
        ops.push(Op::End(Marker::Phase("freeze")));
        ops.push(Op::End(Marker::Step { t: 0, of: 2 }));
        ops.push(Op::Begin(Marker::Step { t: 1, of: 2 }));
        ops.extend(group(0, tile_body(3000)));
        ops.push(Op::End(Marker::Step { t: 1, of: 2 }));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 3);
        assert_eq!(f.labels.len(), f.sections.len());
        assert_eq!(f.labels[0], SectionMeta { phase: None, step: Some((0, 2)) });
        assert_eq!(f.labels[1], SectionMeta { phase: Some("freeze"), step: Some((0, 2)) });
        assert_eq!(f.labels[2], SectionMeta { phase: None, step: Some((1, 2)) });
        // degraded programs carry one default label
        let d = fuse(&[Op::Zero { dst: VReg(0) }], 8);
        assert_eq!(d.labels, vec![SectionMeta::default()]);
    }

    #[test]
    fn overlapping_writes_degrade_to_seq() {
        let mut ops = group(0, tile_body(1000));
        ops.extend(group(8, tile_body(1004))); // write ranges overlap
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 1);
        assert!(matches!(f.sections[0], Section::Seq(_)));
    }

    #[test]
    fn read_of_other_groups_write_degrades_to_seq() {
        let mut ops = group(0, tile_body(1000));
        // second group reads the first group's output row
        let mut body = tile_body(2000);
        body[1] = Op::Load { dst: VReg(0), addr: 1000 };
        ops.extend(group(8, body));
        let f = fuse(&ops, 8);
        assert!(matches!(f.sections[0], Section::Seq(_)));
    }

    #[test]
    fn reading_own_write_is_fine() {
        let mut body = tile_body(1000);
        body.push(Op::RowLoad { m: MReg(0), row: 0, addr: 1000 });
        body.push(Op::RowStore { m: MReg(0), row: 0, addr: 1000 });
        let mut ops = group(0, body);
        ops.extend(group(8, tile_body(2000)));
        let f = fuse(&ops, 8);
        assert!(matches!(f.sections[0], Section::Par(ref b) if b.len() == 2));
    }

    #[test]
    fn register_leak_collapses_whole_program() {
        // group 2 reads v5 which it never writes
        let mut ops = group(0, tile_body(1000));
        let mut body = tile_body(2000);
        body[3] = Op::Outer { m: MReg(0), a: VReg(5), b: VReg(1) };
        ops.extend(group(8, body));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 1);
        assert!(matches!(f.sections[0], Section::Seq(ref s) if s.len() == ops.len()));
    }

    #[test]
    fn outer_before_tile_zero_is_not_self_contained() {
        let body = vec![
            Op::Load { dst: VReg(0), addr: 64 },
            Op::Load { dst: VReg(1), addr: 128 },
            Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) },
            Op::RowStore { m: MReg(0), row: 0, addr: 0 },
        ];
        assert!(!self_contained(&body, 8));
        // row loads covering every row also satisfy the RMW requirement
        let mut loaded = Vec::new();
        for row in 0..8 {
            loaded.push(Op::RowLoad { m: MReg(0), row, addr: 512 + row * 8 });
        }
        loaded.extend(body[0..2].to_vec());
        loaded.push(Op::Outer { m: MReg(0), a: VReg(0), b: VReg(1) });
        assert!(self_contained(&loaded, 8));
    }

    #[test]
    fn loose_ops_between_groups_collapse_to_seq() {
        let mut ops = group(0, tile_body(1000));
        ops.push(Op::Zero { dst: VReg(9) });
        ops.extend(group(8, tile_body(2000)));
        let f = fuse(&ops, 8);
        assert_eq!(f.sections.len(), 1);
        assert!(matches!(f.sections[0], Section::Seq(_)));
    }

    #[test]
    fn gather_footprint_is_conservative() {
        // gather strides across another group's write → Seq
        let mut body = tile_body(3000);
        body.push(Op::Gather { dst: VReg(2), base: 990, stride: 8 }); // spans 990..1047
        body.push(Op::Fma { acc: VReg(2), a: VReg(0), b: VReg(1) });
        let mut ops = group(0, tile_body(1000));
        ops.extend(group(8, body));
        let f = fuse(&ops, 8);
        assert!(matches!(f.sections[0], Section::Seq(_)));
    }

    #[test]
    fn merge_coalesces_intervals() {
        assert_eq!(merge(vec![(8, 16), (0, 8), (20, 24)]), vec![(0, 16), (20, 24)]);
        assert_eq!(merge(vec![(0, 4), (2, 6)]), vec![(0, 6)]);
    }
}
