//! §3.5 — minimal cover with axis-parallel coefficient lines.
//!
//! For 2D stencils the minimal axis-parallel line cover reduces to minimum
//! vertex cover of a bipartite graph: interpret the `(2r+1)×(2r+1)`
//! coefficient matrix as an adjacency matrix with `U` = rows, `V` =
//! columns, one edge per non-zero weight. Minimum vertex cover of a
//! bipartite graph equals maximum matching (König's theorem) and both are
//! polynomial; we compute the matching with Hopcroft–Karp and extract the
//! cover with the standard alternating-path construction.

use super::line::CoeffLine;
use crate::stencil::CoeffTensor;
use std::collections::HashSet;

/// A bipartite graph given by adjacency lists from `U` to `V`.
#[derive(Debug, Clone)]
pub struct Bipartite {
    /// Number of `U` vertices.
    pub nu: usize,
    /// Number of `V` vertices.
    pub nv: usize,
    /// `adj[u]` = neighbours of `u` in `V`.
    pub adj: Vec<Vec<usize>>,
}

impl Bipartite {
    /// Build the bipartite graph of a 2D coefficient tensor: `U` = row
    /// offsets, `V` = column offsets (both indexed `0..2r+1`), edges at
    /// non-zero weights.
    pub fn from_coeffs(coeffs: &CoeffTensor) -> Self {
        assert_eq!(coeffs.spec.dims, 2, "König reduction is 2D-only (§3.5)");
        let s = coeffs.spec.side();
        let mut adj = vec![Vec::new(); s];
        for i in 0..s {
            for j in 0..s {
                if coeffs.data[i * s + j] != 0.0 {
                    adj[i].push(j);
                }
            }
        }
        Self { nu: s, nv: s, adj }
    }

    /// Maximum matching via Hopcroft–Karp. Returns (`match_u`, `match_v`)
    /// with `usize::MAX` marking unmatched vertices.
    pub fn hopcroft_karp(&self) -> (Vec<usize>, Vec<usize>) {
        const NIL: usize = usize::MAX;
        let (nu, nv) = (self.nu, self.nv);
        let mut mu = vec![NIL; nu];
        let mut mv = vec![NIL; nv];
        let mut dist = vec![0usize; nu];

        // BFS layering over free U vertices; returns true if an augmenting
        // path exists.
        let bfs = |mu: &[usize], mv: &[usize], dist: &mut [usize]| -> bool {
            let mut q = std::collections::VecDeque::new();
            let inf = usize::MAX;
            for u in 0..nu {
                if mu[u] == NIL {
                    dist[u] = 0;
                    q.push_back(u);
                } else {
                    dist[u] = inf;
                }
            }
            let mut found = false;
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    let w = mv[v];
                    if w == NIL {
                        found = true;
                    } else if dist[w] == inf {
                        dist[w] = dist[u] + 1;
                        q.push_back(w);
                    }
                }
            }
            found
        };

        // DFS along the BFS layering.
        fn dfs(
            g: &Bipartite,
            u: usize,
            mu: &mut [usize],
            mv: &mut [usize],
            dist: &mut [usize],
        ) -> bool {
            const NIL: usize = usize::MAX;
            for idx in 0..g.adj[u].len() {
                let v = g.adj[u][idx];
                let w = mv[v];
                let ok = w == NIL
                    || (dist[w] == dist[u].wrapping_add(1) && dfs(g, w, mu, mv, dist));
                if ok {
                    mu[u] = v;
                    mv[v] = u;
                    return true;
                }
            }
            dist[u] = usize::MAX;
            false
        }

        while bfs(&mu, &mv, &mut dist) {
            for u in 0..nu {
                if mu[u] == NIL {
                    dfs(self, u, &mut mu, &mut mv, &mut dist);
                }
            }
        }
        (mu, mv)
    }

    /// Minimum vertex cover via König's theorem. Returns (`rows`, `cols`):
    /// the `U`-side and `V`-side vertices of the cover.
    pub fn min_vertex_cover(&self) -> (Vec<usize>, Vec<usize>) {
        const NIL: usize = usize::MAX;
        let (mu, mv) = self.hopcroft_karp();
        // Z = vertices reachable by alternating paths from unmatched U.
        let mut zu = vec![false; self.nu];
        let mut zv = vec![false; self.nv];
        let mut stack: Vec<usize> = (0..self.nu).filter(|&u| mu[u] == NIL).collect();
        for &u in &stack {
            zu[u] = true;
        }
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                // travel U→V on non-matching edges
                if mu[u] == v || zv[v] {
                    continue;
                }
                zv[v] = true;
                // travel V→U on matching edges
                let w = mv[v];
                if w != NIL && !zu[w] {
                    zu[w] = true;
                    stack.push(w);
                }
            }
        }
        let rows = (0..self.nu).filter(|&u| !zu[u]).collect();
        let cols = (0..self.nv).filter(|&v| zv[v]).collect();
        (rows, cols)
    }

    /// Brute-force minimum cover size (exponential; test oracle only —
    /// `nu + nv <= 20` keeps this at ~1M subsets).
    pub fn brute_force_cover_size(&self) -> usize {
        let edges: Vec<(usize, usize)> = (0..self.nu)
            .flat_map(|u| self.adj[u].iter().map(move |&v| (u, v)))
            .collect();
        if edges.is_empty() {
            return 0;
        }
        let total = self.nu + self.nv;
        assert!(total <= 20, "brute force oracle limited to small graphs");
        let mut best = total;
        for set in 0u32..(1 << total) {
            let size = set.count_ones() as usize;
            if size >= best {
                continue;
            }
            let covered = edges.iter().all(|&(u, v)| {
                set & (1 << u) != 0 || set & (1 << (self.nu + v)) != 0
            });
            if covered {
                best = size;
            }
        }
        best
    }
}

/// The minimal axis-parallel line cover of a 2D coefficient tensor (§3.5).
///
/// Column-side cover vertices become lines along dimension 0 (contiguous
/// input vectors — preferred), row-side vertices lines along dimension 1;
/// weights at intersections are claimed by the dim-0 lines first.
pub fn minimal_axis_cover_2d(coeffs: &CoeffTensor) -> Vec<CoeffLine> {
    let g = Bipartite::from_coeffs(coeffs);
    let (rows, cols) = g.min_vertex_cover();
    let r = coeffs.spec.order as isize;
    let mut claimed: HashSet<Vec<isize>> = HashSet::new();
    let mut out = Vec::new();
    // dim-0 lines (fixed column offset) first: contiguous A access.
    for &j in &cols {
        let oj = j as isize - r;
        let mut line = CoeffLine::axis(coeffs, 0, &[oj]);
        claim_line(&mut line, &mut claimed, r);
        if line.nonzeros() > 0 {
            out.push(line);
        }
    }
    for &i in &rows {
        let oi = i as isize - r;
        let mut line = CoeffLine::axis(coeffs, 1, &[oi]);
        claim_line(&mut line, &mut claimed, r);
        if line.nonzeros() > 0 {
            out.push(line);
        }
    }
    out
}

fn claim_line(line: &mut CoeffLine, claimed: &mut HashSet<Vec<isize>>, r: isize) {
    for t in -r..=r {
        if line.weights[(t + r) as usize] != 0.0 {
            let pos = line.point(t);
            if !claimed.insert(pos) {
                line.clear_weight(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{StencilKind, StencilSpec};

    fn coeffs_from_mask(r: usize, mask: &[&[u8]]) -> CoeffTensor {
        let spec = StencilSpec::box2d(r);
        let s = spec.side();
        assert_eq!(mask.len(), s);
        let mut c = CoeffTensor { spec, data: vec![0.0; s * s] };
        for i in 0..s {
            for j in 0..s {
                c.data[i * s + j] = if mask[i][j] != 0 { (1 + i * s + j) as f64 } else { 0.0 };
            }
        }
        c
    }

    #[test]
    fn koenig_matches_brute_force_on_shapes() {
        let cases: Vec<CoeffTensor> = vec![
            CoeffTensor::paper_default(StencilSpec::box2d(1)),
            CoeffTensor::paper_default(StencilSpec::box2d(2)),
            CoeffTensor::paper_default(StencilSpec::star2d(1)),
            CoeffTensor::paper_default(StencilSpec::star2d(3)),
            CoeffTensor::paper_default(StencilSpec::diag2d(1)),
            CoeffTensor::paper_default(StencilSpec::diag2d(2)),
            coeffs_from_mask(1, &[&[1, 0, 1], &[0, 0, 0], &[1, 0, 1]]),
            coeffs_from_mask(2, &[
                &[1, 0, 0, 0, 1],
                &[0, 0, 1, 0, 0],
                &[0, 1, 1, 1, 0],
                &[0, 0, 1, 0, 0],
                &[1, 0, 0, 0, 1],
            ]),
        ];
        for c in cases {
            let g = Bipartite::from_coeffs(&c);
            let (rows, cols) = g.min_vertex_cover();
            let (mu, _) = g.hopcroft_karp();
            let matching = mu.iter().filter(|&&v| v != usize::MAX).count();
            // König: |min cover| == |max matching|
            assert_eq!(rows.len() + cols.len(), matching);
            assert_eq!(matching, g.brute_force_cover_size());
            // and the cover actually covers every edge
            for u in 0..g.nu {
                for &v in &g.adj[u] {
                    assert!(rows.contains(&u) || cols.contains(&v));
                }
            }
        }
    }

    #[test]
    fn star_minimal_cover_is_two_lines() {
        for r in 1..=3 {
            let c = CoeffTensor::paper_default(StencilSpec::star2d(r));
            let lines = minimal_axis_cover_2d(&c);
            assert_eq!(lines.len(), 2, "r={r}");
        }
    }

    #[test]
    fn box_minimal_cover_is_2r_plus_1_lines() {
        for r in 1..=3 {
            let c = CoeffTensor::paper_default(StencilSpec::box2d(r));
            let lines = minimal_axis_cover_2d(&c);
            assert_eq!(lines.len(), 2 * r + 1, "r={r}");
        }
    }

    #[test]
    fn minimal_cover_reconstructs() {
        use crate::scatter::line::LineCover;
        for spec in [
            StencilSpec::box2d(2),
            StencilSpec::star2d(2),
            StencilSpec::new(2, 1, StencilKind::Diagonal).unwrap(),
        ] {
            let c = CoeffTensor::paper_default(spec);
            let cover = LineCover { spec, lines: minimal_axis_cover_2d(&c) };
            assert!(cover.reconstructs(&c), "{spec}");
        }
    }

    #[test]
    fn diagonal_stencil_axis_cover_needs_2r_plus_1_lines() {
        // The diagonal stencil's nonzeros form a permutation-like pattern:
        // every row has a nonzero, so the axis-parallel minimum is large —
        // exactly why Eq. (16) introduces diagonal lines instead.
        let c = CoeffTensor::paper_default(StencilSpec::diag2d(1));
        let g = Bipartite::from_coeffs(&c);
        assert_eq!(g.brute_force_cover_size(), 3);
    }

    #[test]
    fn empty_graph_cover_is_zero() {
        let spec = StencilSpec::box2d(1);
        let c = CoeffTensor { spec, data: vec![0.0; 9] };
        let g = Bipartite::from_coeffs(&c);
        assert_eq!(g.brute_force_cover_size(), 0);
        let (rows, cols) = g.min_vertex_cover();
        assert!(rows.is_empty() && cols.is_empty());
    }

    /// The cover property, checked positionally: every non-zero footprint
    /// weight belongs to exactly one line (with its original value), and
    /// no line carries weight at a zero position.
    fn assert_exactly_once(coeffs: &CoeffTensor, lines: &[CoeffLine]) {
        let s = coeffs.spec.side();
        let r = coeffs.spec.order as isize;
        let mut owners = vec![0usize; s * s];
        let mut sums = vec![0.0f64; s * s];
        for line in lines {
            for t in -r..=r {
                let w = line.weights[(t + r) as usize];
                if w != 0.0 {
                    let p = line.point(t);
                    let idx = ((p[0] + r) * s as isize + (p[1] + r)) as usize;
                    owners[idx] += 1;
                    sums[idx] += w;
                }
            }
        }
        for idx in 0..s * s {
            if coeffs.data[idx] != 0.0 {
                assert_eq!(owners[idx], 1, "position {idx} covered {} times", owners[idx]);
                assert_eq!(sums[idx], coeffs.data[idx], "position {idx} weight changed");
            } else {
                assert_eq!(owners[idx], 0, "zero position {idx} got a weight");
            }
        }
    }

    /// Random 2D coefficient tensor: box-spec container, random non-zero
    /// mask (at least the centre), random non-zero weights.
    fn random_coeffs(rng: &mut crate::util::prop::Rng, r: usize) -> CoeffTensor {
        let spec = StencilSpec::box2d(r);
        let s = spec.side();
        let mut data = vec![0.0f64; s * s];
        for w in data.iter_mut() {
            if rng.below(3) == 0 {
                let mut v = rng.f64();
                if v == 0.0 {
                    v = 0.5;
                }
                *w = v;
            }
        }
        let centre = (s / 2) * s + s / 2;
        if data.iter().all(|w| *w == 0.0) {
            data[centre] = 1.0;
        }
        CoeffTensor { spec, data }
    }

    #[test]
    fn minimal_cover_covers_every_weight_exactly_once_up_to_order_4() {
        // deterministic paper shapes, orders 1..=4
        for r in 1..=4usize {
            for spec in [StencilSpec::box2d(r), StencilSpec::star2d(r), StencilSpec::diag2d(r)] {
                let c = CoeffTensor::paper_default(spec);
                assert_exactly_once(&c, &minimal_axis_cover_2d(&c));
            }
        }
        // random masks and weights
        crate::util::prop::cases(60, 0x2D11, |rng| {
            let c = random_coeffs(rng, rng.range(1, 4));
            assert_exactly_once(&c, &minimal_axis_cover_2d(&c));
        });
    }

    #[test]
    fn minimal_cover_line_count_is_koenig_minimum_up_to_order_4() {
        // König: |min cover| = |max matching|; the line construction drops
        // nothing (no minimum-cover vertex is redundant), so the line
        // count must equal the matching size — and, for orders where the
        // brute-force oracle is tractable, the true minimum.
        for r in 1..=4usize {
            for spec in [StencilSpec::box2d(r), StencilSpec::star2d(r), StencilSpec::diag2d(r)] {
                let c = CoeffTensor::paper_default(spec);
                let lines = minimal_axis_cover_2d(&c);
                let g = Bipartite::from_coeffs(&c);
                let (mu, _) = g.hopcroft_karp();
                let matching = mu.iter().filter(|&&v| v != usize::MAX).count();
                assert_eq!(lines.len(), matching, "{spec}");
                if r <= 3 {
                    assert_eq!(lines.len(), g.brute_force_cover_size(), "{spec}");
                }
                // closed forms (§3.5): star needs 2 lines, box and the
                // permutation-patterned diagonal need 2r+1
                let want = match spec.kind {
                    StencilKind::Star => 2,
                    _ => 2 * r + 1,
                };
                assert_eq!(lines.len(), want, "{spec}");
            }
        }
        // random masks, orders the brute-force oracle handles quickly
        crate::util::prop::cases(40, 0x2D12, |rng| {
            let c = random_coeffs(rng, rng.range(1, 2));
            let lines = minimal_axis_cover_2d(&c);
            let g = Bipartite::from_coeffs(&c);
            assert_eq!(lines.len(), g.brute_force_cover_size());
        });
    }
}
