//! The paper's §3 contribution: the scatter-mode, outer-product stencil
//! formulation.
//!
//! - [`line`] — coefficient lines (the "essential concept underlying the
//!   basic formula", §3.3) and their expansion into the shifted coefficient
//!   vectors of Eq. (9)–(12).
//! - [`options`] — the coefficient-line cover options of §4.1 / Table 1 &
//!   Table 2: parallel, orthogonal, hybrid, plus diagonal covers (Eq. (15))
//!   and the minimal axis-parallel cover.
//! - [`cover`] — §3.5: minimal axis-parallel line cover via minimum vertex
//!   cover of a bipartite graph (Hopcroft–Karp matching + König's theorem).
//! - [`analysis`] — §3.4 instruction-count theory (`2r+1 → 2r/n + 1` per
//!   output vector) and the Table 1 / Table 2 outer-product counts.

pub mod analysis;
pub mod cover;
pub mod line;
pub mod options;

pub use line::{CoeffLine, LineCover};
pub use options::{build_cover, CoverOption};
