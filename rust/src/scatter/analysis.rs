//! §3.4 — theoretical instruction-count analysis.
//!
//! Vectorization needs `#nonzeros / n` FMA instructions per `n` outputs;
//! the outer-product method needs `Σ_lines (2r + n) / n`-ish outer products
//! (each line with all `2r+1` weights yields `2r + n` coefficient vectors;
//! single-weight lines yield `n`). The paper's headline: per output
//! *vector*, box stencils drop from `2r + 1` (vector FMAs per line ×
//! lines… i.e. `(2r+1)^d / n^(d-1)`-style counts collapse) to `2r/n + 1`.

use super::line::LineCover;
use super::options::{build_cover, CoverOption};
use crate::stencil::{CoeffTensor, StencilSpec};

/// Closed-form and measured instruction counts for one (spec, option, n).
#[derive(Debug, Clone)]
pub struct InstrAnalysis {
    /// Stencil analyzed.
    pub spec: StencilSpec,
    /// Cover option analyzed.
    pub option: CoverOption,
    /// Output-block extent `n` (the matrix-register side).
    pub n: usize,
    /// Vector-FMA instructions per output vector for plain vectorization
    /// (= number of non-zero weights, one FMA each per output vector).
    pub vec_fma_per_outvec: f64,
    /// Outer products per output vector for this cover (counted from the
    /// actual expansion, Table 1 / Table 2 semantics).
    pub outer_per_outvec: f64,
    /// The paper's asymptotic per-output-vector count `2r/n + 1` scaled by
    /// the number of *full* lines (box: `2r+1` lines ⇒
    /// `(2r+1)(2r+n)/n / (2r+1) = (2r+n)/n` per line).
    pub paper_asymptote: f64,
    /// `vec_fma_per_outvec / outer_per_outvec` — the theoretical speedup
    /// upper bound from instruction counts alone.
    pub instr_ratio: f64,
}

/// Outer products per output vector, from the expanded cover.
///
/// An `n×n` output block holds `n` output vectors, and a cover expansion
/// covers the whole block, so the per-vector count is
/// `cover.outer_products(n) / n`.
pub fn outer_per_outvec(cover: &LineCover, n: usize) -> f64 {
    cover.outer_products(n) as f64 / n as f64
}

/// Run the analysis for one configuration.
pub fn analyze(spec: StencilSpec, option: CoverOption, n: usize) -> anyhow::Result<InstrAnalysis> {
    let coeffs = CoeffTensor::paper_default(spec);
    let cover = build_cover(&coeffs, option)?;
    let r = spec.order as f64;
    let nf = n as f64;
    Ok(InstrAnalysis {
        spec,
        option,
        n,
        vec_fma_per_outvec: spec.nonzero_points() as f64,
        outer_per_outvec: outer_per_outvec(&cover, n),
        paper_asymptote: cover.len() as f64 * (2.0 * r / nf + 1.0),
        instr_ratio: spec.nonzero_points() as f64 / outer_per_outvec(&cover, n),
    })
}

/// The paper's §3.4 claim for box stencils: average instructions per output
/// vector drop from `2r + 1` *per line* to `2r/n + 1` per line.
pub fn box_per_line_reduction(r: usize, n: usize) -> (f64, f64) {
    ((2 * r + 1) as f64, 2.0 * r as f64 / n as f64 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box2d_outer_count_matches_eq12() {
        // Eq. (12): (2r+1)(2r+n) outer products per n×n block.
        for r in 1..=3 {
            let spec = StencilSpec::box2d(r);
            let coeffs = CoeffTensor::paper_default(spec);
            let cover = build_cover(&coeffs, CoverOption::Parallel).unwrap();
            let n = 8;
            assert_eq!(cover.outer_products(n), (2 * r + 1) * (2 * r + n));
        }
    }

    #[test]
    fn box_per_outvec_is_paper_formula() {
        // (2r+1)(2r+n)/n per output vector == (2r+1) * (2r/n + 1).
        for r in 1..=3 {
            for n in [4usize, 8, 16] {
                let a = analyze(StencilSpec::box2d(r), CoverOption::Parallel, n).unwrap();
                let expect = (2 * r + 1) as f64 * (2.0 * r as f64 / n as f64 + 1.0);
                assert!((a.outer_per_outvec - expect).abs() < 1e-12);
                assert!((a.paper_asymptote - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn instruction_ratio_grows_with_n() {
        // As n grows, outer products per output vector fall toward 2r+1
        // per cover, so the ratio improves — the 1/n decrease of §3.4.
        let r4 = analyze(StencilSpec::box2d(1), CoverOption::Parallel, 4).unwrap();
        let r8 = analyze(StencilSpec::box2d(1), CoverOption::Parallel, 8).unwrap();
        let r16 = analyze(StencilSpec::box2d(1), CoverOption::Parallel, 16).unwrap();
        assert!(r4.instr_ratio < r8.instr_ratio);
        assert!(r8.instr_ratio < r16.instr_ratio);
    }

    #[test]
    fn star_parallel_vs_orthogonal_growth_rates() {
        // §5.2 / Table 1: parallel grows O(n) with r (adds 2r·n), the
        // orthogonal option grows O(1) (adds 4r per extra order). Check the
        // *difference* between r and r+1 for both options.
        let n = 8;
        let d = |opt: CoverOption, r: usize| {
            let a = analyze(StencilSpec::star2d(r), opt, n).unwrap();
            let b = analyze(StencilSpec::star2d(r + 1), opt, n).unwrap();
            (b.outer_per_outvec - a.outer_per_outvec) * n as f64
        };
        let dp = d(CoverOption::Parallel, 1);
        let dq = d(CoverOption::Orthogonal, 1);
        assert!(dp > dq, "parallel should grow faster ({dp} vs {dq})");
        assert!((dp - (2.0 * n as f64 + 2.0)).abs() < 1e-9); // 2n + 2
        assert!(dq <= 4.0 + 1e-9); // O(1) in n
    }

    #[test]
    fn star3d_hybrid_between_parallel_and_orthogonal() {
        for r in 1..=3 {
            let n = 8;
            let p = analyze(StencilSpec::star3d(r), CoverOption::Parallel, n).unwrap();
            let o = analyze(StencilSpec::star3d(r), CoverOption::Orthogonal, n).unwrap();
            let h = analyze(StencilSpec::star3d(r), CoverOption::Hybrid, n).unwrap();
            assert!(
                o.outer_per_outvec <= h.outer_per_outvec + 1e-9
                    && h.outer_per_outvec <= p.outer_per_outvec + 1e-9,
                "r={r}: o={} h={} p={}",
                o.outer_per_outvec,
                h.outer_per_outvec,
                p.outer_per_outvec
            );
        }
    }

    #[test]
    fn per_line_reduction_formula() {
        let (before, after) = box_per_line_reduction(1, 8);
        assert_eq!(before, 3.0);
        assert_eq!(after, 1.25);
    }
}
