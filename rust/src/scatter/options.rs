//! Coefficient-line cover options (§4.1, Table 1 & Table 2).
//!
//! A cover assigns every non-zero footprint weight to exactly one line.
//! Options differ in how many lines they use (fewer lines → fewer outer
//! products) versus how memory-friendly the induced input-vector accesses
//! are (lines along non-unit-stride dimensions read contiguous `A`
//! vectors; a line along the unit-stride dimension forces strided /
//! transposed input vectors — §4.1's trade-off).

use super::cover::minimal_axis_cover_2d;
use super::line::{CoeffLine, LineCover};
use crate::stencil::{CoeffTensor, StencilKind, StencilSpec};

use std::collections::HashSet;
use std::fmt;
use std::str::FromStr;

/// Which cover of the non-zero weights to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoverOption {
    /// All lines parallel to one non-unit-stride dimension (Table 1 row 1,
    /// Table 2 row 1). Works for every stencil shape; the only option for
    /// box stencils.
    Parallel,
    /// Star stencils: one full line per dimension through the centre
    /// (Table 1 row 2, Table 2 row 2). Minimal outer products, strided
    /// input vectors for the unit-stride-dim line, and (3D) two output
    /// tile orientations.
    Orthogonal,
    /// 3D star: middle-plane parallel lines + one unit-stride-dim line
    /// (Table 2 row 3). Single output tile orientation, intermediate
    /// outer-product count.
    Hybrid,
    /// 2D: the provably minimal axis-parallel cover via König's theorem
    /// (§3.5).
    MinimalAxis,
    /// 2D diagonal stencils: the two diagonal lines of Eq. (15)/(16).
    Diagonals,
}

impl CoverOption {
    /// Short label used in Table 3 annotations (`p`, `o`, `h`, ...).
    pub fn label(&self) -> &'static str {
        match self {
            CoverOption::Parallel => "p",
            CoverOption::Orthogonal => "o",
            CoverOption::Hybrid => "h",
            CoverOption::MinimalAxis => "m",
            CoverOption::Diagonals => "d",
        }
    }

    /// The options that are legal for a given stencil.
    pub fn applicable(spec: StencilSpec) -> Vec<CoverOption> {
        let mut v = vec![CoverOption::Parallel];
        if spec.kind == StencilKind::Star {
            v.push(CoverOption::Orthogonal);
            if spec.dims == 3 {
                v.push(CoverOption::Hybrid);
            }
        }
        if spec.kind == StencilKind::Diagonal {
            v.push(CoverOption::Diagonals);
        }
        if spec.dims == 2 {
            v.push(CoverOption::MinimalAxis);
        }
        v
    }
}

impl fmt::Display for CoverOption {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", format!("{self:?}").to_lowercase())
    }
}

impl FromStr for CoverOption {
    type Err = anyhow::Error;

    /// Parse a cover-option name: the lowercase `Display` form or the
    /// one-letter `label` (`parallel`/`p`, `orthogonal`/`o`, `hybrid`/`h`,
    /// `minimalaxis`/`minimal`/`m`, `diagonals`/`d`).
    fn from_str(s: &str) -> anyhow::Result<CoverOption> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "parallel" | "p" => CoverOption::Parallel,
            "orthogonal" | "o" => CoverOption::Orthogonal,
            "hybrid" | "h" => CoverOption::Hybrid,
            "minimalaxis" | "minimal" | "m" => CoverOption::MinimalAxis,
            "diagonals" | "d" => CoverOption::Diagonals,
            other => anyhow::bail!(
                "unknown cover option '{other}' (parallel|orthogonal|hybrid|minimalaxis|diagonals)"
            ),
        })
    }
}

/// Build the requested cover for a coefficient tensor.
///
/// Returns an error when the option is not applicable to the stencil shape
/// (e.g. `Orthogonal` for a box stencil cannot cover the corner weights).
pub fn build_cover(coeffs: &CoeffTensor, option: CoverOption) -> anyhow::Result<LineCover> {
    let spec = coeffs.spec;
    anyhow::ensure!(
        CoverOption::applicable(spec).contains(&option),
        "cover option {option:?} is not applicable to {spec}"
    );
    let lines = match (option, spec.dims) {
        (CoverOption::Parallel, 2) => parallel_lines(coeffs, 0),
        (CoverOption::Parallel, 3) => parallel_lines(coeffs, 1),
        (CoverOption::Orthogonal, 2) => {
            // CLS(*, r) then CLS(r, *) — Table 1.
            claim(coeffs, vec![proto_axis(0, &[0]), proto_axis(1, &[0])])
        }
        (CoverOption::Orthogonal, 3) => {
            // CLS(r, *, r), CLS(*, r, r), CLS(r, r, *) — Table 2.
            claim(
                coeffs,
                vec![proto_axis(1, &[0, 0]), proto_axis(0, &[0, 0]), proto_axis(2, &[0, 0])],
            )
        }
        (CoverOption::Hybrid, 3) => {
            // CLS(i, *, r) for all i, plus CLS(r, r, *) — Table 2.
            let r = spec.order as isize;
            let mut protos: Vec<(usize, Vec<isize>)> =
                (-r..=r).map(|oi| proto_axis(1, &[oi, 0])).collect();
            protos.push(proto_axis(2, &[0, 0]));
            claim(coeffs, protos)
        }
        (CoverOption::Diagonals, 2) => {
            let mut main = CoeffLine::diagonal(coeffs, false);
            let mut anti = CoeffLine::diagonal(coeffs, true);
            // centre is shared; give it to the main diagonal
            anti.clear_weight(0);
            // For r >= 1 the diagonals only intersect at the centre.
            let lines: Vec<CoeffLine> =
                [main.take_if_nonzero(), anti.take_if_nonzero()].into_iter().flatten().collect();
            lines
        }
        (CoverOption::MinimalAxis, 2) => minimal_axis_cover_2d(coeffs),
        _ => unreachable!("applicability checked above"),
    };
    let cover = LineCover { spec, lines };
    anyhow::ensure!(
        cover.reconstructs(coeffs),
        "internal error: {option:?} cover does not reconstruct {spec}"
    );
    Ok(cover)
}

impl CoeffLine {
    fn take_if_nonzero(&mut self) -> Option<CoeffLine> {
        if self.nonzeros() > 0 {
            Some(self.clone())
        } else {
            None
        }
    }
}

/// `(dim, fixed)` prototype for an axis line, consumed by [`claim`].
fn proto_axis(dim: usize, fixed: &[isize]) -> (usize, Vec<isize>) {
    (dim, fixed.to_vec())
}

/// Build lines in priority order; each footprint position is claimed by the
/// first line containing it (later lines get that weight zeroed). Lines that
/// end up all-zero are dropped.
fn claim(coeffs: &CoeffTensor, protos: Vec<(usize, Vec<isize>)>) -> Vec<CoeffLine> {
    let r = coeffs.spec.order as isize;
    let mut claimed: HashSet<Vec<isize>> = HashSet::new();
    let mut out = Vec::new();
    for (dim, fixed) in protos {
        let mut line = CoeffLine::axis(coeffs, dim, &fixed);
        for t in -r..=r {
            let pos = line.point(t);
            if line.weights[(t + r) as usize] != 0.0 {
                if claimed.contains(&pos) {
                    line.clear_weight(t);
                } else {
                    claimed.insert(pos);
                }
            }
        }
        if line.nonzeros() > 0 {
            out.push(line);
        }
    }
    out
}

/// All lines parallel to `line_dim`, one per combination of fixed offsets
/// that contains at least one non-zero weight.
fn parallel_lines(coeffs: &CoeffTensor, line_dim: usize) -> Vec<CoeffLine> {
    let spec = coeffs.spec;
    let r = spec.order as isize;
    let mut out = Vec::new();
    let mut push = |fixed: &[isize]| {
        let line = CoeffLine::axis(coeffs, line_dim, fixed);
        if line.nonzeros() > 0 {
            out.push(line);
        }
    };
    match spec.dims {
        2 => {
            for o in -r..=r {
                push(&[o]);
            }
        }
        3 => {
            for a in -r..=r {
                for b in -r..=r {
                    push(&[a, b]);
                }
            }
        }
        _ => unreachable!(),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover(spec: StencilSpec, opt: CoverOption) -> LineCover {
        build_cover(&CoeffTensor::paper_default(spec), opt).unwrap()
    }

    #[test]
    fn box2d_parallel_line_count() {
        for r in 1..=3 {
            assert_eq!(cover(StencilSpec::box2d(r), CoverOption::Parallel).len(), 2 * r + 1);
        }
    }

    #[test]
    fn star2d_parallel_matches_table1() {
        // Table 1: (2r + n) + 2r·n outer products for block extent n.
        let n = 8;
        for r in 1..=4 {
            let c = cover(StencilSpec::star2d(r), CoverOption::Parallel);
            assert_eq!(c.len(), 2 * r + 1);
            assert_eq!(c.outer_products(n), (2 * r + n) + 2 * r * n, "r={r}");
        }
    }

    #[test]
    fn star2d_orthogonal_matches_table1() {
        // Table 1: 2(2r + n). The centre is claimed by the first line, so
        // the second line has 2r weights and still yields 2r+n-? vectors…
        // the paper counts 2(2r+n); with the centre removed the second line
        // yields 2r+n-1 or 2r+n vectors depending on n, r. We assert the
        // paper's asymptotic form with a slack of one vector per line.
        let n = 8;
        for r in 1..=4 {
            let c = cover(StencilSpec::star2d(r), CoverOption::Orthogonal);
            assert_eq!(c.len(), 2);
            let ops = c.outer_products(n);
            let paper = 2 * (2 * r + n);
            assert!(ops <= paper && ops >= paper - 2, "r={r}: ops={ops} paper={paper}");
        }
    }

    #[test]
    fn star3d_option_counts_match_table2() {
        let n = 8;
        for r in 1..=3 {
            let p = cover(StencilSpec::star3d(r), CoverOption::Parallel);
            assert_eq!(p.len(), 4 * r + 1);
            assert_eq!(p.outer_products(n), (2 * r + n) + 4 * r * n, "parallel r={r}");

            let o = cover(StencilSpec::star3d(r), CoverOption::Orthogonal);
            assert_eq!(o.len(), 3);
            let ops = o.outer_products(n);
            let paper = 3 * (2 * r + n);
            assert!(ops <= paper && ops >= paper - 4, "orthogonal r={r}: {ops} vs {paper}");

            let h = cover(StencilSpec::star3d(r), CoverOption::Hybrid);
            assert_eq!(h.len(), 2 * r + 2);
            let ops = h.outer_products(n);
            let paper = 2 * (2 * r + n) + 2 * r * n;
            assert!(ops <= paper && ops >= paper - 2, "hybrid r={r}: {ops} vs {paper}");
        }
    }

    #[test]
    fn box3d_parallel_line_count() {
        for r in 1..=2 {
            let c = cover(StencilSpec::box3d(r), CoverOption::Parallel);
            assert_eq!(c.len(), (2 * r + 1) * (2 * r + 1));
        }
    }

    #[test]
    fn diagonal_cover_is_two_lines() {
        let c = cover(StencilSpec::diag2d(1), CoverOption::Diagonals);
        assert_eq!(c.len(), 2);
        // 2 full diagonals minus the shared centre = 4r + 1 nonzeros
        let nz: usize = c.lines.iter().map(|l| l.nonzeros()).sum();
        assert_eq!(nz, 5);
    }

    #[test]
    fn inapplicable_options_rejected() {
        let box2d = CoeffTensor::paper_default(StencilSpec::box2d(1));
        assert!(build_cover(&box2d, CoverOption::Orthogonal).is_err());
        assert!(build_cover(&box2d, CoverOption::Hybrid).is_err());
        let star2d = CoeffTensor::paper_default(StencilSpec::star2d(1));
        assert!(build_cover(&star2d, CoverOption::Hybrid).is_err());
        let star3d = CoeffTensor::paper_default(StencilSpec::star3d(1));
        assert!(build_cover(&star3d, CoverOption::MinimalAxis).is_err());
        assert!(build_cover(&star3d, CoverOption::Diagonals).is_err());
    }

    #[test]
    fn cover_option_roundtrips_through_strings() {
        for opt in [
            CoverOption::Parallel,
            CoverOption::Orthogonal,
            CoverOption::Hybrid,
            CoverOption::MinimalAxis,
            CoverOption::Diagonals,
        ] {
            assert_eq!(opt.to_string().parse::<CoverOption>().unwrap(), opt);
            assert_eq!(opt.label().parse::<CoverOption>().unwrap(), opt);
        }
        assert!("bogus".parse::<CoverOption>().is_err());
    }

    #[test]
    fn every_applicable_cover_reconstructs() {
        // build_cover internally asserts reconstruction; exercise the whole
        // option × spec matrix.
        let specs = [
            StencilSpec::box2d(1),
            StencilSpec::box2d(3),
            StencilSpec::star2d(1),
            StencilSpec::star2d(4),
            StencilSpec::diag2d(2),
            StencilSpec::box3d(1),
            StencilSpec::box3d(2),
            StencilSpec::star3d(1),
            StencilSpec::star3d(3),
        ];
        for spec in specs {
            let c = CoeffTensor::paper_default(spec);
            for opt in CoverOption::applicable(spec) {
                let cov = build_cover(&c, opt).unwrap();
                assert!(!cov.is_empty(), "{spec} {opt:?}");
            }
        }
    }
}
