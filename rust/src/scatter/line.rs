//! Coefficient lines and their outer-product expansion (Eq. (7)–(12)).
//!
//! A *coefficient line* is a 1-D slice of the stencil's coefficient tensor:
//! a direction `dir` through the footprint plus the `2r+1` weights along it.
//! The paper's final formula (Eq. (12)) expands each line into `n + 2r`
//! shifted *coefficient vectors*: input position `p` (relative to the output
//! block start along the line) is scattered to block rows `k` with weight
//! `w[p - k + r]` — exactly the sub-sequences of a `C^o` column.
//!
//! Weights are stored in **gather orientation** (`w[t + r]` multiplies
//! `A[k + t]` when computing `B[k]`); the scatter reversal of Eq. (5) is
//! what the `p - k` index flip in [`CoeffLine::coeff_vector`] realizes, so
//! no separate scatter copy is needed (see the `scatter_identity` test).

use crate::stencil::{CoeffTensor, StencilSpec};


/// One coefficient line: a direction through the footprint and its weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CoeffLine {
    /// Direction of the line; components in `{-1, 0, 1}`, at least one
    /// non-zero. Axis-parallel lines have a single non-zero component;
    /// diagonal lines (Eq. (16)) have two.
    pub dir: Vec<isize>,
    /// Base offset of the line's `t = 0` point within the footprint
    /// (components in `-r..=r`; zero along `dir`'s non-zero components).
    pub base: Vec<isize>,
    /// Gather-orientation weights indexed by `t + r`, `t` in `-r..=r`.
    /// Zero entries mark weights assigned to other lines of the cover (or
    /// genuinely-zero footprint positions).
    pub weights: Vec<f64>,
}

impl CoeffLine {
    /// Axis-parallel line along `dim` at fixed offsets `fixed` (one per
    /// non-line dimension, increasing dim order), taking ALL weights of the
    /// tensor on that line.
    pub fn axis(coeffs: &CoeffTensor, dim: usize, fixed: &[isize]) -> Self {
        let dims = coeffs.spec.dims;
        let mut dir = vec![0isize; dims];
        dir[dim] = 1;
        let mut base = vec![0isize; dims];
        let mut fi = 0;
        for d in 0..dims {
            if d != dim {
                base[d] = fixed[fi];
                fi += 1;
            }
        }
        Self { dir, base, weights: coeffs.line(dim, fixed) }
    }

    /// 2D diagonal line through the centre (Eq. (16)); `anti` selects the
    /// anti-diagonal.
    pub fn diagonal(coeffs: &CoeffTensor, anti: bool) -> Self {
        assert_eq!(coeffs.spec.dims, 2);
        Self {
            dir: if anti { vec![1, -1] } else { vec![1, 1] },
            base: vec![0, 0],
            weights: coeffs.diag_line(anti),
        }
    }

    /// Stencil order `r` implied by the stored weights.
    pub fn order(&self) -> usize {
        (self.weights.len() - 1) / 2
    }

    /// Footprint offset of the line point at parameter `t` (`-r..=r`).
    pub fn point(&self, t: isize) -> Vec<isize> {
        self.dir
            .iter()
            .zip(&self.base)
            .map(|(&d, &b)| b + t * d)
            .collect()
    }

    /// Number of non-zero weights on this line.
    pub fn nonzeros(&self) -> usize {
        self.weights.iter().filter(|w| **w != 0.0).count()
    }

    /// Zero out the weight at parameter `t` (used when another line of the
    /// cover owns that footprint position, e.g. the shared centre of star
    /// stencils).
    pub fn clear_weight(&mut self, t: isize) {
        let r = self.order() as isize;
        self.weights[(t + r) as usize] = 0.0;
    }

    /// The shifted coefficient vector of Eq. (12) for input position `p`
    /// (relative to the output-block start along the line direction,
    /// `p` in `-r ..= n-1+r`) and block extent `n`:
    ///
    /// `cv[k] = w[(p - k) + r]` when `|p - k| <= r`, else 0.
    ///
    /// Input element at line position `p` is scattered to output row `k`
    /// with the gather weight for displacement `p - k`.
    pub fn coeff_vector(&self, p: isize, n: usize) -> Vec<f64> {
        let r = self.order() as isize;
        (0..n as isize)
            .map(|k| {
                let d = p - k;
                if (-r..=r).contains(&d) {
                    self.weights[(d + r) as usize]
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// True when `coeff_vector(p, n)` has any non-zero entry — computed
    /// without allocating (§Perf: the code generators test this in their
    /// innermost loops).
    pub fn cv_nonzero(&self, p: isize, n: usize) -> bool {
        let r = self.order() as isize;
        let d_lo = (-r).max(p - n as isize + 1);
        let d_hi = r.min(p);
        (d_lo..=d_hi).any(|d| self.weights[(d + r) as usize] != 0.0)
    }

    /// All `(p, cv)` pairs with a non-zero coefficient vector, `p` in
    /// `-r ..= n-1+r`. This is the per-line outer-product workload; its
    /// length is what Table 1 / Table 2 count.
    pub fn coeff_vectors(&self, n: usize) -> Vec<(isize, Vec<f64>)> {
        let r = self.order() as isize;
        (-r..=(n as isize - 1 + r))
            .filter_map(|p| {
                let cv = self.coeff_vector(p, n);
                if cv.iter().any(|v| *v != 0.0) {
                    Some((p, cv))
                } else {
                    None
                }
            })
            .collect()
    }
}

/// A set of coefficient lines that together cover every non-zero weight of
/// a stencil exactly once (§3.5 "minimal cover", §4.1 "options").
#[derive(Debug, Clone, PartialEq)]
pub struct LineCover {
    /// The stencil this cover belongs to.
    pub spec: StencilSpec,
    /// The lines; each non-zero footprint weight appears in exactly one.
    pub lines: Vec<CoeffLine>,
}

impl LineCover {
    /// Verify the cover property: summing each line's weights back into a
    /// dense tensor reproduces the original coefficient tensor exactly.
    pub fn reconstructs(&self, coeffs: &CoeffTensor) -> bool {
        let mut acc = CoeffTensor { spec: self.spec, data: vec![0.0; coeffs.data.len()] };
        let r = self.spec.order as isize;
        for line in &self.lines {
            for t in -r..=r {
                let w = line.weights[(t + r) as usize];
                if w != 0.0 {
                    let off = line.point(t);
                    let idx = acc.dense_index(&off);
                    acc.data[idx] += w;
                }
            }
        }
        acc.data
            .iter()
            .zip(&coeffs.data)
            .all(|(a, b)| (a - b).abs() < 1e-15)
    }

    /// Total outer products for an `n`-extent output block, counting only
    /// non-zero coefficient vectors (the quantity in Table 1 / Table 2).
    pub fn outer_products(&self, n: usize) -> usize {
        self.lines.iter().map(|l| l.coeff_vectors(n).len()).sum()
    }

    /// Number of lines.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// True if the cover has no lines.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::StencilSpec;

    #[test]
    fn axis_line_extracts_gather_column() {
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let l = CoeffLine::axis(&c, 0, &[0]); // middle column, line along i
        assert_eq!(l.weights, vec![c.at(&[-1, 0]), c.at(&[0, 0]), c.at(&[1, 0])]);
        assert_eq!(l.point(-1), vec![-1, 0]);
        assert_eq!(l.point(1), vec![1, 0]);
    }

    #[test]
    fn coeff_vector_matches_eq9() {
        // Eq. (9): for the middle line of 2D9P (r=1), input position p=-1
        // (the paper's A_{i-2,j} relative to a block starting at i-1...)
        // must scatter only to row 0 with the "top" weight, p=0 to rows
        // 0..2 etc. With gather weights (w_m1, w_0, w_p1):
        let c = CoeffTensor::paper_default(StencilSpec::box2d(1));
        let l = CoeffLine::axis(&c, 0, &[0]);
        let (wm1, w0, wp1) = (l.weights[0], l.weights[1], l.weights[2]);
        let n = 4;
        // p = -1: contributes to k=0 with displacement p-k=-1 → w[-1+r]=wm1
        assert_eq!(l.coeff_vector(-1, n), vec![wm1, 0.0, 0.0, 0.0]);
        // p = 0: k=0 → w0; k=1 → wm1
        assert_eq!(l.coeff_vector(0, n), vec![w0, wm1, 0.0, 0.0]);
        // p = 1: k=0 → wp1; k=1 → w0; k=2 → wm1
        assert_eq!(l.coeff_vector(1, n), vec![wp1, w0, wm1, 0.0]);
        // p = n-1+r = 4: only k=3 with wp1
        assert_eq!(l.coeff_vector(4, n), vec![0.0, 0.0, 0.0, wp1]);
    }

    #[test]
    fn coeff_vector_count_is_2r_plus_n() {
        // A full line (all 2r+1 weights non-zero) yields exactly 2r+n
        // non-zero coefficient vectors (§3.4).
        for r in 1..=4usize {
            let c = CoeffTensor::paper_default(StencilSpec::box2d(r));
            let l = CoeffLine::axis(&c, 0, &[0]);
            assert_eq!(l.coeff_vectors(8).len(), 2 * r + 8);
        }
    }

    #[test]
    fn single_weight_line_yields_n_vectors() {
        // Table 1: a line with one non-zero weight produces n outer
        // products.
        let c = CoeffTensor::paper_default(StencilSpec::star2d(1));
        // line along i at j-offset 1 has only the (0, 1) weight
        let l = CoeffLine::axis(&c, 0, &[1]);
        assert_eq!(l.nonzeros(), 1);
        assert_eq!(l.coeff_vectors(8).len(), 8);
    }

    #[test]
    fn scatter_identity() {
        // Functional check that coeff_vector realizes the scatter reversal:
        // summing cv(p)[k] * A[p] over p equals the gather formula at k.
        let c = CoeffTensor::paper_default(StencilSpec::box2d(2));
        let l = CoeffLine::axis(&c, 0, &[0]);
        let r = 2isize;
        let n = 6usize;
        // Synthetic 1-D signal along the line.
        let a = |p: isize| 0.3 + 0.7 * (p as f64) + 0.05 * (p as f64).powi(2);
        for k in 0..n as isize {
            // gather: B[k] = Σ_t w[t+r] A[k+t]
            let gather: f64 = (-r..=r).map(|t| l.weights[(t + r) as usize] * a(k + t)).sum();
            // scatter: B[k] = Σ_p cv(p)[k] A[p]
            let scatter: f64 = (-r..=(n as isize - 1 + r))
                .map(|p| l.coeff_vector(p, n)[k as usize] * a(p))
                .sum();
            assert!((gather - scatter).abs() < 1e-12);
        }
    }

    #[test]
    fn diagonal_line_points() {
        let c = CoeffTensor::paper_default(StencilSpec::diag2d(1));
        let main = CoeffLine::diagonal(&c, false);
        let anti = CoeffLine::diagonal(&c, true);
        assert_eq!(main.point(-1), vec![-1, -1]);
        assert_eq!(anti.point(-1), vec![-1, 1]);
        assert_eq!(anti.point(1), vec![1, -1]);
    }
}
