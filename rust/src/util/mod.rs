//! Small utilities standing in for crates absent from the offline vendor
//! set: JSON (serde_json), property testing (proptest), and benchmark
//! timing (criterion).

pub mod bench;
pub mod json;
pub mod prop;
