//! Small utilities standing in for crates absent from the offline vendor
//! set: JSON (serde_json), property testing (proptest), benchmark
//! timing (criterion), and atomic file replacement (tempfile+rename).

pub mod bench;
pub mod fsx;
pub mod json;
pub mod prop;
