//! Minimal JSON parser/emitter (the vendored offline crate set has no
//! serde_json; this covers the manifest and report needs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (kept as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered by key).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content (if a string).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean content (if a bool).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric content (if a number).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric content as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array content.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => out.push(c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"[{"name":"step_2d5p_n64","n":64,"spec":{"dims":2,"kind":"star"},"steps":1}]"#;
        let v = Json::parse(src).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("step_2d5p_n64"));
        assert_eq!(arr[0].get("n").unwrap().as_usize(), Some(64));
        assert_eq!(
            arr[0].get("spec").unwrap().get("dims").unwrap().as_usize(),
            Some(2)
        );
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"b\"A"));
        let rt = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("17").unwrap().as_usize(), Some(17));
    }

    #[test]
    fn bools() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("false").unwrap().as_bool(), Some(false));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn builder() {
        let v = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
        assert_eq!(v.to_string_compact(), r#"{"a":1,"b":"x"}"#);
    }
}
