//! Filesystem helpers: atomic file replacement.
//!
//! The serving CLI flushes live metrics snapshots periodically while
//! scrapers may read the same path concurrently; a plain
//! `fs::write` would expose half-written JSON. [`write_atomic`]
//! writes to a sibling `.tmp` file and renames it into place —
//! `rename(2)` is atomic on POSIX filesystems within one mount, so a
//! reader observes either the old complete file or the new one, never
//! a prefix.

use std::fs;
use std::io::Write;
use std::path::Path;

/// Write `contents` to `path` atomically: write a sibling
/// `<path>.tmp`, fsync-free flush, then rename over the target.
/// The temp file is removed on failure.
pub fn write_atomic(path: &Path, contents: &str) -> std::io::Result<()> {
    let tmp = path.with_extension(match path.extension() {
        Some(ext) => format!("{}.tmp", ext.to_string_lossy()),
        None => "tmp".to_string(),
    });
    let result = (|| {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.flush()?;
        drop(f);
        fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rename_replaces_the_target_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("stencil-fsx-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.json");
        write_atomic(&path, "{\"v\":1}\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"v\":1}\n");
        // overwrite: reader sees old or new, and afterwards only new
        write_atomic(&path, "{\"v\":2}\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"v\":2}\n");
        // no .tmp residue next to the target
        let residue: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(residue.is_empty(), "{residue:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn extensionless_targets_get_a_plain_tmp_suffix() {
        let dir = std::env::temp_dir().join(format!("stencil-fsx-noext-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snapshot");
        write_atomic(&path, "data").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "data");
        fs::remove_dir_all(&dir).unwrap();
    }
}
