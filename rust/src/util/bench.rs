//! Minimal benchmark timing harness (criterion replacement).
//!
//! `cargo bench` targets use [`time_it`] for wall-clock measurements of
//! host-side work and report simulated-cycle metrics straight from
//! [`crate::sim::RunStats`] (the paper's figures are in simulated cycles,
//! which are deterministic — no statistical machinery needed).

use std::time::Instant;

/// Wall-clock several iterations; returns (best, mean) seconds.
pub fn time_it(iters: usize, mut f: impl FnMut()) -> (f64, f64) {
    assert!(iters > 0);
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    (best, total / iters as f64)
}

/// Pretty seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// A markdown table writer used by the bench harness.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render as github markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str("| ");
            out.push_str(&r.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive() {
        let (best, mean) = time_it(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(best > 0.0 && mean >= best);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(0.002).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
    }
}
