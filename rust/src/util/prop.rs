//! Tiny property-testing helper (the offline crate set has no proptest).
//!
//! Deterministic SplitMix64-based case generation: `cases(n, seed, f)`
//! runs `f` on `n` independently-seeded RNGs; failures report the case
//! seed so they can be replayed with `Rng::new(seed)`.

/// SplitMix64 PRNG — tiny, fast, good enough for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`.
    pub fn below(&mut self, bound: usize) -> usize {
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[-1, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli(1/2).
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `n` generated cases; panic with the failing case seed on error.
pub fn cases(n: usize, seed: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x100000001B3)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property case {case} failed (replay with Rng::new({case_seed:#x}))");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
            let x = r.range(3, 9);
            assert!((3..=9).contains(&x));
            assert!((-1.0..1.0).contains(&r.f64()));
        }
    }

    #[test]
    fn cases_runs_all() {
        let mut count = 0;
        cases(25, 42, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn cases_propagates_failures() {
        cases(5, 1, |rng| assert!(rng.below(10) > 100));
    }
}
