//! # Stencil Matrixization
//!
//! A reproduction of *Stencil Matrixization* (Zhao et al., CS.DC 2023): a
//! stencil-computation algorithm built on **vector outer products** (ARM
//! SME / IBM MMA-style instructions), together with everything needed to
//! evaluate it:
//!
//! - [`stencil`] — stencil specs, coefficient algebra (gather ↔ scatter,
//!   Eq. (5)), grids and the scalar reference oracle.
//! - [`scatter`] — the paper's §3 contribution: coefficient lines, the
//!   outer-product expansion (Eq. (12)), cover options (parallel /
//!   orthogonal / hybrid) and the minimal axis-parallel line cover solved
//!   via König's theorem (§3.5), plus the §3.4 instruction-count analysis.
//! - [`sim`] — the evaluation substrate: a configurable, SME-like
//!   functional + timing simulator (vector & matrix register files, outer
//!   product unit, L1/L2/memory hierarchy) replacing the paper's
//!   proprietary ARM simulator.
//! - [`codegen`] — code generators emitting the kernel IR: the paper's
//!   outer-product method (§4: multi-dimensional unrolling,
//!   outer-product scheduling, data reorganization) and the baselines
//!   (scalar, compiler-style auto-vectorization, DLT, temporal
//!   vectorization).
//! - [`kir`] — the backend-agnostic kernel IR all five generators emit,
//!   with two lowerings: KIR → simulator ISA (timing, unchanged
//!   programs) and KIR → host execution (the paper's algorithm running
//!   natively on the CPU, bitwise equal to the simulated output) — the
//!   latter with two engines: an op-by-op interpreter and the default
//!   *compiling* engine (fused loop nests, precomputed gather tables,
//!   independent row groups threaded across cores, bitwise equal to the
//!   interpreter at any thread count).
//! - [`obs`] — the observability layer: low-overhead structured spans
//!   (a compile-away no-op when disabled) threaded through serving,
//!   kernels, the execution engine and the tuner; Chrome trace-event
//!   export; Prometheus-style metrics exposition; per-phase profiles
//!   (embed / compute / freeze / exchange / extract) feeding the bench
//!   snapshot; a live metrics registry (atomic counters / gauges /
//!   streaming histograms) served over HTTP (`/metrics`, `/healthz`,
//!   `/profile`); and a cost-model accuracy auditor recording predicted
//!   vs measured performance per compiled plan.
//! - [`runtime`] — the PJRT runtime loading AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and executing them from Rust; Python never runs
//!   at request time (gated behind the `pjrt` cargo feature; a stub
//!   otherwise).
//! - [`serve`] — the serving subsystem: slab domain decomposition with
//!   halo exchange, a work-stealing worker pool with per-step barriers,
//!   an LRU cache of compiled shard kernels, and a batched request
//!   front-end with backpressure, coalescing and JSON metrics. Sharded
//!   multi-threaded evolution is *bitwise* equal to the scalar oracle.
//! - [`tune`] — sim-in-the-loop autotuning: a search space over the
//!   paper's optimization choices (cover option × unroll × scheduling ×
//!   layout × method), an analytic cost model for pruning, oracle-verified
//!   empirical ranking on the simulator, and a versioned JSON tuning
//!   database consumed by `serve`, `coordinator` and the bench harness.
//! - [`coordinator`] — experiment runner, parameter sweeps, report tables
//!   and the async batch driver.
//! - [`bench_harness`] — regenerates every figure and table of the paper's
//!   evaluation (Fig. 3, Fig. 4, Fig. 5, Table 3) plus ablations.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

pub mod bench_harness;
pub mod codegen;
pub mod coordinator;
pub mod kir;
pub mod obs;
pub mod runtime;
pub mod scatter;
pub mod serve;
pub mod sim;
pub mod stencil;
pub mod tune;
pub mod util;

/// Vector length in f64 lanes (512-bit vectors, §5.1).
pub const VLEN: usize = 8;
