//! Extra ablations DESIGN.md commits to (beyond the paper's own Fig. 3/4
//! ablations): unroll-factor sweep, matrix-register count sensitivity,
//! and the data-reorganization (EXT) vs gather-load choice proxy via the
//! split-line penalty.

use super::report::Report;
use crate::codegen::{run_method, Method, OuterParams};
use crate::scatter::CoverOption;
use crate::stencil::StencilSpec;
use crate::sim::SimConfig;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// Unroll-factor sweep for a 2D box and a 3D box stencil.
pub fn unroll_sweep(cfg: &SimConfig) -> anyhow::Result<Report> {
    let mut table = Table::new(&["stencil", "N", "ui", "uk", "cyc/pt"]);
    let mut points = Vec::new();
    // 2D: uj ∈ {1,2,4,8}
    for uk in [1usize, 2, 4, 8] {
        let spec = StencilSpec::box2d(1);
        let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk, scheduled: true };
        let res = run_method(cfg, spec, 64, Method::Outer(p), true)?;
        anyhow::ensure!(res.verified());
        table.row(vec![
            spec.name(),
            "64".into(),
            "1".into(),
            uk.to_string(),
            format!("{:.3}", res.cycles_per_point()),
        ]);
        points.push(obj(vec![
            ("stencil", Json::Str(spec.name())),
            ("ui", Json::Num(1.0)),
            ("uk", Json::Num(uk as f64)),
            ("cycles_per_point", Json::Num(res.cycles_per_point())),
        ]));
    }
    // 3D: (ui, uk) grid
    for (ui, uk) in [(1usize, 1usize), (2, 1), (4, 1), (2, 2), (4, 2), (8, 1)] {
        let spec = StencilSpec::box3d(1);
        let p = OuterParams { option: CoverOption::Parallel, ui, uk, scheduled: true };
        let res = run_method(cfg, spec, 16, Method::Outer(p), true)?;
        anyhow::ensure!(res.verified());
        table.row(vec![
            spec.name(),
            "16".into(),
            ui.to_string(),
            uk.to_string(),
            format!("{:.3}", res.cycles_per_point()),
        ]);
        points.push(obj(vec![
            ("stencil", Json::Str(spec.name())),
            ("ui", Json::Num(ui as f64)),
            ("uk", Json::Num(uk as f64)),
            ("cycles_per_point", Json::Num(res.cycles_per_point())),
        ]));
    }
    Ok(Report {
        name: "ablation-unroll".into(),
        title: "unroll-factor sweep (§4.2)".into(),
        table,
        json: Json::Arr(points),
    })
}

/// Matrix-register count sensitivity: 4 / 8 / 16 tiles.
pub fn mreg_sweep(cfg: &SimConfig) -> anyhow::Result<Report> {
    let mut table = Table::new(&["mregs", "uk", "cyc/pt (2d9p N=64)"]);
    let mut points = Vec::new();
    for (mregs, uk) in [(4usize, 4usize), (8, 8), (16, 16)] {
        let c = cfg.clone().with_mregs(mregs);
        let spec = StencilSpec::box2d(1);
        let p = OuterParams {
            option: CoverOption::Parallel,
            ui: 1,
            uk,
            scheduled: true,
        };
        let res = run_method(&c, spec, 64, Method::Outer(p), true)?;
        anyhow::ensure!(res.verified());
        table.row(vec![
            mregs.to_string(),
            uk.to_string(),
            format!("{:.3}", res.cycles_per_point()),
        ]);
        points.push(obj(vec![
            ("mregs", Json::Num(mregs as f64)),
            ("cycles_per_point", Json::Num(res.cycles_per_point())),
        ]));
    }
    Ok(Report {
        name: "ablation-mregs".into(),
        title: "matrix-register count sensitivity".into(),
        table,
        json: Json::Arr(points),
    })
}

/// Tuned vs. paper-default plans: what does closing the loop buy, per
/// stencil? Runs a cost-guided tune per row and compares the winner's
/// cycles per point with the paper-default plan's (both oracle-verified
/// inside the tuner).
pub fn tuned_vs_default(cfg: &SimConfig) -> anyhow::Result<Report> {
    use crate::tune::{tune, Strategy};
    let mut table =
        Table::new(&["stencil", "N", "default", "def cyc/pt", "tuned", "cyc/pt", "speedup"]);
    let mut points = Vec::new();
    let cells: &[(StencilSpec, usize)] = &[
        (StencilSpec::box2d(1), 64),
        (StencilSpec::star2d(2), 64),
        (StencilSpec::diag2d(1), 64),
        (StencilSpec::box3d(1), 16),
        (StencilSpec::star3d(2), 16),
    ];
    for &(spec, n) in cells {
        let out = tune(cfg, spec, n, 8, Strategy::CostGuided)?;
        let (best, default) = (out.best(), out.paper_default());
        table.row(vec![
            spec.name(),
            n.to_string(),
            default.plan.label(spec.dims),
            format!("{:.3}", default.cycles_per_point),
            best.plan.label(spec.dims),
            format!("{:.3}", best.cycles_per_point),
            format!("{:.2}x", out.speedup_vs_default()),
        ]);
        points.push(obj(vec![
            ("stencil", Json::Str(spec.name())),
            ("n", Json::Num(n as f64)),
            ("default_plan", Json::Str(default.plan.label(spec.dims))),
            ("default_cycles_per_point", Json::Num(default.cycles_per_point)),
            ("tuned_plan", Json::Str(best.plan.label(spec.dims))),
            ("tuned_cycles_per_point", Json::Num(best.cycles_per_point)),
            ("speedup", Json::Num(out.speedup_vs_default())),
        ]));
    }
    Ok(Report {
        name: "ablation-tuned".into(),
        title: "tuned vs. paper-default plans (cost-guided search, budget 8)".into(),
        table,
        json: Json::Arr(points),
    })
}

/// All ablations.
pub fn run_all(cfg: &SimConfig) -> anyhow::Result<Vec<Report>> {
    Ok(vec![unroll_sweep(cfg)?, mreg_sweep(cfg)?, tuned_vs_default(cfg)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_tiles_do_not_hurt() {
        // with scheduling, unrolling further amortizes CV loads: uk=8
        // should be at least as good as uk=1 for the 2D box stencil.
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let run = |uk| {
            let p = OuterParams { option: CoverOption::Parallel, ui: 1, uk, scheduled: true };
            run_method(&cfg, spec, 64, Method::Outer(p), true).unwrap().cycles_per_point()
        };
        assert!(run(8) <= run(1) * 1.02);
    }
}
