//! Table 3 — speedup comparison, normalized to auto-vectorization, over
//! the full stencil × size matrix (best per row marked `*`, the paper's
//! grey cells). "our" reports the best coefficient-line option ×
//! unrolling, with its label in brackets (`p-j8`, `o-j4`, `h-k4`, ...),
//! exactly like the paper's bracketed annotations.

use super::report::Report;
use crate::codegen::{run_method, verify::speedup, Method, OuterParams};
use crate::scatter::CoverOption;
use crate::stencil::{StencilKind, StencilSpec};
use crate::sim::SimConfig;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// The 2D matrix rows: box r=1..3, star r=1..3; sizes 64²..512².
pub const SIZES_2D: &[usize] = &[64, 128, 256, 512];
/// The 3D matrix rows: box r=1..2, star r=1..3; sizes 8³..64³.
pub const SIZES_3D: &[usize] = &[8, 16, 32, 64];

/// The candidate (option, ui, uk) configurations we let "our" method pick
/// from per cell (the paper also picks the best per cell).
pub fn candidates(spec: StencilSpec) -> Vec<OuterParams> {
    let mut v = Vec::new();
    if spec.dims == 2 {
        for uk in [4usize, 8] {
            v.push(OuterParams { option: CoverOption::Parallel, ui: 1, uk, scheduled: true });
        }
        if spec.kind == StencilKind::Star {
            v.push(OuterParams { option: CoverOption::Orthogonal, ui: 1, uk: 4, scheduled: true });
        }
    } else {
        for (ui, uk) in [(4usize, 1usize), (4, 2), (8, 1)] {
            v.push(OuterParams { option: CoverOption::Parallel, ui, uk, scheduled: true });
        }
        if spec.kind == StencilKind::Star {
            v.push(OuterParams { option: CoverOption::Orthogonal, ui: 4, uk: 1, scheduled: true });
            v.push(OuterParams { option: CoverOption::Hybrid, ui: 1, uk: 4, scheduled: true });
        }
    }
    v
}

/// The Table-3 stencil rows for one dimensionality (also the row set of
/// the `bench-json` snapshot).
pub fn rows(dims: usize) -> Vec<StencilSpec> {
    let mut v = Vec::new();
    let box_orders: &[usize] = if dims == 2 { &[1, 2, 3] } else { &[1, 2] };
    for &r in box_orders {
        v.push(StencilSpec { dims, order: r, kind: StencilKind::Box });
    }
    for r in 1..=3usize {
        v.push(StencilSpec { dims, order: r, kind: StencilKind::Star });
    }
    v
}

/// Run one dimensionality's half of Table 3.
pub fn run_half(cfg: &SimConfig, dims: usize) -> anyhow::Result<Report> {
    let sizes = if dims == 2 { SIZES_2D } else { SIZES_3D };
    let mut header = vec!["stencil".to_string()];
    for &n in sizes {
        header.push(format!("N={n} DLT"));
        header.push(format!("N={n} TV"));
        header.push(format!("N={n} our (option)"));
    }
    let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut points = Vec::new();
    for spec in rows(dims) {
        let mut row = vec![spec.name()];
        for &n in sizes {
            let base = run_method(cfg, spec, n, Method::AutoVec, true)?;
            let dlt = run_method(cfg, spec, n, Method::Dlt, true)?;
            let tv = run_method(cfg, spec, n, Method::Tv, true)?;
            // best of our candidates
            let mut best: Option<(OuterParams, f64)> = None;
            for params in candidates(spec) {
                let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
                anyhow::ensure!(res.verified(), "{spec} {params:?} N={n}");
                let s = speedup(&base, &res);
                if best.map(|(_, b)| s > b).unwrap_or(true) {
                    best = Some((params, s));
                }
            }
            let (bp, bs) = best.unwrap();
            let sd = speedup(&base, &dlt);
            let st = speedup(&base, &tv);
            let star = |v: f64| if v >= sd.max(st).max(bs) { "*" } else { "" };
            row.push(format!("{sd:.2}{}", star(sd)));
            row.push(format!("{st:.2}{}", star(st)));
            row.push(format!("{bs:.2}{} ({})", star(bs), bp.label(dims)));
            points.push(obj(vec![
                ("stencil", Json::Str(spec.name())),
                ("n", Json::Num(n as f64)),
                ("dlt", Json::Num(sd)),
                ("tv", Json::Num(st)),
                ("ours", Json::Num(bs)),
                ("option", Json::Str(bp.label(dims))),
            ]));
        }
        table.row(row);
    }
    Ok(Report {
        name: format!("table3-{dims}d"),
        title: format!("{dims}D speedups over auto-vectorization (best per cell *)"),
        table,
        json: Json::Arr(points),
    })
}

/// Both halves.
pub fn run_all(cfg: &SimConfig) -> anyhow::Result<Vec<Report>> {
    Ok(vec![run_half(cfg, 2)?, run_half(cfg, 3)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_cover_star_options() {
        let c2 = candidates(StencilSpec::star2d(2));
        assert!(c2.iter().any(|p| p.option == CoverOption::Orthogonal));
        let c3 = candidates(StencilSpec::star3d(2));
        assert!(c3.iter().any(|p| p.option == CoverOption::Hybrid));
        let b = candidates(StencilSpec::box2d(1));
        assert!(b.iter().all(|p| p.option == CoverOption::Parallel));
    }
}
