//! Figure 3 — performance of star stencils with the coefficient-line
//! options (parallel / orthogonal, plus hybrid in 3D), orders 1–4.
//!
//! Panels: (a) 2D 64² in-cache, (b) 2D 512² out-of-cache, (c) 3D 16³,
//! (d) 3D 64³. The paper's shape to reproduce: parallel wins at order 1;
//! the orthogonal (and 3D hybrid) curves are *flatter* as the order grows
//! (outer products grow O(1) vs O(n) per order, §5.2 / Table 1–2).

use super::report::Report;
use crate::codegen::{run_method, Method, OuterParams};
use crate::scatter::CoverOption;
use crate::stencil::StencilSpec;
use crate::sim::SimConfig;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// Panel definition: (panel id, dims, N, orders).
pub const PANELS: &[(&str, usize, usize, &[usize])] = &[
    ("fig3a", 2, 64, &[1, 2, 3, 4]),
    ("fig3b", 2, 512, &[1, 2, 3, 4]),
    ("fig3c", 3, 16, &[1, 2, 3, 4]),
    ("fig3d", 3, 64, &[1, 2, 3]),
];

/// Options plotted per panel dimensionality.
pub fn options_for(dims: usize) -> Vec<(CoverOption, usize, usize)> {
    // (option, ui, uk) with the paper's unroll factors
    if dims == 2 {
        vec![(CoverOption::Parallel, 1, 8), (CoverOption::Orthogonal, 1, 4)]
    } else {
        vec![
            (CoverOption::Parallel, 4, 1),
            (CoverOption::Orthogonal, 4, 1),
            (CoverOption::Hybrid, 1, 4),
        ]
    }
}

/// Run one panel; returns the report (cycles/point per option × order).
pub fn run_panel(
    cfg: &SimConfig,
    panel: &str,
    dims: usize,
    n: usize,
    orders: &[usize],
) -> anyhow::Result<Report> {
    let opts = options_for(dims);
    let mut header = vec!["order".to_string()];
    header.extend(opts.iter().map(|(o, _, _)| format!("{o:?} (cyc/pt)")));
    let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut points = Vec::new();
    for &r in orders {
        let spec = StencilSpec::new(dims, r, crate::stencil::StencilKind::Star)?;
        let mut row = vec![r.to_string()];
        for &(option, ui, uk) in &opts {
            let params = OuterParams { option, ui, uk, scheduled: true };
            let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
            anyhow::ensure!(res.verified(), "{spec} {option:?}: err {}", res.max_err);
            row.push(format!("{:.3}", res.cycles_per_point()));
            points.push(obj(vec![
                ("panel", Json::Str(panel.into())),
                ("order", Json::Num(r as f64)),
                ("option", Json::Str(format!("{option:?}"))),
                ("cycles_per_point", Json::Num(res.cycles_per_point())),
                ("fmopa", Json::Num(res.stats.fmopa() as f64)),
                ("mem_bytes", Json::Num(res.stats.mem_bytes() as f64)),
            ]));
        }
        table.row(row);
    }
    Ok(Report {
        name: panel.to_string(),
        title: format!("star {dims}D N={n}: CLS options vs order (lower is better)"),
        table,
        json: Json::Arr(points),
    })
}

/// Run all four panels.
pub fn run_all(cfg: &SimConfig) -> anyhow::Result<Vec<Report>> {
    PANELS
        .iter()
        .map(|&(panel, dims, n, orders)| run_panel(cfg, panel, dims, n, orders))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_shape_parallel_wins_r1_orthogonal_flatter() {
        let cfg = SimConfig::default();
        let rep = run_panel(&cfg, "fig3a", 2, 64, &[1, 3]).unwrap();
        let pts = match &rep.json {
            Json::Arr(a) => a.clone(),
            _ => unreachable!(),
        };
        let get = |order: f64, option: &str| {
            pts.iter()
                .find(|p| {
                    p.get("order").unwrap().as_f64() == Some(order)
                        && p.get("option").unwrap().as_str() == Some(option)
                })
                .unwrap()
                .get("cycles_per_point")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // parallel best at order 1 (paper: "parallel obtains the best
        // performance for order=1 in all cases")
        assert!(get(1.0, "Parallel") <= get(1.0, "Orthogonal") * 1.05);
        // orthogonal grows more slowly with order (flatter curve)
        let growth_p = get(3.0, "Parallel") / get(1.0, "Parallel");
        let growth_o = get(3.0, "Orthogonal") / get(1.0, "Orthogonal");
        assert!(
            growth_o < growth_p,
            "orthogonal should be flatter: {growth_o:.2} vs {growth_p:.2}"
        );
    }
}
