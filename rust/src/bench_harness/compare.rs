//! The CI perf-regression gate: compare a fresh `BENCH_8.json` snapshot
//! against the checked-in `bench/baseline.json`.
//!
//! The primary gate keys on **simulated cycles**, which are fully
//! deterministic (the simulator has no noise), so a >tolerance increase
//! on any (stencil, method) cell is a real codegen/model regression,
//! not machine jitter. Host wall-clock is noisier, so it gets a wider,
//! two-band gate: per-cell compiled-engine `host_seconds`, per-cell
//! SIMD-engine `simd_seconds` and per-row serving throughput
//! (`fused_serve.fused_mpts_per_s`) **fail** only beyond
//! [`HOST_FAIL_TOLERANCE`] (10%) and are reported as advisory
//! notes between [`HOST_ADVISORY_TOLERANCE`] (2%) and the failure
//! band. Op-count drifts are reported as notes (an op-count change
//! with flat cycles is usually an intentional codegen change; refresh
//! the baseline alongside it).
//!
//! Bootstrap: a baseline with `"pending": true` (the state checked in
//! before the first refresh) makes the gate advisory — the full
//! per-cell table is still rendered from the current snapshot (so the
//! CI summary always shows the numbers), nothing fails — and
//! CONTRIBUTING.md documents how to promote a CI-produced snapshot into
//! the real baseline. When both snapshots carry fused-serve phase
//! profiles, per-phase drift is reported as advisory notes so a
//! wall-clock regression can be attributed to embed / compute / freeze
//! / exchange / extract.

use crate::obs::PhaseProfile;
use crate::util::bench::Table;
use crate::util::json::Json;

/// The method columns every snapshot row carries.
const METHODS: [&str; 5] = ["scalar", "autovec", "dlt", "tv", "outer"];

/// Default regression tolerance: fail the gate when a method's simulated
/// cycles exceed the baseline by more than 2%.
pub const DEFAULT_TOLERANCE: f64 = 0.02;

/// Host wall-clock failure band: compiled-engine `host_seconds` (per
/// cell) or serving `fused_mpts_per_s` (per row) moving more than this
/// much in the slow direction fails the gate.
pub const HOST_FAIL_TOLERANCE: f64 = 0.10;

/// Host wall-clock advisory band: slow-direction drift beyond this (but
/// within [`HOST_FAIL_TOLERANCE`]) is reported without failing.
pub const HOST_ADVISORY_TOLERANCE: f64 = 0.02;

/// One compared (stencil, method) cell.
#[derive(Debug, Clone)]
pub struct CellDelta {
    /// Stencil row name (e.g. `2d9p-box-r1`).
    pub stencil: String,
    /// Method name (scalar/autovec/dlt/tv/outer).
    pub method: String,
    /// Baseline simulated cycles.
    pub base_cycles: f64,
    /// Current simulated cycles.
    pub cur_cycles: f64,
    /// Relative cycle change (positive = slower).
    pub delta: f64,
    /// Whether the cell fails the gate.
    pub regressed: bool,
    /// Relative compiled-engine wall-clock change (positive = slower),
    /// when both snapshots carry `host_seconds` for the cell.
    pub host_delta: Option<f64>,
    /// Relative SIMD-engine wall-clock change (positive = slower), when
    /// both snapshots carry `simd_seconds` for the cell.
    pub simd_delta: Option<f64>,
    /// Op-count drift note, when host_ops moved.
    pub ops_note: Option<String>,
}

/// Outcome of one baseline comparison.
#[derive(Debug, Clone)]
pub struct Comparison {
    /// True when the baseline is a `pending` placeholder (gate
    /// advisory).
    pub pending: bool,
    /// Tolerance the gate ran with.
    pub tolerance: f64,
    /// Every compared cell.
    pub cells: Vec<CellDelta>,
    /// Human-readable summaries of the failing cells (empty = gate
    /// passes).
    pub regressions: Vec<String>,
    /// Host wall-clock regressions beyond [`HOST_FAIL_TOLERANCE`]
    /// (compiled- and SIMD-engine seconds per cell, serving Mpts/s per
    /// row) — these fail the gate.
    pub host_regressions: Vec<String>,
    /// Host wall-clock drift inside the advisory band
    /// ([`HOST_ADVISORY_TOLERANCE`]..[`HOST_FAIL_TOLERANCE`]) —
    /// reported, never failing.
    pub host_advisories: Vec<String>,
    /// Advisory per-phase drift notes from the fused-serve profiles
    /// (wall-clock; never gated).
    pub phase_notes: Vec<String>,
}

impl Comparison {
    /// True when the gate passes (no sim-cycle regression and no host
    /// wall-clock regression beyond the failure band, or pending
    /// baseline).
    pub fn passed(&self) -> bool {
        self.pending || (self.regressions.is_empty() && self.host_regressions.is_empty())
    }

    /// Render the comparison as a markdown report (what CI appends to
    /// the job summary).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# perf gate — sim cycles vs bench/baseline.json\n\n");
        if self.pending {
            out.push_str(
                "**baseline pending** — `bench/baseline.json` is a placeholder; the gate is \
                 advisory until a CI `BENCH_8.json` is promoted (see CONTRIBUTING.md). The \
                 table below reports the current snapshot against itself.\n\n",
            );
        }
        let mut table = Table::new(&[
            "stencil",
            "method",
            "baseline cyc",
            "current cyc",
            "delta",
            "host delta",
            "simd delta",
            "status",
        ]);
        for c in &self.cells {
            let status = if c.regressed {
                "REGRESSED".to_string()
            } else {
                match &c.ops_note {
                    Some(note) => format!("ok ({note})"),
                    None => "ok".to_string(),
                }
            };
            table.row(vec![
                c.stencil.clone(),
                c.method.clone(),
                format!("{:.0}", c.base_cycles),
                format!("{:.0}", c.cur_cycles),
                format!("{:+.2}%", c.delta * 100.0),
                match c.host_delta {
                    Some(d) => format!("{:+.2}%", d * 100.0),
                    None => "—".to_string(),
                },
                match c.simd_delta {
                    Some(d) => format!("{:+.2}%", d * 100.0),
                    None => "—".to_string(),
                },
                status,
            ]);
        }
        out.push_str(&table.to_markdown());
        out.push('\n');
        if self.pending {
            out.push_str(&format!(
                "gate **advisory**: baseline pending; {} cell(s) reported, nothing gated.\n",
                self.cells.len()
            ));
        } else if self.regressions.is_empty() {
            out.push_str(&format!(
                "gate **passed**: no method regressed more than {:.1}% ({} cells compared).\n",
                self.tolerance * 100.0,
                self.cells.len()
            ));
        } else {
            out.push_str(&format!(
                "gate **FAILED**: {} regression(s) beyond {:.1}%:\n",
                self.regressions.len(),
                self.tolerance * 100.0
            ));
            for r in &self.regressions {
                out.push_str(&format!("- {r}\n"));
            }
        }
        if !self.pending {
            if self.host_regressions.is_empty() {
                out.push_str(&format!(
                    "host gate **passed**: no wall-clock regression beyond {:.0}%.\n",
                    HOST_FAIL_TOLERANCE * 100.0
                ));
            } else {
                out.push_str(&format!(
                    "host gate **FAILED**: {} wall-clock regression(s) beyond {:.0}%:\n",
                    self.host_regressions.len(),
                    HOST_FAIL_TOLERANCE * 100.0
                ));
                for r in &self.host_regressions {
                    out.push_str(&format!("- {r}\n"));
                }
            }
        }
        if !self.host_advisories.is_empty() {
            out.push_str(&format!(
                "\nadvisory host drift ({:.0}%–{:.0}% band; never failing):\n",
                HOST_ADVISORY_TOLERANCE * 100.0,
                HOST_FAIL_TOLERANCE * 100.0
            ));
            for n in &self.host_advisories {
                out.push_str(&format!("- {n}\n"));
            }
        }
        if !self.phase_notes.is_empty() {
            out.push_str("\nadvisory per-phase drift (fused-serve wall-clock; never gated):\n");
            for n in &self.phase_notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out
    }
}

fn cell_f64(methods: &Json, method: &str, field: &str) -> Option<f64> {
    methods.get(method)?.get(field)?.as_f64()
}

/// Compare `current` (a fresh snapshot) against `baseline`.
///
/// Errors on schema mismatches a refresh must fix (version, fingerprint,
/// sizes, missing rows); returns regressions via [`Comparison`].
pub fn compare(baseline: &Json, current: &Json, tolerance: f64) -> anyhow::Result<Comparison> {
    if baseline.get("pending").and_then(Json::as_bool) == Some(true) {
        // bootstrap: nothing to gate against, but still render every
        // cell from the current snapshot (against itself, delta 0) so
        // the CI summary always carries the numbers
        let cells = self_cells(current)?;
        return Ok(Comparison {
            pending: true,
            tolerance,
            cells,
            regressions: Vec::new(),
            host_regressions: Vec::new(),
            host_advisories: Vec::new(),
            phase_notes: Vec::new(),
        });
    }
    for field in ["version", "fingerprint", "sizes"] {
        let b = baseline.get(field);
        let c = current.get(field);
        anyhow::ensure!(
            b.is_some() && b == c,
            "baseline/current '{field}' mismatch ({b:?} vs {c:?}) — refresh bench/baseline.json \
             (see CONTRIBUTING.md)"
        );
    }
    let base_rows = baseline
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("baseline has no results array"))?;
    let cur_rows = current
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("current snapshot has no results array"))?;
    let mut cells = Vec::new();
    let mut regressions = Vec::new();
    let mut host_regressions = Vec::new();
    let mut host_advisories = Vec::new();
    let mut phase_notes = Vec::new();
    for brow in base_rows {
        let stencil = brow
            .get("stencil")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("baseline row without stencil name"))?;
        let crow = cur_rows
            .iter()
            .find(|r| r.get("stencil").and_then(Json::as_str) == Some(stencil))
            .ok_or_else(|| anyhow::anyhow!("current snapshot is missing stencil '{stencil}'"))?;
        let (bm, cm) = (
            brow.get("methods")
                .ok_or_else(|| anyhow::anyhow!("baseline row '{stencil}' without methods"))?,
            crow.get("methods")
                .ok_or_else(|| anyhow::anyhow!("current row '{stencil}' without methods"))?,
        );
        for method in METHODS {
            let base_cycles = cell_f64(bm, method, "cycles")
                .ok_or_else(|| anyhow::anyhow!("baseline {stencil}/{method} has no cycles"))?;
            let cur_cycles = cell_f64(cm, method, "cycles")
                .ok_or_else(|| anyhow::anyhow!("current {stencil}/{method} has no cycles"))?;
            let delta = (cur_cycles - base_cycles) / base_cycles.max(1.0);
            let regressed = delta > tolerance;
            let ops_note = match (cell_f64(bm, method, "host_ops"), cell_f64(cm, method, "host_ops"))
            {
                (Some(b), Some(c)) if b != c => {
                    Some(format!("ops {:.0} → {:.0}", b, c))
                }
                _ => None,
            };
            if regressed {
                regressions.push(format!(
                    "{stencil}/{method}: {base_cycles:.0} → {cur_cycles:.0} cycles ({:+.2}%)",
                    delta * 100.0
                ));
            }
            // host wall-clock band: compiled-engine seconds per cell
            // (positive delta = slower)
            let host_delta = match (
                cell_f64(bm, method, "host_seconds"),
                cell_f64(cm, method, "host_seconds"),
            ) {
                (Some(b), Some(c)) if b > 0.0 => {
                    let d = (c - b) / b;
                    let note = format!(
                        "{stencil}/{method}: host {:.2}ms → {:.2}ms ({:+.2}%)",
                        b * 1e3,
                        c * 1e3,
                        d * 100.0
                    );
                    if d > HOST_FAIL_TOLERANCE {
                        host_regressions.push(note);
                    } else if d > HOST_ADVISORY_TOLERANCE {
                        host_advisories.push(note);
                    }
                    Some(d)
                }
                _ => None,
            };
            // same two bands for the SIMD engine's wall-clock (absent in
            // pre-v6 baselines, so the comparison degrades gracefully)
            let simd_delta = match (
                cell_f64(bm, method, "simd_seconds"),
                cell_f64(cm, method, "simd_seconds"),
            ) {
                (Some(b), Some(c)) if b > 0.0 => {
                    let d = (c - b) / b;
                    let note = format!(
                        "{stencil}/{method}: simd {:.2}ms → {:.2}ms ({:+.2}%)",
                        b * 1e3,
                        c * 1e3,
                        d * 100.0
                    );
                    if d > HOST_FAIL_TOLERANCE {
                        host_regressions.push(note);
                    } else if d > HOST_ADVISORY_TOLERANCE {
                        host_advisories.push(note);
                    }
                    Some(d)
                }
                _ => None,
            };
            cells.push(CellDelta {
                stencil: stencil.to_string(),
                method: method.to_string(),
                base_cycles,
                cur_cycles,
                delta,
                regressed,
                host_delta,
                simd_delta,
                ops_note,
            });
        }
        // host band, serving side: fused throughput per row (positive
        // delta = fewer Mpts/s = slower)
        let mpts = |row: &Json| {
            row.get("fused_serve").and_then(|f| f.get("fused_mpts_per_s")).and_then(Json::as_f64)
        };
        if let (Some(b), Some(c)) = (mpts(brow), mpts(crow)) {
            if b > 0.0 {
                let d = (b - c) / b;
                let note = format!(
                    "{stencil}: fused serve {b:.2} → {c:.2} Mpts/s ({:+.2}%)",
                    -d * 100.0
                );
                if d > HOST_FAIL_TOLERANCE {
                    host_regressions.push(note);
                } else if d > HOST_ADVISORY_TOLERANCE {
                    host_advisories.push(note);
                }
            }
        }
        // advisory: attribute fused-serve wall-clock drift to a phase
        // when both snapshots carry a traced profile (v5+)
        let prof = |row: &Json| {
            row.get("fused_serve")
                .and_then(|f| f.get("profile"))
                .map(PhaseProfile::from_json)
        };
        if let (Some(bp), Some(cp)) = (prof(brow), prof(crow)) {
            for ((name, b), (_, c)) in bp.phases().iter().zip(cp.phases().iter()) {
                if *b > 1e-6 && *c > *b * 2.0 {
                    phase_notes.push(format!(
                        "{stencil}: {name} {:.2}ms → {:.2}ms",
                        b * 1e3,
                        c * 1e3
                    ));
                }
            }
        }
    }
    Ok(Comparison {
        pending: false,
        tolerance,
        cells,
        regressions,
        host_regressions,
        host_advisories,
        phase_notes,
    })
}

/// Every (stencil, method) cell of one snapshot, compared against
/// itself — the table a pending baseline renders.
fn self_cells(snapshot: &Json) -> anyhow::Result<Vec<CellDelta>> {
    let rows = snapshot
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("current snapshot has no results array"))?;
    let mut cells = Vec::new();
    for row in rows {
        let stencil = row
            .get("stencil")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("snapshot row without stencil name"))?;
        let methods = row
            .get("methods")
            .ok_or_else(|| anyhow::anyhow!("row '{stencil}' without methods"))?;
        for method in METHODS {
            let cycles = cell_f64(methods, method, "cycles")
                .ok_or_else(|| anyhow::anyhow!("{stencil}/{method} has no cycles"))?;
            cells.push(CellDelta {
                stencil: stencil.to_string(),
                method: method.to_string(),
                base_cycles: cycles,
                cur_cycles: cycles,
                delta: 0.0,
                regressed: false,
                host_delta: None,
                simd_delta: None,
                ops_note: None,
            });
        }
    }
    Ok(cells)
}

/// Multiply every `key` numeric field of a snapshot by `factor` (the
/// self-test's injected perturbation). `round` quantizes the product to
/// an integer — what the `cycles` fields expect.
pub fn inflate_key(snapshot: &Json, key: &str, factor: f64, round: bool) -> Json {
    match snapshot {
        Json::Obj(m) => Json::Obj(
            m.iter()
                .map(|(k, v)| {
                    let v = if k == key {
                        match v {
                            Json::Num(n) => {
                                let x = n * factor;
                                Json::Num(if round { x.round() } else { x })
                            }
                            other => other.clone(),
                        }
                    } else {
                        inflate_key(v, key, factor, round)
                    };
                    (k.clone(), v)
                })
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(|v| inflate_key(v, key, factor, round)).collect()),
        other => other.clone(),
    }
}

/// Multiply every `cycles` field of a snapshot by `factor` (the
/// self-test's injected regression).
pub fn inflate_cycles(snapshot: &Json, factor: f64) -> Json {
    inflate_key(snapshot, "cycles", factor, true)
}

/// Prove the gate trips: compare `current` against itself with injected
/// regressions — cycle inflation beyond tolerance, host wall-clock
/// inflation and serving-throughput deflation beyond
/// [`HOST_FAIL_TOLERANCE`] — and error if any goes undetected. CI runs
/// this every build so a silently vacuous gate cannot survive.
pub fn self_test(current: &Json, tolerance: f64) -> anyhow::Result<Comparison> {
    anyhow::ensure!(
        current.get("pending").and_then(Json::as_bool) != Some(true),
        "self-test needs a real snapshot, not a pending placeholder"
    );
    let inflated = inflate_cycles(current, 1.0 + 2.0 * tolerance + 0.01);
    let cmp = compare(current, &inflated, tolerance)?;
    anyhow::ensure!(
        !cmp.regressions.is_empty(),
        "perf-gate self-test failed: injected cycle regression was not detected"
    );
    // host wall-clock band: +2× the failure tolerance must fail …
    let slow = inflate_key(current, "host_seconds", 1.0 + 2.0 * HOST_FAIL_TOLERANCE, false);
    let cmp_slow = compare(current, &slow, tolerance)?;
    anyhow::ensure!(
        !cmp_slow.host_regressions.is_empty() && !cmp_slow.passed(),
        "perf-gate self-test failed: injected host wall-clock regression was not detected"
    );
    // … while drift inside the advisory band only advises
    let mild = inflate_key(
        current,
        "host_seconds",
        1.0 + (HOST_ADVISORY_TOLERANCE + HOST_FAIL_TOLERANCE) / 2.0,
        false,
    );
    let cmp_mild = compare(current, &mild, tolerance)?;
    anyhow::ensure!(
        cmp_mild.passed() && !cmp_mild.host_advisories.is_empty(),
        "perf-gate self-test failed: advisory-band host drift mis-gated"
    );
    // the SIMD engine's wall-clock sits behind the same two bands
    let simd_slow = inflate_key(current, "simd_seconds", 1.0 + 2.0 * HOST_FAIL_TOLERANCE, false);
    let cmp_simd = compare(current, &simd_slow, tolerance)?;
    anyhow::ensure!(
        !cmp_simd.host_regressions.is_empty() && !cmp_simd.passed(),
        "perf-gate self-test failed: injected simd wall-clock regression was not detected"
    );
    // serving throughput: a >10% Mpts/s drop must fail
    let starved = inflate_key(current, "fused_mpts_per_s", 1.0 - 2.0 * HOST_FAIL_TOLERANCE, false);
    let cmp_starved = compare(current, &starved, tolerance)?;
    anyhow::ensure!(
        !cmp_starved.host_regressions.is_empty() && !cmp_starved.passed(),
        "perf-gate self-test failed: injected serving-throughput regression was not detected"
    );
    // and the unperturbed comparison must pass
    let clean = compare(current, current, tolerance)?;
    anyhow::ensure!(
        clean.passed() && !clean.pending,
        "perf-gate self-test failed: identical snapshots did not pass"
    );
    Ok(cmp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    fn tiny_snapshot() -> &'static Json {
        // real snapshot at tiny sizes: deterministic, all rows present;
        // computed once and shared across the tests in this module
        static SNAP: std::sync::OnceLock<Json> = std::sync::OnceLock::new();
        SNAP.get_or_init(|| super::super::snapshot::run(&SimConfig::default(), 16, 8).unwrap())
    }

    #[test]
    fn identical_snapshots_pass() {
        let snap = tiny_snapshot();
        let cmp = compare(snap, snap, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.passed() && !cmp.pending);
        assert_eq!(cmp.cells.len(), 11 * 5);
        assert!(cmp.regressions.is_empty());
        let md = cmp.to_markdown();
        assert!(md.contains("gate **passed**"), "{md}");
    }

    #[test]
    fn injected_regression_fails_the_gate() {
        let snap = tiny_snapshot();
        // +5% on every cycles cell: every cell must regress at 2%
        let worse = inflate_cycles(snap, 1.05);
        let cmp = compare(snap, &worse, DEFAULT_TOLERANCE).unwrap();
        assert!(!cmp.passed());
        assert_eq!(cmp.regressions.len(), 11 * 5);
        assert!(cmp.to_markdown().contains("gate **FAILED**"));
        // +1% stays inside the 2% tolerance
        let slightly = inflate_cycles(snap, 1.01);
        let cmp = compare(snap, &slightly, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.passed(), "{:?}", cmp.regressions);
        // improvements never fail
        let better = inflate_cycles(snap, 0.90);
        assert!(compare(snap, &better, DEFAULT_TOLERANCE).unwrap().passed());
    }

    #[test]
    fn self_test_detects_and_clears() {
        let snap = tiny_snapshot();
        let cmp = self_test(snap, DEFAULT_TOLERANCE).unwrap();
        assert!(!cmp.regressions.is_empty());
    }

    #[test]
    fn host_gate_has_two_bands() {
        let snap = tiny_snapshot();
        // +25% host wall-clock: beyond the 10% failure band
        let slow = inflate_key(snap, "host_seconds", 1.25, false);
        let cmp = compare(snap, &slow, DEFAULT_TOLERANCE).unwrap();
        assert!(!cmp.passed());
        assert!(cmp.regressions.is_empty(), "sim cycles untouched");
        assert!(!cmp.host_regressions.is_empty());
        assert!(cmp.to_markdown().contains("host gate **FAILED**"));
        // +5%: inside the 2%–10% advisory band — reported, not failing
        let mild = inflate_key(snap, "host_seconds", 1.05, false);
        let cmp = compare(snap, &mild, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.passed());
        assert!(!cmp.host_advisories.is_empty());
        assert!(cmp.to_markdown().contains("advisory host drift"));
        // host improvements never fail or advise
        let fast = inflate_key(snap, "host_seconds", 0.5, false);
        let cmp = compare(snap, &fast, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.passed() && cmp.host_advisories.is_empty());
        // serving throughput drop beyond 10% fails too
        let starved = inflate_key(snap, "fused_mpts_per_s", 0.8, false);
        let cmp = compare(snap, &starved, DEFAULT_TOLERANCE).unwrap();
        assert!(!cmp.passed());
        let mentions_mpts = cmp.host_regressions.iter().any(|r| r.contains("Mpts/s"));
        assert!(mentions_mpts, "{:?}", cmp.host_regressions);
        // the simd engine's wall-clock sits behind the same bands
        let simd_slow = inflate_key(snap, "simd_seconds", 1.25, false);
        let cmp = compare(snap, &simd_slow, DEFAULT_TOLERANCE).unwrap();
        assert!(!cmp.passed());
        let mentions_simd = cmp.host_regressions.iter().any(|r| r.contains("simd"));
        assert!(mentions_simd, "{:?}", cmp.host_regressions);
        let simd_mild = inflate_key(snap, "simd_seconds", 1.05, false);
        let cmp = compare(snap, &simd_mild, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.passed());
        assert!(!cmp.host_advisories.is_empty());
    }

    #[test]
    fn pending_baseline_is_advisory_but_renders_the_table() {
        let baseline = Json::parse(r#"{"version":6,"kind":"table3-snapshot","pending":true,"results":[]}"#)
            .unwrap();
        let snap = tiny_snapshot();
        let cmp = compare(&baseline, snap, DEFAULT_TOLERANCE).unwrap();
        assert!(cmp.pending && cmp.passed());
        // the bugfix: a pending baseline still renders every cell of the
        // current snapshot instead of an empty report
        assert_eq!(cmp.cells.len(), 11 * 5);
        let md = cmp.to_markdown();
        assert!(md.contains("baseline pending"));
        assert!(md.contains("gate **advisory**"), "{md}");
        assert!(md.contains("| stencil | method |"), "{md}");
        // a pending placeholder cannot satisfy the self-test
        assert!(self_test(&baseline, DEFAULT_TOLERANCE).is_err());
    }

    #[test]
    fn schema_mismatches_error_with_refresh_hint() {
        let snap = tiny_snapshot();
        let mut other = snap.clone();
        if let Json::Obj(m) = &mut other {
            m.insert("fingerprint".into(), Json::Str("other-machine".into()));
        }
        let err = compare(&other, snap, DEFAULT_TOLERANCE).unwrap_err().to_string();
        assert!(err.contains("refresh"), "{err}");
        // missing stencil row
        let mut short = snap.clone();
        if let Json::Obj(m) = &mut short {
            let rows = m.get("results").and_then(Json::as_arr).unwrap();
            let truncated = Json::Arr(rows[..rows.len() - 1].to_vec());
            m.insert("results".into(), truncated);
        }
        assert!(compare(snap, &short, DEFAULT_TOLERANCE).is_err());
    }
}
