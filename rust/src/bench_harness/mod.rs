//! Regenerates every figure and table of the paper's evaluation (§5).
//!
//! | Module | Paper artifact |
//! |---|---|
//! | [`fig3`] | Fig. 3 — star-stencil coefficient-line options vs order |
//! | [`fig4`] | Fig. 4 — multi-dimensional unrolling + scheduling ablation |
//! | [`fig5`] | Fig. 5 — autovec / DLT / TV / ours on r = 1 stencils |
//! | [`table3`] | Table 3 — speedups over auto-vectorization, full matrix |
//! | [`ablation`] | extra ablations (unroll, mregs, tuned-vs-default) |
//! | [`snapshot`] | machine-readable perf snapshot (`BENCH_8.json`: sim cycles + host wall-clock + fused-vs-unfused serving incl. per-phase profile) |
//! | [`compare`] | the CI perf-regression gate (`bench-compare`): fresh snapshot vs `bench/baseline.json`; >2% sim-cycle or >10% host wall-clock / serving-Mpts/s drift fails |
//!
//! Absolute cycle counts come from our simulator, not the paper's
//! proprietary one, so the comparison target is the *shape* of each
//! result (who wins, growth with order, in- vs out-of-cache behaviour);
//! EXPERIMENTS.md records paper-vs-measured side by side.
//!
//! Every number is produced by [`crate::codegen::run_method`], which
//! verifies the simulated program's output against the scalar oracle
//! before reporting — a result from an incorrect program is impossible.

pub mod ablation;
pub mod compare;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod report;
pub mod snapshot;
pub mod table3;

pub use report::Report;
