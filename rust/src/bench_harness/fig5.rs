//! Figure 5 — comparison with existing vectorization methods for r = 1
//! stencils: compiler auto-vectorization (baseline), DLT [20], temporal
//! vectorization [57], and the paper's method.
//!
//! Paper shapes to reproduce: ours best on in-cache sizes with box
//! stencils gaining more than stars; TV relatively strongest on
//! out-of-cache 2D sizes; DLT a modest constant factor.

use super::report::Report;
use crate::codegen::{run_method, verify::speedup, Method, MethodResult, OuterParams};
use crate::stencil::{StencilKind, StencilSpec};
use crate::sim::SimConfig;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// (stencil kind, dims) panels × the paper's four sizes each.
pub fn sizes(dims: usize) -> &'static [usize] {
    if dims == 2 {
        &[64, 128, 256, 512]
    } else {
        &[8, 16, 32, 64]
    }
}

/// The figure's method set for one stencil spec.
pub fn methods(spec: StencilSpec) -> Vec<(&'static str, Method)> {
    vec![
        ("autovec", Method::AutoVec),
        ("dlt", Method::Dlt),
        ("tv", Method::Tv),
        ("ours", Method::Outer(OuterParams::paper_best(spec))),
    ]
}

/// Run the full figure: 2D/3D × box/star, r = 1, four sizes each.
pub fn run_all(cfg: &SimConfig) -> anyhow::Result<Vec<Report>> {
    let mut reports = Vec::new();
    for dims in [2usize, 3] {
        for kind in [StencilKind::Box, StencilKind::Star] {
            let spec = StencilSpec { dims, order: 1, kind };
            let mut table =
                Table::new(&["N", "autovec", "dlt", "tv", "ours", "(speedups over autovec)"]);
            let mut points = Vec::new();
            for &n in sizes(dims) {
                let mut results: Vec<(&str, MethodResult)> = Vec::new();
                for (name, m) in methods(spec) {
                    let res = run_method(cfg, spec, n, m, true)?;
                    anyhow::ensure!(res.verified(), "{spec} {name} N={n}: {}", res.max_err);
                    results.push((name, res));
                }
                let base = results[0].1.clone();
                let mut row = vec![n.to_string()];
                for (name, res) in &results {
                    let s = speedup(&base, res);
                    row.push(format!("{s:.2}x"));
                    points.push(obj(vec![
                        ("stencil", Json::Str(spec.name())),
                        ("n", Json::Num(n as f64)),
                        ("method", Json::Str(name.to_string())),
                        ("speedup", Json::Num(s)),
                        ("cycles_per_point", Json::Num(res.cycles_per_point())),
                    ]));
                }
                row.push(String::new());
                table.row(row);
            }
            reports.push(Report {
                name: format!("fig5-{}", spec.name()),
                title: format!("{} r=1: methods vs size (speedup over autovec)", spec.name()),
                table,
                json: Json::Arr(points),
            });
        }
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_beats_baselines_in_cache_box2d() {
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let base = run_method(&cfg, spec, 64, Method::AutoVec, true).unwrap();
        let ours = run_method(
            &cfg,
            spec,
            64,
            Method::Outer(OuterParams::paper_best(spec)),
            true,
        )
        .unwrap();
        let dlt = run_method(&cfg, spec, 64, Method::Dlt, true).unwrap();
        let s_ours = speedup(&base, &ours);
        let s_dlt = speedup(&base, &dlt);
        assert!(s_ours > 1.8, "ours {s_ours:.2}");
        assert!(s_ours > s_dlt, "ours {s_ours:.2} vs dlt {s_dlt:.2}");
    }
}
