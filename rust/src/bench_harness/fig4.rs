//! Figure 4 — improvement from multi-dimensional unrolling (§4.2) and
//! outer-product scheduling (§4.3).
//!
//! Three variants per stencil: *naive* (no unrolling, per-tile reloads),
//! *+unroll* (the paper's unroll factors, still per-tile generation) and
//! *+unroll+sched* (shared input/coefficient vectors — the full method).
//! Paper shape: unrolling alone has limited effect ("the unrolling seems
//! to have limited effects in all cases"); scheduling on top is where the
//! gain is.

use super::report::Report;
use crate::codegen::{run_method, Method, OuterParams};
use crate::stencil::{StencilKind, StencilSpec};
use crate::sim::SimConfig;
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

/// Panels: (id, dims, N).
pub const PANELS: &[(&str, usize, usize)] = &[
    ("fig4a", 2, 64),
    ("fig4b", 2, 512),
    ("fig4c", 3, 16),
    ("fig4d", 3, 64),
];

/// Stencils per panel: box and star, orders 1..=3 (2D) / box 1..=2 +
/// star 1..=3 (3D), with the best coefficient-line option of Fig. 3.
fn specs(dims: usize) -> Vec<StencilSpec> {
    let mut v = Vec::new();
    let box_orders: &[usize] = if dims == 2 { &[1, 2, 3] } else { &[1, 2] };
    for &r in box_orders {
        v.push(StencilSpec { dims, order: r, kind: StencilKind::Box });
    }
    for r in 1..=3usize {
        v.push(StencilSpec { dims, order: r, kind: StencilKind::Star });
    }
    v
}

/// The three Fig. 4 variants of the paper's method for `spec`.
pub fn variants(spec: StencilSpec) -> [(&'static str, OuterParams); 3] {
    let best = OuterParams::paper_best(spec);
    [
        ("naive", OuterParams { ui: 1, uk: 1, scheduled: false, ..best }),
        ("unroll", OuterParams { scheduled: false, ..best }),
        ("unroll+sched", best),
    ]
}

/// Run one panel.
pub fn run_panel(cfg: &SimConfig, panel: &str, dims: usize, n: usize) -> anyhow::Result<Report> {
    let mut table = Table::new(&[
        "stencil",
        "naive (cyc/pt)",
        "unroll (cyc/pt)",
        "unroll+sched (cyc/pt)",
        "sched gain",
    ]);
    let mut points = Vec::new();
    for spec in specs(dims) {
        let mut cpp = Vec::new();
        for (vname, params) in variants(spec) {
            let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
            anyhow::ensure!(res.verified(), "{spec} {vname}: err {}", res.max_err);
            cpp.push(res.cycles_per_point());
            points.push(obj(vec![
                ("panel", Json::Str(panel.into())),
                ("stencil", Json::Str(spec.name())),
                ("variant", Json::Str(vname.into())),
                ("cycles_per_point", Json::Num(res.cycles_per_point())),
            ]));
        }
        table.row(vec![
            spec.name(),
            format!("{:.3}", cpp[0]),
            format!("{:.3}", cpp[1]),
            format!("{:.3}", cpp[2]),
            format!("{:.2}x", cpp[0] / cpp[2]),
        ]);
    }
    Ok(Report {
        name: panel.to_string(),
        title: format!("{dims}D N={n}: unrolling + scheduling ablation"),
        table,
        json: Json::Arr(points),
    })
}

/// Run all four panels.
pub fn run_all(cfg: &SimConfig) -> anyhow::Result<Vec<Report>> {
    PANELS.iter().map(|&(p, d, n)| run_panel(cfg, p, d, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduling_improves_over_naive() {
        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let [naive, _unroll, sched] = variants(spec);
        let a = run_method(&cfg, spec, 64, Method::Outer(naive.1), true).unwrap();
        let b = run_method(&cfg, spec, 64, Method::Outer(sched.1), true).unwrap();
        assert!(a.verified() && b.verified());
        assert!(
            b.cycles_per_point() < a.cycles_per_point(),
            "sched {:.3} should beat naive {:.3}",
            b.cycles_per_point(),
            a.cycles_per_point()
        );
    }
}
