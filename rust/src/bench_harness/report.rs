//! Report plumbing: markdown + JSON outputs for each regenerated
//! figure/table, written under `target/bench-reports/`.

use crate::util::bench::Table;
use crate::util::json::Json;
use std::path::PathBuf;

/// A named report: one regenerated paper artifact.
pub struct Report {
    /// Identifier, e.g. `fig3a`.
    pub name: String,
    /// Human title.
    pub title: String,
    /// The rendered table.
    pub table: Table,
    /// Raw datapoints for machine consumption.
    pub json: Json,
}

impl Report {
    /// Output directory (created on demand).
    pub fn dir() -> PathBuf {
        let d = PathBuf::from("target/bench-reports");
        let _ = std::fs::create_dir_all(&d);
        d
    }

    /// Write `<name>.md` and `<name>.json`; returns the markdown.
    pub fn save(&self) -> anyhow::Result<String> {
        let md = format!("# {} — {}\n\n{}", self.name, self.title, self.table.to_markdown());
        std::fs::write(Self::dir().join(format!("{}.md", self.name)), &md)?;
        std::fs::write(
            Self::dir().join(format!("{}.json", self.name)),
            self.json.to_string_compact(),
        )?;
        Ok(md)
    }

    /// Print to stdout and save.
    pub fn emit(&self) -> anyhow::Result<()> {
        let md = self.save()?;
        println!("{md}");
        Ok(())
    }
}
