//! Machine-readable perf snapshot (`BENCH_3.json`): per-method simulated
//! cycles *and* host wall-clock for the Table-3 stencil rows at one
//! representative size per dimensionality.
//!
//! This is the bench-trajectory artifact: small enough to regenerate on
//! every CI run (`stencil-matrix bench-json`), complete enough to detect
//! perf regressions in any method on either backend. Every simulated
//! number passes through [`run_method`] and every host number through
//! [`run_host`] (the KIR host executor), so a snapshot can only contain
//! oracle-verified runs.

use super::table3;
use crate::codegen::{run_host, run_method, verify::speedup, HostRun, Method, OuterParams};
use crate::sim::SimConfig;
use crate::util::json::{obj, Json};

/// Snapshot schema version (2: host wall-clock columns).
pub const SNAPSHOT_VERSION: u64 = 2;

fn method_json(
    cycles: u64,
    cycles_per_point: f64,
    speedup: f64,
    host: &HostRun,
    points: usize,
) -> Json {
    obj(vec![
        ("cycles", Json::Num(cycles as f64)),
        ("cycles_per_point", Json::Num(cycles_per_point)),
        ("speedup", Json::Num(speedup)),
        ("host_seconds", Json::Num(host.seconds)),
        (
            "host_mpts_per_s",
            Json::Num((points * host.steps) as f64 / host.seconds.max(1e-12) / 1e6),
        ),
        ("host_ops", Json::Num(host.ops as f64)),
    ])
}

/// Run the host backend for one cell, enforcing the same verification
/// bar as the simulated run.
fn host_cell(cfg: &SimConfig, spec: crate::stencil::StencilSpec, n: usize, method: Method) -> anyhow::Result<HostRun> {
    let host = run_host(cfg, spec, n, method)?;
    anyhow::ensure!(host.verified(), "{spec} {method} N={n} host: max_err {}", host.max_err);
    Ok(host)
}

/// Build the snapshot: every Table-3 spec at `n2d`² / `n3d`³, methods
/// scalar / autovec / dlt / tv / outer (best Table-3 candidate per cell,
/// with its plan label). Speedups are vs. auto-vectorization, the
/// paper's baseline; each cell also carries the KIR host executor's
/// wall-clock next to the simulated cycles.
pub fn run(cfg: &SimConfig, n2d: usize, n3d: usize) -> anyhow::Result<Json> {
    let mut results = Vec::new();
    for dims in [2usize, 3] {
        let n = if dims == 2 { n2d } else { n3d };
        for spec in table3::rows(dims) {
            let base = run_method(cfg, spec, n, Method::AutoVec, true)?;
            anyhow::ensure!(base.verified(), "{spec} autovec N={n}: max_err {}", base.max_err);
            let base_host = host_cell(cfg, spec, n, Method::AutoVec)?;
            let mut methods: Vec<(&str, Json)> = Vec::new();
            methods.push((
                "autovec",
                method_json(
                    base.stats.cycles,
                    base.cycles_per_point(),
                    1.0,
                    &base_host,
                    base.points(),
                ),
            ));
            for (name, method) in
                [("scalar", Method::Scalar), ("dlt", Method::Dlt), ("tv", Method::Tv)]
            {
                let res = run_method(cfg, spec, n, method, true)?;
                anyhow::ensure!(res.verified(), "{spec} {method} N={n}: max_err {}", res.max_err);
                let host = host_cell(cfg, spec, n, method)?;
                methods.push((
                    name,
                    method_json(
                        res.stats.cycles,
                        res.cycles_per_point(),
                        speedup(&base, &res),
                        &host,
                        res.points(),
                    ),
                ));
            }
            // "our" method: best of the Table-3 candidate set for the cell
            let mut best: Option<(OuterParams, crate::codegen::MethodResult)> = None;
            for params in table3::candidates(spec) {
                let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
                anyhow::ensure!(res.verified(), "{spec} {params:?} N={n}");
                if best
                    .as_ref()
                    .map(|(_, b)| res.cycles_per_point() < b.cycles_per_point())
                    .unwrap_or(true)
                {
                    best = Some((params, res));
                }
            }
            let (bp, bres) = best.expect("candidate set is never empty");
            let best_host = host_cell(cfg, spec, n, Method::Outer(bp))?;
            let mut outer = method_json(
                bres.stats.cycles,
                bres.cycles_per_point(),
                speedup(&base, &bres),
                &best_host,
                bres.points(),
            );
            if let Json::Obj(m) = &mut outer {
                m.insert("plan".to_string(), Json::Str(bp.label(dims)));
            }
            methods.push(("outer", outer));
            results.push(obj(vec![
                ("stencil", Json::Str(spec.name())),
                ("dims", Json::Num(dims as f64)),
                ("n", Json::Num(n as f64)),
                ("methods", obj(methods)),
            ]));
        }
    }
    Ok(obj(vec![
        ("version", Json::Num(SNAPSHOT_VERSION as f64)),
        ("kind", Json::Str("table3-snapshot".into())),
        ("fingerprint", Json::Str(cfg.fingerprint())),
        (
            "sizes",
            obj(vec![("2d", Json::Num(n2d as f64)), ("3d", Json::Num(n3d as f64))]),
        ),
        ("results", Json::Arr(results)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_covers_every_table3_row() {
        // tiny sizes keep this test fast; CI regenerates at 64/16
        let j = run(&SimConfig::default(), 16, 8).unwrap();
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(2));
        let results = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 6 + 5); // 2D rows + 3D rows
        for r in results {
            let methods = r.get("methods").unwrap();
            for m in ["scalar", "autovec", "dlt", "tv", "outer"] {
                let e = methods.get(m).unwrap_or_else(|| panic!("missing {m}"));
                assert!(e.get("cycles").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(e.get("speedup").and_then(Json::as_f64).unwrap() > 0.0);
                // host wall-clock columns ride along with the sim cycles
                assert!(e.get("host_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_mpts_per_s").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_ops").and_then(Json::as_f64).unwrap() > 0.0);
            }
            assert_eq!(
                methods.get("autovec").unwrap().get("speedup").and_then(Json::as_f64),
                Some(1.0)
            );
            assert!(methods.get("outer").unwrap().get("plan").and_then(Json::as_str).is_some());
        }
        // round-trips through the parser
        let rt = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(rt.get("kind").and_then(Json::as_str), Some("table3-snapshot"));
    }
}
