//! Machine-readable perf snapshot (`BENCH_8.json`): per-method simulated
//! cycles *and* host wall-clock — interpreter vs compiled vs explicit
//! SIMD — for the Table-3 stencil rows at one representative size per
//! dimensionality, plus a fused-vs-unfused serving measurement per row
//! (temporal blocking at depth [`FUSE_STEPS`]) with a traced per-phase
//! profile (embed / compute / freeze / exchange / extract seconds).
//!
//! This is the bench-trajectory artifact: small enough to regenerate on
//! every CI run (`stencil-matrix bench-json`), complete enough to detect
//! perf regressions in any method on either backend. The simulated
//! cycles and op counts are **deterministic** (the simulator has no
//! noise), which is what `bench/baseline.json` + the `bench-compare` CI
//! gate key on; host wall-clock (including the fused-serve columns) is
//! advisory. Every simulated number passes through [`run_method`] and
//! every host number through [`run_host`], so a snapshot can only
//! contain oracle-verified runs — all three host engines are checked
//! bitwise-equal per cell, and the fused serve run is checked bitwise
//! against the unfused one.

use super::table3;
use crate::codegen::{run_host, run_method, verify::speedup, HostRun, Method, OuterParams};
use crate::kir::Engine;
use crate::serve::{KernelMethod, ShardedEvolver};
use crate::stencil::DenseGrid;
use crate::sim::SimConfig;
use crate::util::json::{obj, Json};
use std::time::Instant;

/// Snapshot schema version (6: explicit-SIMD engine columns per cell).
pub const SNAPSHOT_VERSION: u64 = 6;

/// Time-tile depth of the snapshot's fused serving measurement.
pub const FUSE_STEPS: usize = 4;

/// Time steps the fused serving measurement advances per run.
const FUSE_TOTAL_STEPS: usize = 8;

fn mpts(points: usize, run: &HostRun) -> f64 {
    run.mpts_per_s(points)
}

fn method_json(
    cycles: u64,
    cycles_per_point: f64,
    speedup: f64,
    interp: &HostRun,
    compiled: &HostRun,
    simd: &HostRun,
    points: usize,
) -> Json {
    obj(vec![
        ("cycles", Json::Num(cycles as f64)),
        ("cycles_per_point", Json::Num(cycles_per_point)),
        ("speedup", Json::Num(speedup)),
        // compiled engine (the serving default)
        ("host_seconds", Json::Num(compiled.seconds)),
        ("host_mpts_per_s", Json::Num(mpts(points, compiled))),
        ("host_threads", Json::Num(compiled.threads as f64)),
        // interpreter twin + the engine-vs-interpreter ratio
        ("host_interp_seconds", Json::Num(interp.seconds)),
        ("host_interp_mpts_per_s", Json::Num(mpts(points, interp))),
        (
            "engine_speedup",
            Json::Num(interp.seconds / compiled.seconds.max(1e-12)),
        ),
        // explicit-SIMD engine + its ratio over the compiled engine
        ("simd_seconds", Json::Num(simd.seconds)),
        ("simd_mpts_per_s", Json::Num(mpts(points, simd))),
        (
            "simd_speedup",
            Json::Num(compiled.seconds / simd.seconds.max(1e-12)),
        ),
        ("host_ops", Json::Num(compiled.ops as f64)),
    ])
}

/// Run all three host engines for one cell, enforcing the same
/// verification bar as the simulated run plus bitwise engine equality.
/// Returns (interpreter, compiled, simd).
fn host_cell(
    cfg: &SimConfig,
    spec: crate::stencil::StencilSpec,
    n: usize,
    method: Method,
) -> anyhow::Result<(HostRun, HostRun, HostRun)> {
    let interp = run_host(cfg, spec, n, method, Engine::Interpret)?;
    anyhow::ensure!(interp.verified(), "{spec} {method} N={n} host: max_err {}", interp.max_err);
    let compiled = run_host(cfg, spec, n, method, Engine::Compiled)?;
    anyhow::ensure!(
        compiled.grid.data == interp.grid.data,
        "{spec} {method} N={n}: engines disagree bitwise"
    );
    anyhow::ensure!(compiled.ops == interp.ops, "{spec} {method} N={n}: op counts diverge");
    let simd = run_host(cfg, spec, n, method, Engine::Simd)?;
    anyhow::ensure!(
        simd.grid.data == interp.grid.data,
        "{spec} {method} N={n}: simd engine disagrees bitwise with the interpreter"
    );
    anyhow::ensure!(simd.ops == interp.ops, "{spec} {method} N={n}: simd op count diverges");
    Ok((interp, compiled, simd))
}

/// Fused-vs-unfused serving measurement for one stencil row: evolve the
/// deterministic verification grid [`FUSE_TOTAL_STEPS`] steps through
/// the sharded evolver with the outer KIR kernel, once with per-step
/// halo exchanges (`T = 1`) and once temporally blocked at
/// [`FUSE_STEPS`]. The two outputs are checked **bitwise equal**;
/// wall-clock is best-of-2 and advisory (never gated).
fn fused_serve(spec: crate::stencil::StencilSpec, n: usize) -> anyhow::Result<Json> {
    let shape = vec![n + 2 * spec.order; spec.dims];
    let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
    let ev = ShardedEvolver::new(2);
    let shards = 2usize;
    let method = KernelMethod::Outer;
    // warm the plan cache so one-time kernel compilation stays out of
    // the timed runs
    ev.evolve_fused(spec, &grid, FUSE_TOTAL_STEPS, shards, method, 1)?;
    ev.evolve_fused(spec, &grid, FUSE_TOTAL_STEPS, shards, method, FUSE_STEPS)?;
    let time = |fuse: usize| -> anyhow::Result<(f64, DenseGrid, crate::serve::FuseReport)> {
        let mut best = f64::INFINITY;
        let mut last = None;
        for _ in 0..2 {
            let t0 = Instant::now();
            let r = ev.evolve_fused(spec, &grid, FUSE_TOTAL_STEPS, shards, method, fuse)?;
            best = best.min(t0.elapsed().as_secs_f64());
            last = Some(r);
        }
        let (g, _, fr) = last.unwrap();
        Ok((best, g, fr))
    };
    let (unfused_s, unfused_g, fr1) = time(1)?;
    let (fused_s, fused_g, frt) = time(FUSE_STEPS)?;
    anyhow::ensure!(
        fused_g == unfused_g,
        "{spec}: fused serving diverged bitwise from unfused"
    );
    // one traced fused run *after* the timed ones: the spans feed the
    // per-phase profile without perturbing the advisory wall-clocks
    let (traced, spans) = crate::obs::span::trace(|| {
        ev.evolve_fused(spec, &grid, FUSE_TOTAL_STEPS, shards, method, FUSE_STEPS)
    });
    traced?;
    let profile = crate::obs::profile::aggregate(&spans);
    let point_steps = (n.pow(spec.dims as u32) * FUSE_TOTAL_STEPS) as f64;
    Ok(obj(vec![
        ("steps", Json::Num(FUSE_TOTAL_STEPS as f64)),
        ("fuse_steps", Json::Num(frt.fuse_steps as f64)),
        ("halo_exchanges_unfused", Json::Num(fr1.halo_exchanges as f64)),
        ("halo_exchanges_fused", Json::Num(frt.halo_exchanges as f64)),
        ("unfused_seconds", Json::Num(unfused_s)),
        ("fused_seconds", Json::Num(fused_s)),
        ("unfused_mpts_per_s", Json::Num(point_steps / unfused_s.max(1e-12) / 1e6)),
        ("fused_mpts_per_s", Json::Num(point_steps / fused_s.max(1e-12) / 1e6)),
        ("fused_speedup", Json::Num(unfused_s / fused_s.max(1e-12))),
        ("profile", profile.to_json()),
    ]))
}

/// Build the snapshot: every Table-3 spec at `n2d`² / `n3d`³, methods
/// scalar / autovec / dlt / tv / outer (best Table-3 candidate per cell,
/// with its plan label). Speedups are vs. auto-vectorization, the
/// paper's baseline; each cell also carries the host engines'
/// wall-clock next to the simulated cycles (interpreter, compiled and
/// simd — the last bitwise-checked against the first), and each row a
/// fused-vs-unfused serving measurement ([`fused_serve`]).
pub fn run(cfg: &SimConfig, n2d: usize, n3d: usize) -> anyhow::Result<Json> {
    let mut results = Vec::new();
    for dims in [2usize, 3] {
        let n = if dims == 2 { n2d } else { n3d };
        for spec in table3::rows(dims) {
            let base = run_method(cfg, spec, n, Method::AutoVec, true)?;
            anyhow::ensure!(base.verified(), "{spec} autovec N={n}: max_err {}", base.max_err);
            let (base_i, base_c, base_s) = host_cell(cfg, spec, n, Method::AutoVec)?;
            let mut methods: Vec<(&str, Json)> = Vec::new();
            methods.push((
                "autovec",
                method_json(
                    base.stats.cycles,
                    base.cycles_per_point(),
                    1.0,
                    &base_i,
                    &base_c,
                    &base_s,
                    base.points(),
                ),
            ));
            for (name, method) in
                [("scalar", Method::Scalar), ("dlt", Method::Dlt), ("tv", Method::Tv)]
            {
                let res = run_method(cfg, spec, n, method, true)?;
                anyhow::ensure!(res.verified(), "{spec} {method} N={n}: max_err {}", res.max_err);
                let (hi, hc, hs) = host_cell(cfg, spec, n, method)?;
                methods.push((
                    name,
                    method_json(
                        res.stats.cycles,
                        res.cycles_per_point(),
                        speedup(&base, &res),
                        &hi,
                        &hc,
                        &hs,
                        res.points(),
                    ),
                ));
            }
            // "our" method: best of the Table-3 candidate set for the cell
            let mut best: Option<(OuterParams, crate::codegen::MethodResult)> = None;
            for params in table3::candidates(spec) {
                let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
                anyhow::ensure!(res.verified(), "{spec} {params:?} N={n}");
                if best
                    .as_ref()
                    .map(|(_, b)| res.cycles_per_point() < b.cycles_per_point())
                    .unwrap_or(true)
                {
                    best = Some((params, res));
                }
            }
            let (bp, bres) = best.expect("candidate set is never empty");
            let (bi, bc, bs) = host_cell(cfg, spec, n, Method::Outer(bp))?;
            let mut outer = method_json(
                bres.stats.cycles,
                bres.cycles_per_point(),
                speedup(&base, &bres),
                &bi,
                &bc,
                &bs,
                bres.points(),
            );
            if let Json::Obj(m) = &mut outer {
                m.insert("plan".to_string(), Json::Str(bp.label(dims)));
            }
            methods.push(("outer", outer));
            results.push(obj(vec![
                ("stencil", Json::Str(spec.name())),
                ("dims", Json::Num(dims as f64)),
                ("n", Json::Num(n as f64)),
                ("methods", obj(methods)),
                ("fused_serve", fused_serve(spec, n)?),
            ]));
        }
    }
    Ok(obj(vec![
        ("version", Json::Num(SNAPSHOT_VERSION as f64)),
        ("kind", Json::Str("table3-snapshot".into())),
        ("fingerprint", Json::Str(cfg.fingerprint())),
        (
            "sizes",
            obj(vec![("2d", Json::Num(n2d as f64)), ("3d", Json::Num(n3d as f64))]),
        ),
        ("results", Json::Arr(results)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_covers_every_table3_row() {
        // tiny sizes keep this test fast; CI regenerates at 64/16
        let j = run(&SimConfig::default(), 16, 8).unwrap();
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(6));
        let results = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 6 + 5); // 2D rows + 3D rows
        for r in results {
            let methods = r.get("methods").unwrap();
            for m in ["scalar", "autovec", "dlt", "tv", "outer"] {
                let e = methods.get(m).unwrap_or_else(|| panic!("missing {m}"));
                assert!(e.get("cycles").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(e.get("speedup").and_then(Json::as_f64).unwrap() > 0.0);
                // both host engines ride along with the sim cycles
                assert!(e.get("host_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_mpts_per_s").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_interp_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("engine_speedup").and_then(Json::as_f64).unwrap() > 0.0);
                // the simd engine rides along (bitwise-checked inside run)
                assert!(e.get("simd_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("simd_mpts_per_s").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("simd_speedup").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(e.get("host_threads").and_then(Json::as_f64).unwrap() >= 1.0);
                assert!(e.get("host_ops").and_then(Json::as_f64).unwrap() > 0.0);
            }
            assert_eq!(
                methods.get("autovec").unwrap().get("speedup").and_then(Json::as_f64),
                Some(1.0)
            );
            assert!(methods.get("outer").unwrap().get("plan").and_then(Json::as_str).is_some());
            // the fused-vs-unfused serving cell (bitwise-checked inside run)
            let fs = r.get("fused_serve").expect("row carries fused_serve");
            assert_eq!(fs.get("steps").and_then(Json::as_usize), Some(8));
            let t = fs.get("fuse_steps").and_then(Json::as_usize).unwrap();
            assert!((1..=FUSE_STEPS).contains(&t));
            let unfused_x = fs.get("halo_exchanges_unfused").and_then(Json::as_usize).unwrap();
            let fused_x = fs.get("halo_exchanges_fused").and_then(Json::as_usize).unwrap();
            assert_eq!(unfused_x, 8 - 1);
            assert_eq!(fused_x, 8usize.div_ceil(t) - 1);
            assert!(fs.get("fused_speedup").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(fs.get("fused_mpts_per_s").and_then(Json::as_f64).unwrap() > 0.0);
            // the traced per-phase profile rides on the fused serve cell
            let prof = crate::obs::PhaseProfile::from_json(fs.get("profile").unwrap());
            assert!(prof.spans > 0, "traced run recorded phase spans");
            assert!(prof.total() > 0.0);
        }
        // round-trips through the parser
        let rt = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(rt.get("kind").and_then(Json::as_str), Some("table3-snapshot"));
    }
}
