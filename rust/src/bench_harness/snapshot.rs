//! Machine-readable perf snapshot (`BENCH_4.json`): per-method simulated
//! cycles *and* host wall-clock — compiled engine vs interpreter — for
//! the Table-3 stencil rows at one representative size per
//! dimensionality.
//!
//! This is the bench-trajectory artifact: small enough to regenerate on
//! every CI run (`stencil-matrix bench-json`), complete enough to detect
//! perf regressions in any method on either backend. The simulated
//! cycles and op counts are **deterministic** (the simulator has no
//! noise), which is what `bench/baseline.json` + the `bench-compare` CI
//! gate key on; host wall-clock is advisory. Every simulated number
//! passes through [`run_method`] and every host number through
//! [`run_host`], so a snapshot can only contain oracle-verified runs —
//! and the two host engines are checked bitwise-equal per cell.

use super::table3;
use crate::codegen::{run_host, run_method, verify::speedup, HostRun, Method, OuterParams};
use crate::kir::Engine;
use crate::sim::SimConfig;
use crate::util::json::{obj, Json};

/// Snapshot schema version (3: compiled-vs-interpreter host columns).
pub const SNAPSHOT_VERSION: u64 = 3;

fn mpts(points: usize, run: &HostRun) -> f64 {
    run.mpts_per_s(points)
}

fn method_json(
    cycles: u64,
    cycles_per_point: f64,
    speedup: f64,
    interp: &HostRun,
    compiled: &HostRun,
    points: usize,
) -> Json {
    obj(vec![
        ("cycles", Json::Num(cycles as f64)),
        ("cycles_per_point", Json::Num(cycles_per_point)),
        ("speedup", Json::Num(speedup)),
        // compiled engine (the serving default)
        ("host_seconds", Json::Num(compiled.seconds)),
        ("host_mpts_per_s", Json::Num(mpts(points, compiled))),
        ("host_threads", Json::Num(compiled.threads as f64)),
        // interpreter twin + the engine-vs-interpreter ratio
        ("host_interp_seconds", Json::Num(interp.seconds)),
        ("host_interp_mpts_per_s", Json::Num(mpts(points, interp))),
        (
            "engine_speedup",
            Json::Num(interp.seconds / compiled.seconds.max(1e-12)),
        ),
        ("host_ops", Json::Num(compiled.ops as f64)),
    ])
}

/// Run both host engines for one cell, enforcing the same verification
/// bar as the simulated run plus bitwise engine equality. Returns
/// (interpreter, compiled).
fn host_cell(
    cfg: &SimConfig,
    spec: crate::stencil::StencilSpec,
    n: usize,
    method: Method,
) -> anyhow::Result<(HostRun, HostRun)> {
    let interp = run_host(cfg, spec, n, method, Engine::Interpret)?;
    anyhow::ensure!(interp.verified(), "{spec} {method} N={n} host: max_err {}", interp.max_err);
    let compiled = run_host(cfg, spec, n, method, Engine::Compiled)?;
    anyhow::ensure!(
        compiled.grid.data == interp.grid.data,
        "{spec} {method} N={n}: engines disagree bitwise"
    );
    anyhow::ensure!(compiled.ops == interp.ops, "{spec} {method} N={n}: op counts diverge");
    Ok((interp, compiled))
}

/// Build the snapshot: every Table-3 spec at `n2d`² / `n3d`³, methods
/// scalar / autovec / dlt / tv / outer (best Table-3 candidate per cell,
/// with its plan label). Speedups are vs. auto-vectorization, the
/// paper's baseline; each cell also carries both host engines'
/// wall-clock next to the simulated cycles.
pub fn run(cfg: &SimConfig, n2d: usize, n3d: usize) -> anyhow::Result<Json> {
    let mut results = Vec::new();
    for dims in [2usize, 3] {
        let n = if dims == 2 { n2d } else { n3d };
        for spec in table3::rows(dims) {
            let base = run_method(cfg, spec, n, Method::AutoVec, true)?;
            anyhow::ensure!(base.verified(), "{spec} autovec N={n}: max_err {}", base.max_err);
            let (base_i, base_c) = host_cell(cfg, spec, n, Method::AutoVec)?;
            let mut methods: Vec<(&str, Json)> = Vec::new();
            methods.push((
                "autovec",
                method_json(
                    base.stats.cycles,
                    base.cycles_per_point(),
                    1.0,
                    &base_i,
                    &base_c,
                    base.points(),
                ),
            ));
            for (name, method) in
                [("scalar", Method::Scalar), ("dlt", Method::Dlt), ("tv", Method::Tv)]
            {
                let res = run_method(cfg, spec, n, method, true)?;
                anyhow::ensure!(res.verified(), "{spec} {method} N={n}: max_err {}", res.max_err);
                let (hi, hc) = host_cell(cfg, spec, n, method)?;
                methods.push((
                    name,
                    method_json(
                        res.stats.cycles,
                        res.cycles_per_point(),
                        speedup(&base, &res),
                        &hi,
                        &hc,
                        res.points(),
                    ),
                ));
            }
            // "our" method: best of the Table-3 candidate set for the cell
            let mut best: Option<(OuterParams, crate::codegen::MethodResult)> = None;
            for params in table3::candidates(spec) {
                let res = run_method(cfg, spec, n, Method::Outer(params), true)?;
                anyhow::ensure!(res.verified(), "{spec} {params:?} N={n}");
                if best
                    .as_ref()
                    .map(|(_, b)| res.cycles_per_point() < b.cycles_per_point())
                    .unwrap_or(true)
                {
                    best = Some((params, res));
                }
            }
            let (bp, bres) = best.expect("candidate set is never empty");
            let (bi, bc) = host_cell(cfg, spec, n, Method::Outer(bp))?;
            let mut outer = method_json(
                bres.stats.cycles,
                bres.cycles_per_point(),
                speedup(&base, &bres),
                &bi,
                &bc,
                bres.points(),
            );
            if let Json::Obj(m) = &mut outer {
                m.insert("plan".to_string(), Json::Str(bp.label(dims)));
            }
            methods.push(("outer", outer));
            results.push(obj(vec![
                ("stencil", Json::Str(spec.name())),
                ("dims", Json::Num(dims as f64)),
                ("n", Json::Num(n as f64)),
                ("methods", obj(methods)),
            ]));
        }
    }
    Ok(obj(vec![
        ("version", Json::Num(SNAPSHOT_VERSION as f64)),
        ("kind", Json::Str("table3-snapshot".into())),
        ("fingerprint", Json::Str(cfg.fingerprint())),
        (
            "sizes",
            obj(vec![("2d", Json::Num(n2d as f64)), ("3d", Json::Num(n3d as f64))]),
        ),
        ("results", Json::Arr(results)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_covers_every_table3_row() {
        // tiny sizes keep this test fast; CI regenerates at 64/16
        let j = run(&SimConfig::default(), 16, 8).unwrap();
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(3));
        let results = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 6 + 5); // 2D rows + 3D rows
        for r in results {
            let methods = r.get("methods").unwrap();
            for m in ["scalar", "autovec", "dlt", "tv", "outer"] {
                let e = methods.get(m).unwrap_or_else(|| panic!("missing {m}"));
                assert!(e.get("cycles").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(e.get("speedup").and_then(Json::as_f64).unwrap() > 0.0);
                // both host engines ride along with the sim cycles
                assert!(e.get("host_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_mpts_per_s").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("host_interp_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("engine_speedup").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(e.get("host_threads").and_then(Json::as_f64).unwrap() >= 1.0);
                assert!(e.get("host_ops").and_then(Json::as_f64).unwrap() > 0.0);
            }
            assert_eq!(
                methods.get("autovec").unwrap().get("speedup").and_then(Json::as_f64),
                Some(1.0)
            );
            assert!(methods.get("outer").unwrap().get("plan").and_then(Json::as_str).is_some());
        }
        // round-trips through the parser
        let rt = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(rt.get("kind").and_then(Json::as_str), Some("table3-snapshot"));
    }
}
