//! The batched request front-end: a bounded queue with backpressure,
//! coalescing of identical queued requests, per-request latency and
//! aggregate throughput metrics (JSON), and a dispatcher that executes
//! requests on the sharded multi-threaded evolver.
//!
//! This module also hosts [`EvolutionService`], the PJRT artifact-serving
//! request path that previously lived in `coordinator::service` (that
//! module now re-exports from here): the native sharded server and the
//! compiled-artifact server are the two backends of the same serving
//! layer.

use super::metrics::ServiceMetrics;
use super::scheduler::{KernelMethod, ShardedEvolver};
use crate::kir::Engine;
use crate::obs::registry;
use crate::obs::span::span;
use crate::runtime::{PjrtRuntime, Registry, StencilEngine};
use crate::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use crate::util::json::{obj, Json};
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Native sharded serving
// ---------------------------------------------------------------------------

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads in the pool.
    pub workers: usize,
    /// Shards per request (0 = one per worker).
    pub shards: usize,
    /// Bounded queue capacity; submissions beyond it block (or are
    /// rejected via [`StencilServer::try_submit`]).
    pub queue_depth: usize,
    /// Plan-cache capacity (compiled kernels).
    pub plan_cache: usize,
    /// Host execution engine for KIR shard kernels (`outer`, compiled
    /// tuned plans): the compiling engine by default, with the op-by-op
    /// interpreter as the bitwise-identical reference twin.
    pub engine: Engine,
    /// Time-tile depth `T`: fuse up to `T` time steps per kernel
    /// application behind `order * T`-deep ghosts, exchanging halos only
    /// every `T` steps (1 = classic per-step exchange). Capped per
    /// request so deep halos never starve the shard count
    /// ([`crate::serve::Partition::max_fuse`]); results are bitwise
    /// independent of `T`. `tuned`-kernel requests additionally adopt
    /// the tuning database plan's depth when it is larger, so a fused
    /// tune winner actually runs fused.
    pub fuse_steps: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            shards: 0,
            queue_depth: 32,
            plan_cache: 32,
            engine: Engine::default(),
            fuse_steps: 1,
        }
    }
}

/// A request to evolve the deterministic verification grid for a stencil.
///
/// Identical requests still *queued* are coalesced: they share one
/// computation and one response. (Requests are identified by every field,
/// so two requests differing only in `seed` are distinct artifacts; a
/// request already popped by the dispatcher is recomputed, not joined.)
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShardRequest {
    /// The stencil to apply.
    pub spec: StencilSpec,
    /// Interior extent per dimension (storage is `n + 2·order`).
    pub n: usize,
    /// Time steps to advance.
    pub steps: usize,
    /// Seed of the deterministic input grid.
    pub seed: u64,
    /// Shard kernel to use.
    pub method: KernelMethod,
    /// Check the result bitwise against the scalar oracle.
    pub verify: bool,
}

/// Per-request outcome accounting.
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Seconds spent queued before dispatch.
    pub queue_seconds: f64,
    /// Seconds spent computing (kernel + verification).
    pub service_seconds: f64,
    /// Seconds spent in the sharded kernel path (excludes queueing and
    /// verification; a request that compiles a new shard plan pays that
    /// one-time compilation here too) — what the metrics' `kernel_time`
    /// p50/p99 track.
    pub kernel_seconds: f64,
    /// Interior points of the grid.
    pub points: usize,
    /// Time steps advanced.
    pub steps: usize,
    /// Shards actually used (after clamping).
    pub shards: usize,
    /// Effective time-tile depth `T` this request ran with (fused steps
    /// per kernel application, after capping).
    pub fused_steps: usize,
    /// Halo-exchange rounds this request performed
    /// (`ceil(steps / T) - 1` for multi-shard runs).
    pub halo_exchanges: usize,
    /// Submissions that shared this computation (1 = no coalescing).
    pub waiters: usize,
    /// Max |error| vs the scalar oracle (0.0 expected), if verified.
    pub max_err: Option<f64>,
    /// Label of the tuning-database plan the kernel LRU matched for this
    /// request (`tuned` kernel on a server with a tuning DB; `None`
    /// otherwise, including when the DB has no entry for the stencil).
    pub tuned_plan: Option<String>,
}

/// A served response: the evolved grid plus accounting.
#[derive(Debug, Clone)]
pub struct ShardResponse {
    /// The evolved grid (storage shape).
    pub grid: DenseGrid,
    /// Accounting for this request.
    pub report: ShardReport,
}

struct Slot {
    state: Mutex<Option<Result<Arc<ShardResponse>, String>>>,
    ready: Condvar,
}

impl Slot {
    fn new() -> Arc<Slot> {
        Arc::new(Slot { state: Mutex::new(None), ready: Condvar::new() })
    }

    fn fulfill(&self, result: Result<Arc<ShardResponse>, String>) {
        *self.state.lock().unwrap() = Some(result);
        self.ready.notify_all();
    }
}

/// A handle to a submitted request; coalesced submissions share the
/// underlying response.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the request has been served.
    pub fn wait(&self) -> anyhow::Result<Arc<ShardResponse>> {
        let mut state = self.slot.state.lock().unwrap();
        loop {
            match &*state {
                Some(Ok(resp)) => return Ok(Arc::clone(resp)),
                Some(Err(msg)) => anyhow::bail!("{msg}"),
                None => state = self.slot.ready.wait(state).unwrap(),
            }
        }
    }
}

struct Pending {
    req: ShardRequest,
    slot: Arc<Slot>,
    enqueued: Instant,
    waiters: usize,
}

struct QueueInner {
    entries: VecDeque<Pending>,
    closed: bool,
}

/// Everything the dispatcher thread needs. The thread holds an
/// `Arc<ServerInner>` — *not* the outer [`StencilServer`] — so dropping
/// the server handle still fires its `Drop`, which shuts the queue and
/// joins the thread (no leaked dispatcher).
struct ServerInner {
    cfg: ServeConfig,
    evolver: ShardedEvolver,
    queue: Mutex<QueueInner>,
    not_full: Condvar,
    not_empty: Condvar,
    metrics: Mutex<ServiceMetrics>,
    /// Server construction time — the epoch `last_request_ns` counts from.
    epoch: Instant,
    /// Nanoseconds since `epoch` at which the most recent request
    /// finished (0 = none yet); feeds the `/healthz` last-request age.
    last_request_ns: AtomicU64,
}

impl ServerInner {
    /// Under the queue lock: coalesce onto an identical queued request,
    /// or enqueue, or give the request back if the queue is full.
    fn admit(&self, q: &mut QueueInner, req: ShardRequest) -> Result<Ticket, ShardRequest> {
        let _g = span("serve.enqueue", "serve");
        if let Some(p) = q.entries.iter_mut().find(|p| p.req == req) {
            let _c = span("serve.coalesce", "serve");
            p.waiters += 1;
            self.metrics.lock().unwrap().record_coalesced();
            return Ok(Ticket { slot: Arc::clone(&p.slot) });
        }
        if q.entries.len() >= self.cfg.queue_depth {
            return Err(req);
        }
        let slot = Slot::new();
        q.entries.push_back(Pending {
            req,
            slot: Arc::clone(&slot),
            enqueued: Instant::now(),
            waiters: 1,
        });
        self.metrics.lock().unwrap().record_queue_depth(q.entries.len());
        self.not_empty.notify_all();
        Ok(Ticket { slot })
    }

    fn effective_shards(&self) -> usize {
        if self.cfg.shards == 0 {
            self.evolver.pool().workers()
        } else {
            self.cfg.shards
        }
    }

    fn pop_blocking(&self) -> Option<Pending> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(p) = q.entries.pop_front() {
                self.not_full.notify_all();
                return Some(p);
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
    }

    fn handle(&self, pending: Pending) {
        let _g = span("serve.dispatch", "serve");
        let queue_seconds = pending.enqueued.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let result = self.execute(&pending.req);
        let service_seconds = t0.elapsed().as_secs_f64();
        let waiters = pending.waiters;
        match result {
            Ok((grid, max_err, shards, kernel_seconds, fuse)) => {
                let tuned_plan = if pending.req.method == KernelMethod::Tuned {
                    self.evolver.cache().tuned_label(pending.req.spec)
                } else {
                    None
                };
                let points = pending.req.n.pow(pending.req.spec.dims as u32);
                {
                    let mut m = self.metrics.lock().unwrap();
                    // served work: each coalesced waiter received these
                    // point-steps, same as `completed` counts submissions
                    m.record_completed(
                        waiters as u64,
                        (points * pending.req.steps * waiters) as u64,
                    );
                    m.record_queue_wait(queue_seconds);
                    m.record_service_time(service_seconds);
                    m.record_kernel_time(kernel_seconds);
                    m.halo_exchanges.record(fuse.halo_exchanges as f64);
                    m.fused_steps.record(fuse.fuse_steps as f64);
                }
                self.touch();
                let report = ShardReport {
                    queue_seconds,
                    service_seconds,
                    kernel_seconds,
                    points,
                    steps: pending.req.steps,
                    shards,
                    fused_steps: fuse.fuse_steps,
                    halo_exchanges: fuse.halo_exchanges,
                    waiters,
                    max_err,
                    tuned_plan,
                };
                pending.slot.fulfill(Ok(Arc::new(ShardResponse { grid, report })));
            }
            Err(e) => {
                self.metrics.lock().unwrap().record_failed(waiters as u64);
                self.touch();
                pending.slot.fulfill(Err(format!("{e:#}")));
            }
        }
    }

    /// Stamp "a request just finished" for the `/healthz` age readout.
    fn touch(&self) {
        let ns = self.epoch.elapsed().as_nanos() as u64;
        self.last_request_ns.store(ns.max(1), Ordering::Relaxed);
    }

    /// Execute one request (no queue involved). Returns the grid, the
    /// verification error (when requested), the shard count used, the
    /// kernel-only wall-clock seconds, and the fusion accounting.
    fn execute(
        &self,
        req: &ShardRequest,
    ) -> anyhow::Result<(DenseGrid, Option<f64>, usize, f64, super::scheduler::FuseReport)> {
        anyhow::ensure!(req.n >= 1, "empty domain");
        let storage = vec![req.n + 2 * req.spec.order; req.spec.dims];
        let grid = DenseGrid::verification_input(&storage, req.seed);
        let shards = self.effective_shards();
        // a tuned request adopts the DB plan's time-tile depth (a fused
        // tune winner should actually run fused); the server-wide
        // setting still applies, and evolve_fused caps either against
        // shard starvation
        let fuse_steps = if req.method == KernelMethod::Tuned {
            self.cfg.fuse_steps.max(self.evolver.cache().tuned_fuse(req.spec))
        } else {
            self.cfg.fuse_steps
        };
        let t_kernel = Instant::now();
        let (out, used, fuse) = self.evolver.evolve_fused(
            req.spec,
            &grid,
            req.steps,
            shards,
            req.method,
            fuse_steps,
        )?;
        let kernel_seconds = t_kernel.elapsed().as_secs_f64();
        let max_err = if req.verify {
            // oracle/taps are bitwise; the KIR host kernels (`outer`, and
            // tuned plans the DB compiled to host kernels) match within
            // 1e-9 because the outer-product accumulation order differs —
            // but a tuned request that fell back to the taps kernel keeps
            // the bitwise bar
            let bitwise = match req.method {
                KernelMethod::Oracle | KernelMethod::Taps => true,
                KernelMethod::Outer => false,
                KernelMethod::Tuned => !self.evolver.cache().tuned_runs_host(req.spec),
            };
            let coeffs = CoeffTensor::paper_default(req.spec);
            let want = reference::evolve(&coeffs, &grid, req.steps);
            let err = out.max_abs_diff_interior(&want, 0);
            if bitwise {
                anyhow::ensure!(
                    err == 0.0,
                    "sharded result diverged from the scalar oracle (max err {err:e})"
                );
            } else {
                anyhow::ensure!(
                    err < 1e-9,
                    "host-kernel result outside the 1e-9 bar (max err {err:e})"
                );
            }
            Some(err)
        } else {
            None
        };
        Ok((out, max_err, used, kernel_seconds, fuse))
    }
}

/// The batched sharded stencil server.
///
/// Lifecycle: construct, optionally [`StencilServer::start`] a background
/// dispatcher (or call [`StencilServer::drain`] manually for deterministic
/// tests), submit requests, wait on tickets. [`StencilServer::shutdown`]
/// (or simply dropping the last server handle) closes the queue, stops
/// the dispatcher and fails any unserved tickets.
pub struct StencilServer {
    inner: Arc<ServerInner>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl StencilServer {
    /// Build a server (spawns the worker pool immediately).
    pub fn new(cfg: ServeConfig) -> StencilServer {
        let mut cache = super::scheduler::PlanCache::new(cfg.plan_cache);
        cache.set_engine(cfg.engine);
        StencilServer::with_cache(cfg, Arc::new(cache))
    }

    /// Build a server whose kernel LRU consults a tuning database before
    /// compiling shard kernels: `tuned`-kernel requests are matched with
    /// `db`'s best entry for their stencil on the machine identified by
    /// `fingerprint` (see [`crate::sim::SimConfig::fingerprint`]), and
    /// responses report the matched plan in
    /// [`ShardReport::tuned_plan`].
    pub fn with_tune_db(
        cfg: ServeConfig,
        db: Arc<crate::tune::TuneDb>,
        fingerprint: String,
    ) -> StencilServer {
        let mut cache =
            super::scheduler::PlanCache::with_tune_db(cfg.plan_cache, db, fingerprint);
        cache.set_engine(cfg.engine);
        StencilServer::with_cache(cfg, Arc::new(cache))
    }

    fn with_cache(cfg: ServeConfig, cache: Arc<super::scheduler::PlanCache>) -> StencilServer {
        let evolver = ShardedEvolver::with_parts(
            Arc::new(super::pool::WorkerPool::new(cfg.workers)),
            cache,
        );
        StencilServer {
            inner: Arc::new(ServerInner {
                cfg,
                evolver,
                queue: Mutex::new(QueueInner { entries: VecDeque::new(), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                metrics: Mutex::new(ServiceMetrics::default()),
                epoch: Instant::now(),
                last_request_ns: AtomicU64::new(0),
            }),
            dispatcher: Mutex::new(None),
        }
    }

    /// Shards used per request.
    pub fn effective_shards(&self) -> usize {
        self.inner.effective_shards()
    }

    /// Requests currently queued (coalesced submissions count once).
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().entries.len()
    }

    /// Submit a request, blocking while the queue is full (backpressure).
    /// An identical request still queued is coalesced instead.
    pub fn submit(&self, req: ShardRequest) -> anyhow::Result<Ticket> {
        let mut q = self.inner.queue.lock().unwrap();
        let mut req = req;
        loop {
            anyhow::ensure!(!q.closed, "server is shut down");
            match self.inner.admit(&mut q, req) {
                Ok(ticket) => return Ok(ticket),
                Err(back) => {
                    req = back;
                    q = self.inner.not_full.wait(q).unwrap();
                }
            }
        }
    }

    /// Non-blocking submit: errors immediately when the queue is full
    /// (still coalesces identical queued requests).
    pub fn try_submit(&self, req: ShardRequest) -> anyhow::Result<Ticket> {
        let mut q = self.inner.queue.lock().unwrap();
        anyhow::ensure!(!q.closed, "server is shut down");
        match self.inner.admit(&mut q, req) {
            Ok(ticket) => Ok(ticket),
            Err(_) => {
                self.inner.metrics.lock().unwrap().record_rejected();
                anyhow::bail!(
                    "queue full ({} pending, depth {})",
                    q.entries.len(),
                    self.inner.cfg.queue_depth
                );
            }
        }
    }

    /// Serve the next queued request on the calling thread; `false` when
    /// the queue is empty. Deterministic alternative to the dispatcher.
    pub fn process_next(&self) -> bool {
        let pending = self.inner.queue.lock().unwrap().entries.pop_front();
        match pending {
            Some(p) => {
                self.inner.not_full.notify_all();
                self.inner.handle(p);
                true
            }
            None => false,
        }
    }

    /// Serve queued requests until the queue is empty.
    pub fn drain(&self) {
        while self.process_next() {}
    }

    /// Spawn the background dispatcher thread.
    pub fn start(&self) {
        let inner = Arc::clone(&self.inner);
        let handle = std::thread::Builder::new()
            .name("stencil-serve-dispatch".to_string())
            .spawn(move || {
                while let Some(p) = inner.pop_blocking() {
                    inner.handle(p);
                }
            })
            .expect("failed to spawn dispatcher");
        *self.dispatcher.lock().unwrap() = Some(handle);
    }

    /// Close the queue, stop the dispatcher, and fail any unserved
    /// tickets. Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        let leftovers: Vec<Pending> = {
            let mut q = self.inner.queue.lock().unwrap();
            q.closed = true;
            q.entries.drain(..).collect()
        };
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
        let handle = self.dispatcher.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        for p in leftovers {
            p.slot
                .fulfill(Err("server shut down before request was served".to_string()));
        }
    }

    /// Liveness verdict for the `/healthz` endpoint: queue depth, worker
    /// liveness, age of the most recent completed request, and the
    /// shard-imbalance verdict read from the live gauge.
    pub fn health_json(&self) -> Json {
        let workers = self.inner.evolver.pool().workers();
        let alive = self.inner.evolver.pool().alive();
        let last_ns = self.inner.last_request_ns.load(Ordering::Relaxed);
        let last_request_age_s = if last_ns == 0 {
            Json::Null
        } else {
            let age = self.inner.epoch.elapsed().as_secs_f64() - last_ns as f64 / 1e9;
            Json::Num(age.max(0.0))
        };
        let imbalance = registry::global().gauge("stencil_shard_imbalance").get();
        let balance = if imbalance == 0.0 {
            "idle"
        } else if imbalance <= 1.5 {
            "balanced"
        } else {
            "skewed"
        };
        let status = if alive == workers { "ok" } else { "degraded" };
        obj(vec![
            ("status", Json::Str(status.to_string())),
            ("queue_depth", Json::Num(self.queue_len() as f64)),
            ("workers", Json::Num(workers as f64)),
            ("workers_alive", Json::Num(alive as f64)),
            ("last_request_age_s", last_request_age_s),
            ("shard_imbalance", Json::Num(imbalance)),
            ("shard_balance", Json::Str(balance.to_string())),
        ])
    }

    /// Full metrics snapshot (service + plan cache + config) as JSON.
    pub fn metrics_json(&self) -> Json {
        let service = self.inner.metrics.lock().unwrap().to_json();
        let cs = self.inner.evolver.cache().stats();
        obj(vec![
            ("service", service),
            (
                "plan_cache",
                obj(vec![
                    ("hits", Json::Num(cs.hits as f64)),
                    ("misses", Json::Num(cs.misses as f64)),
                    ("evictions", Json::Num(cs.evictions as f64)),
                    ("tuned_hits", Json::Num(cs.tuned_hits as f64)),
                    ("resident", Json::Num(cs.len as f64)),
                ]),
            ),
            (
                "config",
                obj(vec![
                    ("workers", Json::Num(self.inner.evolver.pool().workers() as f64)),
                    ("shards", Json::Num(self.effective_shards() as f64)),
                    ("queue_depth", Json::Num(self.inner.cfg.queue_depth as f64)),
                    ("plan_cache", Json::Num(self.inner.cfg.plan_cache as f64)),
                    ("engine", Json::Str(self.inner.cfg.engine.to_string())),
                    ("fuse_steps", Json::Num(self.inner.cfg.fuse_steps as f64)),
                ]),
            ),
        ])
    }
}

impl Drop for StencilServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// PJRT artifact serving (moved from coordinator::service)
// ---------------------------------------------------------------------------

/// A request to advance a grid via a compiled PJRT artifact.
#[derive(Debug, Clone)]
pub struct EvolveRequest {
    /// Artifact name (see `artifacts/manifest.json`).
    pub artifact: String,
    /// Number of executions (each advances `artifact.steps` steps).
    pub executions: usize,
    /// Verify the result against the scalar oracle.
    pub verify: bool,
}

/// Serves evolve requests over compiled XLA artifacts, caching compiled
/// executables per artifact. (Requires the `pjrt` cargo feature at run
/// time; without it `new` returns an error.)
pub struct EvolutionService {
    runtime: PjrtRuntime,
    registry: Registry,
    engines: HashMap<String, StencilEngine>,
}

impl EvolutionService {
    /// Start the service over an artifact directory.
    pub fn new(artifact_dir: &Path) -> anyhow::Result<EvolutionService> {
        let runtime = PjrtRuntime::cpu()?;
        let registry = Registry::load(artifact_dir)?;
        Ok(EvolutionService { runtime, registry, engines: HashMap::new() })
    }

    /// Platform the service runs on.
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Artifact names available.
    pub fn artifacts(&self) -> Vec<String> {
        self.registry.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Compile (or fetch the cached) engine for an artifact.
    pub fn engine(&mut self, name: &str) -> anyhow::Result<&StencilEngine> {
        if !self.engines.contains_key(name) {
            let meta = self.registry.find(name)?.clone();
            let exe = self.runtime.compile(&meta)?;
            self.engines.insert(name.to_string(), StencilEngine::new(exe));
        }
        Ok(&self.engines[name])
    }

    /// Serve one request: build the deterministic verification input for
    /// the artifact's shape, evolve, and report.
    pub fn serve(
        &mut self,
        req: &EvolveRequest,
    ) -> anyhow::Result<(DenseGrid, crate::runtime::EvolutionReport)> {
        let engine = self.engine(&req.artifact)?;
        let shape = engine.meta().shape();
        let grid = DenseGrid::verification_input(&shape, 0xC0FFEE);
        engine.evolve(&grid, req.executions, req.verify)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_req(seed: u64) -> ShardRequest {
        ShardRequest {
            spec: StencilSpec::box2d(1),
            n: 12,
            steps: 2,
            seed,
            method: KernelMethod::Taps,
            verify: true,
        }
    }

    #[test]
    fn submit_drain_wait_roundtrip() {
        let server = StencilServer::new(ServeConfig {
            workers: 2,
            shards: 2,
            queue_depth: 8,
            plan_cache: 8,
            ..ServeConfig::default()
        });
        let t = server.submit(small_req(1)).unwrap();
        assert_eq!(server.queue_len(), 1);
        server.drain();
        let resp = t.wait().unwrap();
        assert_eq!(resp.report.max_err, Some(0.0));
        assert_eq!(resp.report.steps, 2);
        assert_eq!(resp.report.points, 12 * 12);
        assert_eq!(resp.report.shards, 2);
        assert_eq!(resp.grid.shape, vec![14, 14]);
    }

    #[test]
    fn fused_server_exchanges_halos_every_t_steps() {
        let server = StencilServer::new(ServeConfig {
            workers: 2,
            shards: 2,
            queue_depth: 8,
            plan_cache: 8,
            fuse_steps: 4,
            ..ServeConfig::default()
        });
        let req = ShardRequest {
            spec: StencilSpec::box2d(1),
            n: 24,
            steps: 8,
            seed: 5,
            method: KernelMethod::Taps,
            verify: true,
        };
        let t = server.submit(req).unwrap();
        server.drain();
        let resp = t.wait().unwrap();
        // fused taps stays bitwise equal to the scalar oracle
        assert_eq!(resp.report.max_err, Some(0.0));
        assert_eq!(resp.report.fused_steps, 4);
        assert_eq!(resp.report.shards, 2);
        // halo exchanges drop from steps - 1 = 7 to ceil(8/4) - 1 = 1
        assert_eq!(resp.report.halo_exchanges, 1);
        let m = server.metrics_json();
        let service = m.get("service").unwrap();
        for key in ["halo_exchanges", "fused_steps"] {
            let rec = service.get(key).unwrap_or_else(|| panic!("metrics missing {key}"));
            assert_eq!(rec.get("count").unwrap().as_usize(), Some(1), "{key}");
            assert!(rec.get("p50").unwrap().as_f64().is_some(), "{key}");
            assert!(rec.get("p99").unwrap().as_f64().is_some(), "{key}");
        }
        assert_eq!(
            service.get("halo_exchanges").unwrap().get("max").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            m.get("config").unwrap().get("fuse_steps").unwrap().as_usize(),
            Some(4)
        );
    }

    #[test]
    fn identical_requests_coalesce() {
        let server = StencilServer::new(ServeConfig::default());
        let a = server.submit(small_req(7)).unwrap();
        let b = server.submit(small_req(7)).unwrap();
        let c = server.submit(small_req(8)).unwrap(); // different seed
        assert_eq!(server.queue_len(), 2);
        server.drain();
        let ra = a.wait().unwrap();
        let rb = b.wait().unwrap();
        let rc = c.wait().unwrap();
        assert_eq!(ra.report.waiters, 2);
        assert!(Arc::ptr_eq(&ra, &rb));
        assert_eq!(rc.report.waiters, 1);
        assert_ne!(ra.grid, rc.grid);
    }

    #[test]
    fn health_json_reports_liveness_and_last_request_age() {
        let server = StencilServer::new(ServeConfig::default());
        let h = server.health_json();
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        // no request served yet: age is null
        assert!(matches!(h.get("last_request_age_s"), Some(Json::Null)), "{h:?}");
        let t = server.submit(small_req(11)).unwrap();
        server.drain();
        t.wait().unwrap();
        let h = server.health_json();
        assert!(h.get("last_request_age_s").unwrap().as_f64().unwrap() >= 0.0, "{h:?}");
        assert_eq!(
            h.get("workers").unwrap().as_f64(),
            h.get("workers_alive").unwrap().as_f64()
        );
        let balance = h.get("shard_balance").unwrap().as_str().unwrap();
        assert!(["idle", "balanced", "skewed"].contains(&balance), "{balance}");
        assert_eq!(h.get("queue_depth").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn shutdown_fails_unserved_tickets() {
        let server = StencilServer::new(ServeConfig::default());
        let t = server.submit(small_req(3)).unwrap();
        server.shutdown();
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
        assert!(server.submit(small_req(4)).is_err());
    }

    #[test]
    fn dropping_a_started_server_stops_its_dispatcher() {
        // the dispatcher holds ServerInner, not the outer handle, so this
        // Drop runs, joins the thread, and fails the pending ticket
        let server = StencilServer::new(ServeConfig::default());
        server.start();
        let t = {
            // submit while the dispatcher may already be draining
            server.submit(small_req(5)).unwrap()
        };
        drop(server);
        // the ticket either completed before shutdown or was failed by it
        match t.wait() {
            Ok(resp) => assert_eq!(resp.report.max_err, Some(0.0)),
            Err(e) => assert!(e.to_string().contains("shut down"), "{e}"),
        }
    }
}
