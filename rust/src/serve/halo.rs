//! Halo exchange: refresh each tile's ghost rows from its neighbours'
//! freshly-computed owned rows between time steps.
//!
//! Two variants of the same copy:
//!
//! - [`exchange_serial`] over `&mut [DenseGrid]` — used by tests and as
//!   the specification of the exchange;
//! - [`refresh_ghosts`] over `&[Mutex<DenseGrid>]` — the form the worker
//!   pool runs, one call per shard. It never holds two tile locks at
//!   once (neighbour rows are copied out into a scratch buffer first), so
//!   concurrent exchange jobs for adjacent shards cannot deadlock; the
//!   regions are disjoint (a shard only *writes* its own ghost rows and
//!   only *reads* neighbours' owned rows), so the result equals the
//!   serial exchange.

use super::partition::Partition;
use crate::obs::registry::{self, Histogram, SECONDS_BUCKETS};
use crate::obs::span::span_arg;
use crate::stencil::DenseGrid;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Live histogram of time spent *acquiring* neighbour/own tile locks
/// during a ghost refresh — contention here means exchange jobs are
/// serializing behind compute stragglers.
fn wait_histogram() -> &'static Histogram {
    static H: OnceLock<Histogram> = OnceLock::new();
    H.get_or_init(|| {
        registry::global().histogram("stencil_serve_halo_wait_seconds", &SECONDS_BUCKETS)
    })
}

/// Rows `[row, row + count)` of `tile` as a linear range, given `rest`
/// elements per row.
fn row_range(row: usize, count: usize, rest: usize) -> std::ops::Range<usize> {
    row * rest..(row + count) * rest
}

/// A contiguous band of tile-local rows: the unit of halo traffic. Both
/// exchange paths (coordinator-mediated copies and peer-to-peer
/// `HaloPush` frames) move exactly these bands, so their contents are
/// identical by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Band {
    /// First tile-local row of the band.
    pub row: usize,
    /// Number of rows.
    pub count: usize,
}

impl Band {
    /// Linear element range of the band within a tile with `rest`
    /// elements per row.
    pub fn range(&self, rest: usize) -> std::ops::Range<usize> {
        row_range(self.row, self.count, rest)
    }
}

/// The band of shard `s`'s tile that its *lower* neighbour `s - 1` needs
/// as upper ghost rows, or `None` when there is no such neighbour (or it
/// has no upper ghosts).
pub fn outgoing_band_to_lower(part: &Partition, s: usize) -> Option<Band> {
    if s == 0 {
        return None;
    }
    let count = part.slabs[s - 1].ghost_hi;
    if count == 0 {
        return None;
    }
    Some(Band { row: part.slabs[s].ghost_lo, count })
}

/// The band of shard `s`'s tile that its *upper* neighbour `s + 1` needs
/// as lower ghost rows, or `None`.
pub fn outgoing_band_to_upper(part: &Partition, s: usize) -> Option<Band> {
    if s + 1 >= part.len() {
        return None;
    }
    let count = part.slabs[s + 1].ghost_lo;
    if count == 0 {
        return None;
    }
    let slab = &part.slabs[s];
    Some(Band { row: slab.ghost_lo + slab.rows() - count, count })
}

/// Where shard `s`'s tile stores ghost rows arriving *from* its lower
/// neighbour, or `None` when it has none.
pub fn incoming_band_from_lower(part: &Partition, s: usize) -> Option<Band> {
    let count = part.slabs[s].ghost_lo;
    if count == 0 {
        return None;
    }
    Some(Band { row: 0, count })
}

/// Where shard `s`'s tile stores ghost rows arriving *from* its upper
/// neighbour, or `None` when it has none.
pub fn incoming_band_from_upper(part: &Partition, s: usize) -> Option<Band> {
    let slab = &part.slabs[s];
    let count = slab.ghost_hi;
    if count == 0 {
        return None;
    }
    Some(Band { row: slab.ghost_lo + slab.rows(), count })
}

/// Copy a band out of a tile into a fresh buffer.
pub fn extract_band(tile: &DenseGrid, band: Band, rest: usize) -> Vec<f64> {
    tile.data[band.range(rest)].to_vec()
}

/// Copy a previously extracted band into a tile.
pub fn apply_band(tile: &mut DenseGrid, band: Band, rest: usize, data: &[f64]) {
    tile.data[band.range(rest)].copy_from_slice(data);
}

/// Serially refresh every tile's ghost rows from its neighbours' owned
/// rows. `tiles[s]` must have shape `part.tile_shape(s)`.
pub fn exchange_serial(part: &Partition, tiles: &mut [DenseGrid]) {
    assert_eq!(tiles.len(), part.len());
    let rest = part.row_elems();
    for s in 0..tiles.len() {
        if let Some((src_range, dst_range)) = lower_ghost_copy(part, s, rest) {
            let buf = tiles[s - 1].data[src_range].to_vec();
            tiles[s].data[dst_range].copy_from_slice(&buf);
        }
        if let Some((src_range, dst_range)) = upper_ghost_copy(part, s, rest) {
            let buf = tiles[s + 1].data[src_range].to_vec();
            tiles[s].data[dst_range].copy_from_slice(&buf);
        }
    }
}

/// Refresh shard `s`'s ghost rows, locking one tile at a time. Each
/// ghost copy's lock-acquisition time feeds the
/// `stencil_serve_halo_wait_seconds` live histogram.
pub fn refresh_ghosts(part: &Partition, tiles: &[Mutex<DenseGrid>], s: usize) {
    assert_eq!(tiles.len(), part.len());
    let _g = span_arg("serve.halo_exchange", "serve", ("shard", s as f64));
    let rest = part.row_elems();
    if let Some((src_range, dst_range)) = lower_ghost_copy(part, s, rest) {
        timed_ghost_copy(&tiles[s - 1], &tiles[s], src_range, dst_range);
    }
    if let Some((src_range, dst_range)) = upper_ghost_copy(part, s, rest) {
        timed_ghost_copy(&tiles[s + 1], &tiles[s], src_range, dst_range);
    }
}

/// One ghost copy (`src[src_range]` → `dst[dst_range]`), recording the
/// combined time spent blocked on the two tile locks.
fn timed_ghost_copy(
    src: &Mutex<DenseGrid>,
    dst: &Mutex<DenseGrid>,
    src_range: std::ops::Range<usize>,
    dst_range: std::ops::Range<usize>,
) {
    let t0 = Instant::now();
    let src = src.lock().unwrap();
    let src_wait = t0.elapsed();
    let buf = src.data[src_range].to_vec();
    drop(src);
    let t1 = Instant::now();
    let mut dst = dst.lock().unwrap();
    wait_histogram().observe((src_wait + t1.elapsed()).as_secs_f64());
    dst.data[dst_range].copy_from_slice(&buf);
}

/// Source range (in tile `s - 1`) and destination range (in tile `s`) for
/// shard `s`'s lower ghost rows, or `None` when it has none. Shard s's
/// lower ghosts are global rows [lo - ghost_lo, lo), i.e. the last
/// ghost_lo owned rows of shard s-1 (heights >= halo guarantee they all
/// belong to that one neighbour).
fn lower_ghost_copy(
    part: &Partition,
    s: usize,
    rest: usize,
) -> Option<(std::ops::Range<usize>, std::ops::Range<usize>)> {
    let dst = incoming_band_from_lower(part, s)?;
    let src = outgoing_band_to_upper(part, s - 1)?;
    Some((src.range(rest), dst.range(rest)))
}

/// Source range (in tile `s + 1`) and destination range (in tile `s`) for
/// shard `s`'s upper ghost rows, or `None` when it has none. Shard s's
/// upper ghosts are global rows [hi, hi + ghost_hi), i.e. the first
/// ghost_hi owned rows of shard s+1.
fn upper_ghost_copy(
    part: &Partition,
    s: usize,
    rest: usize,
) -> Option<(std::ops::Range<usize>, std::ops::Range<usize>)> {
    let dst = incoming_band_from_upper(part, s)?;
    let src = outgoing_band_to_lower(part, s + 1)?;
    Some((src.range(rest), dst.range(rest)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, CoeffTensor, StencilSpec};

    /// The specification run: extract tiles, then alternate per-tile
    /// oracle applications with serial halo exchanges. Must equal the
    /// global oracle bitwise — the exactness guarantee the whole serving
    /// subsystem rests on.
    fn sharded_oracle_evolve(
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
    ) -> DenseGrid {
        let coeffs = CoeffTensor::paper_default(spec);
        let part = Partition::new(&grid.shape, shards, spec.order).unwrap();
        let mut tiles = part.extract(grid);
        for step in 0..steps {
            for t in tiles.iter_mut() {
                // tiles too small to hold any interior point are all
                // frozen boundary: the oracle would reject them, and the
                // correct result is a plain copy (i.e. no-op)
                if t.shape.iter().all(|&n| n > 2 * spec.order) {
                    *t = reference::apply(&coeffs, t);
                }
            }
            if step + 1 < steps {
                exchange_serial(&part, &mut tiles);
            }
        }
        let refs: Vec<&DenseGrid> = tiles.iter().collect();
        part.assemble(&refs).unwrap()
    }

    #[test]
    fn sharded_evolution_is_bitwise_exact_2d() {
        for (order, n, steps) in [(1usize, 16usize, 3usize), (2, 17, 2), (3, 20, 2)] {
            let spec = StencilSpec::box2d(order);
            let shape = vec![n; 2];
            let grid = DenseGrid::verification_input(&shape, 42);
            let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, steps);
            for shards in [1usize, 2, 3, 4, 7] {
                let got = sharded_oracle_evolve(spec, &grid, steps, shards);
                assert_eq!(got, want, "order {order} N={n} steps={steps} x{shards}");
            }
        }
    }

    #[test]
    fn sharded_evolution_is_bitwise_exact_3d() {
        let spec = StencilSpec::star3d(2);
        let grid = DenseGrid::verification_input(&[11, 9, 8], 7);
        let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, 2);
        for shards in [1usize, 2, 3, 5] {
            let got = sharded_oracle_evolve(spec, &grid, 2, shards);
            assert_eq!(got, want, "x{shards}");
        }
    }

    #[test]
    fn fused_exchange_every_t_steps_is_bitwise_exact() {
        // the temporal-blocking specification: with ghosts of depth
        // order·T, applying the oracle T times per tile between
        // exchanges reproduces the global evolution bitwise — the deep
        // halo absorbs the ghost band shrinking by `order` per fused
        // step
        for (order, n, steps, t) in [(1usize, 20usize, 8usize, 4usize), (2, 21, 6, 2), (1, 16, 5, 4)]
        {
            let spec = StencilSpec::box2d(order);
            let shape = vec![n; 2];
            let grid = DenseGrid::verification_input(&shape, 99);
            let coeffs = CoeffTensor::paper_default(spec);
            let want = reference::evolve(&coeffs, &grid, steps);
            for shards in [1usize, 2, 3] {
                let part = Partition::new(&shape, shards, spec.order * t).unwrap();
                let mut tiles = part.extract(&grid);
                let mut remaining = steps;
                while remaining > 0 {
                    let chunk = t.min(remaining);
                    for tile in tiles.iter_mut() {
                        for _ in 0..chunk {
                            if tile.shape.iter().all(|&s| s > 2 * spec.order) {
                                *tile = reference::apply(&coeffs, tile);
                            }
                        }
                    }
                    remaining -= chunk;
                    if remaining > 0 {
                        exchange_serial(&part, &mut tiles);
                    }
                }
                let refs: Vec<&DenseGrid> = tiles.iter().collect();
                let got = part.assemble(&refs).unwrap();
                assert_eq!(got, want, "order {order} N={n} steps={steps} T={t} x{shards}");
            }
        }
    }

    #[test]
    fn locked_exchange_matches_serial() {
        let spec = StencilSpec::box2d(1);
        let coeffs = CoeffTensor::paper_default(spec);
        let grid = DenseGrid::verification_input(&[12, 6], 9);
        let part = Partition::new(&grid.shape, 3, 1).unwrap();

        let mut serial = part.extract(&grid);
        for t in serial.iter_mut() {
            *t = reference::apply(&coeffs, t);
        }
        let locked: Vec<Mutex<DenseGrid>> = serial.iter().cloned().map(Mutex::new).collect();

        exchange_serial(&part, &mut serial);
        for s in 0..part.len() {
            refresh_ghosts(&part, &locked, s);
        }
        for (s, m) in locked.iter().enumerate() {
            assert_eq!(*m.lock().unwrap(), serial[s], "shard {s}");
        }
    }

    #[test]
    fn ghost_refresh_feeds_the_wait_histogram() {
        // histogram is process-global: assert the delta across this
        // refresh (2 shards × 1 ghost copy each = 2 observations)
        let before = wait_histogram().count();
        let grid = DenseGrid::verification_input(&[10, 6], 3);
        let part = Partition::new(&grid.shape, 2, 1).unwrap();
        let locked: Vec<Mutex<DenseGrid>> =
            part.extract(&grid).into_iter().map(Mutex::new).collect();
        for s in 0..part.len() {
            refresh_ghosts(&part, &locked, s);
        }
        assert!(wait_histogram().count() >= before + 2);
    }

    #[test]
    fn band_extents_mirror_ghost_geometry() {
        let part = Partition::new(&[24, 5], 3, 2).unwrap();
        // edge shards have one neighbour, the middle shard two
        assert_eq!(outgoing_band_to_lower(&part, 0), None);
        assert_eq!(incoming_band_from_lower(&part, 0), None);
        assert_eq!(outgoing_band_to_upper(&part, 2), None);
        assert_eq!(incoming_band_from_upper(&part, 2), None);
        // shard 1's outgoing band to shard 0 covers exactly what shard 0
        // stores as upper ghosts, and vice versa
        for s in 0..part.len() {
            if let Some(out) = outgoing_band_to_lower(&part, s) {
                let inc = incoming_band_from_upper(&part, s - 1).unwrap();
                assert_eq!(out.count, inc.count, "shard {s} -> lower");
            }
            if let Some(out) = outgoing_band_to_upper(&part, s) {
                let inc = incoming_band_from_lower(&part, s + 1).unwrap();
                assert_eq!(out.count, inc.count, "shard {s} -> upper");
            }
        }
        // the outgoing band is always within the sender's owned rows
        for s in 0..part.len() {
            let slab = &part.slabs[s];
            for band in [outgoing_band_to_lower(&part, s), outgoing_band_to_upper(&part, s)]
                .into_iter()
                .flatten()
            {
                assert!(band.row >= slab.ghost_lo, "shard {s}");
                assert!(band.row + band.count <= slab.ghost_lo + slab.rows(), "shard {s}");
            }
        }
    }

    #[test]
    fn extract_apply_band_roundtrips_through_peer_geometry() {
        // moving every band through extract/apply reproduces the serial
        // exchange bit-for-bit — the peer path's correctness in miniature
        let grid = DenseGrid::verification_input(&[18, 6], 11);
        for (shards, halo) in [(2usize, 1usize), (3, 2), (4, 3)] {
            let part = Partition::new(&grid.shape, shards, halo).unwrap();
            let rest = part.row_elems();
            let mut want = part.extract(&grid);
            // perturb ghosts so the exchange has something to fix
            let mut got = want.clone();
            for (s, t) in got.iter_mut().enumerate() {
                if let Some(b) = incoming_band_from_lower(&part, s) {
                    t.data[b.range(rest)].fill(-1.0);
                }
                if let Some(b) = incoming_band_from_upper(&part, s) {
                    t.data[b.range(rest)].fill(-2.0);
                }
            }
            exchange_serial(&part, &mut want);
            // peer path: extract each outgoing band, apply at the receiver
            let src = got.clone();
            for s in 0..part.len() {
                if let Some(out) = outgoing_band_to_lower(&part, s) {
                    let data = extract_band(&src[s], out, rest);
                    let inc = incoming_band_from_upper(&part, s - 1).unwrap();
                    apply_band(&mut got[s - 1], inc, rest, &data);
                }
                if let Some(out) = outgoing_band_to_upper(&part, s) {
                    let data = extract_band(&src[s], out, rest);
                    let inc = incoming_band_from_lower(&part, s + 1).unwrap();
                    apply_band(&mut got[s + 1], inc, rest, &data);
                }
            }
            assert_eq!(got, want, "x{shards} halo {halo}");
        }
    }
}
