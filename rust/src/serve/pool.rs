//! A `std::thread` worker pool with per-worker deques, work stealing, and
//! batch barriers — the execution substrate of the sharded scheduler.
//!
//! Shards are uneven (remainder rows go to leading shards) and there may
//! be more shards than workers, so each worker owns a deque: it pops its
//! own jobs from the front and steals from the *back* of other workers'
//! deques when idle. [`WorkerPool::run_batch`] submits a batch and blocks
//! until every job in it has finished — the per-step barrier between
//! compute and halo-exchange phases. Panics inside jobs are caught per
//! job and surfaced as one error after the barrier, so a poisoned shard
//! cannot deadlock the step.

use crate::obs::registry::{self, Counter};
use crate::obs::span::span_arg;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of pool work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

struct State {
    /// Jobs queued but not yet popped (not: currently executing).
    pending: usize,
    shutdown: bool,
}

struct Shared {
    queues: Vec<Mutex<VecDeque<Job>>>,
    state: Mutex<State>,
    wake: Condvar,
    /// Live registry handles (fetched once at pool construction): jobs a
    /// worker popped from its own deque vs. jobs it stole — the
    /// `stencil_pool_jobs_total{kind=...}` telemetry behind shard-balance
    /// analysis.
    own_jobs: Counter,
    stolen_jobs: Counter,
}

/// Counts a batch down to zero and wakes the submitter.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.done.wait(r).unwrap();
        }
    }
}

/// Fixed-size thread pool executing [`Job`]s with work stealing.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl WorkerPool {
    /// Spawn a pool of `workers.max(1)` threads.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            state: Mutex::new(State { pending: 0, shutdown: false }),
            wake: Condvar::new(),
            own_jobs: registry::global().counter_with("stencil_pool_jobs_total", "kind=\"own\""),
            stolen_jobs: registry::global()
                .counter_with("stencil_pool_jobs_total", "kind=\"stolen\""),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("stencil-worker-{i}"))
                    .spawn(move || worker_loop(&sh, i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, next: AtomicUsize::new(0) }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Worker threads still running (a worker that panicked outside a
    /// caught job, or exited, no longer counts) — the `/healthz` worker
    /// liveness readout.
    pub fn alive(&self) -> usize {
        self.handles.iter().filter(|h| !h.is_finished()).count()
    }

    /// Distribute jobs round-robin over the worker deques and wake everyone.
    fn scatter(&self, jobs: Vec<Job>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        self.shared.state.lock().unwrap().pending += n;
        let w = self.shared.queues.len();
        for job in jobs {
            let q = self.next.fetch_add(1, Ordering::Relaxed) % w;
            self.shared.queues[q].lock().unwrap().push_back(job);
        }
        self.shared.wake.notify_all();
    }

    /// Run a batch of jobs to completion (the barrier). Returns an error
    /// if any job panicked, after the whole batch has drained.
    pub fn run_batch(&self, jobs: Vec<Job>) -> anyhow::Result<()> {
        let total = jobs.len();
        if total == 0 {
            return Ok(());
        }
        let _batch = span_arg("pool.batch", "serve", ("jobs", total as f64));
        let latch = Arc::new(Latch::new(total));
        let panics: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let wrapped: Vec<Job> = jobs
            .into_iter()
            .map(|job| {
                let latch = Arc::clone(&latch);
                let panics = Arc::clone(&panics);
                let wrapped: Job = Box::new(move || {
                    let result =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    if let Err(payload) = result {
                        panics.lock().unwrap().push(panic_message(&payload));
                    }
                    latch.count_down();
                });
                wrapped
            })
            .collect();
        self.scatter(wrapped);
        latch.wait();
        let failed = panics.lock().unwrap();
        anyhow::ensure!(
            failed.is_empty(),
            "{} of {total} pool job(s) panicked: {}",
            failed.len(),
            failed.join("; ")
        );
        Ok(())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.wake.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker job panicked".to_string()
    }
}

fn worker_loop(sh: &Shared, idx: usize) {
    loop {
        if let Some(job) = pop(sh, idx) {
            job();
            continue;
        }
        let mut st = sh.state.lock().unwrap();
        loop {
            if st.shutdown {
                return;
            }
            if st.pending > 0 {
                // jobs exist somewhere (possibly mid-push); retry popping
                break;
            }
            st = sh.wake.wait(st).unwrap();
        }
        drop(st);
        std::thread::yield_now();
    }
}

/// Pop own front, then steal from the back of the other deques.
fn pop(sh: &Shared, idx: usize) -> Option<Job> {
    let w = sh.queues.len();
    if let Some(job) = sh.queues[idx].lock().unwrap().pop_front() {
        sh.state.lock().unwrap().pending -= 1;
        sh.own_jobs.inc();
        return Some(job);
    }
    for k in 1..w {
        let q = (idx + k) % w;
        if let Some(job) = sh.queues[q].lock().unwrap().pop_back() {
            sh.state.lock().unwrap().pending -= 1;
            sh.stolen_jobs.inc();
            return Some(job);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_runs_every_job() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Job> = (0..64)
            .map(|_| {
                let hits = Arc::clone(&hits);
                let j: Job = Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
                j
            })
            .collect();
        pool.run_batch(jobs).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn barrier_separates_batches() {
        // every job of batch 2 must observe all of batch 1's effects
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let first: Vec<Job> = (0..16)
            .map(|_| {
                let c = Arc::clone(&counter);
                let j: Job = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                j
            })
            .collect();
        pool.run_batch(first).unwrap();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let second: Vec<Job> = (0..8)
            .map(|_| {
                let c = Arc::clone(&counter);
                let seen = Arc::clone(&seen);
                let j: Job = Box::new(move || {
                    seen.lock().unwrap().push(c.load(Ordering::SeqCst));
                });
                j
            })
            .collect();
        pool.run_batch(second).unwrap();
        assert!(seen.lock().unwrap().iter().all(|&v| v >= 16));
    }

    #[test]
    fn uneven_jobs_all_complete_via_stealing() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Job> = (0..12)
            .map(|i| {
                let hits = Arc::clone(&hits);
                let j: Job = Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(30));
                    }
                    hits.fetch_add(1, Ordering::SeqCst);
                });
                j
            })
            .collect();
        pool.run_batch(jobs).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn panics_surface_as_errors_not_deadlocks() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Job> = (0..4)
            .map(|i| {
                let j: Job = Box::new(move || {
                    if i == 2 {
                        panic!("shard {i} exploded");
                    }
                });
                j
            })
            .collect();
        let err = pool.run_batch(jobs).unwrap_err().to_string();
        assert!(err.contains("shard 2 exploded"), "{err}");
        // pool still usable afterwards
        pool.run_batch(vec![Box::new(|| {}) as Job]).unwrap();
    }

    #[test]
    fn job_counters_and_liveness_feed_the_registry() {
        // counters are process-global (other pool tests feed the same
        // families), so assert the delta across this batch only
        let own = registry::global().counter_with("stencil_pool_jobs_total", "kind=\"own\"");
        let stolen =
            registry::global().counter_with("stencil_pool_jobs_total", "kind=\"stolen\"");
        let before = own.get() + stolen.get();
        let pool = WorkerPool::new(2);
        assert_eq!(pool.alive(), 2);
        let jobs: Vec<Job> = (0..8).map(|_| Box::new(|| {}) as Job).collect();
        pool.run_batch(jobs).unwrap();
        assert!(own.get() + stolen.get() >= before + 8);
        assert_eq!(pool.alive(), 2, "workers survive the batch");
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = WorkerPool::new(0); // clamps to 1
        assert_eq!(pool.workers(), 1);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        pool.run_batch(vec![Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }) as Job])
            .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
