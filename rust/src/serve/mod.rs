//! The serving subsystem: sharded, multi-threaded stencil evolution
//! behind a batched request front-end.
//!
//! The paper evaluates one kernel at a time on a single simulated core;
//! serving heavy traffic requires the classic scaling move stencil
//! systems apply *above* the vector/matrix-unit layer (cf. the
//! Cerebras-WSE and vectorization-scheme lines of related work): split
//! the grid into shards with ghost cells, exchange halos between time
//! steps, and keep every core busy with batched requests.
//!
//! - [`partition`] — slab domain decomposition with ghost rows sized by
//!   `order × T` (the time-tile depth; `T = 1` is the classic per-step
//!   halo), and tile extraction/assembly.
//! - [`halo`] — ghost-row refresh between fused applications (serial
//!   spec + the lock-per-tile form the pool runs).
//! - [`pool`] — `std::thread` worker pool: per-worker deques, work
//!   stealing, per-batch barrier.
//! - [`scheduler`] — compiled shard kernels (oracle/taps: bitwise-
//!   identical to the scalar oracle; `outer`: the paper's algorithm
//!   compiled through [`crate::kir`] and executed natively on the host
//!   by the compiling engine — [`crate::kir::Engine::Compiled`], with
//!   the op-by-op interpreter as the bitwise-identical reference twin;
//!   a single-shard request fans its row groups across every core), an
//!   LRU plan cache keyed by (spec, shape, method) that consults the
//!   [`crate::tune`] database before compiling `tuned` shard kernels —
//!   to real host kernels when the plan supports it — and the step
//!   loop (compute batch → barrier → halo exchange). With temporal
//!   blocking (`ServeConfig::fuse_steps`, `serve --fuse-steps`), each
//!   compute batch advances `T` fused steps behind `order × T`-deep
//!   ghosts, so halo exchanges (and embed/extract round-trips) per
//!   request drop from `steps` to `ceil(steps / T)` — bitwise
//!   identically to the unfused evolution.
//! - [`service`] — the batched front-end: bounded queue with
//!   backpressure, coalescing of identical requests, dispatcher thread;
//!   also hosts the PJRT artifact service absorbed from `coordinator`.
//! - [`cluster`] — the same machinery scaled from one process to a
//!   fleet: a std-only framed TCP protocol, worker nodes wrapping this
//!   module's [`ShardedEvolver`], and a coordinator that places slabs
//!   and re-places work on node loss. Two exchange paths, both bitwise
//!   identical to the single-process path: **peer** (steady-state
//!   default — nodes push `order × T`-deep boundary bands directly to
//!   each other once per T steps, overlapped with interior compute)
//!   and **mediated** (tiles round-trip through the coordinator; the
//!   automatic fallback when a peer plan fails).
//! - [`metrics`] — latency/throughput/traffic counters reported as JSON,
//!   including per-request kernel wall-clock with p50/p99; every
//!   recorder also mirrors into the process-global
//!   [`crate::obs::registry`] (cumulative counters, gauges, streaming
//!   histograms), the source behind the live `/metrics` endpoint.
//!
//! **Exactness guarantee**: with the oracle/taps kernels, sharded
//! multi-threaded evolution is bitwise equal to
//! [`crate::stencil::reference::evolve`] — tiles see exactly the
//! neighbourhoods the global sweep sees, the frozen global boundary stays
//! inside tile-boundary bands, and the shard kernels preserve the
//! oracle's accumulation order. With the KIR host kernels (`outer`,
//! compiled tuned plans) results match the oracle within 1e-9 and
//! sharded execution is bitwise equal to single-shard execution of the
//! same kernel (see `rust/tests/shard_correctness.rs`).

pub mod cluster;
pub mod halo;
pub mod metrics;
pub mod partition;
pub mod pool;
pub mod scheduler;
pub mod service;

pub use cluster::{ClusterReport, Coordinator, ExchangeMode, NodeConfig, NodeHandle};
pub use metrics::{LatencyRecorder, ServiceMetrics};
pub use partition::{Partition, Slab};
pub use pool::WorkerPool;
pub use scheduler::{
    CompiledPlan, FuseReport, KernelMethod, PlanCache, PlanKey, ShardedEvolver, TunedInfo,
};
pub use service::{
    EvolutionService, EvolveRequest, ServeConfig, ShardRequest, ShardResponse, StencilServer,
    Ticket,
};
