//! Serving metrics: per-request latency recorders and the aggregate
//! counters the server reports as JSON (via the repo's own `util::json`),
//! plus the live-registry handles that mirror every record into the
//! global [`crate::obs::registry`] so `/metrics` scrapes see cumulative
//! `stencil_serve_*` counters and streaming latency histograms — the
//! end-of-run JSON snapshot is a summary view, the registry is the
//! continuously-fed source of truth.

use crate::obs::registry::{self, Counter, Gauge, Histogram, SECONDS_BUCKETS};
use crate::util::json::{obj, Json};
use std::time::Instant;

/// Retained percentile window: memory stays bounded on long-running
/// servers; count/mean/max are exact over the full history.
const WINDOW: usize = 4096;

/// Records a latency distribution in seconds. Aggregates (count, mean,
/// max) are exact; percentiles are nearest-rank over a sliding window of
/// the most recent [`WINDOW`] samples, sorted once per snapshot.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    window: Vec<f64>,
    next: usize,
    count: u64,
    sum: f64,
    max: f64,
}

impl LatencyRecorder {
    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.count += 1;
        self.sum += seconds;
        self.max = self.max.max(seconds);
        if self.window.len() < WINDOW {
            self.window.push(seconds);
        } else {
            self.window[self.next] = seconds;
            self.next = (self.next + 1) % WINDOW;
        }
    }

    /// Samples recorded over the recorder's lifetime.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean over all samples, or 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Maximum over all samples, or 0 when empty.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// The retained window, sorted ascending (`total_cmp`, so a NaN
    /// sample cannot panic the snapshot path).
    fn sorted_window(&self) -> Vec<f64> {
        let mut sorted = self.window.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        sorted
    }

    /// Nearest-rank percentile (`p` in 0..=100) over the retained
    /// window, or 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        percentile_of(&self.sorted_window(), p)
    }

    /// Summary as a JSON object (seconds — keys suffixed `_s`). Sorts
    /// the window once.
    pub fn to_json(&self) -> Json {
        self.to_json_suffixed("_s")
    }

    /// Summary as a JSON object for unit-less samples (plain
    /// `mean`/`p50`/… keys) — the recorder also serves count
    /// distributions such as halo exchanges per request.
    pub fn to_json_counts(&self) -> Json {
        self.to_json_suffixed("")
    }

    fn to_json_suffixed(&self, suffix: &str) -> Json {
        let sorted = self.sorted_window();
        Json::Obj(
            [
                ("count".to_string(), Json::Num(self.count as f64)),
                (format!("mean{suffix}"), Json::Num(self.mean())),
                (format!("p50{suffix}"), Json::Num(percentile_of(&sorted, 50.0))),
                (format!("p95{suffix}"), Json::Num(percentile_of(&sorted, 95.0))),
                (format!("p99{suffix}"), Json::Num(percentile_of(&sorted, 99.0))),
                (format!("max{suffix}"), Json::Num(self.max())),
                ("window_len".to_string(), Json::Num(self.window.len() as f64)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// Nearest-rank percentile over an ascending-sorted slice: the value at
/// 1-based rank `⌈p/100 · n⌉`, clamped to `[1, n]` so `p = 0` reads the
/// minimum and `p = 100` the maximum; 0 when empty.
fn percentile_of(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    let rank = (p / 100.0 * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Pre-fetched global-registry handles, one per `stencil_serve_*`
/// family. Fetched once at construction (the registry mutex is taken
/// only then); every [`ServiceMetrics`] record mirrors into these with
/// a few relaxed atomics.
#[derive(Debug, Clone)]
struct LiveHandles {
    completed: Counter,
    failed: Counter,
    coalesced: Counter,
    rejected: Counter,
    point_steps: Counter,
    queue_depth: Gauge,
    queue_wait: Histogram,
    service_time: Histogram,
    kernel_time: Histogram,
}

impl Default for LiveHandles {
    fn default() -> LiveHandles {
        let r = registry::global();
        LiveHandles {
            completed: r.counter("stencil_serve_completed_total"),
            failed: r.counter("stencil_serve_failed_total"),
            coalesced: r.counter("stencil_serve_coalesced_total"),
            rejected: r.counter("stencil_serve_rejected_total"),
            point_steps: r.counter("stencil_serve_point_steps_total"),
            queue_depth: r.gauge("stencil_serve_queue_depth"),
            queue_wait: r.histogram("stencil_serve_queue_wait_seconds", &SECONDS_BUCKETS),
            service_time: r.histogram("stencil_serve_service_seconds", &SECONDS_BUCKETS),
            kernel_time: r.histogram("stencil_serve_kernel_seconds", &SECONDS_BUCKETS),
        }
    }
}

/// Aggregate serving counters; owned by the server behind a mutex.
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    started: Instant,
    live: LiveHandles,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that failed (evolution error or verification mismatch).
    pub failed: u64,
    /// Submissions merged into an already-queued identical request.
    pub coalesced: u64,
    /// `try_submit` calls rejected by backpressure.
    pub rejected: u64,
    /// Deepest queue occupancy observed.
    pub max_queue_depth: usize,
    /// Point-steps served (grid points × time steps, summed over every
    /// completed submission — coalesced waiters each count the work they
    /// received, mirroring `completed`).
    pub point_steps: u64,
    /// Time spent waiting in the queue.
    pub queue_wait: LatencyRecorder,
    /// Time spent computing (per request, excludes queueing).
    pub service_time: LatencyRecorder,
    /// Per-request kernel wall-clock (sharded evolution only — excludes
    /// queueing and verification, but includes one-time shard-plan
    /// compilation on cache misses); p50/p99 are in the JSON snapshot.
    pub kernel_time: LatencyRecorder,
    /// Halo-exchange rounds per request — with temporal blocking this
    /// drops from `steps - 1` to `ceil(steps / T) - 1`, which is the
    /// fusion win made observable in production telemetry (p50/p99 in
    /// the JSON snapshot alongside `kernel_time`).
    pub halo_exchanges: LatencyRecorder,
    /// Effective time-tile depth `T` per request (fused steps per kernel
    /// application, after capping against shard starvation).
    pub fused_steps: LatencyRecorder,
}

impl Default for ServiceMetrics {
    fn default() -> ServiceMetrics {
        ServiceMetrics {
            started: Instant::now(),
            live: LiveHandles::default(),
            completed: 0,
            failed: 0,
            coalesced: 0,
            rejected: 0,
            max_queue_depth: 0,
            point_steps: 0,
            queue_wait: LatencyRecorder::default(),
            service_time: LatencyRecorder::default(),
            kernel_time: LatencyRecorder::default(),
            halo_exchanges: LatencyRecorder::default(),
            fused_steps: LatencyRecorder::default(),
        }
    }
}

impl ServiceMetrics {
    /// Seconds since the server started.
    pub fn uptime(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Aggregate throughput in point-steps per second of uptime.
    pub fn throughput(&self) -> f64 {
        self.point_steps as f64 / self.uptime().max(1e-12)
    }

    /// Record `waiters` completed submissions covering `point_steps`
    /// grid-point time-steps (JSON counters + live registry).
    pub fn record_completed(&mut self, waiters: u64, point_steps: u64) {
        self.completed += waiters;
        self.point_steps += point_steps;
        self.live.completed.add(waiters);
        self.live.point_steps.add(point_steps);
    }

    /// Record `waiters` failed submissions.
    pub fn record_failed(&mut self, waiters: u64) {
        self.failed += waiters;
        self.live.failed.add(waiters);
    }

    /// Record one submission coalesced into a queued identical request.
    pub fn record_coalesced(&mut self) {
        self.coalesced += 1;
        self.live.coalesced.inc();
    }

    /// Record one backpressure rejection.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
        self.live.rejected.inc();
    }

    /// Record the current queue occupancy (high-water mark + live gauge).
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth);
        self.live.queue_depth.set(depth as f64);
    }

    /// Record one request's queue wait (recorder + live histogram).
    pub fn record_queue_wait(&mut self, seconds: f64) {
        self.queue_wait.record(seconds);
        self.live.queue_wait.observe(seconds);
    }

    /// Record one request's service time (recorder + live histogram).
    pub fn record_service_time(&mut self, seconds: f64) {
        self.service_time.record(seconds);
        self.live.service_time.observe(seconds);
    }

    /// Record one request's kernel wall-clock (recorder + live
    /// histogram).
    pub fn record_kernel_time(&mut self, seconds: f64) {
        self.kernel_time.record(seconds);
        self.live.kernel_time.observe(seconds);
    }

    /// Snapshot as a JSON object.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("uptime_s", Json::Num(self.uptime())),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("coalesced", Json::Num(self.coalesced as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("point_steps", Json::Num(self.point_steps as f64)),
            ("throughput_pts_per_s", Json::Num(self.throughput())),
            ("queue_wait", self.queue_wait.to_json()),
            ("service_time", self.service_time.to_json()),
            ("kernel_time", self.kernel_time.to_json()),
            ("halo_exchanges", self.halo_exchanges.to_json_counts()),
            ("fused_steps", self.fused_steps.to_json_counts()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut r = LatencyRecorder::default();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            r.record(v);
        }
        assert_eq!(r.count(), 5);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(50.0), 3.0);
        assert_eq!(r.percentile(100.0), 5.0);
        assert_eq!(r.max(), 5.0);
        assert!((r.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let r = LatencyRecorder::default();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.max(), 0.0);
    }

    #[test]
    fn window_is_bounded_but_aggregates_are_exact() {
        let mut r = LatencyRecorder::default();
        let n = super::WINDOW + 100;
        for i in 0..n {
            r.record(i as f64);
        }
        assert_eq!(r.count(), n as u64);
        assert_eq!(r.max(), (n - 1) as f64);
        assert!((r.mean() - (n - 1) as f64 / 2.0).abs() < 1e-9);
        // the retained window holds only the most recent WINDOW samples
        assert_eq!(r.percentile(0.0), 100.0);
        assert_eq!(r.percentile(100.0), (n - 1) as f64);
    }

    #[test]
    fn partially_filled_window_percentiles() {
        let mut r = LatencyRecorder::default();
        r.record(2.0);
        // one sample: every percentile reads it
        assert_eq!(r.percentile(0.0), 2.0);
        assert_eq!(r.percentile(50.0), 2.0);
        assert_eq!(r.percentile(99.0), 2.0);
        r.record(4.0);
        // two samples: p50 is the lower value, anything above it the upper
        assert_eq!(r.percentile(50.0), 2.0);
        assert_eq!(r.percentile(51.0), 4.0);
        assert_eq!(r.percentile(100.0), 4.0);
        assert_eq!(r.to_json().get("window_len").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn wraparound_at_exactly_window_plus_one() {
        // the (WINDOW + 1)-th sample overwrites the oldest slot: the
        // window holds 1..=WINDOW while count/max stay exact
        let mut r = LatencyRecorder::default();
        for i in 0..=super::WINDOW {
            r.record(i as f64);
        }
        assert_eq!(r.count(), (super::WINDOW + 1) as u64);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(100.0), super::WINDOW as f64);
        let j = r.to_json_counts();
        assert_eq!(j.get("window_len").unwrap().as_usize(), Some(super::WINDOW));
        assert_eq!(j.get("count").unwrap().as_usize(), Some(super::WINDOW + 1));
    }

    #[test]
    fn count_recorder_json_has_unsuffixed_keys() {
        let mut r = LatencyRecorder::default();
        for v in [7.0, 1.0, 3.0] {
            r.record(v);
        }
        let j = r.to_json_counts();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("p50").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("max").unwrap().as_f64(), Some(7.0));
        assert!(j.get("p99").unwrap().as_f64().is_some());
        assert!(j.get("p50_s").is_none(), "count snapshots carry no seconds suffix");
    }

    #[test]
    fn record_methods_mirror_into_the_live_registry() {
        // the registry is process-global and other tests record into the
        // same families concurrently, so assert deltas, not totals
        let r = registry::global();
        let before_completed = r.counter("stencil_serve_completed_total").get();
        let before_kernel = r.histogram("stencil_serve_kernel_seconds", &SECONDS_BUCKETS).count();
        let mut m = ServiceMetrics::default();
        m.record_completed(2, 100);
        m.record_failed(1);
        m.record_coalesced();
        m.record_rejected();
        m.record_queue_depth(7);
        m.record_queue_wait(0.001);
        m.record_service_time(0.002);
        m.record_kernel_time(0.0015);
        assert_eq!(m.completed, 2);
        assert_eq!(m.point_steps, 100);
        assert_eq!(m.failed, 1);
        assert_eq!(m.coalesced, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.max_queue_depth, 7);
        assert_eq!(m.kernel_time.count(), 1);
        assert!(r.counter("stencil_serve_completed_total").get() >= before_completed + 2);
        let after_kernel = r.histogram("stencil_serve_kernel_seconds", &SECONDS_BUCKETS).count();
        assert!(after_kernel >= before_kernel + 1);
    }

    #[test]
    fn json_snapshot_roundtrips() {
        let mut m = ServiceMetrics::default();
        m.completed = 3;
        m.point_steps = 12_000;
        m.queue_wait.record(0.5);
        m.service_time.record(1.5);
        m.kernel_time.record(1.25);
        m.halo_exchanges.record(1.0);
        m.fused_steps.record(4.0);
        let text = m.to_json().to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("completed").unwrap().as_usize(), Some(3));
        let kt = back.get("kernel_time").unwrap();
        assert_eq!(kt.get("count").unwrap().as_usize(), Some(1));
        assert!(kt.get("p50_s").unwrap().as_f64().is_some());
        assert!(kt.get("p99_s").unwrap().as_f64().is_some());
        let he = back.get("halo_exchanges").unwrap();
        assert_eq!(he.get("p50").unwrap().as_f64(), Some(1.0));
        assert_eq!(back.get("fused_steps").unwrap().get("max").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            back.get("service_time").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        assert!(back.get("throughput_pts_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
