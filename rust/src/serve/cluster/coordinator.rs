//! The cluster coordinator: places grid slabs on worker nodes, drives
//! fused T-step evolution, and recovers when a node dies mid-evolution.
//!
//! Two data paths share the same partition, band geometry, and assembly:
//!
//! - **Peer** ([`ExchangeMode::Peer`], the steady-state default): the
//!   coordinator distributes one [`proto::ExchangePlan`] per evolution
//!   (placement, neighbour addresses, band extents, epoch tags) plus
//!   each node's tiles, waits for every `PlanReady` (staging registered
//!   everywhere before any band can fly), fires `PlanStart`, and then
//!   drops out of the per-round loop entirely — nodes exchange
//!   `order·T`-deep boundary bands directly and overlap them with
//!   interior compute (see [`super::peer`]). The coordinator only
//!   collects `PlanDone` tiles and stats at the end. Any peer failure —
//!   a lost node, a band timeout, a `PlanErr` — invalidates the plan
//!   and the evolution restarts on the coordinator-mediated path from
//!   the original grid (evolution is a pure function, so the retry is
//!   bitwise identical).
//! - **Mediated** ([`ExchangeMode::Mediated`], the PR 9 path and the
//!   fallback): every round-trip goes through the coordinator.
//!
//! The evolution loop is a line-for-line mirror of
//! [`ShardedEvolver::evolve_fused`](crate::serve::ShardedEvolver::evolve_fused)
//! with the pool batch replaced by RPCs:
//!
//! 1. cap the time-tile depth `T` with [`Partition::max_fuse`] (deep
//!    halos must not starve the shard count) and build the one
//!    partition with ghosts of depth `order × T`;
//! 2. per chunk of `T` steps, send every shard's tile to a node
//!    (round-robin, pipelined per connection) and collect the evolved
//!    tiles;
//! 3. between chunks, run [`halo::exchange_serial`] over the collected
//!    tiles — one exchange per `T` steps, so cross-node traffic
//!    amortizes exactly like the in-process fused path;
//! 4. assemble the owned rows.
//!
//! Because the partition, chunking, exchange, and assembly are the same
//! code the in-process evolver uses, and a node's tile evolution is
//! bitwise equal to a local fused plan application (see
//! [`super::node`]), the fleet result is **bitwise identical** to the
//! single-process sharded evolver — which is itself bitwise identical
//! to the scalar oracle for the oracle/taps kernels.
//!
//! **Node loss.** The coordinator keeps every input tile of the current
//! round until its evolved reply lands, so losing a node is recoverable
//! by construction: dead nodes are dropped, their unanswered chunks are
//! re-placed on the surviving nodes, and the round re-runs until every
//! chunk is in (or no nodes remain). Re-sent chunks are idempotent —
//! evolution is a pure function of the tile.

use super::frame::VersionMismatch;
use super::node::NodeHandle;
use super::proto::{
    self, ChunkRequest, ExchangePlan, Msg, MsgRecv, NodeStatus, PlanRequest, PlanStats,
};
use crate::kir::Engine;
use crate::obs::registry::{self, Counter, Gauge, Histogram, SECONDS_BUCKETS};
use crate::obs::span::{span, span_arg};
use crate::serve::scheduler::{FuseReport, KernelMethod};
use crate::serve::{halo, Partition};
use crate::stencil::{DenseGrid, StencilSpec};
use crate::util::json::{obj, Json};
use std::collections::BTreeSet;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant, SystemTime};

/// Per-RPC reply timeout: how long the coordinator waits for one node's
/// chunk replies before declaring the node dead and re-placing.
pub const DEFAULT_RPC_TIMEOUT: Duration = Duration::from_secs(60);

struct NodeConn {
    addr: SocketAddr,
    /// `None` once the node is declared dead.
    stream: Option<TcpStream>,
    up: Gauge,
    chunks: Counter,
}

impl NodeConn {
    fn mark_dead(&mut self) {
        self.stream = None;
        self.up.set(0.0);
    }
}

/// Which data path carries halo bands between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExchangeMode {
    /// Nodes push boundary bands directly to each other, overlapped
    /// with interior compute; the coordinator only distributes the plan
    /// and collects the result. The steady-state default.
    #[default]
    Peer,
    /// Every tile round-trips through the coordinator each round and
    /// the coordinator runs the halo exchange itself (the PR 9 path;
    /// also the automatic fallback when a peer plan fails).
    Mediated,
}

impl std::fmt::Display for ExchangeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExchangeMode::Peer => "peer",
            ExchangeMode::Mediated => "mediated",
        })
    }
}

impl std::str::FromStr for ExchangeMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<ExchangeMode> {
        match s {
            "peer" => Ok(ExchangeMode::Peer),
            "mediated" => Ok(ExchangeMode::Mediated),
            other => anyhow::bail!("unknown exchange mode '{other}' (choose peer|mediated)"),
        }
    }
}

/// Accounting of one fleet evolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterReport {
    /// Nodes connected when the evolution started.
    pub nodes: usize,
    /// Nodes still alive when it finished.
    pub nodes_alive: usize,
    /// Shards (slabs) the grid was split into.
    pub shards: usize,
    /// Fusion accounting (same meaning as the in-process evolver's).
    pub fuse: FuseReport,
    /// Chunk RPCs that completed successfully. On the peer path this
    /// counts shard-rounds executed node-side (same unit of work).
    pub chunks: usize,
    /// Chunks re-placed after a node loss.
    pub replacements: usize,
    /// Request bytes put on the wire (frames included).
    pub bytes_sent: usize,
    /// Reply bytes taken off the wire (frames included).
    pub bytes_recv: usize,
    /// Data path that produced the result.
    pub path: ExchangeMode,
    /// True when a peer plan failed and the evolution was re-run on the
    /// mediated path (`path` is then [`ExchangeMode::Mediated`]).
    pub fell_back: bool,
    /// Halo-band bytes moved node↔node (peer path only; bands between
    /// two shards on the same node never touch the wire).
    pub band_bytes: usize,
    /// Exchange time hidden behind interior compute, microseconds
    /// (summed over nodes and rounds; peer path only).
    pub exchange_hidden_us: u64,
    /// Exchange time on the critical path, microseconds: band
    /// extraction, waits, and application (peer), or the coordinator's
    /// serial exchange (mediated).
    pub exchange_visible_us: u64,
}

impl ClusterReport {
    /// Fraction of exchange time hidden behind compute, in `[0, 1]`.
    /// `1.0` when there was no exchange work at all (single shard, or
    /// every band landed before it was needed).
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.exchange_hidden_us + self.exchange_visible_us;
        if total == 0 {
            1.0
        } else {
            self.exchange_hidden_us as f64 / total as f64
        }
    }

    /// Total exchange seconds (hidden + visible).
    pub fn exchange_seconds(&self) -> f64 {
        (self.exchange_hidden_us + self.exchange_visible_us) as f64 / 1e6
    }
}

/// Epoch tags for peer exchange plans: unique per coordinator process
/// (counter) and across restarts (wall-clock salt), so a stale band
/// from an abandoned plan can never be mistaken for a live one.
fn next_epoch() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let now = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    now ^ n.rotate_left(32)
}

/// A connected fleet of worker nodes.
pub struct Coordinator {
    nodes: Vec<NodeConn>,
    engine: Engine,
    rpc_timeout: Duration,
    replacements: Counter,
    bytes_sent: Counter,
    bytes_recv: Counter,
    rpc_seconds: Histogram,
    exchange_seconds_peer: Histogram,
    exchange_seconds_mediated: Histogram,
    exchange_bytes_peer: Counter,
    exchange_bytes_mediated: Counter,
    overlap_ratio: Gauge,
    peer_fallbacks: Counter,
}

impl Coordinator {
    /// Connect to every node address (e.g. `["127.0.0.1:7401",
    /// "10.0.0.2:7401"]`) and health-check each with a `Ping`. Fails if
    /// any node is unreachable or does not speak protocol version
    /// [`super::frame::VERSION`]; `engine` must match what the nodes
    /// compile (checked per chunk node-side).
    pub fn connect(addrs: &[String], engine: Engine) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(!addrs.is_empty(), "a cluster needs at least one node address");
        let r = registry::global();
        let mut nodes = Vec::with_capacity(addrs.len());
        for (i, a) in addrs.iter().enumerate() {
            let addr = a
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("bad node address '{a}': {e}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("node address '{a}' resolved to nothing"))?;
            let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))
                .map_err(|e| anyhow::anyhow!("cannot connect to cluster node {addr}: {e}"))?;
            stream.set_read_timeout(Some(Duration::from_millis(50)))?;
            stream.set_write_timeout(Some(Duration::from_secs(30)))?;
            stream.set_nodelay(true)?;
            let up = r.gauge_with("stencil_cluster_node_up", &format!("node=\"{i}\""));
            up.set(1.0);
            nodes.push(NodeConn {
                addr,
                stream: Some(stream),
                up,
                chunks: r.counter_with("stencil_cluster_chunks_total", &format!("node=\"{i}\"")),
            });
        }
        let mut c = Coordinator {
            nodes,
            engine,
            rpc_timeout: DEFAULT_RPC_TIMEOUT,
            replacements: r.counter("stencil_cluster_replacements_total"),
            bytes_sent: r.counter("stencil_cluster_bytes_sent_total"),
            bytes_recv: r.counter("stencil_cluster_bytes_recv_total"),
            rpc_seconds: r.histogram("stencil_cluster_rpc_seconds", &SECONDS_BUCKETS),
            exchange_seconds_peer: r.histogram_with(
                "stencil_cluster_exchange_seconds",
                "path=\"peer\"",
                &SECONDS_BUCKETS,
            ),
            exchange_seconds_mediated: r.histogram_with(
                "stencil_cluster_exchange_seconds",
                "path=\"mediated\"",
                &SECONDS_BUCKETS,
            ),
            exchange_bytes_peer: r
                .counter_with("stencil_cluster_exchange_bytes_total", "path=\"peer\""),
            exchange_bytes_mediated: r
                .counter_with("stencil_cluster_exchange_bytes_total", "path=\"mediated\""),
            overlap_ratio: r.gauge("stencil_cluster_overlap_ratio"),
            peer_fallbacks: r.counter("stencil_cluster_peer_fallbacks_total"),
        };
        for i in 0..c.nodes.len() {
            let addr = c.nodes[i].addr;
            c.ping_node(i)?
                .ok_or_else(|| anyhow::anyhow!("cluster node {addr} did not answer the ping"))?;
        }
        Ok(c)
    }

    /// Convenience for tests and `cluster-bench`: connect to in-process
    /// nodes spawned with [`super::node::spawn_local`].
    pub fn connect_local(handles: &[NodeHandle], engine: Engine) -> anyhow::Result<Coordinator> {
        let addrs: Vec<String> = handles.iter().map(|h| h.addr().to_string()).collect();
        Coordinator::connect(&addrs, engine)
    }

    /// Override the per-node reply timeout (tests use a short one so a
    /// killed node is detected quickly).
    pub fn set_rpc_timeout(&mut self, t: Duration) {
        self.rpc_timeout = t;
    }

    /// Nodes still considered alive.
    pub fn nodes_alive(&self) -> usize {
        self.nodes.iter().filter(|n| n.stream.is_some()).count()
    }

    /// Total nodes this coordinator was built with.
    pub fn nodes_total(&self) -> usize {
        self.nodes.len()
    }

    /// Ping one node; `Ok(None)` means it is (now) dead. A peer
    /// answering with a different protocol version is a hard error (not
    /// a dead node): version skew is an operator mistake that re-placing
    /// slabs can never fix, so it must surface as its own message.
    fn ping_node(&mut self, i: usize) -> anyhow::Result<Option<NodeStatus>> {
        let node = &mut self.nodes[i];
        let addr = node.addr;
        let Some(stream) = node.stream.as_mut() else { return Ok(None) };
        if proto::send_msg(stream, &Msg::Ping).is_err() {
            node.mark_dead();
            return Ok(None);
        }
        let start = Instant::now();
        loop {
            match proto::recv_msg(stream, Duration::from_secs(5)) {
                Ok(MsgRecv::Msg(Msg::Pong(st), _)) => return Ok(Some(st)),
                Ok(MsgRecv::Msg(other, _)) => {
                    anyhow::bail!("node {addr} answered ping with {other:?}")
                }
                Ok(MsgRecv::Idle) => {
                    if start.elapsed() > Duration::from_secs(5) {
                        node.mark_dead();
                        return Ok(None);
                    }
                }
                Err(e) if e.downcast_ref::<VersionMismatch>().is_some() => {
                    node.mark_dead();
                    return Err(e.context(format!(
                        "cluster node {addr} failed the protocol handshake"
                    )));
                }
                Ok(MsgRecv::Eof) | Err(_) => {
                    node.mark_dead();
                    return Ok(None);
                }
            }
        }
    }

    /// Health-check every node; the fleet analogue of `/healthz`.
    pub fn health_json(&mut self) -> Json {
        let mut statuses = Vec::new();
        for i in 0..self.nodes.len() {
            let addr = self.nodes[i].addr;
            let st = self.ping_node(i).ok().flatten();
            statuses.push(obj(vec![
                ("addr", Json::Str(addr.to_string())),
                ("up", Json::Bool(st.is_some())),
                ("workers", Json::Num(st.as_ref().map(|s| s.workers as f64).unwrap_or(0.0))),
                (
                    "chunks_served",
                    Json::Num(st.as_ref().map(|s| s.chunks_served as f64).unwrap_or(0.0)),
                ),
            ]));
        }
        let alive = self.nodes_alive();
        obj(vec![
            (
                "status",
                Json::Str(
                    if alive == self.nodes.len() {
                        "ok"
                    } else if alive > 0 {
                        "degraded"
                    } else {
                        "down"
                    }
                    .to_string(),
                ),
            ),
            ("nodes", Json::Num(self.nodes.len() as f64)),
            ("nodes_alive", Json::Num(alive as f64)),
            ("node_status", Json::Arr(statuses)),
        ])
    }

    /// Ask every live node to exit its serve loop (used when the
    /// coordinator owns the fleet's lifecycle, e.g. `cluster-bench`).
    pub fn shutdown_nodes(&mut self) {
        for node in &mut self.nodes {
            if let Some(stream) = node.stream.as_mut() {
                let _ = proto::send_msg(stream, &Msg::Shutdown);
            }
            node.mark_dead();
        }
    }

    /// Distributed temporally-blocked evolution — the fleet twin of
    /// [`ShardedEvolver::evolve_fused`](crate::serve::ShardedEvolver::evolve_fused),
    /// bitwise identical to it (and so, for the oracle/taps kernels, to
    /// [`crate::stencil::reference::evolve`]).
    pub fn evolve_fused(
        &mut self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
        fuse: usize,
    ) -> anyhow::Result<(DenseGrid, ClusterReport)> {
        anyhow::ensure!(
            grid.shape.len() == spec.dims,
            "grid shape {:?} does not match {spec}",
            grid.shape
        );
        anyhow::ensure!(
            grid.shape.iter().all(|&n| n > 2 * spec.order),
            "grid {:?} too small for order-{} stencil",
            grid.shape,
            spec.order
        );
        let t = Partition::max_fuse(grid.shape[0], spec.order, shards, fuse).min(steps.max(1));
        let part = Partition::new(&grid.shape, shards, spec.order * t)?;
        let n_shards = part.len();
        let mut report = ClusterReport {
            nodes: self.nodes.len(),
            nodes_alive: self.nodes_alive(),
            shards: n_shards,
            fuse: FuseReport { fuse_steps: t, halo_exchanges: 0 },
            chunks: 0,
            replacements: 0,
            bytes_sent: 0,
            bytes_recv: 0,
            path: ExchangeMode::Mediated,
            fell_back: false,
            band_bytes: 0,
            exchange_hidden_us: 0,
            exchange_visible_us: 0,
        };
        if steps == 0 {
            return Ok((grid.clone(), report));
        }
        let mut tiles = part.extract(grid);
        let mut remaining = steps;
        while remaining > 0 {
            let chunk = t.min(remaining);
            let _g = span_arg("cluster.round", "cluster", ("steps", chunk as f64));
            self.run_round(&mut tiles, spec, method, chunk, &mut report)?;
            remaining -= chunk;
            if remaining > 0 && n_shards > 1 {
                let _g = span("cluster.exchange", "cluster");
                let t0 = Instant::now();
                halo::exchange_serial(&part, &mut tiles);
                let dt = t0.elapsed();
                self.exchange_seconds_mediated.observe(dt.as_secs_f64());
                report.exchange_visible_us += dt.as_micros() as u64;
                report.fuse.halo_exchanges += 1;
            }
        }
        report.nodes_alive = self.nodes_alive();
        // on the mediated path every exchanged byte rides the
        // coordinator's connections, so the per-path wire accounting is
        // the coordinator's own traffic
        self.exchange_bytes_mediated.add((report.bytes_sent + report.bytes_recv) as u64);
        let refs: Vec<&DenseGrid> = tiles.iter().collect();
        Ok((part.assemble(&refs)?, report))
    }

    /// Evolve on the requested data path. The peer path falls back to
    /// the mediated path on *any* plan failure — a dead node, a band
    /// timeout, a version skew — by re-running the whole evolution from
    /// the original grid on the surviving nodes (evolution is a pure
    /// function of the input grid, so the retry is bitwise identical to
    /// what the peer path would have produced).
    #[allow(clippy::too_many_arguments)]
    pub fn evolve_exchange(
        &mut self,
        mode: ExchangeMode,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
        fuse: usize,
    ) -> anyhow::Result<(DenseGrid, ClusterReport)> {
        match mode {
            ExchangeMode::Mediated => self.evolve_fused(spec, grid, steps, shards, method, fuse),
            ExchangeMode::Peer => match self.evolve_peer(spec, grid, steps, shards, method, fuse) {
                Ok(done) => Ok(done),
                Err(peer_err) => {
                    self.peer_fallbacks.inc();
                    let (out, mut report) = self
                        .evolve_fused(spec, grid, steps, shards, method, fuse)
                        .map_err(|med_err| {
                            anyhow::anyhow!(
                                "peer exchange failed ({peer_err:#}) and the mediated \
                                 fallback also failed: {med_err:#}"
                            )
                        })?;
                    report.fell_back = true;
                    Ok((out, report))
                }
            },
        }
    }

    /// The peer-to-peer data path: distribute one exchange plan, let
    /// the nodes run every round among themselves, collect the evolved
    /// tiles. Any failure aborts the plan (callers fall back via
    /// [`Coordinator::evolve_exchange`]).
    fn evolve_peer(
        &mut self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
        fuse: usize,
    ) -> anyhow::Result<(DenseGrid, ClusterReport)> {
        anyhow::ensure!(
            grid.shape.len() == spec.dims,
            "grid shape {:?} does not match {spec}",
            grid.shape
        );
        anyhow::ensure!(
            grid.shape.iter().all(|&n| n > 2 * spec.order),
            "grid {:?} too small for order-{} stencil",
            grid.shape,
            spec.order
        );
        let t = Partition::max_fuse(grid.shape[0], spec.order, shards, fuse).min(steps.max(1));
        let part = Partition::new(&grid.shape, shards, spec.order * t)?;
        let n_shards = part.len();
        let mut report = ClusterReport {
            nodes: self.nodes.len(),
            nodes_alive: self.nodes_alive(),
            shards: n_shards,
            fuse: FuseReport { fuse_steps: t, halo_exchanges: 0 },
            chunks: 0,
            replacements: 0,
            bytes_sent: 0,
            bytes_recv: 0,
            path: ExchangeMode::Peer,
            fell_back: false,
            band_bytes: 0,
            exchange_hidden_us: 0,
            exchange_visible_us: 0,
        };
        if steps == 0 {
            return Ok((grid.clone(), report));
        }
        let live: Vec<usize> =
            (0..self.nodes.len()).filter(|&i| self.nodes[i].stream.is_some()).collect();
        anyhow::ensure!(!live.is_empty(), "no live nodes to run an exchange plan on");

        let epoch = next_epoch();
        let total_rounds = steps.div_ceil(t);
        // owner indices are positions in the plan's peer list, which
        // holds the *live* nodes in order; alternating placement keeps
        // neighbouring shards on different nodes whenever possible, so
        // the overlap machinery is exercised even by two-node fleets
        let owners: Vec<usize> = (0..n_shards).map(|s| s % live.len()).collect();
        let peers: Vec<String> = live.iter().map(|&ni| self.nodes[ni].addr.to_string()).collect();
        let band_timeout_ms = self.band_timeout().as_millis().max(1) as u64;

        let tiles = part.extract(grid);
        let mut assignment: Vec<Vec<(u64, DenseGrid)>> = vec![Vec::new(); live.len()];
        for (s, tile) in tiles.into_iter().enumerate() {
            assignment[owners[s]].push((s as u64, tile));
        }

        // phase 1: ship plan + tiles to every live node, pipelined
        for (li, &ni) in live.iter().enumerate() {
            let req = Msg::EvolvePlan(PlanRequest {
                plan: ExchangePlan {
                    epoch,
                    spec,
                    method,
                    engine: self.engine,
                    steps,
                    fuse: t,
                    local_shards: 0,
                    band_timeout_ms,
                    part: part.clone(),
                    owners: owners.clone(),
                    peers: peers.clone(),
                    self_node: li,
                },
                tiles: std::mem::take(&mut assignment[li]),
            });
            let node = &mut self.nodes[ni];
            let Some(stream) = node.stream.as_mut() else {
                anyhow::bail!("node {} died while the plan was being distributed", node.addr)
            };
            match proto::send_msg(stream, &req) {
                Ok(n) => {
                    report.bytes_sent += n;
                    self.bytes_sent.add(n as u64);
                }
                Err(e) => {
                    let addr = node.addr;
                    node.mark_dead();
                    anyhow::bail!("node {addr} lost while receiving the exchange plan: {e}");
                }
            }
        }

        // phase 2: wait until *every* node has registered its band
        // staging (PlanReady), then release them all (PlanStart) — no
        // band can arrive at a node that is not ready for it
        for &ni in &live {
            self.wait_plan_ready(ni, epoch, &mut report)?;
        }
        for &ni in &live {
            let node = &mut self.nodes[ni];
            let Some(stream) = node.stream.as_mut() else {
                anyhow::bail!("node {} died between PlanReady and PlanStart", node.addr)
            };
            match proto::send_msg(stream, &Msg::PlanStart { epoch }) {
                Ok(n) => {
                    report.bytes_sent += n;
                    self.bytes_sent.add(n as u64);
                }
                Err(e) => {
                    let addr = node.addr;
                    node.mark_dead();
                    anyhow::bail!("node {addr} lost at PlanStart: {e}");
                }
            }
        }

        // phase 3: the nodes run every round among themselves; collect
        // the evolved tiles and per-node stats. Keep draining the other
        // nodes after a failure so every surviving connection returns
        // to a frame boundary before the mediated fallback reuses it.
        let mut out_tiles: Vec<Option<DenseGrid>> = vec![None; n_shards];
        let mut stats = PlanStats::default();
        let mut first_err: Option<anyhow::Error> = None;
        for &ni in &live {
            if let Err(e) =
                self.wait_plan_done(ni, epoch, &part, &mut out_tiles, &mut stats, &mut report)
            {
                first_err.get_or_insert(e);
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let mut evolved = Vec::with_capacity(n_shards);
        for (s, tile) in out_tiles.into_iter().enumerate() {
            evolved.push(tile.ok_or_else(|| anyhow::anyhow!("shard {s} never came back"))?);
        }

        report.nodes_alive = self.nodes_alive();
        report.chunks = n_shards * total_rounds;
        report.fuse.halo_exchanges =
            if n_shards > 1 { total_rounds.saturating_sub(1) } else { 0 };
        report.band_bytes = stats.band_bytes_sent as usize;
        report.exchange_hidden_us = (stats.exchange_hidden_seconds * 1e6) as u64;
        report.exchange_visible_us = (stats.exchange_visible_seconds * 1e6) as u64;
        self.exchange_seconds_peer
            .observe(stats.exchange_hidden_seconds + stats.exchange_visible_seconds);
        self.exchange_bytes_peer.add(stats.band_bytes_sent);
        self.overlap_ratio.set(report.overlap_ratio());

        let refs: Vec<&DenseGrid> = evolved.iter().collect();
        Ok((part.assemble(&refs)?, report))
    }

    /// How long a node may block waiting for one peer band before it
    /// declares the peer lost (distributed in the exchange plan).
    fn band_timeout(&self) -> Duration {
        self.rpc_timeout.min(Duration::from_secs(10))
    }

    /// Wait for one node's `PlanReady` (phase 2 of the peer handshake).
    fn wait_plan_ready(
        &mut self,
        ni: usize,
        epoch: u64,
        report: &mut ClusterReport,
    ) -> anyhow::Result<()> {
        let start = Instant::now();
        let addr = self.nodes[ni].addr;
        loop {
            let node = &mut self.nodes[ni];
            let Some(stream) = node.stream.as_mut() else {
                anyhow::bail!("node {addr} died before acknowledging the exchange plan")
            };
            match proto::recv_msg(stream, Duration::from_secs(10)) {
                Ok(MsgRecv::Msg(Msg::PlanReady { epoch: e }, n)) if e == epoch => {
                    report.bytes_recv += n;
                    self.bytes_recv.add(n as u64);
                    return Ok(());
                }
                Ok(MsgRecv::Msg(Msg::PlanErr { error, .. }, _)) => {
                    anyhow::bail!("node {addr} rejected the exchange plan: {error}");
                }
                Ok(MsgRecv::Msg(other, _)) => {
                    anyhow::bail!("protocol violation from node {addr}: unexpected {other:?}");
                }
                Ok(MsgRecv::Idle) => {
                    if start.elapsed() > self.rpc_timeout {
                        node.mark_dead();
                        anyhow::bail!(
                            "node {addr} did not acknowledge the exchange plan within {:?}",
                            self.rpc_timeout
                        );
                    }
                }
                Ok(MsgRecv::Eof) | Err(_) => {
                    node.mark_dead();
                    anyhow::bail!("node {addr} lost during the plan handshake");
                }
            }
        }
    }

    /// Wait for one node's `PlanDone` (or `PlanErr`) and fold its tiles
    /// and stats into the evolution result.
    fn wait_plan_done(
        &mut self,
        ni: usize,
        epoch: u64,
        part: &Partition,
        out_tiles: &mut [Option<DenseGrid>],
        stats: &mut PlanStats,
        report: &mut ClusterReport,
    ) -> anyhow::Result<()> {
        let start = Instant::now();
        let addr = self.nodes[ni].addr;
        // a healthy node may block one full band timeout on a lost peer
        // before it can report PlanErr — give it that long on top of the
        // usual reply budget, or the coordinator would declare survivors
        // dead moments before their failure reports arrive and leave the
        // mediated fallback with no fleet to run on
        let deadline = self.rpc_timeout + self.band_timeout();
        loop {
            let node = &mut self.nodes[ni];
            let Some(stream) = node.stream.as_mut() else {
                anyhow::bail!("node {addr} died mid-exchange")
            };
            match proto::recv_msg(stream, Duration::from_secs(10)) {
                Ok(MsgRecv::Msg(Msg::PlanDone(done), n)) if done.epoch == epoch => {
                    report.bytes_recv += n;
                    self.bytes_recv.add(n as u64);
                    for (shard, tile) in done.tiles {
                        let s = shard as usize;
                        anyhow::ensure!(
                            s < out_tiles.len(),
                            "node {addr} returned unknown shard {s}"
                        );
                        anyhow::ensure!(
                            out_tiles[s].is_none(),
                            "node {addr} returned shard {s} twice"
                        );
                        let want = part.tile_shape(s);
                        anyhow::ensure!(
                            tile.shape == want,
                            "node {addr} returned tile shape {:?} for shard {s} (expected {want:?})",
                            tile.shape
                        );
                        out_tiles[s] = Some(tile);
                        node.chunks.inc();
                    }
                    stats.rounds = stats.rounds.max(done.stats.rounds);
                    stats.bands_sent += done.stats.bands_sent;
                    stats.band_bytes_sent += done.stats.band_bytes_sent;
                    stats.band_bytes_recv += done.stats.band_bytes_recv;
                    stats.exchange_hidden_seconds += done.stats.exchange_hidden_seconds;
                    stats.exchange_visible_seconds += done.stats.exchange_visible_seconds;
                    stats.compute_seconds += done.stats.compute_seconds;
                    return Ok(());
                }
                Ok(MsgRecv::Msg(Msg::PlanErr { error, .. }, _)) => {
                    anyhow::bail!("node {addr} failed the exchange plan: {error}");
                }
                Ok(MsgRecv::Msg(other, _)) => {
                    anyhow::bail!("protocol violation from node {addr}: unexpected {other:?}");
                }
                Ok(MsgRecv::Idle) => {
                    if start.elapsed() > deadline {
                        node.mark_dead();
                        anyhow::bail!(
                            "node {addr} did not finish the exchange plan within {deadline:?}"
                        );
                    }
                }
                Ok(MsgRecv::Eof) | Err(_) => {
                    node.mark_dead();
                    anyhow::bail!("node {addr} lost mid-exchange");
                }
            }
        }
    }

    /// One chunk round: evolve every tile by `chunk` fused steps on the
    /// fleet, pipelined per node, re-placing on node loss until every
    /// tile is in or no nodes remain.
    fn run_round(
        &mut self,
        tiles: &mut [DenseGrid],
        spec: StencilSpec,
        method: KernelMethod,
        chunk: usize,
        report: &mut ClusterReport,
    ) -> anyhow::Result<()> {
        let mut pending: BTreeSet<usize> = (0..tiles.len()).collect();
        let mut first_attempt = true;
        while !pending.is_empty() {
            let live: Vec<usize> =
                (0..self.nodes.len()).filter(|&i| self.nodes[i].stream.is_some()).collect();
            anyhow::ensure!(
                !live.is_empty(),
                "all cluster nodes lost with {} chunk(s) outstanding",
                pending.len()
            );
            if !first_attempt {
                report.replacements += pending.len();
                self.replacements.add(pending.len() as u64);
            }
            first_attempt = false;

            // place pending shards round-robin over the live nodes
            let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
            for (i, &s) in pending.iter().enumerate() {
                assignment[live[i % live.len()]].push(s);
            }

            // send phase: pipeline every chunk of a node's assignment
            // onto its connection before reading anything back
            for &ni in &live {
                if assignment[ni].is_empty() {
                    continue;
                }
                for idx in 0..assignment[ni].len() {
                    let s = assignment[ni][idx];
                    let req = Msg::EvolveChunk(ChunkRequest {
                        id: s as u64,
                        spec,
                        method,
                        engine: self.engine,
                        steps: chunk,
                        local_shards: 0,
                        tile: tiles[s].clone(),
                    });
                    let node = &mut self.nodes[ni];
                    let Some(stream) = node.stream.as_mut() else { break };
                    match proto::send_msg(stream, &req) {
                        Ok(n) => {
                            report.bytes_sent += n;
                            self.bytes_sent.add(n as u64);
                        }
                        Err(_) => {
                            node.mark_dead();
                            break;
                        }
                    }
                }
            }

            // receive phase: drain each node's replies; a timeout, EOF,
            // or IO error marks the node dead and leaves its unanswered
            // chunks pending for the next placement round
            for &ni in &live {
                if assignment[ni].is_empty() || self.nodes[ni].stream.is_none() {
                    continue;
                }
                let mut expected: BTreeSet<usize> = assignment[ni].iter().copied().collect();
                let _g = span_arg("cluster.rpc", "cluster", ("chunks", expected.len() as f64));
                let start = Instant::now();
                while !expected.is_empty() {
                    if start.elapsed() > self.rpc_timeout {
                        self.nodes[ni].mark_dead();
                        break;
                    }
                    let node = &mut self.nodes[ni];
                    let Some(stream) = node.stream.as_mut() else { break };
                    match proto::recv_msg(stream, Duration::from_secs(10)) {
                        Ok(MsgRecv::Msg(Msg::ChunkOk(rep), n)) => {
                            let s = rep.id as usize;
                            anyhow::ensure!(
                                expected.remove(&s),
                                "node {} answered chunk {s} it was not asked for",
                                node.addr
                            );
                            anyhow::ensure!(
                                rep.tile.shape == tiles[s].shape,
                                "node {} returned tile shape {:?} for shard {s} (expected {:?})",
                                node.addr,
                                rep.tile.shape,
                                tiles[s].shape
                            );
                            tiles[s] = rep.tile;
                            pending.remove(&s);
                            report.bytes_recv += n;
                            self.bytes_recv.add(n as u64);
                            self.rpc_seconds.observe(start.elapsed().as_secs_f64());
                            node.chunks.inc();
                            report.chunks += 1;
                        }
                        Ok(MsgRecv::Msg(Msg::ChunkErr { id, error }, _)) => {
                            // a node-side *computation* error is not a
                            // node loss: every node would fail the same
                            // way, so surface it instead of re-placing
                            anyhow::bail!("node {} failed chunk {id}: {error}", node.addr);
                        }
                        Ok(MsgRecv::Msg(other, _)) => {
                            anyhow::bail!(
                                "protocol violation from node {}: unexpected {other:?}",
                                node.addr
                            );
                        }
                        Ok(MsgRecv::Idle) => continue,
                        Ok(MsgRecv::Eof) | Err(_) => {
                            node.mark_dead();
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }
}
