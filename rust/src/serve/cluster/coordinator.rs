//! The cluster coordinator: places grid slabs on worker nodes, drives
//! fused T-step evolution with coordinator-mediated deep-halo exchange,
//! and re-places work when a node dies mid-evolution.
//!
//! The evolution loop is a line-for-line mirror of
//! [`ShardedEvolver::evolve_fused`](crate::serve::ShardedEvolver::evolve_fused)
//! with the pool batch replaced by RPCs:
//!
//! 1. cap the time-tile depth `T` with [`Partition::max_fuse`] (deep
//!    halos must not starve the shard count) and build the one
//!    partition with ghosts of depth `order × T`;
//! 2. per chunk of `T` steps, send every shard's tile to a node
//!    (round-robin, pipelined per connection) and collect the evolved
//!    tiles;
//! 3. between chunks, run [`halo::exchange_serial`] over the collected
//!    tiles — one exchange per `T` steps, so cross-node traffic
//!    amortizes exactly like the in-process fused path;
//! 4. assemble the owned rows.
//!
//! Because the partition, chunking, exchange, and assembly are the same
//! code the in-process evolver uses, and a node's tile evolution is
//! bitwise equal to a local fused plan application (see
//! [`super::node`]), the fleet result is **bitwise identical** to the
//! single-process sharded evolver — which is itself bitwise identical
//! to the scalar oracle for the oracle/taps kernels.
//!
//! **Node loss.** The coordinator keeps every input tile of the current
//! round until its evolved reply lands, so losing a node is recoverable
//! by construction: dead nodes are dropped, their unanswered chunks are
//! re-placed on the surviving nodes, and the round re-runs until every
//! chunk is in (or no nodes remain). Re-sent chunks are idempotent —
//! evolution is a pure function of the tile.

use super::node::NodeHandle;
use super::proto::{self, ChunkRequest, Msg, MsgRecv, NodeStatus};
use crate::kir::Engine;
use crate::obs::registry::{self, Counter, Gauge, Histogram, SECONDS_BUCKETS};
use crate::obs::span::{span, span_arg};
use crate::serve::scheduler::{FuseReport, KernelMethod};
use crate::serve::{halo, Partition};
use crate::stencil::{DenseGrid, StencilSpec};
use crate::util::json::{obj, Json};
use std::collections::BTreeSet;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Per-RPC reply timeout: how long the coordinator waits for one node's
/// chunk replies before declaring the node dead and re-placing.
pub const DEFAULT_RPC_TIMEOUT: Duration = Duration::from_secs(60);

struct NodeConn {
    addr: SocketAddr,
    /// `None` once the node is declared dead.
    stream: Option<TcpStream>,
    up: Gauge,
    chunks: Counter,
}

impl NodeConn {
    fn mark_dead(&mut self) {
        self.stream = None;
        self.up.set(0.0);
    }
}

/// Accounting of one fleet evolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterReport {
    /// Nodes connected when the evolution started.
    pub nodes: usize,
    /// Nodes still alive when it finished.
    pub nodes_alive: usize,
    /// Shards (slabs) the grid was split into.
    pub shards: usize,
    /// Fusion accounting (same meaning as the in-process evolver's).
    pub fuse: FuseReport,
    /// Chunk RPCs that completed successfully.
    pub chunks: usize,
    /// Chunks re-placed after a node loss.
    pub replacements: usize,
    /// Request bytes put on the wire (frames included).
    pub bytes_sent: usize,
    /// Reply bytes taken off the wire (frames included).
    pub bytes_recv: usize,
}

/// A connected fleet of worker nodes.
pub struct Coordinator {
    nodes: Vec<NodeConn>,
    engine: Engine,
    rpc_timeout: Duration,
    replacements: Counter,
    bytes_sent: Counter,
    bytes_recv: Counter,
    rpc_seconds: Histogram,
}

impl Coordinator {
    /// Connect to every node address (e.g. `["127.0.0.1:7401",
    /// "10.0.0.2:7401"]`) and health-check each with a `Ping`. Fails if
    /// any node is unreachable or does not speak protocol version
    /// [`super::frame::VERSION`]; `engine` must match what the nodes
    /// compile (checked per chunk node-side).
    pub fn connect(addrs: &[String], engine: Engine) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(!addrs.is_empty(), "a cluster needs at least one node address");
        let r = registry::global();
        let mut nodes = Vec::with_capacity(addrs.len());
        for (i, a) in addrs.iter().enumerate() {
            let addr = a
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("bad node address '{a}': {e}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("node address '{a}' resolved to nothing"))?;
            let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))
                .map_err(|e| anyhow::anyhow!("cannot connect to cluster node {addr}: {e}"))?;
            stream.set_read_timeout(Some(Duration::from_millis(50)))?;
            stream.set_write_timeout(Some(Duration::from_secs(30)))?;
            stream.set_nodelay(true)?;
            let up = r.gauge_with("stencil_cluster_node_up", &format!("node=\"{i}\""));
            up.set(1.0);
            nodes.push(NodeConn {
                addr,
                stream: Some(stream),
                up,
                chunks: r.counter_with("stencil_cluster_chunks_total", &format!("node=\"{i}\"")),
            });
        }
        let mut c = Coordinator {
            nodes,
            engine,
            rpc_timeout: DEFAULT_RPC_TIMEOUT,
            replacements: r.counter("stencil_cluster_replacements_total"),
            bytes_sent: r.counter("stencil_cluster_bytes_sent_total"),
            bytes_recv: r.counter("stencil_cluster_bytes_recv_total"),
            rpc_seconds: r.histogram("stencil_cluster_rpc_seconds", &SECONDS_BUCKETS),
        };
        for i in 0..c.nodes.len() {
            let addr = c.nodes[i].addr;
            c.ping_node(i)?
                .ok_or_else(|| anyhow::anyhow!("cluster node {addr} did not answer the ping"))?;
        }
        Ok(c)
    }

    /// Convenience for tests and `cluster-bench`: connect to in-process
    /// nodes spawned with [`super::node::spawn_local`].
    pub fn connect_local(handles: &[NodeHandle], engine: Engine) -> anyhow::Result<Coordinator> {
        let addrs: Vec<String> = handles.iter().map(|h| h.addr().to_string()).collect();
        Coordinator::connect(&addrs, engine)
    }

    /// Override the per-node reply timeout (tests use a short one so a
    /// killed node is detected quickly).
    pub fn set_rpc_timeout(&mut self, t: Duration) {
        self.rpc_timeout = t;
    }

    /// Nodes still considered alive.
    pub fn nodes_alive(&self) -> usize {
        self.nodes.iter().filter(|n| n.stream.is_some()).count()
    }

    /// Total nodes this coordinator was built with.
    pub fn nodes_total(&self) -> usize {
        self.nodes.len()
    }

    /// Ping one node; `Ok(None)` means it is (now) dead.
    fn ping_node(&mut self, i: usize) -> anyhow::Result<Option<NodeStatus>> {
        let node = &mut self.nodes[i];
        let Some(stream) = node.stream.as_mut() else { return Ok(None) };
        if proto::send_msg(stream, &Msg::Ping).is_err() {
            node.mark_dead();
            return Ok(None);
        }
        let start = Instant::now();
        loop {
            match proto::recv_msg(stream, Duration::from_secs(5)) {
                Ok(MsgRecv::Msg(Msg::Pong(st), _)) => return Ok(Some(st)),
                Ok(MsgRecv::Msg(other, _)) => {
                    anyhow::bail!("node {} answered ping with {other:?}", node.addr)
                }
                Ok(MsgRecv::Idle) => {
                    if start.elapsed() > Duration::from_secs(5) {
                        node.mark_dead();
                        return Ok(None);
                    }
                }
                Ok(MsgRecv::Eof) | Err(_) => {
                    node.mark_dead();
                    return Ok(None);
                }
            }
        }
    }

    /// Health-check every node; the fleet analogue of `/healthz`.
    pub fn health_json(&mut self) -> Json {
        let mut statuses = Vec::new();
        for i in 0..self.nodes.len() {
            let addr = self.nodes[i].addr;
            let st = self.ping_node(i).ok().flatten();
            statuses.push(obj(vec![
                ("addr", Json::Str(addr.to_string())),
                ("up", Json::Bool(st.is_some())),
                ("workers", Json::Num(st.as_ref().map(|s| s.workers as f64).unwrap_or(0.0))),
                (
                    "chunks_served",
                    Json::Num(st.as_ref().map(|s| s.chunks_served as f64).unwrap_or(0.0)),
                ),
            ]));
        }
        let alive = self.nodes_alive();
        obj(vec![
            (
                "status",
                Json::Str(
                    if alive == self.nodes.len() {
                        "ok"
                    } else if alive > 0 {
                        "degraded"
                    } else {
                        "down"
                    }
                    .to_string(),
                ),
            ),
            ("nodes", Json::Num(self.nodes.len() as f64)),
            ("nodes_alive", Json::Num(alive as f64)),
            ("node_status", Json::Arr(statuses)),
        ])
    }

    /// Ask every live node to exit its serve loop (used when the
    /// coordinator owns the fleet's lifecycle, e.g. `cluster-bench`).
    pub fn shutdown_nodes(&mut self) {
        for node in &mut self.nodes {
            if let Some(stream) = node.stream.as_mut() {
                let _ = proto::send_msg(stream, &Msg::Shutdown);
            }
            node.mark_dead();
        }
    }

    /// Distributed temporally-blocked evolution — the fleet twin of
    /// [`ShardedEvolver::evolve_fused`](crate::serve::ShardedEvolver::evolve_fused),
    /// bitwise identical to it (and so, for the oracle/taps kernels, to
    /// [`crate::stencil::reference::evolve`]).
    pub fn evolve_fused(
        &mut self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
        fuse: usize,
    ) -> anyhow::Result<(DenseGrid, ClusterReport)> {
        anyhow::ensure!(
            grid.shape.len() == spec.dims,
            "grid shape {:?} does not match {spec}",
            grid.shape
        );
        anyhow::ensure!(
            grid.shape.iter().all(|&n| n > 2 * spec.order),
            "grid {:?} too small for order-{} stencil",
            grid.shape,
            spec.order
        );
        let t = Partition::max_fuse(grid.shape[0], spec.order, shards, fuse).min(steps.max(1));
        let part = Partition::new(&grid.shape, shards, spec.order * t)?;
        let n_shards = part.len();
        let mut report = ClusterReport {
            nodes: self.nodes.len(),
            nodes_alive: self.nodes_alive(),
            shards: n_shards,
            fuse: FuseReport { fuse_steps: t, halo_exchanges: 0 },
            chunks: 0,
            replacements: 0,
            bytes_sent: 0,
            bytes_recv: 0,
        };
        if steps == 0 {
            return Ok((grid.clone(), report));
        }
        let mut tiles = part.extract(grid);
        let mut remaining = steps;
        while remaining > 0 {
            let chunk = t.min(remaining);
            let _g = span_arg("cluster.round", "cluster", ("steps", chunk as f64));
            self.run_round(&mut tiles, spec, method, chunk, &mut report)?;
            remaining -= chunk;
            if remaining > 0 && n_shards > 1 {
                let _g = span("cluster.exchange", "cluster");
                halo::exchange_serial(&part, &mut tiles);
                report.fuse.halo_exchanges += 1;
            }
        }
        report.nodes_alive = self.nodes_alive();
        let refs: Vec<&DenseGrid> = tiles.iter().collect();
        Ok((part.assemble(&refs)?, report))
    }

    /// One chunk round: evolve every tile by `chunk` fused steps on the
    /// fleet, pipelined per node, re-placing on node loss until every
    /// tile is in or no nodes remain.
    fn run_round(
        &mut self,
        tiles: &mut [DenseGrid],
        spec: StencilSpec,
        method: KernelMethod,
        chunk: usize,
        report: &mut ClusterReport,
    ) -> anyhow::Result<()> {
        let mut pending: BTreeSet<usize> = (0..tiles.len()).collect();
        let mut first_attempt = true;
        while !pending.is_empty() {
            let live: Vec<usize> =
                (0..self.nodes.len()).filter(|&i| self.nodes[i].stream.is_some()).collect();
            anyhow::ensure!(
                !live.is_empty(),
                "all cluster nodes lost with {} chunk(s) outstanding",
                pending.len()
            );
            if !first_attempt {
                report.replacements += pending.len();
                self.replacements.add(pending.len() as u64);
            }
            first_attempt = false;

            // place pending shards round-robin over the live nodes
            let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
            for (i, &s) in pending.iter().enumerate() {
                assignment[live[i % live.len()]].push(s);
            }

            // send phase: pipeline every chunk of a node's assignment
            // onto its connection before reading anything back
            for &ni in &live {
                if assignment[ni].is_empty() {
                    continue;
                }
                for idx in 0..assignment[ni].len() {
                    let s = assignment[ni][idx];
                    let req = Msg::EvolveChunk(ChunkRequest {
                        id: s as u64,
                        spec,
                        method,
                        engine: self.engine,
                        steps: chunk,
                        local_shards: 0,
                        tile: tiles[s].clone(),
                    });
                    let node = &mut self.nodes[ni];
                    let Some(stream) = node.stream.as_mut() else { break };
                    match proto::send_msg(stream, &req) {
                        Ok(n) => {
                            report.bytes_sent += n;
                            self.bytes_sent.add(n as u64);
                        }
                        Err(_) => {
                            node.mark_dead();
                            break;
                        }
                    }
                }
            }

            // receive phase: drain each node's replies; a timeout, EOF,
            // or IO error marks the node dead and leaves its unanswered
            // chunks pending for the next placement round
            for &ni in &live {
                if assignment[ni].is_empty() || self.nodes[ni].stream.is_none() {
                    continue;
                }
                let mut expected: BTreeSet<usize> = assignment[ni].iter().copied().collect();
                let _g = span_arg("cluster.rpc", "cluster", ("chunks", expected.len() as f64));
                let start = Instant::now();
                while !expected.is_empty() {
                    if start.elapsed() > self.rpc_timeout {
                        self.nodes[ni].mark_dead();
                        break;
                    }
                    let node = &mut self.nodes[ni];
                    let Some(stream) = node.stream.as_mut() else { break };
                    match proto::recv_msg(stream, Duration::from_secs(10)) {
                        Ok(MsgRecv::Msg(Msg::ChunkOk(rep), n)) => {
                            let s = rep.id as usize;
                            anyhow::ensure!(
                                expected.remove(&s),
                                "node {} answered chunk {s} it was not asked for",
                                node.addr
                            );
                            anyhow::ensure!(
                                rep.tile.shape == tiles[s].shape,
                                "node {} returned tile shape {:?} for shard {s} (expected {:?})",
                                node.addr,
                                rep.tile.shape,
                                tiles[s].shape
                            );
                            tiles[s] = rep.tile;
                            pending.remove(&s);
                            report.bytes_recv += n;
                            self.bytes_recv.add(n as u64);
                            self.rpc_seconds.observe(start.elapsed().as_secs_f64());
                            node.chunks.inc();
                            report.chunks += 1;
                        }
                        Ok(MsgRecv::Msg(Msg::ChunkErr { id, error }, _)) => {
                            // a node-side *computation* error is not a
                            // node loss: every node would fail the same
                            // way, so surface it instead of re-placing
                            anyhow::bail!("node {} failed chunk {id}: {error}", node.addr);
                        }
                        Ok(MsgRecv::Msg(other, _)) => {
                            anyhow::bail!(
                                "protocol violation from node {}: unexpected {other:?}",
                                node.addr
                            );
                        }
                        Ok(MsgRecv::Idle) => continue,
                        Ok(MsgRecv::Eof) | Err(_) => {
                            node.mark_dead();
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }
}
